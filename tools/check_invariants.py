#!/usr/bin/env python
"""Repo invariant checker CLI — static pass + runtime sanitizer driver.

Static rules (see repro.analysis for the full contract):

  R1 host-sync        no hidden host<->device sync in the step-loop graph
  R2 recompile-risk   no shape-/capture-driven recompiles in jit scopes
  R3 lock-discipline  shared engine state mutated only under its lock
  R4 donation-safety  donated buffers never read after the donating call
  R5 pragma-hygiene   inv-ok pragmas are well-formed, justified, and live

Usage::

    PYTHONPATH=src python tools/check_invariants.py [paths ...]
    PYTHONPATH=src python tools/check_invariants.py --report json --out r.json
    PYTHONPATH=src python tools/check_invariants.py --selftest
    PYTHONPATH=src python tools/check_invariants.py --sanitize

* default paths: ``src`` (the whole tree must be clean in CI);
* ``--selftest`` runs the seeded per-rule fixtures
  (repro.analysis.fixtures) and exits non-zero unless every seeded
  violation fires and nothing unseeded does — the checker checking
  itself;
* ``--sanitize`` additionally runs the runtime lane
  (repro.analysis.sanitizer): transfer-guarded fused steps + the
  zero-steady-state-compile assertion.

Exit status: 0 clean, 1 findings (or selftest/sanitizer failure).
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis.report import format_report, run_static  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="static invariant checker (R1-R5) + runtime sanitizer")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to check (default: src)")
    ap.add_argument("--report", choices=["text", "json"], default="text")
    ap.add_argument("--out", help="also write the report to this file")
    ap.add_argument("--selftest", action="store_true",
                    help="run the seeded per-rule fixtures instead of "
                         "checking the tree")
    ap.add_argument("--sanitize", action="store_true",
                    help="also run the runtime sanitizer lane "
                         "(transfer guard + compile counting)")
    args = ap.parse_args(argv)

    rc = 0

    if args.selftest:
        from repro.analysis.fixtures import run_selftest
        ok, lines = run_selftest()
        print("\n".join(lines))
        return 0 if ok else 1

    unsuppressed, suppressed = run_static(args.paths or ["src"])
    report = format_report(unsuppressed, suppressed, fmt=args.report)
    print(report)
    if args.out:
        with open(args.out, "w") as f:
            f.write(report + "\n")
    if unsuppressed:
        rc = 1

    if args.sanitize:
        from repro.analysis.sanitizer import main as sanitize_main
        print("-- runtime sanitizer " + "-" * 40)
        rc = max(rc, sanitize_main([]))

    return rc


if __name__ == "__main__":
    sys.exit(main())
