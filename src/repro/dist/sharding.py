"""Path-based PartitionSpec rules over a ("data", "model") mesh.

Every init_* in the model zoo names its weights consistently (wq/wk/wv are
column-parallel, wo/w_down row-parallel, MoE expert stacks carry an expert
dim, …), so sharding is decided from the *leaf path*, not from callers
threading specs around. The rules are Megatron-style:

- column-parallel matrices shard their output dim over ``model`` and (under
  fsdp) their input dim over ``data``;
- row-parallel matrices shard their input dim over ``model`` and their
  output dim over ``data``;
- MoE expert stacks shard the expert dim over ``model`` (expert
  parallelism — the batched-einsum dispatch in models/moe.py is written for
  exactly this) and the matrix input dim over ``data``;
- embeddings/lm heads shard the vocab dim over ``model``;
- norms, biases without a model-parallel dim, and anything unrecognised
  stay replicated.

Any axis that does not evenly divide its dim is **dropped** (never an
error): the same rule table serves the 512-chip production mesh and a
1-device CPU host mesh, and reduced configs with prime dims simply fall
back to replication. ``cfg.sharding`` selects which axes are live:
``dp`` (replicated params), ``tp``, ``fsdp``, ``fsdp_tp``.
"""
from __future__ import annotations

import math
from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# leaf names whose LAST dim is the model-parallel (output) dim
_COL_PARALLEL = frozenset({
    "wq", "wk", "wv", "wr", "wg", "wa", "w_gate", "w_up",
    "wq_a", "wq_b", "wkv_a", "wkv_b", "in_proj", "lm_head", "router",
    "bq", "bk", "bv", "a",
})
# leaf names whose SECOND-TO-LAST dim is the model-parallel (input) dim
_ROW_PARALLEL = frozenset({"wo", "w_down", "out_proj", "wb", "b"})
# leaves holding a vocab-major embedding table: (V, d)
_EMBED = frozenset({"embed"})
# MoE expert stacks: (..., E, in, out) under an immediate "moe" parent
_EXPERT = frozenset({"w_gate", "w_up", "w_down"})


def path_str(path: Sequence[Any]) -> str:
    """Stable string form of a jax tree path: 'layers/attn/wq'.

    Dict keys, sequence indices, attr names, and flattened indices all
    render as their bare token, joined by '/'; checkpoint manifests key
    leaves by this string and round-trip it on load.
    """
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:  # pragma: no cover - future key types
            parts.append(str(k))
    return "/".join(parts)


def _mesh_sizes(mesh) -> dict:
    return dict(mesh.shape)


def _fit_axes(dim: int, axes: Tuple[str, ...], sizes: dict
              ) -> Optional[Any]:
    """Largest prefix of ``axes`` (present in the mesh) that divides ``dim``.

    Returns a spec entry: an axis name, a tuple of names, or None.
    """
    axes = tuple(a for a in axes if a in sizes)
    while axes:
        if dim % math.prod(sizes[a] for a in axes) == 0:
            break
        axes = axes[:-1]
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


def _entry(spec_axes, dim, sizes):
    """Normalise one per-dim rule entry through the divisibility check."""
    if spec_axes is None:
        return None
    if isinstance(spec_axes, str):
        spec_axes = (spec_axes,)
    return _fit_axes(dim, tuple(spec_axes), sizes)


def _data_axes(mesh) -> Tuple[str, ...]:
    """Every non-'model' mesh axis, in mesh order ('pod' before 'data')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def _param_rule(path, shape, cfg, mesh, *, use_tp: bool, use_fsdp: bool
                ) -> P:
    sizes = _mesh_sizes(mesh)
    names = [p.lower() for p in
             (path_str(path).split("/") if path else [])]
    leaf = names[-1] if names else ""
    parent = names[-2] if len(names) > 1 else ""
    nd = len(shape)
    spec = [None] * nd

    model = "model" if (use_tp and "model" in sizes) else None
    data = _data_axes(mesh) if use_fsdp else None

    if nd >= 1 and leaf in _EMBED:
        # (V, d): vocab over model (matches the tied-head logits einsum),
        # feature over data under fsdp
        spec[0] = model
        if nd >= 2:
            spec[1] = data
    elif nd >= 3 and leaf in _EXPERT and parent == "moe":
        # expert stack (..., E, in, out): experts over model, input over data
        spec[-3] = model
        spec[-2] = data
    elif nd >= 1 and leaf in _COL_PARALLEL:
        spec[-1] = model
        if nd >= 2:
            spec[-2] = data
    elif nd >= 2 and leaf in _ROW_PARALLEL:
        spec[-2] = model
        spec[-1] = data
    # everything else (norms, scalar gates, conv kernels, caches of
    # unknown provenance) stays replicated

    return P(*[_entry(s, d, sizes) for s, d in zip(spec, shape)])


def param_pspecs(params, cfg, mesh):
    """PartitionSpec tree (same structure as ``params``) for model weights.

    ``params`` may hold arrays or ShapeDtypeStructs — anything with a
    ``.shape``. ``cfg.sharding`` picks the parallelism style.
    """
    mode = getattr(cfg, "sharding", "fsdp_tp")
    use_tp = mode in ("tp", "fsdp_tp")
    use_fsdp = mode in ("fsdp", "fsdp_tp")
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [
        _param_rule(path, tuple(leaf.shape), cfg, mesh,
                    use_tp=use_tp, use_fsdp=use_fsdp)
        if mode != "dp" else P(*([None] * len(leaf.shape)))
        for path, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_pspecs(batch, mesh):
    """Shard the leading (batch) dim of every leaf over the data axes."""
    sizes = _mesh_sizes(mesh)
    dp = _data_axes(mesh)

    def rule(leaf):
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        entries = [_entry(dp, shape[0], sizes)] + [None] * (len(shape) - 1)
        return P(*entries)

    return jax.tree_util.tree_map(rule, batch)


def cache_pspecs(cache, cfg, mesh):
    """Decode-cache specs: batch over data; optional split-KV over model.

    Cache leaves are laid out (layers, batch, seq, heads, head_dim) (or
    (batch, ...) for unstacked states); scalars like ``len`` replicate.
    With ``cfg.cache_seq_shard`` the sequence dim additionally shards over
    ``model`` (split-KV decode).
    """
    sizes = _mesh_sizes(mesh)
    dp = _data_axes(mesh)
    seq_shard = getattr(cfg, "cache_seq_shard", False)

    def rule(leaf):
        shape = tuple(leaf.shape)
        nd = len(shape)
        if nd < 2:
            return P(*([None] * nd))
        b_dim = 1 if nd >= 3 else 0
        spec = [None] * nd
        spec[b_dim] = _entry(dp, shape[b_dim], sizes)
        if seq_shard and nd >= 3 and "model" in sizes:
            spec[b_dim + 1] = _entry("model", shape[b_dim + 1], sizes)
        return P(*spec)

    return jax.tree_util.tree_map(rule, cache)


def to_named(spec_tree, mesh):
    """Map every PartitionSpec leaf to a NamedSharding on ``mesh``."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
