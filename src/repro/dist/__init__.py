"""Distributed execution: sharding rules, ring attention, pipelining.

Modules
-------
sharding        path-based PartitionSpec rules over a ("data", "model") mesh
ctx             activation-sharding constraints derived from a ModelConfig
ring_attention  sequence-parallel exact attention over a device ring
pipeline        streamed microbatch pipeline over a stage axis
"""
from repro.dist import ctx, pipeline, ring_attention, sharding

__all__ = ["ctx", "pipeline", "ring_attention", "sharding"]
