"""Sequence-parallel ring attention (exact, flash-style online softmax).

The sequence dim of q/k/v is sharded over one mesh axis; each device keeps
its q block resident and streams k/v blocks around the ring with
``ppermute``, folding every block into a numerically-stable running
softmax (running max ``m``, normaliser ``l``, weighted accumulator
``acc``). After ``n`` hops every q position has attended to the full
sequence, so the result equals single-device attention (kernels/ref
.flash_ref) to float tolerance — with peak activation memory of one
(block x block) score tile instead of the full (S x S) matrix.

Causality is enforced per block from the *global* positions of the q and
k blocks; blocks that are entirely in the future contribute nothing (their
probability mass is masked to zero before accumulation, so a fully-masked
block cannot poison the running max).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

_NEG_INF = -1e30


def _ring_block(q, k, v, *, scale, causal, axis_name, axis_size):
    """Per-device body. q/k/v: (b, C, h, d) local blocks; C = S // n."""
    idx = jax.lax.axis_index(axis_name)
    b, C, h, d = q.shape
    dv = v.shape[-1]

    qf = q.astype(jnp.float32) * scale
    q_pos = idx * C + jnp.arange(C)                       # global q positions

    m = jnp.full((b, h, C), _NEG_INF, jnp.float32)        # running row max
    l = jnp.zeros((b, h, C), jnp.float32)                 # running normaliser
    acc = jnp.zeros((b, h, C, dv), jnp.float32)           # running output
    perm = [(i, (i + 1) % axis_size) for i in range(axis_size)]

    kv = (k, v)
    for hop in range(axis_size):
        k_blk, v_blk = kv
        src = (idx - hop) % axis_size                     # origin of this block
        s = jnp.einsum("bqhd,bkhd->bhqk", qf,
                       k_blk.astype(jnp.float32))         # (b, h, C, C)
        if causal:
            k_pos = src * C + jnp.arange(C)
            mask = k_pos[None, :] <= q_pos[:, None]       # (Cq, Ck)
            mask = jnp.broadcast_to(mask[None, None], s.shape)
        else:
            mask = jnp.ones(s.shape, bool)
        s = jnp.where(mask, s, _NEG_INF)

        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # masked positions must contribute exactly zero even when the whole
        # block is masked (m_new == _NEG_INF would make exp(s - m_new) == 1)
        p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, v_blk.astype(jnp.float32))
        m = m_new

        if hop != axis_size - 1:
            kv = jax.lax.ppermute(kv, axis_name, perm=perm)

    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.transpose(out, (0, 2, 1, 3)).astype(v.dtype)  # (b, C, h, dv)


def make_ring_attention(mesh, *, scale: float, causal: bool = True,
                        axis_name: Optional[str] = None):
    """Build ring attention over ``axis_name`` (default: first mesh axis).

    Returns ``fn(q, k, v)`` taking (b, S, h, d) arrays with S divisible by
    the ring size; the sequence dim is sharded over the ring and the output
    comes back with the same layout.
    """
    axis = axis_name or mesh.axis_names[0]
    n = dict(mesh.shape)[axis]
    seq_spec = P(None, axis, None, None)
    body = partial(_ring_block, scale=scale, causal=causal,
                   axis_name=axis, axis_size=n)
    return shard_map(body, mesh=mesh,
                     in_specs=(seq_spec, seq_spec, seq_spec),
                     out_specs=seq_spec, check_rep=False)
