"""Streamed microbatch pipeline parallelism over a stage mesh axis.

Stage ``i``'s weights (the leading dim of every param leaf) live on device
``i``. Microbatches stream through a GPipe-style schedule: at step ``t``
device 0 feeds microbatch ``t`` into stage 0 while device ``i`` runs the
activation it received last step, then every activation hops one stage
down the ring with ``ppermute``. After ``n_micro + n_stages - 1`` steps
the last stage has emitted every microbatch; the result equals applying
the stages sequentially, with per-device weight memory 1/n of the model
and the bubble amortised by the microbatch count.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def _pipe_body(params, x, *, stage_fn, axis_name, n_stages):
    """Per-device body. params: stage-local leaves with a leading dim of 1
    (the shard of the stacked stage dim); x: (n_micro, mb, ...) replicated."""
    idx = jax.lax.axis_index(axis_name)
    local = jax.tree_util.tree_map(lambda a: a[0], params)
    n_micro = x.shape[0]
    mb_shape = x.shape[1:]

    state = jnp.zeros(mb_shape, x.dtype)          # activation in flight
    outputs = jnp.zeros_like(x)                   # valid only on last stage
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    for t in range(n_micro + n_stages - 1):
        # stage 0 picks up a fresh microbatch; later stages use what the
        # previous stage sent them (zeros during fill/drain — computed on,
        # then discarded by the output mask below)
        feed = x[t] if t < n_micro else jnp.zeros(mb_shape, x.dtype)
        inp = jnp.where(idx == 0, feed, state)
        y = stage_fn(local, inp)
        out_idx = t - (n_stages - 1)              # microbatch leaving stage n-1
        if out_idx >= 0:
            outputs = jnp.where(idx == n_stages - 1,
                                outputs.at[out_idx].set(y), outputs)
        if t != n_micro + n_stages - 2:
            state = jax.lax.ppermute(y, axis_name, perm=perm)

    # broadcast the last stage's outputs to every device so the result is
    # replicated (everyone else contributes zeros)
    outputs = jnp.where(idx == n_stages - 1, outputs, 0.0)
    return jax.lax.psum(outputs, axis_name)


def make_pipeline(mesh, stage_fn: Callable, *, axis_name: str = "pod",
                  n_stages: Optional[int] = None):
    """Build a pipeline over ``axis_name``.

    ``stage_fn(stage_params, x_mb)`` applies ONE stage to one microbatch.
    The returned ``pipe(params, x)`` takes params whose leaves are stacked
    over a leading stage dim (== ring size) and ``x`` of shape
    (n_micro, microbatch, ...); it returns the fully-pipelined result with
    the same shape as ``x``.
    """
    n = n_stages or dict(mesh.shape)[axis_name]
    body = partial(_pipe_body, stage_fn=stage_fn, axis_name=axis_name,
                   n_stages=n)
    return shard_map(body, mesh=mesh,
                     in_specs=(P(axis_name), P()),
                     out_specs=P(), check_rep=False)
