"""Activation-sharding constraints derived from a ModelConfig.

These are *hints* placed with ``with_sharding_constraint`` inside model
code; they only fire when ``cfg.mesh_axes`` names the ambient mesh (the
launch layer sets it — on a bare CPU run it stays empty and every helper
returns None, so model code never needs to branch on distribution).
"""
from __future__ import annotations

from typing import Optional, Tuple

from jax.sharding import PartitionSpec as P


def _dp(axes: Tuple[str, ...]):
    dp = tuple(a for a in axes if a != "model")
    if not dp:
        return None
    return dp[0] if len(dp) == 1 else dp


def logits_spec(cfg) -> Optional[P]:
    """Spec for (batch, seq, vocab) logits: batch over the data axes, vocab
    over ``model`` (the lm head / tied embedding is vocab-sharded — see
    dist.sharding), sequence replicated.

    None when the config carries no mesh axes (single-host runs) so the
    cross-entropy in nn.py skips the constraint entirely.
    """
    axes = tuple(getattr(cfg, "mesh_axes", ()) or ())
    if not axes:
        return None
    tp = "model" if ("model" in axes
                     and getattr(cfg, "sharding", "fsdp_tp")
                     in ("tp", "fsdp_tp")) else None
    return P(_dp(axes), None, tp)


def activation_spec(cfg, ndim: int = 3) -> Optional[P]:
    """Spec for (batch, seq, d_model)-shaped activations: batch over the
    data axes, everything else replicated."""
    axes = tuple(getattr(cfg, "mesh_axes", ()) or ())
    if not axes or ndim < 1:
        return None
    return P(_dp(axes), *([None] * (ndim - 1)))
