import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
placeholder devices; extract memory/cost analyses and the collective
schedule for the roofline report.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-14b \
      --cell train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]

Artifacts land in benchmarks/artifacts/dryrun/<arch>__<cell>__<mesh>.json
(existing artifacts are skipped unless --force)."""

import argparse
import pathlib
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, cells_for, get_config
from repro.configs.base import SHAPE_CELLS, ShapeCell, TrainConfig
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models.api import get_model

try:
    import orjson

    def _dumps(o):
        return orjson.dumps(o, option=orjson.OPT_INDENT_2)
except ImportError:  # pragma: no cover
    import json

    def _dumps(o):
        return json.dumps(o, indent=2).encode()

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / \
    "artifacts" / "dryrun"

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(tok: str) -> int:
    m = _SHAPE_RE.match(tok)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-operand bytes of every collective op in the (post-SPMD)
    optimized HLO, per op kind."""
    out = {k: 0 for k in _COLL_OPS}
    counts = {k: 0 for k in _COLL_OPS}
    for line in hlo_text.splitlines():
        for op in _COLL_OPS:
            # match '= <shape(s)> <op>(' and async '<op>-start('
            if f" {op}(" in line or f" {op}-start(" in line:
                rhs = line.split("=", 1)
                if len(rhs) != 2:
                    continue
                # result may be a tuple: sum all shapes before the op name
                head = rhs[1].split(op)[0]
                nbytes = sum(_shape_bytes(t)
                             for t in re.findall(r"\w+\[[0-9,]*\]", head))
                out[op] += nbytes
                counts[op] += 1
                break
    out["total"] = sum(out[k] for k in _COLL_OPS)
    out["counts"] = counts
    return out


def build_cell(arch: str, cell: ShapeCell, mesh, *, static_rank=None,
               overrides=None):
    """Returns (fn, kwargs_specs) ready for jax.jit(...).lower()."""
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.with_(**overrides)
    if not cfg.mesh_axes:
        cfg = cfg.with_(mesh_axes=tuple(mesh.axis_names))
    if static_rank is not None:
        cfg = cfg.with_(rank=cfg.rank.__class__(
            mode="fixed", realisation="static", static_rank=static_rank,
            fixed_rank=static_rank))
    fns = get_model(cfg)
    specs = fns.input_specs(cell)

    def with_sharding(tree, spec_tree):
        return jax.tree_util.tree_map(
            lambda s, sp: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
            tree, spec_tree, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    params_shape = jax.eval_shape(fns.init, jax.random.PRNGKey(0))
    pspecs = shd.param_pspecs(params_shape, cfg, mesh)
    params_in = with_sharding(params_shape, pspecs)

    if cell.kind == "train":
        from repro.optim import adamw
        from repro.train.loop import make_train_step
        tc = TrainConfig(global_batch=cell.global_batch, seq_len=cell.seq_len)
        step = make_train_step(cfg, tc, lambda p, b, r: fns.loss(p, b))
        opt_shape = jax.eval_shape(adamw.init, params_shape)
        ospecs = adamw.AdamWState(step=P(), m=pspecs, v=pspecs)
        opt_in = with_sharding(opt_shape, ospecs)
        batch = with_sharding(specs["batch"], shd.batch_pspecs(specs["batch"], mesh))
        rng = jax.ShapeDtypeStruct((2,), jnp.uint32,
                                   sharding=NamedSharding(mesh, P()))
        out_specs = (shd.to_named(pspecs, mesh),
                     shd.to_named(ospecs, mesh), None)
        return step, (params_in, opt_in, batch, rng), out_specs

    if cell.kind == "prefill":
        def prefill_step(params, batch):
            logits, _ = fns.loss(params, batch)
            return logits
        batch = with_sharding(specs["batch"], shd.batch_pspecs(specs["batch"], mesh))
        return prefill_step, (params_in, batch), None

    # decode
    cache_spec = shd.cache_pspecs(specs["cache"], cfg, mesh)
    cache_in = with_sharding(specs["cache"], cache_spec)
    tokens = with_sharding(
        specs["tokens"], shd.batch_pspecs({"t": specs["tokens"]}, mesh)["t"])

    def serve_step(params, cache, tokens):
        return fns.decode_step(params, cache, tokens)

    out_specs = (None, shd.to_named(cache_spec, mesh))
    return serve_step, (params_in, cache_in, tokens), out_specs


def run_cell(arch: str, cell: ShapeCell, mesh_kind: str, *, force=False,
             static_rank=None, tag="", overrides=None) -> dict:
    name = f"{arch}__{cell.name}__{mesh_kind}{tag}"
    ART_DIR.mkdir(parents=True, exist_ok=True)
    path = ART_DIR / f"{name}.json"
    if path.exists() and not force:
        print(f"[skip] {name} (artifact exists)")
        import json
        return json.loads(path.read_text())
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.monotonic()
    rec = {"arch": arch, "cell": cell.name, "mesh": mesh_kind,
           "devices": int(np.prod(mesh.devices.shape))}
    try:
        fn, args, out_shardings = build_cell(arch, cell, mesh,
                                             static_rank=static_rank,
                                             overrides=overrides)
        with mesh:
            jitted = (jax.jit(fn, out_shardings=out_shardings)
                      if out_shardings is not None else jax.jit(fn))
            lowered = jitted.lower(*args)
            t_lower = time.monotonic() - t0
            compiled = lowered.compile()
            t_compile = time.monotonic() - t0 - t_lower
        ca = compiled.cost_analysis() or {}
        ma = compiled.memory_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        rec.update({
            "ok": True,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "collectives": coll,
            "memory": {
                "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
                "output_bytes": getattr(ma, "output_size_in_bytes", 0),
                "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
                "alias_bytes": getattr(ma, "alias_size_in_bytes", 0),
            },
        })
        print(f"[ok] {name}: flops={rec['flops']:.3e} "
              f"bytes={rec['bytes_accessed']:.3e} "
              f"coll={coll['total']:.3e} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    except Exception as e:
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
        print(f"[FAIL] {name}: {type(e).__name__}: {e}")
    path.write_bytes(_dumps(rec))
    return rec


# ---------------------------------------------------------------------------
# Calibrated roofline extraction.
#
# XLA's cost_analysis counts a lax.scan body ONCE (verified in-repo), so the
# full-config artifacts under-count per-layer costs. Layers are homogeneous,
# hence every per-step cost is exactly linear in the repeating-unit count k:
# we lower UNROLLED programs at two small depths, fit the line, and
# extrapolate to the full depth. Artifacts are tagged "__calib".
# ---------------------------------------------------------------------------

def _calib_unit(arch: str):
    """(unit values k1<k2, full k, overrides(k)) — k = repeating units."""
    cfg = get_config(arch)
    if arch == "deepseek-v3-671b":
        # unit = one MoE layer; dense bottom + MTP stay constant
        return (1, 3, cfg.num_layers - cfg.first_dense_layers,
                lambda k: {"num_layers": cfg.first_dense_layers + k,
                           "scan_layers": False})
    if arch == "zamba2-7b":
        per = cfg.hybrid_period + 1
        return (1, 2, cfg.num_layers // per,
                lambda k: {"num_layers": per * k, "scan_layers": False})
    if arch == "seamless-m4t-medium":
        return (1, 3, cfg.num_layers,
                lambda k: {"num_layers": k, "num_encoder_layers": k,
                           "scan_layers": False})
    return (1, 3, cfg.num_layers,
            lambda k: {"num_layers": k, "scan_layers": False})


def run_cell_calibrated(arch: str, cell: ShapeCell, mesh_kind: str,
                        *, force=False, static_rank=None, tag="") -> dict:
    name = f"{arch}__{cell.name}__{mesh_kind}__calib{tag}"
    ART_DIR.mkdir(parents=True, exist_ok=True)
    path = ART_DIR / f"{name}.json"
    if path.exists() and not force:
        print(f"[skip] {name}")
        import json
        return json.loads(path.read_text())
    k1, k2, k_full, ov = _calib_unit(arch)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec = {"arch": arch, "cell": cell.name, "mesh": mesh_kind,
           "devices": int(np.prod(mesh.devices.shape)),
           "calibrated": True, "k": [k1, k2, k_full]}
    try:
        pts = []
        for k in (k1, k2):
            fn, args_, outs = build_cell(arch, cell, mesh,
                                         static_rank=static_rank,
                                         overrides=ov(k))
            t0 = time.monotonic()
            with mesh:
                jitted = (jax.jit(fn, out_shardings=outs)
                          if outs is not None else jax.jit(fn))
                compiled = jitted.lower(*args_).compile()
            ca = compiled.cost_analysis() or {}
            coll = collective_bytes(compiled.as_text())
            pts.append({"k": k, "flops": float(ca.get("flops", 0.0)),
                        "bytes": float(ca.get("bytes accessed", 0.0)),
                        "coll": coll["total"],
                        "compile_s": round(time.monotonic() - t0, 1)})

        def extrap(key):
            slope = (pts[1][key] - pts[0][key]) / (k2 - k1)
            # slopes can be slightly negative on tiny decode programs where
            # XLA simplifies the deeper variant more — clamp to the larger
            # measured point (costs are monotone in depth)
            return max(pts[0][key] + slope * (k_full - k1),
                       pts[1][key], 0.0)

        rec.update({
            "ok": True,
            "points": pts,
            "flops": extrap("flops"),
            "bytes_accessed": extrap("bytes"),
            "collectives": {"total": extrap("coll")},
        })
        print(f"[ok] {name}: flops={rec['flops']:.3e} "
              f"bytes={rec['bytes_accessed']:.3e} "
              f"coll={rec['collectives']['total']:.3e}")
    except Exception as e:
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
        print(f"[FAIL] {name}: {type(e).__name__}: {e}")
    path.write_bytes(_dumps(rec))
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--static-rank", type=int, default=None,
                    help="lower the DR-RL serving bucket at this rank")
    ap.add_argument("--tag", default="", help="artifact suffix")
    ap.add_argument("--calibrate", action="store_true",
                    help="unrolled two-depth lowering + linear extrapolation")
    args = ap.parse_args(argv)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = list(ARCH_IDS) if (args.all or args.arch is None) else [args.arch]
    runner = run_cell_calibrated if args.calibrate else run_cell
    n_fail = 0
    for arch in archs:
        cells = cells_for(arch)
        if args.cell:
            cells = [c for c in SHAPE_CELLS if c.name == args.cell]
        for cell in cells:
            for mk in meshes:
                rec = runner(arch, cell, mk, force=args.force,
                             static_rank=args.static_rank, tag=args.tag)
                n_fail += 0 if rec.get("ok") else 1
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
