"""Adaptive serving front-end.

The decode stack lives in ``repro.serve`` (continuous-batching engine with
a slot-paged KV cache and per-slot dynamic ranks); ``AdaptiveServer`` is a
thin compatibility wrapper that keeps the historical lock-step API: a
(b, s0) prompt batch becomes b concurrent engine streams admitted at step
0, decoded greedily for ``n_tokens`` each.

Throughput accounting: ``generate`` warms the engine's executables first
and reports their first-use compilation separately (``compile_s``), so
``tok_per_s`` measures warm decode steps only (prefill time is also
excluded, as before).
"""
from __future__ import annotations

import argparse
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.models.api import get_model
from repro.serve import Request, ServeEngine


class AdaptiveServer:
    """Batched decode server with per-segment, per-stream rank re-decision.

    Compatibility wrapper over :class:`repro.serve.ServeEngine`; compiled
    executables are cached across ``generate`` calls with matching shapes.
    """

    def __init__(self, cfg: ModelConfig, params, policy_params=None,
                 max_len: int = 2048, page_size: int = 16,
                 use_kernel: bool = False, time_per_token: bool = False,
                 factor_cache: Optional[bool] = None):
        self.cfg = cfg
        self.params = params
        self.policy = policy_params
        self.max_len = max_len
        self.page_size = page_size
        self.use_kernel = use_kernel
        self.time_per_token = time_per_token
        self.factor_cache = factor_cache
        self._engines: Dict[tuple, ServeEngine] = {}

    def _engine(self, n_slots: int, seg: int, max_new: int) -> ServeEngine:
        key = (n_slots, seg, max_new)
        eng = self._engines.get(key)
        if eng is None:
            eng = ServeEngine(self.cfg, self.params, self.policy,
                              n_slots=n_slots, max_len=self.max_len,
                              page_size=self.page_size, segment_len=seg,
                              max_new_cap=max_new,
                              use_kernel=self.use_kernel,
                              time_per_token=self.time_per_token,
                              factor_cache=self.factor_cache)
            self._engines[key] = eng
        else:
            eng.reset()
        return eng

    def generate(self, prompts: jnp.ndarray, n_tokens: int,
                 segment_len: Optional[int] = None) -> Dict:
        """prompts: (b, s0) int32. Greedy decode of n_tokens per stream.

        Returns tokens (b, n_tokens), the per-step per-stream rank record,
        warm-decode ``tok_per_s`` and the separated ``compile_s`` /
        ``prefill_s`` costs."""
        seg = segment_len or self.cfg.rank.segment_len
        prompts_np = np.asarray(prompts, np.int32)
        b = prompts_np.shape[0]
        eng = self._engine(b, seg, n_tokens)
        for i in range(b):
            eng.submit(Request(rid=i, tokens=prompts_np[i],
                               max_new=n_tokens))
        eng.warmup()
        outs = eng.run()
        tokens = np.stack([outs[i] for i in range(b)])
        s = eng.stats
        return {
            "tokens": jnp.asarray(tokens),
            "ranks": [r.tolist() for r in eng.ranks_per_step()],
            "tok_per_s": s["tokens_decoded"] / max(s["decode_s"], 1e-9),
            "compile_s": s["compile_s"],
            "prefill_s": s["prefill_s"],
            "token_lat_s": list(eng.token_latencies),   # [] unless timed
            "stats": dict(s),
        }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="drrl-paper")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=64)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    fns = get_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    policy = None
    if cfg.rank.mode == "drrl":
        from repro.core.drrl import init_agent
        policy = init_agent(jax.random.PRNGKey(7), cfg.rank, cfg.d_model)
    server = AdaptiveServer(cfg, params, policy,
                            max_len=args.prompt_len + args.tokens + 8)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    res = server.generate(prompts, args.tokens, segment_len=16)
    print(f"decoded {res['tokens'].shape} at {res['tok_per_s']:.1f} tok/s "
          f"(compile {res['compile_s']:.2f}s, prefill {res['prefill_s']:.2f}s); "
          f"per-slot rank schedule: {res['ranks'][:8]}...")


if __name__ == "__main__":
    main()
