"""Adaptive serving front-end (thin shim over ``repro.serve.api``).

The serving surface lives in ``repro.serve.api``: ``EngineConfig`` +
``SamplingParams`` + ``Engine.submit(prompt, params) -> RequestHandle``
with chunked prefill interleaved into the fused decode step. The
historical :class:`AdaptiveServer` lock-step wrapper is re-exported from
there (deprecated) so old imports keep working.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.models.api import get_model
from repro.serve.api import (AdaptiveServer, Engine, EngineConfig,
                             SamplingParams)

__all__ = ["AdaptiveServer", "main"]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="drrl-paper")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--chunk", type=int, default=16,
                    help="prefill chunk size (0 = legacy one-shot prefill)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    fns = get_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    policy = None
    if cfg.rank.mode == "drrl":
        from repro.core.drrl import init_agent
        policy = init_agent(jax.random.PRNGKey(7), cfg.rank, cfg.d_model)
    eng = Engine(cfg, params, policy, config=EngineConfig(
        n_slots=args.batch, max_len=args.prompt_len + args.tokens + 8,
        segment_len=16, max_new_cap=args.tokens,
        prefill_chunk=args.chunk or None,
        sampling=False))      # greedy-only CLI: keep the lean step
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    import numpy as np
    handles = [eng.submit(np.asarray(prompts[i]),
                          SamplingParams(max_new=args.tokens))
               for i in range(args.batch)]
    eng.warmup()
    eng.run()
    s = eng.stats
    tps = s["tokens_decoded"] / max(s["decode_s"], 1e-9)
    ranks = eng.core.ranks_per_step()
    print(f"decoded ({args.batch}, {args.tokens}) at {tps:.1f} tok/s "
          f"(compile {s['compile_s']:.2f}s, prefill {s['prefill_s']:.2f}s, "
          f"mixed steps {s['mixed_steps']}); "
          f"per-slot rank schedule: {[r.tolist() for r in ranks[:8]]}...")
    print(f"TTFT per request: "
          f"{['%.3fs' % h.ttft_s for h in handles if h.ttft_s is not None]}")


if __name__ == "__main__":
    main()
