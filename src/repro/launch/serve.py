"""Adaptive serving loop with DR-RL bucketed rank dispatch.

The paper's segment-level adaptation (section 4.5.2) on TPU: a small grid of
rank buckets is compiled ahead of time (static shapes); every ``segment_len``
decoded tokens the policy re-evaluates the spectral features of the live KV
cache and picks the bucket for the next segment. The perturbation guardrail
(Eq. 9-11) masks unsafe bucket switches. Incremental subspace extension
(Eq. 12) refreshes the eigenbasis when the rank is raised.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.core import lowrank as lr
from repro.core import perturbation as pert
from repro.models.api import get_model


class AdaptiveServer:
    """Batched decode server with per-segment rank re-decision."""

    def __init__(self, cfg: ModelConfig, params, policy_params=None,
                 max_len: int = 2048):
        self.cfg = cfg
        self.fns = get_model(cfg)
        self.params = params
        self.policy = policy_params
        self.max_len = max_len
        self.rank_grid = cfg.rank.rank_grid
        # one compiled executable per rank bucket (static realisation) + full
        self._exec: Dict[Optional[int], callable] = {}
        self.current_rank: Optional[int] = None
        self.t = 0                      # RL global step for the annealed eps

    def _step_fn(self, rank: Optional[int]):
        if rank in self._exec:
            return self._exec[rank]
        cfg = self.cfg
        if rank is not None:
            cfg = cfg.with_(rank=cfg.rank.__class__(
                mode="fixed", realisation="static", static_rank=rank,
                fixed_rank=rank, rank_grid=cfg.rank.rank_grid))
        else:
            cfg = cfg.with_(rank=cfg.rank.__class__(mode="off"))
        fns = get_model(cfg)
        fn = jax.jit(lambda p, c, t: fns.decode_step(p, c, t))
        self._exec[rank] = fn
        return fn

    def _decide_rank(self, cache) -> Optional[int]:
        """Segment-level decision from the live cache spectra (cheap: Gram
        eigenvalues of the newest layer-0 K cache)."""
        rcfg = self.cfg.rank
        if rcfg.mode == "off":
            return None
        k = cache["k"][0]                       # (b, M, hkv, d)
        kv_len = int(cache["len"])
        if kv_len < 8:
            return int(self.rank_grid[-1])
        kk = k[:, :kv_len].swapaxes(1, 2)       # (b, hkv, n, d)
        s2, _ = lr.gram_spectrum(lr.gram(kk))
        if rcfg.mode == "fixed":
            return int(rcfg.fixed_rank)
        grid_arr = np.asarray(self.rank_grid)
        if rcfg.mode == "adaptive":
            r = lr.rank_for_energy(s2, rcfg.energy_threshold,
                                   self.rank_grid[0], self.rank_grid[-1])
            med = float(np.median(np.asarray(r)))
            # snap to the nearest bucket in the compiled grid
            chosen = int(grid_arr[np.argmin(np.abs(grid_arr - med))])
        elif rcfg.mode == "drrl" and self.policy is not None:
            from repro.core.drrl import build_features
            from repro.core.policy import policy_apply
            b, h = s2.shape[:2]
            h_t = jnp.zeros((b, 8), jnp.float32)
            w_t = jnp.zeros((9,), jnp.float32)
            prev = jnp.full((b, h), self.current_rank or self.rank_grid[-1],
                            jnp.int32)
            ctx = {"k_s2": s2, "q_s2": s2}
            feats, (_, _, bounds_rel, _) = build_features(
                rcfg, ctx, h_t, w_t, 0, prev)
            logits, _ = policy_apply(self.policy, feats)
            eps_t = pert.annealed_threshold(rcfg.epsilon0, rcfg.anneal_lambda,
                                            self.t)
            ok = pert.safety_mask(bounds_rel.reshape(logits.shape), eps_t)
            logits = jnp.where(ok, logits, -1e30)
            chosen = int(self.rank_grid[int(jnp.argmax(jnp.mean(logits, 0)))])
        else:
            chosen = int(np.random.default_rng(self.t).choice(self.rank_grid))
        # guardrail on the *transition* (Eq. 9): veto switches whose bound
        # exceeds the annealed threshold
        if self.current_rank is not None and chosen != self.current_rank:
            grid = list(self.rank_grid)
            bounds, norm = pert.guardrail_report(s2, s2, tuple(grid),
                                                 k.shape[-1])
            rel = bounds / jnp.maximum(norm[..., None], 1e-30)
            eps_t = float(pert.annealed_threshold(
                rcfg.epsilon0, rcfg.anneal_lambda, self.t))
            if float(jnp.mean(rel[..., grid.index(chosen)])) > eps_t:
                chosen = self.current_rank
        return chosen

    def generate(self, prompts: jnp.ndarray, n_tokens: int,
                 segment_len: Optional[int] = None) -> Dict:
        """prompts: (b, s0) int32. Greedy decode n_tokens."""
        seg = segment_len or self.cfg.rank.segment_len
        b = prompts.shape[0]
        cache = self.fns.init_cache(b, self.max_len)
        full = self._step_fn(None)
        logits, cache = full(self.params, cache, prompts)   # prefill
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out = [tok]
        ranks_used = []
        t0 = time.monotonic()
        for i in range(n_tokens - 1):
            if i % seg == 0:
                self.current_rank = self._decide_rank(cache)
                self.t += 1
            ranks_used.append(self.current_rank or -1)
            step = self._step_fn(self.current_rank)
            logits, cache = step(self.params, cache, tok)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out.append(tok)
        dt = time.monotonic() - t0
        return {"tokens": jnp.concatenate(out, axis=1),
                "ranks": ranks_used,
                "tok_per_s": b * (n_tokens - 1) / max(dt, 1e-9)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="drrl-paper")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=64)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    fns = get_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    policy = None
    if cfg.rank.mode == "drrl":
        from repro.core.drrl import init_agent
        policy = init_agent(jax.random.PRNGKey(7), cfg.rank, cfg.d_model)
    server = AdaptiveServer(cfg, params, policy, max_len=args.prompt_len + args.tokens + 8)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    res = server.generate(prompts, args.tokens, segment_len=16)
    print(f"decoded {res['tokens'].shape} at {res['tok_per_s']:.1f} tok/s; "
          f"rank schedule: {res['ranks'][:16]}...")


if __name__ == "__main__":
    main()
