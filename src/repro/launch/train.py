"""Training launcher.

CPU example:    PYTHONPATH=src python -m repro.launch.train --arch drrl-paper \
                    --reduced --steps 50
Production dry: the mesh/sharding path used here is exactly what
                repro.launch.dryrun lowers for the 256/512-chip meshes.
"""
from __future__ import annotations

import argparse

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.data.synthetic import SyntheticLM
from repro.dist import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models.api import get_model
from repro.train.loop import run_training


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="drrl-paper")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", default="none")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    fns = get_model(cfg)
    tc = TrainConfig(global_batch=args.batch, seq_len=args.seq, lr=args.lr,
                     total_steps=args.steps, warmup_steps=max(args.steps // 10, 1),
                     microbatches=args.microbatches,
                     grad_compression=args.grad_compression,
                     checkpoint_every=max(args.steps // 2, 1),
                     checkpoint_dir=args.ckpt_dir or f"/tmp/repro_{args.arch}")
    data = SyntheticLM(cfg.vocab_size, tc.seq_len, tc.global_batch, tc.seed)
    mesh = make_host_mesh()
    ckpt = CheckpointManager(tc.checkpoint_dir) if args.ckpt_dir else None

    kw = {}
    if cfg.rank.mode == "drrl":
        from repro.core.drrl import init_agent
        agent = init_agent(jax.random.PRNGKey(7), cfg.rank, cfg.d_model)
        kw = {"policy_params": agent}

    def loss_fn(p, b, rng):
        extra = {"rank_rng": rng, **kw} if cfg.rank.mode == "drrl" else {}
        return fns.loss(p, b, **extra)

    with mesh:
        params_shape = jax.eval_shape(fns.init, jax.random.PRNGKey(tc.seed))
        pspecs = shd.param_pspecs(params_shape, cfg, mesh)
        out = run_training(cfg, tc, init_fn=fns.init, loss_fn=loss_fn,
                           data=data, ckpt_manager=ckpt, param_specs=pspecs)
    print(f"final loss: {out['history'][-1]['loss']:.4f}")
    return out


if __name__ == "__main__":
    main()
