"""Production mesh definitions (TPU v5e pods; 256 chips/pod).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Whatever devices exist locally, as a ('data','model') mesh with
    model=1 — used by examples/tests on CPU."""
    n = len(jax.devices())
    return jax.make_mesh(
        (n, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


# Hardware constants (TPU v5e) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
CHIPS_PER_POD = 256
