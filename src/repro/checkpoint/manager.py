"""Fault-tolerant checkpointing: atomic directory commits, optional async
save thread, latest-resume, and **elastic re-shard on load** (the manifest
stores logical PartitionSpecs; load() places leaves onto whatever mesh is
live, so a job restarted on a different device count resumes bit-exact).

Format: one .npy per leaf + an orjson manifest {path -> {file, spec, dtype}}.
"""
from __future__ import annotations

import pathlib
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

try:
    import orjson as _json

    def _dumps(o):
        return _json.dumps(o)

    def _loads(b):
        return _json.loads(b)
except ImportError:  # pragma: no cover
    import json as _json

    def _dumps(o):
        return _json.dumps(o).encode()

    def _loads(b):
        return _json.loads(b)

from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import path_str


def _spec_to_json(spec) -> list:
    if spec is None:
        return []
    out = []
    for s in spec:
        if s is None:
            out.append(None)
        elif isinstance(s, (tuple, list)):
            out.append(list(s))
        else:
            out.append(s)
    return out


def _spec_from_json(raw) -> P:
    return P(*[tuple(s) if isinstance(s, list) else s for s in raw])


class CheckpointManager:
    def __init__(self, directory: str, async_save: bool = True,
                 keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.async_save = async_save
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree: Any, specs: Any = None,
             extra: Optional[dict] = None) -> None:
        """Blocks only to fetch device arrays; file IO may run async."""
        self.wait()
        flat, _ = jax.tree_util.tree_flatten_with_path(tree)
        spec_flat = (jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
            if specs is not None else [])
        if len(spec_flat) > len(flat):
            raise ValueError(
                f"specs has {len(spec_flat)} leaves but tree has only "
                f"{len(flat)}")
        # specs may cover only a leading subtree (e.g. param specs for a
        # (params, opt_state) tree): the remaining leaves store no spec and
        # load replicated — zip truncation here used to silently drop them
        # from the checkpoint entirely
        spec_flat += [None] * (len(flat) - len(spec_flat))
        host = [(path_str(p), np.asarray(x)) for p, x in flat]

        def _write():
            tmp = self.dir / f".tmp_step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {"step": step, "leaves": {}, "extra": extra or {}}
            for i, ((name, arr), spec) in enumerate(zip(host, spec_flat)):
                fname = f"leaf_{i}.npy"
                np.save(tmp / fname, arr)
                manifest["leaves"][name] = {
                    "file": fname,
                    "spec": _spec_to_json(spec),
                    "dtype": str(arr.dtype),
                }
            (tmp / "manifest.json").write_bytes(_dumps(manifest))
            final = self.dir / f"step_{step}"
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)           # atomic commit
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ----------------------------------------------------------------- load
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def load(self, template: Any, step: Optional[int] = None,
             mesh=None) -> tuple:
        """Restore into the structure of ``template``. With ``mesh`` given,
        every leaf is device_put with its stored logical spec resolved
        against the *current* mesh (elastic re-shard)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step}"
        manifest = _loads((d / "manifest.json").read_bytes())
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        for path, tmpl in flat:
            name = path_str(path)
            ent = manifest["leaves"][name]
            arr = np.load(d / ent["file"])
            if mesh is not None and ent["spec"]:
                spec = _spec_from_json(ent["spec"])
                # drop axes absent from the current mesh (elastic restore)
                fixed = []
                for s in spec:
                    axes = s if isinstance(s, tuple) else (s,) if s else ()
                    keep = tuple(a for a in axes if a in mesh.axis_names)
                    fixed.append(keep if len(keep) > 1 else
                                 (keep[0] if keep else None))
                arr = jax.device_put(arr, NamedSharding(mesh, P(*fixed)))
            leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return tree, manifest["step"], manifest.get("extra", {})
