"""repro: paper reproduction framework (models, kernels, dist, launch)."""
from repro.compat import ensure_jax_compat

ensure_jax_compat()
