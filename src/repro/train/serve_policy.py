"""Offline trainer for the serving rank policy (``mode="learned"``).

Closes the loop on the paper's RL agent over *recorded serving traces*
(ROADMAP item 4): the engine records per-segment rank decisions
(repro.serve.traces), this module rebuilds the Eq. 6 policy features from
those records **bit-compatibly with serving-time inference** and trains
the Transformer policy net offline:

  stage 1a — BC warm start to the recorded (adaptive-heuristic) actions,
  stage 1b — BC to the greedy *oracle*: per record, the rank-grid argmax
             of the counterfactual Eq. 13 reward under the Eq. 11 safety
             mask, constrained to kept ranks <= the recorded choice (the
             trace stores full spectra, so the reward of every non-taken
             action is computable exactly; the constraint makes the
             oracle dominate the heuristic — never worse reward, never
             more factor-read bytes),
  stage 2  — PPO fine-tuning (core/ppo.py) over per-request trajectories
             ordered by segment clock, rewards from core/rewards.py.

Feature compatibility is the load-bearing constraint: serving's
``decide()`` drrl/learned branch calls ``core.drrl.build_features`` with
``h_t = 0``, ``w_t = 0``, ``layer_id = 0`` and the spectra-only ctx
``{"k_s2": s2, "q_s2": prev_s2}``; the trainer calls the *same function
with the same conventions*, so a checkpoint trained here drops into
``ServeEngine(cfg, params, load_policy(dir))`` without translation and
serving stays device-resident (no per-token host syncs, no steady-state
recompiles — the learned path reuses the jitted decide executable).

Counterfactual quantities per record (spectra are the sufficient
statistic for all three reward terms at serving time):

* fidelity(g)     — head-mean retained spectral energy at ``grid[g]``
                    (``lr.ner_curve``), the serving-time agreement proxy;
* delta_a_rel(g)  — head-mean relative Eq. 9 bound from
                    ``pert.guardrail_report(prev_s2, s2)``;
* reward(g)       — ``core.rewards.reward`` = alpha*fid - beta*flops - gamma*dA.

Checkpoints go through ``checkpoint.manager.CheckpointManager`` plus a
``policy_meta.json`` sidecar recording the architecture, so
:func:`load_policy` can rebuild the template tree without the caller
knowing the arch hyper-parameters.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import RankConfig, TrainConfig
from repro.core import lowrank as lr
from repro.core import perturbation as pert
from repro.core import ppo as ppo_mod
from repro.core.drrl import build_features, feat_dims, rank_grid_index
from repro.core.policy import init_policy, policy_apply
from repro.core.rewards import flops_fraction, reward as eq13_reward
from repro.optim import adamw
from repro.optim.schedules import make_lr_fn
from repro.serve.traces import TraceReader

__all__ = ["POLICY_ARCH", "build_dataset", "evaluate_policy",
           "greedy_actions", "load_policy", "train_serve_policy"]

# architecture of the serving policy net; recorded in policy_meta.json so
# load_policy can rebuild the checkpoint template
POLICY_ARCH = {"d_pol": 64, "n_layers": 2, "n_heads": 4, "d_ff": 128}
_H_DIM = 8      # h_t width — serving feeds zeros of this width


def build_dataset(trace, rank_cfg: RankConfig) -> Dict:
    """Rebuild policy features + counterfactual rewards from a trace.

    ``trace`` is a TraceReader or a trace directory. Returns a dict with
    per-head-row features (the (N*h, dim) layout bc_loss consumes),
    per-record action indices / safety masks / the (N, G) reward matrix,
    and the request/segment bookkeeping PPO trajectories are cut from."""
    reader = trace if isinstance(trace, TraceReader) else TraceReader(trace)
    rec = reader.records
    if not rec or rec["slot"].size == 0:
        raise ValueError(f"trace at {getattr(reader, 'dir', trace)} is empty")
    s2 = jnp.asarray(rec["s2"], jnp.float32)            # (N, h, d)
    prev_s2 = jnp.asarray(rec["prev_s2"], jnp.float32)
    N, h, d = s2.shape
    grid = jnp.asarray(rank_cfg.rank_grid, jnp.int32)
    G = int(grid.shape[0])

    # features exactly as decide()'s drrl/learned branch builds them
    prev = jnp.broadcast_to(
        jnp.asarray(rec["prev_rank"], jnp.int32)[:, None], (N, h))
    feats, (_, _, bounds_rel, _) = build_features(
        rank_cfg, {"k_s2": s2, "q_s2": prev_s2},
        jnp.zeros((N, _H_DIM), jnp.float32), jnp.zeros((9,), jnp.float32),
        0, prev)

    # Eq. 11 mask at each record's own segment clock (decide() anneals
    # per slot); head-row mask mirrors the -1e30 fill before the head-mean
    eps_t = pert.annealed_threshold(
        rank_cfg.epsilon0, rank_cfg.anneal_lambda,
        jnp.asarray(rec["seg_t"], jnp.float32))
    mask_rows = pert.safety_mask(
        bounds_rel.reshape(N * h, G), jnp.repeat(eps_t, h)[:, None])
    # decide() head-means the masked logits, so one vetoing head row
    # kills the action for the whole slot
    mask_rec = mask_rows.reshape(N, h, G).all(axis=1)

    # counterfactual Eq. 13 reward of EVERY grid action at this state
    fid_g = jnp.take(lr.ner_curve(s2), jnp.clip(grid - 1, 0, d - 1),
                     axis=-1).mean(axis=1)              # (N, G)
    rel_g = bounds_rel.mean(axis=1)                     # (N, G)
    rew = eq13_reward(rank_cfg, fid_g, grid[None, :], rel_g, d, d)

    actions = rank_grid_index(
        rank_cfg, jnp.asarray(rec["chosen_rank"], jnp.int32))
    # constrained oracle: best masked reward at a kept rank <= the
    # recorded (adaptive) choice, the recorded action always feasible.
    # Per record this makes oracle reward >= adaptive reward AND oracle
    # rank <= adaptive rank by construction — the dominance point the
    # learned-policy bench gate checks. (The *unconstrained* argmax would
    # happily buy reward with extra rank, i.e. extra factor-read bytes.)
    feas = mask_rec & (grid[None, :] <= grid[actions][:, None])
    feas = feas.at[jnp.arange(N), actions].set(True)
    oracle = jnp.argmax(jnp.where(feas, rew, -jnp.inf), axis=-1)
    return {
        "feats": feats, "mask_rows": mask_rows, "mask_rec": mask_rec,
        "actions": actions, "oracle": oracle, "reward_matrix": rew,
        "fid": fid_g, "grid": grid, "n": N, "h": h, "d": d,
        "rid": np.asarray(rec["rid"]), "seg_t": np.asarray(rec["seg_t"]),
    }


def greedy_actions(policy_params: dict, ds: Dict) -> jnp.ndarray:
    """Per-record grid index the serving decide() path would pick: mask
    each head row, head-mean the logits, argmax."""
    logits, _ = policy_apply(policy_params, ds["feats"])
    logits = jnp.where(ds["mask_rows"], logits, -1e30)
    return jnp.argmax(logits.reshape(ds["n"], ds["h"], -1).mean(axis=1),
                      axis=-1)


def evaluate_policy(ds: Dict, rank_cfg: RankConfig,
                    policy_params: Optional[dict] = None,
                    actions: Optional[jnp.ndarray] = None) -> Dict[str, float]:
    """Offline replay evaluation on the dataset's own reward matrix.

    Pass ``actions`` to score a fixed action stream (e.g. the recorded
    adaptive heuristic), or ``policy_params`` to score a policy through
    the greedy serving mirror. Returns Eq. 13 reward plus the kept-rank
    and read-cost summaries the bench gate compares."""
    if actions is None:
        if policy_params is None:
            raise ValueError("need policy_params or actions")
        actions = greedy_actions(policy_params, ds)
    actions = jnp.asarray(actions, jnp.int32)
    idx = jnp.arange(ds["n"])
    ranks = ds["grid"][actions].astype(jnp.float32)
    return {
        "reward": float(ds["reward_matrix"][idx, actions].mean()),
        "mean_rank": float(ranks.mean()),
        "agreement": float(ds["fid"][idx, actions].mean()),
        "read_frac": float(flops_fraction(ranks, ds["d"], ds["d"]).mean()),
    }


def _windows(ds: Dict, t_win: int) -> np.ndarray:
    """(W, T) record-index windows: each request's records ordered by
    segment clock, chunked into length-T trajectories. Falls back to
    T = 1 when every request is shorter than ``t_win``."""
    rid, seg = ds["rid"], ds["seg_t"]
    order = np.lexsort((seg, rid))
    wins = []
    for r in np.unique(rid):
        seq = order[rid[order] == r]
        for s in range(0, len(seq) - t_win + 1, t_win):
            wins.append(seq[s:s + t_win])
    if not wins:
        return np.arange(ds["n"], dtype=np.int64)[:, None]
    return np.stack(wins)


def _make_traj(agent: dict, ds: Dict, wins: np.ndarray) -> ppo_mod.Trajectory:
    """Offline PPO batch: trace actions re-scored under the current
    (BC-warm-started) policy for logp_old/values_old — the standard
    offline approximation; the clip term then bounds the update away
    from the behaviour data."""
    W, T = wins.shape[0], wins.shape[1]
    h, G = ds["h"], int(ds["grid"].shape[0])
    rec_sel = wins.T                                        # (T, W)
    # head-row indices, record-major so each record's h rows stay adjacent
    rows = (rec_sel[..., None] * h + np.arange(h)).reshape(T, W * h)
    rows_j = jnp.asarray(rows.reshape(-1))
    feats = {k: v[rows_j].reshape(T, W * h, -1)
             for k, v in ds["feats"].items()}
    mask = ds["mask_rows"][rows_j].reshape(T, W * h, G)
    acts = jnp.repeat(ds["actions"][jnp.asarray(rec_sel)][..., None],
                      h, axis=-1).reshape(T, W * h)
    rew = jnp.repeat(
        ds["reward_matrix"][jnp.asarray(rec_sel),
                            ds["actions"][jnp.asarray(rec_sel)]][..., None],
        h, axis=-1).reshape(T, W * h)
    flat = {k: v.reshape(T * W * h, -1) for k, v in feats.items()}
    logits, values = policy_apply(agent, flat)
    logits = jnp.where(mask.reshape(T * W * h, G), logits, -1e30)
    logp = jax.nn.log_softmax(logits, axis=-1)
    logp_old = jnp.take_along_axis(
        logp, acts.reshape(-1, 1), axis=-1)[:, 0].reshape(T, W * h)
    return ppo_mod.Trajectory(
        feats=feats, actions=acts, logp_old=logp_old,
        values_old=values.reshape(T, W * h), rewards=rew, action_mask=mask)


def train_serve_policy(trace, rank_cfg: RankConfig, *,
                       out_dir=None, bc_steps: int = 60,
                       ppo_steps: int = 8, ppo_epochs: int = 2,
                       lr: float = 3e-3, seed: int = 0, t_win: int = 4,
                       eval_every: int = 10) -> Tuple[dict, Dict]:
    """Full offline pipeline over a recorded trace. Returns
    ``(policy_params, history)`` and — when ``out_dir`` is given — writes
    a CheckpointManager checkpoint + policy_meta.json for
    :func:`load_policy`.

    Model selection: snapshots taken every ``eval_every`` BC steps and
    after every PPO step are replayed through :func:`evaluate_policy`,
    and the winner is the highest-reward snapshot whose mean kept rank
    does not exceed the recorded adaptive heuristic's (falling back to
    the best reward outright only if no snapshot qualifies). Rationale:
    the serving gate (check_bench learned_policy) requires match-or-beat
    reward at equal-or-lower rank — an unconstrained reward argmax will
    happily buy reward with extra factor-read bytes, and on tiny traces
    PPO can destabilise the BC solution, so "last checkpoint" is the
    wrong pick on both axes."""
    ds = build_dataset(trace, rank_cfg)
    G = int(ds["grid"].shape[0])
    agent = init_policy(jax.random.PRNGKey(seed), feat_dims(rank_cfg),
                        G, **POLICY_ARCH)
    tc = TrainConfig(lr=lr, total_steps=bc_steps + max(ppo_steps, 1) * ppo_epochs,
                     warmup_steps=5, weight_decay=0.0, grad_clip=1.0)
    lr_fn = make_lr_fn(tc)
    opt = adamw.init(agent)
    history: Dict = {"bc_loss": [], "ppo": [], "eval": {}}

    # constrained snapshot selection (see docstring): best reward at a
    # mean kept rank no higher than the recorded heuristic's
    adaptive_ev = evaluate_policy(ds, rank_cfg, actions=ds["actions"])
    best_le: Optional[Tuple[dict, Dict, str]] = None
    best_any: Optional[Tuple[dict, Dict, str]] = None

    def consider(label: str, a: dict) -> None:
        nonlocal best_le, best_any
        ev = evaluate_policy(ds, rank_cfg, policy_params=a)
        if best_any is None or ev["reward"] > best_any[1]["reward"]:
            best_any = (a, ev, label)
        if (ev["mean_rank"] <= adaptive_ev["mean_rank"] + 1e-6
                and (best_le is None
                     or ev["reward"] > best_le[1]["reward"])):
            best_le = (a, ev, label)

    # stage 1: BC — warm start on the recorded actions, then clone the
    # constrained reward oracle (that's what makes learned >= adaptive).
    # The safety mask can veto a *target* action on individual head rows
    # (decide() head-means across rows, so a per-row veto is legal at
    # record level); the training mask re-admits each row's own target so
    # the -1e30 fill never reaches the cross-entropy.
    h = ds["h"]
    ys_rec = jnp.repeat(ds["actions"][:, None], h, -1).reshape(-1)
    ys_orc = jnp.repeat(ds["oracle"][:, None], h, -1).reshape(-1)
    rows = jnp.arange(ys_rec.shape[0])
    m_rec = ds["mask_rows"].at[rows, ys_rec].set(True)
    m_orc = ds["mask_rows"].at[rows, ys_orc].set(True)
    bc_grad = jax.jit(jax.value_and_grad(
        lambda a, f, y, m: ppo_mod.bc_loss(a, f, y, m)))
    warm = max(bc_steps // 4, 1)
    step = 0
    for i in range(bc_steps):
        ys, m = (ys_rec, m_rec) if i < warm else (ys_orc, m_orc)
        loss, g = bc_grad(agent, ds["feats"], ys, m)
        agent, opt, _ = adamw.update(tc, lr_fn, opt, agent, g)
        history["bc_loss"].append(float(loss))
        step += 1
        if (i + 1) % eval_every == 0 or i + 1 == bc_steps:
            consider(f"bc@{i + 1}", agent)

    # stage 2: PPO over per-request trajectories (segment clock = T axis)
    wins = _windows(ds, t_win)
    ppo_grad = jax.jit(jax.value_and_grad(
        lambda a, tr_: ppo_mod.ppo_loss(a, tr_), has_aux=True))
    for i in range(ppo_steps):
        traj = _make_traj(agent, ds, wins)
        for _ in range(ppo_epochs):
            (loss, pm), g = ppo_grad(agent, traj)
            agent, opt, _ = adamw.update(tc, lr_fn, opt, agent, g)
            step += 1
        history["ppo"].append({"loss": float(loss),
                               **{k: float(v) for k, v in pm.items()}})
        consider(f"ppo@{i + 1}", agent)

    agent, learned_ev, picked = best_le if best_le is not None else best_any
    history["eval"] = {
        "learned": learned_ev, "picked": picked,
        "adaptive": adaptive_ev,
        "oracle": evaluate_policy(ds, rank_cfg, actions=ds["oracle"]),
        "n_records": ds["n"],
    }

    if out_dir is not None:
        out = pathlib.Path(out_dir)
        mgr = CheckpointManager(out, async_save=False, keep=2)
        mgr.save(step, agent)
        (out / "policy_meta.json").write_text(json.dumps({
            "n_actions": G, "h_dim": _H_DIM, "arch": POLICY_ARCH,
            "rank_grid": [int(r) for r in np.asarray(ds["grid"])],
            "eval": history["eval"],
        }))
    return agent, history


def load_policy(directory) -> dict:
    """Load a trained serving policy for ``ServeEngine(cfg, params, pol)``
    / ``EngineConfig(... mode="learned")``. Rebuilds the template tree
    from policy_meta.json, so callers need no arch knowledge."""
    out = pathlib.Path(directory)
    mpath = out / "policy_meta.json"
    if not mpath.exists():
        raise FileNotFoundError(
            f"no policy_meta.json in {out} — train with "
            "repro.train.serve_policy.train_serve_policy(out_dir=...)")
    meta = json.loads(mpath.read_text())
    G = int(meta["n_actions"])
    dims = {"h_t": int(meta["h_dim"]), "w_t": 9, "ner": G, "bounds": G,
            "prev_rank": G, "layer_id": 1}
    template = init_policy(jax.random.PRNGKey(0), dims, G, **meta["arch"])
    mgr = CheckpointManager(out, async_save=False)
    tree, _, _ = mgr.load(template)
    return tree
