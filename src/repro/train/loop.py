"""Training-step factory: grad accumulation, AdamW, metrics; mesh-aware.

The same factory serves the CPU examples (1 device, dp) and the production
dry-run (512 devices, fsdp_tp) — only the shardings differ.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.optim import adamw
from repro.optim.schedules import make_lr_fn


def make_train_step(cfg: ModelConfig, tc: TrainConfig,
                    loss_fn: Callable[..., Tuple[jnp.ndarray, Any]],
                    grad_compression: Optional[str] = None):
    """loss_fn(params, batch, rng) -> (loss, aux). Returns
    train_step(params, opt_state, batch, rng) -> (params, opt_state, metrics)."""
    lr_fn = make_lr_fn(tc)
    compression = grad_compression or tc.grad_compression

    def compute_grads(params, batch, rng):
        def lf(p, b):
            loss, _ = loss_fn(p, b, rng)
            return loss

        if tc.microbatches <= 1:
            loss, grads = jax.value_and_grad(lf)(params, batch)
            return loss, grads

        k = tc.microbatches

        def reshape(x):
            return x.reshape((k, x.shape[0] // k) + x.shape[1:])

        mbs = jax.tree_util.tree_map(reshape, batch)

        def body(carry, mb):
            acc, err, loss_acc = carry
            loss, grads = jax.value_and_grad(lf)(params, mb)
            if compression == "bf16":
                # error-feedback bf16 accumulation
                new_acc, new_err = [], []
                for a, e, g in zip(jax.tree_util.tree_leaves(acc),
                                   jax.tree_util.tree_leaves(err),
                                   jax.tree_util.tree_leaves(grads)):
                    s = a.astype(jnp.float32) + g.astype(jnp.float32) + e
                    c = s.astype(jnp.bfloat16)
                    new_acc.append(c)
                    new_err.append(s - c.astype(jnp.float32))
                td = jax.tree_util.tree_structure(acc)
                acc = jax.tree_util.tree_unflatten(td, new_acc)
                err = jax.tree_util.tree_unflatten(td, new_err)
            else:
                acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return (acc, err, loss_acc + loss), None

        acc0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape,
                                jnp.bfloat16 if compression == "bf16"
                                else jnp.float32), params)
        err0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, _, loss_sum), _ = jax.lax.scan(body, (acc0, err0, 0.0), mbs)
        grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32) / k,
                                       grads)
        return loss_sum / k, grads

    def train_step(params, opt_state, batch, rng):
        loss, grads = compute_grads(params, batch, rng)
        params, opt_state, om = adamw.update(tc, lr_fn, opt_state, params,
                                             grads)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return train_step


def run_training(cfg: ModelConfig, tc: TrainConfig, *, init_fn, loss_fn,
                 data, ckpt_manager=None, param_specs=None, hooks=(),
                 straggler_warn_s: float = 60.0) -> Dict[str, Any]:
    """Simple single-process driver with checkpoint/restart and per-step
    timeout (straggler) logging. Returns final state + history."""
    rng = jax.random.PRNGKey(tc.seed)
    params = init_fn(rng)
    opt_state = adamw.init(params)
    start_step = 0
    if ckpt_manager is not None:
        latest = ckpt_manager.latest_step()
        if latest is not None:
            (params, opt_state), start_step, _ = ckpt_manager.load(
                (params, opt_state), latest)
            print(f"[ckpt] resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, tc, loss_fn))
    history = []
    for step in range(start_step, tc.total_steps):
        batch = data.batch_at(step)
        t0 = time.monotonic()
        params, opt_state, metrics = step_fn(
            params, opt_state, batch, jax.random.fold_in(rng, step))
        metrics = {k: float(v) for k, v in metrics.items()}
        dt = time.monotonic() - t0
        if dt > straggler_warn_s:
            print(f"[straggler] step {step} took {dt:.1f}s")
        if step % tc.log_every == 0 or step == tc.total_steps - 1:
            history.append({"step": step, **metrics, "s_per_step": dt})
            print(f"step {step:5d} loss {metrics['loss']:.4f} "
                  f"lr {metrics['lr']:.2e} gnorm {metrics['grad_norm']:.2f} "
                  f"({dt:.2f}s)")
        for hook in hooks:
            hook(step, params, metrics)
        if ckpt_manager is not None and tc.checkpoint_every > 0 \
                and (step + 1) % tc.checkpoint_every == 0:
            ckpt_manager.save(step + 1, (params, opt_state), param_specs)
    if ckpt_manager is not None:
        ckpt_manager.wait()
    return {"params": params, "opt_state": opt_state, "history": history}
