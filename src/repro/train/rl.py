"""Hybrid RL training pipeline (paper section 4.5.3):

  stage 1 — Behaviour Cloning from the greedy oracle (exhaustive grid sweep
            of the Eq. 13 reward per layer/head),
  stage 2 — PPO fine-tuning with the Eq. 13 reward collected from live
            rollouts (layer index = MDP time axis).

Everything runs on the LM whose attention the agent controls; the LM params
stay frozen during agent training (the paper adapts ranks at inference
time) — joint fine-tuning is exercised separately in benchmarks/table1.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.core import ppo as ppo_mod
from repro.core.oracle import oracle_actions
from repro.core.rewards import reward
from repro.models import transformer as tr
from repro.optim import adamw
from repro.optim.schedules import make_lr_fn


def collect_rollout(cfg: ModelConfig, params, agent, batch, rng, t: int = 0
                    ) -> Tuple[ppo_mod.Trajectory, Dict]:
    """One rollout: forward pass with sampled actions; returns a Trajectory
    with T = num_layers, B = batch * kv_heads."""
    logits, aux = tr.forward_dense(
        cfg, params, batch["tokens"], policy_params=agent, rank_rng=rng,
        greedy=False, compute_fidelity=True, collect_aux="rl")
    la = aux["layers"]
    L = cfg.num_layers
    b = batch["tokens"].shape[0]
    hkv, hq = cfg.num_kv_heads, cfg.num_heads
    dh = cfg.resolved_head_dim()

    fid = la["fidelity"]                            # (L, b, hq)
    fid_kv = fid.reshape(L, b, hkv, hq // hkv).mean(-1)
    rw = reward(cfg.rank, fid_kv, la["rank"], la["delta_a_rel"], dh, dh)

    B = b * hkv
    feats = {k: v.reshape(L, B, -1) for k, v in la["features"].items()}
    traj = ppo_mod.Trajectory(
        feats=feats,
        actions=la["action_idx"].reshape(L, B),
        logp_old=la["logp"].reshape(L, B),
        values_old=la["value"].reshape(L, B),
        rewards=rw.reshape(L, B),
        action_mask=la["action_mask"].reshape(L, B, -1),
    )
    metrics = {
        "reward_mean": jnp.mean(rw),
        "fidelity_mean": jnp.mean(fid),
        "rank_mean": jnp.mean(la["rank"].astype(jnp.float32)),
        "lm_loss_proxy": jnp.mean(jnp.square(logits[..., 0]) * 0),
    }
    return traj, metrics


def collect_bc_batch(cfg: ModelConfig, params, agent, batch, rng
                     ) -> Tuple[Dict, jnp.ndarray, jnp.ndarray]:
    """Collect (features, oracle_actions, action_mask) for BC."""
    _, aux = tr.forward_dense(
        cfg, params, batch["tokens"], policy_params=agent, rank_rng=rng,
        greedy=True, collect_aux="rl", collect_qkv=True)
    la = aux["layers"]
    L = cfg.num_layers
    b = batch["tokens"].shape[0]
    hkv = cfg.num_kv_heads

    qkv = la["qkv"]                                 # each (L, b, s, h, d)
    oracle = jax.vmap(
        lambda q, k, v: oracle_actions(cfg.rank, q, k, v)[0]
    )(qkv["q"], qkv["k"], qkv["v"])                 # (L, b, hkv)

    B = L * b * hkv
    feats = {k: v.reshape(B, -1) for k, v in la["features"].items()}
    return feats, oracle.reshape(B), la["action_mask"].reshape(B, -1)


def train_agent(cfg: ModelConfig, params, agent, data, *,
                bc_steps: int = 20, ppo_steps: int = 30,
                ppo_epochs: int = 2, lr: float = 3e-4, seed: int = 0
                ) -> Tuple[dict, Dict]:
    """Full hybrid pipeline. Returns (trained agent, history)."""
    tc = TrainConfig(lr=lr, total_steps=bc_steps + ppo_steps * ppo_epochs,
                     warmup_steps=5, weight_decay=0.0, grad_clip=1.0)
    lr_fn = make_lr_fn(tc)
    opt = adamw.init(agent)
    rng = jax.random.PRNGKey(seed)
    history = {"bc_loss": [], "ppo": []}

    # ---- stage 1: behaviour cloning -------------------------------------
    bc_grad = jax.jit(jax.value_and_grad(
        lambda a, f, y, m: ppo_mod.bc_loss(a, f, y, m)))
    collect_bc = jax.jit(
        lambda p, a, b, r: collect_bc_batch(cfg, p, a, b, r))
    for i in range(bc_steps):
        rng, k1 = jax.random.split(rng)
        feats, ys, mask = collect_bc(params, agent, data.batch_at(i), k1)
        loss, g = bc_grad(agent, feats, ys, mask)
        agent, opt, _ = adamw.update(tc, lr_fn, opt, agent, g)
        history["bc_loss"].append(float(loss))

    # ---- stage 2: PPO ----------------------------------------------------
    rollout = jax.jit(lambda p, a, b, r, t: collect_rollout(cfg, p, a, b, r, t))
    ppo_grad = jax.jit(jax.value_and_grad(
        lambda a, tr_: ppo_mod.ppo_loss(a, tr_), has_aux=True))
    for i in range(ppo_steps):
        rng, k1 = jax.random.split(rng)
        traj, metrics = rollout(params, agent, data.batch_at(1000 + i), k1, i)
        for _ in range(ppo_epochs):
            (loss, pm), g = ppo_grad(agent, traj)
            agent, opt, _ = adamw.update(tc, lr_fn, opt, agent, g)
        history["ppo"].append({
            "reward": float(metrics["reward_mean"]),
            "rank_mean": float(metrics["rank_mean"]),
            "fidelity": float(metrics["fidelity_mean"]),
            "loss": float(loss),
        })
    return agent, history
