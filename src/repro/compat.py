"""Forward-compat shims for the pinned jax (0.4.37 / jaxlib 0.4.36).

Call sites across the repo (tests, launch, dist) target the newer mesh API:
``jax.make_mesh(shape, names, axis_types=...)`` and ``jax.sharding.AxisType``.
Both appeared after 0.4.37. On an older jax we provide the missing enum and
accept-and-drop the ``axis_types`` kwarg — axis types only select the
sharding-in-types tracing mode, which nothing in this repo relies on for
correctness (all shardings are expressed as explicit PartitionSpecs).

Importing :mod:`repro` applies the shim exactly once; on a new-enough jax it
is a no-op.
"""
from __future__ import annotations

import enum
import functools
import inspect

import jax


def ensure_jax_compat() -> None:
    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        orig = jax.make_mesh

        @functools.wraps(orig)
        def make_mesh(axis_shapes, axis_names, *, axis_types=None,
                      devices=None):
            del axis_types
            return orig(axis_shapes, axis_names, devices=devices)

        make_mesh._repro_compat = True
        jax.make_mesh = make_mesh
