"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_ref(q, k, v, *, scale: float, causal: bool = True,
              q_offset: int = 0):
    """q: (b, hq, sq, dq), k: (b, hkv, skv, dq), v: (b, hkv, skv, dv).
    GQA: hq % hkv == 0. Returns (b, hq, sq, dv)."""
    b, hq, sq, dq = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    n_rep = hq // hkv
    kr = jnp.repeat(k, n_rep, axis=1)
    vr = jnp.repeat(v, n_rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kr).astype(jnp.float32) * scale
    if causal:
        q_pos = jnp.arange(sq)[:, None] + q_offset
        k_pos = jnp.arange(skv)[None, :]
        s = jnp.where((k_pos <= q_pos)[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(vr.dtype), vr)


def decode_ref(q, k, v, kv_len, *, scale: float):
    """Single-step decode. q: (b, hq, dq); k: (b, hkv, M, dq);
    v: (b, hkv, M, dv); kv_len: () or (b,) valid prefix length.
    Returns (b, hq, dv)."""
    b, hq, dq = q.shape
    hkv, M = k.shape[1], k.shape[2]
    n_rep = hq // hkv
    kr = jnp.repeat(k, n_rep, axis=1)
    vr = jnp.repeat(v, n_rep, axis=1)
    s = jnp.einsum("bhd,bhkd->bhk", q, kr).astype(jnp.float32) * scale
    valid = jnp.arange(M)[None, None, :] < jnp.reshape(kv_len, (-1, 1, 1))
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", p.astype(vr.dtype), vr)


def decode_chunk_ref(q, k, v, kv_len, q_start, *, scale: float):
    """Chunked-prefill decode oracle. q: (b, hq, C, dq); k: (b, hkv, M, dq);
    v: (b, hkv, M, dv); kv_len/q_start: () or (b,). Query j of row b sees
    keys k_pos <= q_start[b] + j (and k_pos < kv_len[b]).
    Returns ((b, hq, C, dv), probs (b, hq, C, M))."""
    b, hq, C, dq = q.shape
    hkv, M = k.shape[1], k.shape[2]
    n_rep = hq // hkv
    kr = jnp.repeat(k, n_rep, axis=1)
    vr = jnp.repeat(v, n_rep, axis=1)
    s = jnp.einsum("bhcd,bhkd->bhck", q, kr).astype(jnp.float32) * scale
    k_pos = jnp.arange(M)[None, None, None, :]
    q_pos = (jnp.reshape(q_start, (-1, 1, 1, 1))
             + jnp.arange(C)[None, None, :, None])
    ok = (k_pos <= q_pos) & (k_pos < jnp.reshape(kv_len, (-1, 1, 1, 1)))
    s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhck,bhkd->bhcd", p.astype(vr.dtype), vr), p
