"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode for
correctness validation; on TPU they compile through Mosaic. The XLA einsum
path in repro.models.attention remains the lowering used by the dry-run
(see DESIGN.md section 3 — kernels are the TPU runtime hot-spot layer)."""
from __future__ import annotations

import jax

from repro.kernels.decode_attn import flash_decode
from repro.kernels.lowrank_flash import lowrank_flash


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def flash_attention(q, k, v, *, scale: float, causal: bool = True,
                    block_q: int = 128, block_k: int = 128,
                    q_offset: int = 0, interpret=None):
    """Flash attention over (b, h, s, d) layouts; d may be a truncated rank.
    See repro.kernels.ref.flash_ref for exact semantics."""
    if interpret is None:
        interpret = _on_cpu()
    return lowrank_flash(q, k, v, scale=scale, causal=causal,
                         block_q=block_q, block_k=block_k,
                         q_offset=q_offset, interpret=interpret)


def decode_attention(q, k, v, kv_len, *, scale: float, block_k: int = 512,
                     interpret=None, return_probs: bool = False,
                     q_start=None, q_lens=None):
    """Flash-decode; kv_len may be () or per-row (b,). ``q`` is (b, hq, r)
    for one decode token or (b, hq, C, r) for a per-row chunk of C query
    tokens (chunked prefill interleaved into the fused serve step, or a
    speculative verify block) with ``q_start`` the per-row cache position
    of the first query and ``q_lens`` the optional per-row valid query
    count (padding queries come out exactly zero). ``return_probs`` also
    returns the normalised attention rows (b, hq, [C,] M) for the serving
    engine's attention-mass accumulator.
    See repro.kernels.ref.decode_ref / decode_chunk_ref."""
    if interpret is None:
        interpret = _on_cpu()
    return flash_decode(q, k, v, kv_len, scale=scale, block_k=block_k,
                        interpret=interpret, return_probs=return_probs,
                        q_start=q_start, q_lens=q_lens)
