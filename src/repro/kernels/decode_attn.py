"""Pallas TPU flash-decode: one new query token against a (possibly
rank-truncated) KV cache with a dynamic valid-prefix length.

Grid: (batch*q_heads, kv_blocks) with running-softmax scratch accumulation —
the split-KV pattern that keeps the MXU busy for long caches at batch decode.
The cache factor dim may be the truncated rank r (DR-RL serving bucket) or
the full head dim — the continuous-batching engine feeds the factor-form
paged cache kt = K . B_r here, so the score contraction reads r/d of the
dense K bytes.

``kv_len`` may be a scalar (lock-step batch) or a per-row (b,) vector — the
continuous-batching engine (repro.serve) decodes heterogeneous streams in
one executable, so every batch row carries its own valid prefix length.
Per-row *rank* needs no kernel support: the engine pads the q factors to
the widest bucket and zeroes the columns beyond each row's rank, which
leaves the score contraction exact (adding 0.0 terms).

``return_probs=True`` additionally emits the normalised attention row
p (b, hq, M) of the new token: the serving engine accumulates per-key
attention mass in-graph (the weighted-Gram basis input), and emitting p
from the kernel's own running softmax avoids a second score pass over the
cache. The row is accumulated unnormalised in a VMEM scratch strip,
rescaled by the same exp(m_prev - m_new) correction as the output
accumulator, and divided by the final denominator once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *rest,
                   scale: float, block_k: int, hq: int, return_probs: bool):
    if return_probs:
        p_ref, m_scr, l_scr, acc_scr, p_scr = rest
    else:
        p_ref, p_scr = None, None
        m_scr, l_scr, acc_scr = rest
    ki = pl.program_id(1)
    n_k = pl.num_programs(1)
    kv_len = len_ref[pl.program_id(0) // hq]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        if return_probs:
            p_scr[...] = jnp.zeros_like(p_scr)

    k_start = ki * block_k

    @pl.when(k_start < kv_len)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # (1, r) -> use (8, r) tile
        k = k_ref[0].astype(jnp.float32)                  # (bk, r)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < kv_len, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        v = v_ref[0].astype(jnp.float32)
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                              preferred_element_type=jnp.float32))
        if return_probs:
            p_scr[...] = p_scr[...] * corr[:, None]
            p_scr[0, pl.ds(k_start, block_k)] = p[0]
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)
        if return_probs:
            p_ref[0] = (p_scr[...] / denom).astype(p_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("scale", "block_k", "interpret",
                                    "return_probs"))
def flash_decode(q, k, v, kv_len, *, scale: float, block_k: int = 512,
                 interpret: bool = False, return_probs: bool = False):
    """q: (b, hq, r); k: (b, hkv, M, r); v: (b, hkv, M, dv); kv_len: () or (b,).
    Returns (b, hq, dv), or ((b, hq, dv), (b, hq, M) probs) with
    ``return_probs``."""
    b, hq, r = q.shape
    hkv, M, dv = k.shape[1], k.shape[2], v.shape[3]
    n_rep = hq // hkv
    block_k = min(block_k, max(M, 8))
    pad_k = (-M) % block_k
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    M_p = M + pad_k

    qf = q.reshape(b * hq, 1, r)
    kf = k.reshape(b * hkv, M_p, r)
    vf = v.reshape(b * hkv, M_p, dv)
    lens = jnp.broadcast_to(jnp.reshape(kv_len, (-1,)), (b,)).astype(jnp.int32)

    grid = (b * hq, M_p // block_k)
    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                               hq=hq, return_probs=return_probs)
    out_shape = [jax.ShapeDtypeStruct((b * hq, 1, dv), v.dtype)]
    out_specs = [pl.BlockSpec((1, 1, dv), lambda bh, ki: (bh, 0, 0))]
    scratch = [
        pltpu.VMEM((1,), jnp.float32),
        pltpu.VMEM((1,), jnp.float32),
        pltpu.VMEM((1, dv), jnp.float32),
    ]
    if return_probs:
        out_shape.append(jax.ShapeDtypeStruct((b * hq, 1, M_p), jnp.float32))
        out_specs.append(pl.BlockSpec((1, 1, M_p), lambda bh, ki: (bh, 0, 0)))
        scratch.append(pltpu.VMEM((1, M_p), jnp.float32))
    res = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, r), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, block_k, r),
                         lambda bh, ki, n_rep=n_rep: (bh // n_rep, ki, 0)),
            pl.BlockSpec((1, block_k, dv),
                         lambda bh, ki, n_rep=n_rep: (bh // n_rep, ki, 0)),
        ],
        out_specs=out_specs if return_probs else out_specs[0],
        out_shape=out_shape if return_probs else out_shape[0],
        scratch_shapes=scratch,
        interpret=interpret,
    )(lens, qf, kf, vf)
    if return_probs:
        o, p = res
        return (o.reshape(b, hq, dv),
                p.reshape(b, hq, M_p)[:, :, :M])
    return res.reshape(b, hq, dv)
