"""Pallas TPU flash-decode: new query tokens against a (possibly
rank-truncated) KV cache with a dynamic valid-prefix length.

Grid: (batch*q_heads, kv_blocks) with running-softmax scratch accumulation —
the split-KV pattern that keeps the MXU busy for long caches at batch decode.
The cache factor dim may be the truncated rank r (DR-RL serving bucket) or
the full head dim — the continuous-batching engine feeds the factor-form
paged cache kt = K . B_r here, so the score contraction reads r/d of the
dense K bytes.

``kv_len`` may be a scalar (lock-step batch) or a per-row (b,) vector — the
continuous-batching engine (repro.serve) decodes heterogeneous streams in
one executable, so every batch row carries its own valid prefix length.
Per-row *rank* needs no kernel support: the engine pads the q factors to
the widest bucket and zeroes the columns beyond each row's rank, which
leaves the score contraction exact (adding 0.0 terms).

**Chunked prefill** (repro.serve.api): q may carry a block of C query
tokens per row — ``q: (b, hq, C, r)`` — with a per-row ``q_start`` giving
the cache position of the row's first query. Query j of row b then sees
keys ``k_pos <= q_start[b] + j`` (causal within the chunk, everything
before it unmasked), so one executable serves decode rows (C=1,
q_start = kv_len-1) and mid-prefill rows (C = chunk size) side by side.
Rows whose chunk is shorter than C pad with garbage queries whose outputs
the engine discards; the ``kv_len`` mask caps what they can see, and a
fully-masked query row contributes exact zeros (not exp(0) garbage) to
its own accumulator. An optional per-row ``q_lens`` tightens that
contract: queries at index >= q_lens[b] are fully masked, so their output
and probability rows come out exactly zero rather than echoing the last
valid query's window.

**Speculative decode** (repro.serve.spec) reuses both chunk forms: the
draft phase runs the C=1 shape over a statically narrowed factor slice
(r_cap columns of kt = K . B_r — the aggressive draft rank), and the
verify phase is exactly the chunked-prefill shape: one (C, M) causal
block per row scores a row's whole draft run in a single pass.

``return_probs=True`` additionally emits the normalised attention rows
p (b, hq, C, M): the serving engine accumulates per-key attention mass
in-graph (the weighted-Gram basis input), and emitting p from the
kernel's own running softmax avoids a second score pass over the cache.
The rows are accumulated unnormalised in a VMEM scratch strip, rescaled
by the same exp(m_prev - m_new) correction as the output accumulator,
and divided by the final denominator once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, qstart_ref, qlen_ref, q_ref, k_ref, v_ref,
                   o_ref, *rest, scale: float, block_k: int, hq: int,
                   return_probs: bool):
    if return_probs:
        p_ref, m_scr, l_scr, acc_scr, p_scr = rest
    else:
        p_ref, p_scr = None, None
        m_scr, l_scr, acc_scr = rest
    ki = pl.program_id(1)
    n_k = pl.num_programs(1)
    row = pl.program_id(0) // hq
    kv_len = len_ref[row]
    q_start = qstart_ref[row]
    q_len = qlen_ref[row]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        if return_probs:
            p_scr[...] = jnp.zeros_like(p_scr)

    k_start = ki * block_k

    @pl.when(k_start < kv_len)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # (C, r)
        k = k_ref[0].astype(jnp.float32)                  # (bk, r)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        q_idx = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        q_pos = q_start + q_idx
        s = jnp.where((k_pos <= q_pos) & (k_pos < kv_len) & (q_idx < q_len),
                      s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        # a chunk query whose causal window hasn't reached this block yet
        # is fully masked here: m_new stays NEG_INF and the naive
        # exp(s - m_new) would be exp(0) = 1 per key — force exact zeros
        p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_new[:, None]))
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        v = v_ref[0].astype(jnp.float32)
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                              preferred_element_type=jnp.float32))
        if return_probs:
            p_scr[...] = p_scr[...] * corr[:, None]
            p_scr[:, pl.ds(k_start, block_k)] = p
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)
        if return_probs:
            p_ref[0] = (p_scr[...] / denom).astype(p_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("scale", "block_k", "interpret",
                                    "return_probs"))
def flash_decode(q, k, v, kv_len, *, scale: float, block_k: int = 512,
                 interpret: bool = False, return_probs: bool = False,
                 q_start=None, q_lens=None):
    """q: (b, hq, r) single decode token, or (b, hq, C, r) per-row query
    chunk; k: (b, hkv, M, r); v: (b, hkv, M, dv); kv_len: () or (b,) valid
    keys INCLUDING the new chunk. ``q_start``: () or (b,) cache position of
    each row's first query (default ``kv_len - C``: the chunk sits at the
    end of the valid prefix — for C=1 that is the classic decode mask
    ``k_pos < kv_len``). ``q_lens``: optional (b,) valid query count per
    row; queries at index >= q_lens[b] are fully masked and their output /
    probability rows are exact zeros (default: all C valid). Returns
    (b, hq, dv) / (b, hq, C, dv), with the normalised probability rows
    (b, hq, [C,] M) appended when ``return_probs``."""
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, :, None, :]
    b, hq, C, r = q.shape
    hkv, M, dv = k.shape[1], k.shape[2], v.shape[3]
    n_rep = hq // hkv
    block_k = min(block_k, max(M, 8))
    pad_k = (-M) % block_k
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    M_p = M + pad_k

    qf = q.reshape(b * hq, C, r)
    kf = k.reshape(b * hkv, M_p, r)
    vf = v.reshape(b * hkv, M_p, dv)
    lens = jnp.broadcast_to(jnp.reshape(kv_len, (-1,)), (b,)).astype(jnp.int32)
    qs = (lens - C if q_start is None else
          jnp.broadcast_to(jnp.reshape(q_start, (-1,)), (b,)).astype(jnp.int32))
    ql = (jnp.full((b,), C, jnp.int32) if q_lens is None else
          jnp.broadcast_to(jnp.reshape(q_lens, (-1,)), (b,)).astype(jnp.int32))

    grid = (b * hq, M_p // block_k)
    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                               hq=hq, return_probs=return_probs)
    out_shape = [jax.ShapeDtypeStruct((b * hq, C, dv), v.dtype)]
    out_specs = [pl.BlockSpec((1, C, dv), lambda bh, ki: (bh, 0, 0))]
    scratch = [
        pltpu.VMEM((C,), jnp.float32),
        pltpu.VMEM((C,), jnp.float32),
        pltpu.VMEM((C, dv), jnp.float32),
    ]
    if return_probs:
        out_shape.append(jax.ShapeDtypeStruct((b * hq, C, M_p), jnp.float32))
        out_specs.append(pl.BlockSpec((1, C, M_p), lambda bh, ki: (bh, 0, 0)))
        scratch.append(pltpu.VMEM((C, M_p), jnp.float32))
    res = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, C, r), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, block_k, r),
                         lambda bh, ki, n_rep=n_rep: (bh // n_rep, ki, 0)),
            pl.BlockSpec((1, block_k, dv),
                         lambda bh, ki, n_rep=n_rep: (bh // n_rep, ki, 0)),
        ],
        out_specs=out_specs if return_probs else out_specs[0],
        out_shape=out_shape if return_probs else out_shape[0],
        scratch_shapes=scratch,
        interpret=interpret,
    )(lens, qs, ql, qf, kf, vf)
    if return_probs:
        o, p = res
        o = o.reshape(b, hq, C, dv)
        p = p.reshape(b, hq, C, M_p)[..., :M]
        return (o[:, :, 0], p[:, :, 0]) if squeeze else (o, p)
    o = res.reshape(b, hq, C, dv)
    return o[:, :, 0] if squeeze else o
