"""Pallas TPU flash-decode: one new query token against a (possibly
rank-truncated) KV cache with a dynamic valid-prefix length.

Grid: (batch*q_heads, kv_blocks) with running-softmax scratch accumulation —
the split-KV pattern that keeps the MXU busy for long caches at batch decode.
The cache factor dim may be the truncated rank r (DR-RL serving bucket) or
the full head dim.

``kv_len`` may be a scalar (lock-step batch) or a per-row (b,) vector — the
continuous-batching engine (repro.serve) decodes heterogeneous streams in
one executable, so every batch row carries its own valid prefix length.
Per-row *rank* needs no kernel support: the engine pads the q/k factors to
the widest bucket and zeroes the columns beyond each row's rank, which
leaves the score contraction exact (adding 0.0 terms).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, block_k: int, hq: int):
    ki = pl.program_id(1)
    n_k = pl.num_programs(1)
    kv_len = len_ref[pl.program_id(0) // hq]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = ki * block_k

    @pl.when(k_start < kv_len)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # (1, r) -> use (8, r) tile
        k = k_ref[0].astype(jnp.float32)                  # (bk, r)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < kv_len, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        v = v_ref[0].astype(jnp.float32)
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                              preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("scale", "block_k", "interpret"))
def flash_decode(q, k, v, kv_len, *, scale: float, block_k: int = 512,
                 interpret: bool = False):
    """q: (b, hq, r); k: (b, hkv, M, r); v: (b, hkv, M, dv); kv_len: () or (b,).
    Returns (b, hq, dv)."""
    b, hq, r = q.shape
    hkv, M, dv = k.shape[1], k.shape[2], v.shape[3]
    n_rep = hq // hkv
    block_k = min(block_k, max(M, 8))
    pad_k = (-M) % block_k
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    M_p = M + pad_k

    qf = q.reshape(b * hq, 1, r)
    kf = k.reshape(b * hkv, M_p, r)
    vf = v.reshape(b * hkv, M_p, dv)
    lens = jnp.broadcast_to(jnp.reshape(kv_len, (-1,)), (b,)).astype(jnp.int32)

    grid = (b * hq, M_p // block_k)
    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                               hq=hq)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, r), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, block_k, r),
                         lambda bh, ki, n_rep=n_rep: (bh // n_rep, ki, 0)),
            pl.BlockSpec((1, block_k, dv),
                         lambda bh, ki, n_rep=n_rep: (bh // n_rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, dv), lambda bh, ki: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, 1, dv), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, dv), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qf, kf, vf)
    return out.reshape(b, hq, dv)
