"""Pallas TPU flash attention with a rank-r score contraction.

The paper's DR-RL serving path feeds rank-r factors q~ (b, h, s, r) and
k~ (b, h, s, r) (r from the policy's bucket) — the score matmul contracts
over r instead of d_head, which is where the FLOPs saving lands. The same
kernel runs the full-rank path (r == d_head).

Tiling: grid (batch*q_heads, q_blocks, kv_blocks), kv innermost so the
running-softmax accumulators persist in VMEM scratch across kv steps.
Causal blocks entirely above the diagonal are skipped via @pl.when.
GQA is handled in the k/v index_map (q-head -> kv-head integer division),
so the broadcast never materialises in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  sq: int, skv: int, q_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q + q_offset
    k_start = ki * block_k

    def compute():
        q = q_ref[0].astype(jnp.float32)                 # (bq, r)
        k = k_ref[0].astype(jnp.float32)                 # (bk, r)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = k_pos < skv                               # tail padding
        if causal:
            mask = mask & (k_pos <= q_pos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        v = v_ref[0].astype(jnp.float32)                 # (bk, dv)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv
        m_scr[...] = m_new

    if causal:
        # skip blocks entirely above the causal diagonal
        pl.when(k_start <= q_start + block_q - 1)(compute)
    else:
        compute()

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.maximum(l, 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "block_q", "block_k", "q_offset",
                     "interpret"))
def lowrank_flash(q, k, v, *, scale: float, causal: bool = True,
                  block_q: int = 128, block_k: int = 128, q_offset: int = 0,
                  interpret: bool = False):
    """q: (b, hq, sq, r); k: (b, hkv, skv, r); v: (b, hkv, skv, dv).
    Returns (b, hq, sq, dv). r is the (possibly truncated) contraction dim."""
    b, hq, sq, r = q.shape
    hkv, skv, dv = k.shape[1], k.shape[2], v.shape[3]
    n_rep = hq // hkv
    block_q = min(block_q, max(sq, 8))
    block_k = min(block_k, max(skv, 8))

    pad_q = (-sq) % block_q
    pad_k = (-skv) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    sq_p, skv_p = sq + pad_q, skv + pad_k

    qf = q.reshape(b * hq, sq_p, r)
    kf = k.reshape(b * hkv, skv_p, r)
    vf = v.reshape(b * hkv, skv_p, dv)

    grid = (b * hq, sq_p // block_q, skv_p // block_k)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, sq=sq, skv=skv, q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, r), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, r),
                         lambda bh, qi, ki, n_rep=n_rep: (bh // n_rep, ki, 0)),
            pl.BlockSpec((1, block_k, dv),
                         lambda bh, qi, ki, n_rep=n_rep: (bh // n_rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dv), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq_p, dv), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, dv), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(b, hq, sq_p, dv)
    return out[:, :, :sq]
