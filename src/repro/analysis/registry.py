"""Repo-specific knowledge feeding the generic rule visitors.

Everything the AST cannot see on its own lives here, in one reviewed
place: the step-loop entry points and control-plane stops (R1), the
dynamic attribute hops the call graph needs (``self.fns.
decode_step_paged`` is a model-registry lookup, ``self.core.step`` a
composition edge), the shared-state -> owning-lock map (R3), and the
donation rules whose ``donate_argnums`` are computed at runtime
(backend-conditional tuples the indexer cannot fold) (R4).

Rules also honour *inline* declarations so fixtures and future classes
can self-register without editing this file:

* ``_inv_locks_ = {"attr": ("lockname", ...)}`` class attribute — R3;
* literal ``donate_argnums`` tuples on ``jax.jit`` bindings — R4
  (picked up by the indexer, no registry entry needed).
"""
from __future__ import annotations

from dataclasses import dataclass, field

# --------------------------------------------------------------------------
# R1 — host-sync: step-loop entry points, control-plane stops, dynamic hops
# --------------------------------------------------------------------------

# (path suffix, qualname): the host-side fused-step loop.  The graph is
# built by reachability from these — not a hardcoded file list.
HOST_ENTRIES: tuple[tuple[str, str], ...] = (
    ("serve/engine.py", "ServeEngine.step"),
    ("serve/engine.py", "ServeEngine.run"),
    ("serve/api.py", "Engine.step"),
    ("serve/api.py", "Engine.run"),
    ("serve/frontend.py", "FrontEnd._loop"),
)

# Control-plane boundaries the host-sync rule does not cross, with the
# reason each is exempt (admission and warmup legitimately block).
HOST_STOPS: dict[tuple[str, str], str] = {
    ("serve/engine.py", "ServeEngine._admit"):
        "admission/prefill is control-plane; its one-shot prefill sync is "
        "measured separately as prefill_s and never runs between decode "
        "dispatches of live slots",
    ("serve/engine.py", "ServeEngine.warmup"):
        "warmup exists to absorb compiles and syncs before serving",
    ("serve/engine.py", "ServeEngine.reset"):
        "reset tears the serving state down; latency is irrelevant",
    ("serve/api.py", "Engine.warmup"):
        "warmup exists to absorb compiles and syncs before serving",
    ("serve/api.py", "Engine.reset"):
        "reset tears the serving state down; latency is irrelevant",
}

# Dynamic attribute hops: ``self.<a>.<b>(...)`` edges the resolver
# cannot derive.  Keyed by the last one or two dotted parts.
ATTR_TARGETS: dict[str, tuple[str, str]] = {
    # model-registry indirection: the fused step's decode body
    "fns.decode_step_paged": ("models/transformer.py", "decode_step_paged"),
    # composition edges across the serving layers
    "core.step": ("serve/engine.py", "ServeEngine.step"),
    "core.run": ("serve/engine.py", "ServeEngine.run"),
    "engine.step": ("serve/api.py", "Engine.step"),
    # trace-recorder hooks off the step loop (engine.trace is None unless
    # EngineConfig.record_traces is set)
    "trace.on_decision": ("serve/traces.py", "TraceRecorder.on_decision"),
    "trace.on_step": ("serve/traces.py", "TraceRecorder.on_step"),
    "trace.on_evict": ("serve/traces.py", "TraceRecorder.on_evict"),
    # observability hooks off the step loop (always constructed; pure
    # host Python — see repro.obs.core's module docstring)
    "obs.step_phases": ("obs/core.py", "Observability.step_phases"),
    "obs.stats_view": ("obs/core.py", "Observability.stats_view"),
    "obs.reset_run": ("obs/core.py", "Observability.reset_run"),
    "obs.on_admit": ("obs/core.py", "Observability.on_admit"),
    "obs.on_first_token": ("obs/core.py", "Observability.on_first_token"),
    "obs.on_finish": ("obs/core.py", "Observability.on_finish"),
    "obs.on_decide": ("obs/core.py", "Observability.on_decide"),
    "obs.on_drift": ("obs/core.py", "Observability.on_drift"),
    "obs.on_prefill_chunk": ("obs/core.py", "Observability.on_prefill_chunk"),
    "obs.on_spec_accept": ("obs/core.py", "Observability.on_spec_accept"),
    "obs.on_token_latency": ("obs/core.py", "Observability.on_token_latency"),
    "obs.set_prefix_size": ("obs/core.py", "Observability.set_prefix_size"),
    "obs.record_event": ("obs/core.py", "Observability.record_event"),
    "obs.flight_dump": ("obs/core.py", "Observability.flight_dump"),
    "obs.rank_telemetry": ("obs/core.py", "Observability.rank_telemetry"),
}


# --------------------------------------------------------------------------
# R3 — lock discipline
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class LockRule:
    """Shared attributes of one class and the lock(s) that own them.

    * ``locks``: any-of — a mutation under any listed lock is fine;
    * ``attrs``: ``self.<attr>`` chains whose stores must hold a lock;
    * ``mutator_methods``: method names that count as mutation when
      called on a registered attr (``self.cache.allocate(...)``);
    * ``assume_held``: methods whose bodies run with the lock held —
      every intra-class call site is checked to actually hold it;
    * ``external``: methods whose mutations are serialised by
      something outside this class; the justification is mandatory
      and rendered in the report.
    """

    path_suffix: str
    cls: str
    locks: tuple[str, ...]
    attrs: tuple[str, ...]
    mutator_methods: tuple[str, ...] = ()
    assume_held: tuple[str, ...] = ()
    external: dict[str, str] = field(default_factory=dict)


_STEP_LOOP_WHY = (
    "step-loop method: the stepping thread is the sole driver by "
    "contract, serialised against submit/cancel by "
    "repro.serve.api.Engine._step_lock (and FrontEnd's single thread)"
)

LOCK_RULES: tuple[LockRule, ...] = (
    LockRule(
        path_suffix="serve/engine.py",
        cls="ServeEngine",
        locks=("_lock",),
        attrs=("sched", "cache", "prefix", "_hits", "_snaps",
               "_spectra_pending", "last_emitted", "request_first_tok_t"),
        mutator_methods=(
            # scheduler
            "submit", "cancel_pending", "evict", "admit",
            # paged KV cache
            "allocate", "release", "retain", "unref", "copy_page",
            "write_prefill",
            # prefix radix tree (match/touch_path move the LRU clock)
            "insert", "evict_lru", "touch_path", "match",
        ),
        assume_held=("_admit_locked", "_can_allocate", "_apply_prefix_hit"),
        external={
            "step": _STEP_LOOP_WHY,
            "_adopt_pools": _STEP_LOOP_WHY,
            "_step_live_spec": _STEP_LOOP_WHY,
            "_evict_finished": _STEP_LOOP_WHY,
            "_maybe_decide": _STEP_LOOP_WHY,
            "_maybe_snapshot": _STEP_LOOP_WHY,
            "_insert_prefix": _STEP_LOOP_WHY,
            "_stamp_first_token": _STEP_LOOP_WHY,
            "_check_drift": _STEP_LOOP_WHY,
            "_sync_control": _STEP_LOOP_WHY,
            "warmup": _STEP_LOOP_WHY,
            "run": _STEP_LOOP_WHY,
            "_reset_state": "called from __init__ and from reset() "
                            "(which holds _lock)",
        },
    ),
    LockRule(
        path_suffix="serve/api.py",
        cls="Engine",
        locks=("_submit_lock", "_step_lock"),
        attrs=("_handles", "_next_rid", "_streaming", "_finished_seen"),
    ),
    LockRule(
        path_suffix="serve/api.py",
        cls="RequestHandle",
        locks=("_cv",),
        attrs=("_toks", "_result", "ttft_s", "done_s", "cancelled",
               "_stopped"),
    ),
    LockRule(
        path_suffix="serve/frontend.py",
        cls="Router",
        locks=("_lock",),
        attrs=("_rr", "routed", "route_kinds"),
        assume_held=("_pick",),
    ),
    LockRule(
        path_suffix="serve/frontend.py",
        cls="FrontEnd",
        locks=("_idle_cv",),
        attrs=("_error",),
        external={
            "_loop": "the stepping thread is the sole writer; readers "
                     "(_raise_if_dead) tolerate one poll of staleness",
        },
    ),
)


# --------------------------------------------------------------------------
# R4 — donation safety
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class DonationRule:
    """Calls through ``self.<binding>``/``<binding>`` donate the listed
    positional args.  These mirror jit bindings whose donate_argnums
    are backend-conditional at runtime; the static rule assumes the
    worst case (donation active)."""

    path_suffix: str
    bindings: tuple[str, ...]
    positions: tuple[int, ...]


DONATION_RULES: tuple[DonationRule, ...] = (
    # ServeEngine.__init__: jax.jit(self._step*_impl, donate_argnums=
    # (1, 2, 3, 4, 11)) — k/v/kt/mass pools + out_buf
    DonationRule("serve/engine.py",
                 ("_step", "_step_mixed", "_step_spec"),
                 (1, 2, 3, 4, 11)),
    # policy.make_decide_fn: decide(..., donate_argnums=(2, 6, 7)) —
    # kt_pool, basis, spectra
    DonationRule("serve/engine.py", ("_decide",), (2, 6, 7)),
)

# Calls that adopt/overwrite donated buffers: a call to the method
# counts as reassignment of the listed expressions.
DONATION_REASSIGNERS: dict[str, tuple[str, ...]] = {
    "_adopt_pools": ("self.cache.k_pool", "self.cache.v_pool",
                     "self.cache.kt_pool", "self.cache.mass_pool"),
}
