"""Module index + call graph over a set of Python source files.

The index is built once per checker run (plain ``ast``, no imports of
the analyzed code) and shared by every rule:

* ``FuncInfo`` per function/method, keyed ``(path, qualname)`` with
  nested functions as ``outer.<locals>.inner``;
* an import table per module so bare names and module-attribute calls
  (``policy.draft_ranks``) resolve across files;
* jit/pallas root detection — ``@jax.jit``, ``@functools.partial(
  jax.jit, ...)``, ``name = jax.jit(fn, ...)`` rebinds, and
  ``pl.pallas_call(kernel, ...)`` (through a local
  ``functools.partial`` binding);
* donation bindings: ``jax.jit(fn, donate_argnums=(...))`` with a
  literal tuple records which positional args of calls through that
  binding are donated (non-literal tuples — e.g. backend-conditional
  ones — are covered by the explicit registry instead).

Resolution is deliberately conservative: an edge is added only when a
name resolves to an indexed function (same module, import table, or
the repo registry's dynamic-attribute map); unresolvable calls are
dropped, and the registry names the dynamic hops that matter.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass

FuncKey = tuple[str, str]  # (path, qualname)


@dataclass
class FuncInfo:
    path: str
    qualname: str
    node: ast.FunctionDef | ast.AsyncFunctionDef
    cls: str | None = None           # enclosing class name, if a method
    parent: FuncKey | None = None    # enclosing function, if nested
    jit_root: bool = False           # body executes under trace
    params: tuple[str, ...] = ()

    @property
    def key(self) -> FuncKey:
        return (self.path, self.qualname)

    @property
    def name(self) -> str:
        return self.qualname.rsplit(".", 1)[-1]


@dataclass
class DonationBinding:
    """``binding = jax.jit(fn, donate_argnums=(...))`` with literal nums.

    ``binding`` is the bare or ``self.``-attribute name calls go
    through; ``positions`` are donated positional-arg indices.
    """

    path: str
    binding: str                     # "g" or "_step" (for self._step)
    positions: tuple[int, ...]
    target: FuncKey | None = None    # the wrapped function, when resolved


def _jit_in_expr(node: ast.expr) -> bool:
    """Is this decorator/callee expression jax.jit (possibly through
    functools.partial)?"""
    if isinstance(node, ast.Attribute) and node.attr == "jit":
        return True
    if isinstance(node, ast.Name) and node.id == "jit":
        return True
    if isinstance(node, ast.Call):
        f = node.func
        is_partial = (isinstance(f, ast.Attribute) and f.attr == "partial") or (
            isinstance(f, ast.Name) and f.id == "partial"
        )
        if is_partial and node.args:
            return _jit_in_expr(node.args[0])
        return _jit_in_expr(f)
    return False


def _is_pallas_call(node: ast.Call) -> bool:
    f = node.func
    return (isinstance(f, ast.Attribute) and f.attr == "pallas_call") or (
        isinstance(f, ast.Name) and f.id == "pallas_call"
    )


def attr_chain(node: ast.expr) -> str | None:
    """Dotted source form of a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _literal_ints(node: ast.expr) -> tuple[int, ...] | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if not (isinstance(e, ast.Constant) and isinstance(e.value, int)):
                return None
            out.append(e.value)
        return tuple(out)
    return None


class _Indexer(ast.NodeVisitor):
    def __init__(self, index: "ModuleIndex", path: str) -> None:
        self.index = index
        self.path = path
        self.stack: list[str] = []       # qualname parts
        self.cls_stack: list[str] = []
        self.fn_stack: list[FuncKey] = []

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.index.classes[(self.path, node.name)] = node
        self.stack.append(node.name)
        self.cls_stack.append(node.name)
        self.generic_visit(node)
        self.cls_stack.pop()
        self.stack.pop()

    def _visit_func(self, node) -> None:
        qual = ".".join(self.stack + [node.name]) if self.stack else node.name
        info = FuncInfo(
            path=self.path,
            qualname=qual,
            node=node,
            cls=self.cls_stack[-1] if self.cls_stack else None,
            parent=self.fn_stack[-1] if self.fn_stack else None,
            jit_root=any(_jit_in_expr(d) for d in node.decorator_list),
            params=tuple(
                a.arg
                for a in (node.args.posonlyargs + node.args.args
                          + node.args.kwonlyargs)
            ),
        )
        self.index.funcs[info.key] = info
        self.index.by_name.setdefault(node.name, []).append(info)
        # children of a function live under ``qual.<locals>.``
        self.stack.extend([node.name, "<locals>"])
        self.fn_stack.append(info.key)
        self.generic_visit(node)
        self.fn_stack.pop()
        del self.stack[-2:]

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            self.index.imports[self.path][a.asname or a.name.split(".")[0]] = (
                a.name
            )

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        mod = node.module or ""
        for a in node.names:
            self.index.imports[self.path][a.asname or a.name] = (
                f"{mod}.{a.name}" if mod else a.name
            )
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        # name = jax.jit(fn, ...): jit root + optional donation binding
        if isinstance(node.value, ast.Call) and _jit_in_expr(node.value.func):
            call = node.value
            target_fn = call.args[0] if call.args else None
            donate: tuple[int, ...] | None = None
            for kw in call.keywords:
                if kw.arg == "donate_argnums":
                    donate = _literal_ints(kw.value)
            tkey = None
            if target_fn is not None:
                chain = attr_chain(target_fn)
                if chain:
                    tkey = self.index.resolve(self.path, chain,
                                              cls=self.cls_stack[-1]
                                              if self.cls_stack else None)
                    if tkey is not None:
                        self.index.funcs[tkey].jit_root = True
            for t in node.targets:
                tchain = attr_chain(t)
                if tchain and donate:
                    binding = tchain.split(".")[-1]
                    dup = next(
                        (d for d in self.index.donations
                         if (d.path, d.binding, d.positions)
                         == (self.path, binding, donate)),
                        None,
                    )
                    if dup is None:
                        self.index.donations.append(
                            DonationBinding(self.path, binding, donate, tkey)
                        )
                    elif tkey is not None and dup.target is None:
                        dup.target = tkey
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        if _is_pallas_call(node) and node.args:
            self._mark_pallas_kernel(node.args[0])
        self.generic_visit(node)

    def _mark_pallas_kernel(self, kernel_expr: ast.expr) -> None:
        # direct function, or a local ``kernel = functools.partial(f, ...)``
        chain = attr_chain(kernel_expr)
        if isinstance(kernel_expr, ast.Call):  # partial(f, ...) inline
            if kernel_expr.args:
                chain = attr_chain(kernel_expr.args[0])
        if chain is None:
            return
        key = self.index.resolve(self.path, chain)
        if key is None and self.fn_stack:
            # local binding inside the enclosing function
            outer = self.index.funcs[self.fn_stack[-1]].node
            for stmt in ast.walk(outer):
                if not (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1):
                    continue
                t = stmt.targets[0]
                if not (isinstance(t, ast.Name) and t.id == chain):
                    continue
                v = stmt.value
                if isinstance(v, ast.Call) and v.args:
                    inner = attr_chain(v.args[0])
                    if inner:
                        key = self.index.resolve(self.path, inner)
                elif isinstance(v, ast.Name):
                    key = self.index.resolve(self.path, v.id)
        if key is not None:
            self.index.funcs[key].jit_root = True


class ModuleIndex:
    """All parsed files of one checker run."""

    def __init__(self) -> None:
        self.files: dict[str, tuple[str, ast.Module]] = {}
        self.funcs: dict[FuncKey, FuncInfo] = {}
        self.classes: dict[tuple[str, str], ast.ClassDef] = {}
        self.by_name: dict[str, list[FuncInfo]] = {}
        self.imports: dict[str, dict[str, str]] = {}
        self.donations: list[DonationBinding] = []
        self.modname: dict[str, str] = {}       # path -> dotted module
        self.path_of_mod: dict[str, str] = {}
        # dynamic attribute hops the AST can't see (filled from registry)
        self.attr_targets: dict[str, FuncKey] = {}

    # -- building --------------------------------------------------------
    def add_file(self, path: str, source: str, modname: str = "") -> None:
        try:
            tree = ast.parse(source, filename=path)
        except SyntaxError:
            return
        self.files[path] = (source, tree)
        self.imports.setdefault(path, {})
        if modname:
            self.modname[path] = modname
            self.path_of_mod[modname] = path

    def build(self) -> None:
        for path, (_, tree) in self.files.items():
            _Indexer(self, path).visit(tree)
        # second pass: jit rebinds / pallas kernels may reference
        # functions indexed after their own module was walked
        for path, (_, tree) in self.files.items():
            _Rebinder(self, path).visit(tree)

    # -- resolution ------------------------------------------------------
    def resolve(self, path: str, chain: str,
                cls: str | None = None) -> FuncKey | None:
        """Resolve a dotted Name/Attribute chain from *path* to a
        function key, or None."""
        parts = chain.split(".")
        if parts[0] == "self" and len(parts) >= 2:
            if len(parts) == 2 and cls:
                key = (path, f"{cls}.{parts[1]}")
                if key in self.funcs:
                    return key
            # self.x.y / unresolved methods: dynamic hop registry by
            # the last two (then one) dotted parts
            return self._dynamic(parts)
        imp = self.imports.get(path, {})
        # bare name: same module, then import table
        if len(parts) == 1:
            for info in self.by_name.get(parts[0], ()):
                if info.path == path:
                    return info.key
            full = imp.get(parts[0])
            if full:
                mod, _, fn = full.rpartition(".")
                p = self.path_of_mod.get(mod)
                if p and (p, fn) in self.funcs:
                    return (p, fn)
            return None
        # module-attribute: policy.draft_ranks / moe_mod.moe_ffn
        head = imp.get(parts[0])
        if head:
            p = self.path_of_mod.get(head)
            if p:
                key = (p, ".".join(parts[1:]))
                if key in self.funcs:
                    return key
        return self._dynamic(parts)

    def _dynamic(self, parts: list[str]) -> FuncKey | None:
        if len(parts) >= 2:
            key = self.attr_targets.get(".".join(parts[-2:]))
            if key is not None:
                return key
        return self.attr_targets.get(parts[-1])

    # -- graph -----------------------------------------------------------
    def edges_from(self, key: FuncKey) -> set[FuncKey]:
        info = self.funcs[key]
        out: set[FuncKey] = set()
        for node in ast.walk(info.node):
            # nested defs belong to their parent's behaviour
            if (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and node is not info.node):
                k = (info.path, f"{info.qualname}.<locals>.{node.name}")
                if k in self.funcs:
                    out.add(k)
                continue
            if isinstance(node, (ast.Name, ast.Attribute)):
                chain = attr_chain(node)
                if chain is None:
                    continue
                # references count as edges too: callbacks, vmap(f),
                # functools.partial(f), jit rebinds
                k = self.resolve(info.path, chain, cls=info.cls)
                if k is not None and k != key:
                    out.add(k)
        return out

    def reachable(self, entries: list[FuncKey],
                  stops: set[FuncKey] = frozenset()) -> set[FuncKey]:
        seen: set[FuncKey] = set()
        todo = [k for k in entries if k in self.funcs]
        while todo:
            k = todo.pop()
            if k in seen or k in stops:
                continue
            seen.add(k)
            for nxt in self.edges_from(k):
                if nxt not in seen and nxt not in stops:
                    todo.append(nxt)
        return seen

    def jit_entries(self) -> list[FuncKey]:
        return [k for k, f in self.funcs.items() if f.jit_root]


class _Rebinder(ast.NodeVisitor):
    """Second indexing pass: now that every function is known, resolve
    jit rebinds and pallas kernels that point across modules."""

    def __init__(self, index: ModuleIndex, path: str) -> None:
        self.ix = _Indexer(index, path)

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.ix.cls_stack.append(node.name)
        self.generic_visit(node)
        self.ix.cls_stack.pop()

    def _visit_func(self, node) -> None:
        key = None
        for k, f in self.ix.index.funcs.items():
            if f.node is node:
                key = k
                break
        if key:
            self.ix.fn_stack.append(key)
        self.generic_visit(node)
        if key:
            self.ix.fn_stack.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def visit_Assign(self, node: ast.Assign) -> None:
        self.ix.visit_Assign(node)

    def visit_Call(self, node: ast.Call) -> None:
        if _is_pallas_call(node) and node.args:
            self.ix._mark_pallas_kernel(node.args[0])
        self.generic_visit(node)
