"""Static pass driver: file discovery, suppression, R5 hygiene, and
the text/JSON findings report."""
from __future__ import annotations

import json
import os
from dataclasses import asdict

from repro.analysis import registry as default_registry
from repro.analysis.callgraph import ModuleIndex
from repro.analysis.pragmas import PragmaIndex
from repro.analysis.rules import (
    RULE_IDS,
    Finding,
    rule_r1,
    rule_r2,
    rule_r3,
    rule_r4,
)

__all__ = ["Finding", "RULE_IDS", "format_report", "run_static"]


def _modname(path: str) -> str:
    """Dotted module name for import-table resolution: everything
    after the last ``src/`` segment (or the relative path itself)."""
    norm = path.replace(os.sep, "/")
    if "/src/" in norm:
        norm = norm.rsplit("/src/", 1)[1]
    elif norm.startswith("src/"):
        norm = norm[len("src/"):]
    norm = norm.removesuffix(".py").removesuffix("/__init__")
    return norm.strip("/").replace("/", ".")


def discover_files(roots: list[str]) -> list[str]:
    out: list[str] = []
    for root in roots:
        if os.path.isfile(root):
            if root.endswith(".py"):
                out.append(root)
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", ".git", ".ruff_cache")]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    out.append(os.path.join(dirpath, fn))
    return sorted(set(out))


def build_index(paths: list[str],
                reg=default_registry) -> tuple[ModuleIndex, PragmaIndex]:
    index = ModuleIndex()
    pragmas = PragmaIndex()
    for path in paths:
        try:
            with open(path, encoding="utf-8") as f:
                source = f.read()
        except OSError:
            continue
        index.add_file(path, source, modname=_modname(path))
        pragmas.add_file(path, source)
    index.build()
    for name, target in reg.ATTR_TARGETS.items():
        key = _resolve_target(index, target)
        if key is not None:
            index.attr_targets[name] = key
    return index, pragmas


def _resolve_target(index: ModuleIndex, target: tuple[str, str]):
    suffix, qual = target
    for (path, qualname) in index.funcs:
        if qualname == qual and path.endswith(suffix):
            return (path, qualname)
    return None


def run_static(roots: list[str],
               reg=default_registry) -> tuple[list[Finding], list[Finding]]:
    """Run R1-R5 over *roots*.

    Returns ``(unsuppressed, suppressed)`` findings, both sorted.  R5
    findings (malformed/stale pragmas) are never suppressible.
    """
    paths = discover_files(roots)
    index, pragmas = build_index(paths, reg)

    raw: list[Finding] = []
    for rule_fn in (rule_r1, rule_r2, rule_r3, rule_r4):
        raw.extend(rule_fn(index, reg))

    # a nested function is scanned both as itself and inside its
    # parent: keep one finding per physical location
    seen: set[tuple[str, str, int, int]] = set()
    deduped: list[Finding] = []
    for f in sorted(raw, key=lambda f: (f.path, f.line, f.col, f.rule)):
        k = (f.rule, f.path, f.line, f.col)
        if k not in seen:
            seen.add(k)
            deduped.append(f)

    unsuppressed: list[Finding] = []
    suppressed: list[Finding] = []
    for f in deduped:
        if pragmas.suppresses(f.path, f.rule, f.line):
            suppressed.append(f)
        else:
            unsuppressed.append(f)

    # R5: pragma hygiene
    for p in pragmas.all_pragmas():
        complaint = p.malformed
        if complaint is not None:
            unsuppressed.append(Finding(
                "R5", p.path, p.line, 0, f"malformed pragma: {complaint}"))
        elif not p.used_by:
            unsuppressed.append(Finding(
                "R5", p.path, p.line, 0,
                f"stale pragma inv-ok[{','.join(p.rules)}]: no listed rule "
                f"fires on this line any more — delete it"))

    unsuppressed.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return unsuppressed, suppressed


def format_report(unsuppressed: list[Finding], suppressed: list[Finding],
                  *, fmt: str = "text") -> str:
    if fmt == "json":
        return json.dumps(
            {
                "findings": [
                    {**asdict(f), "rule_name": f.rule_name}
                    for f in unsuppressed
                ],
                "suppressed": [
                    {**asdict(f), "rule_name": f.rule_name}
                    for f in suppressed
                ],
                "counts": {
                    rid: sum(1 for f in unsuppressed if f.rule == rid)
                    for rid in RULE_IDS
                },
                "ok": not unsuppressed,
            },
            indent=2,
        )
    lines: list[str] = []
    for f in unsuppressed:
        lines.append(f"{f.path}:{f.line}:{f.col}: {f.rule} "
                     f"[{f.rule_name}] {f.message}")
    if suppressed:
        lines.append(f"-- {len(suppressed)} finding(s) suppressed by "
                     f"justified inv-ok pragmas")
    lines.append(
        f"{len(unsuppressed)} unsuppressed finding(s)"
        if unsuppressed else "invariants clean: 0 unsuppressed findings"
    )
    return "\n".join(lines)
