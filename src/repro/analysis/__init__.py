"""repro.analysis — repo-specific invariant checker.

Static AST rules over the serving/kernel tree plus a runtime sanitizer
lane, both driven by ``tools/check_invariants.py``:

  R1 host-sync       device->host syncs inside the fused-step call graph
  R2 recompile-risk  Python-value-dependent shapes / mutable captures in
                     jit or pallas scopes
  R3 lock-discipline registered shared state mutated without its lock
  R4 donation-safety donated buffers read after the donating call
  R5 pragma-hygiene  stale or unjustified ``# inv-ok[...]`` pragmas

Suppression pragma (justification string is mandatory)::

    x = jax.device_get(acc)   # inv-ok[R1]: the one sanctioned sync

Runtime side (``repro.analysis.sanitizer``): wraps the engine's fused
step in ``jax.transfer_guard("disallow")`` and counts XLA executables
via ``jax.log_compiles`` to assert zero new compiles after warmup.
"""
from .pragmas import Pragma, scan_pragmas
from .report import Finding, format_report, run_static
from .rules import RULE_IDS

__all__ = [
    "Finding",
    "Pragma",
    "RULE_IDS",
    "format_report",
    "run_static",
    "scan_pragmas",
]
