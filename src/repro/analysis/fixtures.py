"""Seeded violation fixtures for the invariant checker's selftest.

One synthetic module per rule, each containing at least one *seeded*
violation (marked with a ``# seeded[R#]`` comment on the offending
line) next to a clean twin that must NOT fire.  The selftest
(``tools/check_invariants.py --selftest`` and tests/test_analysis.py)
writes these to a temp dir, runs the full static pass with the fixture
registry below, and asserts the found (rule, line) set matches the
seeded set exactly — both directions: every seeded line fires, and
nothing unseeded does.

The marker comment is *not* pragma syntax, so it never suppresses the
finding it labels.
"""
from __future__ import annotations

import os
import re
import tempfile
from types import SimpleNamespace

from repro.analysis.registry import LockRule
from repro.analysis.report import run_static

SEED_RE = re.compile(r"#\s*seeded\[(R[1-5])\]")

FIXTURES: dict[str, str] = {
    # R1: host syncs reachable from a registered step-loop entry point.
    "fix_r1.py": '''\
import jax
import numpy as np


class Engine:
    def step(self):
        x = self._compute()
        jax.block_until_ready(x)  # seeded[R1]
        host = np.asarray(self._buf())  # seeded[R1]
        return x.item() + host.sum()  # seeded[R1]

    def warmup(self):
        # registered stop: syncing here is control-plane, not flagged
        jax.block_until_ready(self._compute())

    def _compute(self):
        return jax.numpy.zeros(())

    def _buf(self):
        return jax.numpy.zeros((4,))
''',
    # R2: recompile risk inside a jit root.
    "fix_r2.py": '''\
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("n",))
def good(x, n):
    return x + jnp.arange(n)


@jax.jit
def bad(x, n):
    return x + jnp.arange(n)  # seeded[R2]


@jax.jit
def bad_slice(x, k):
    return x[:k].sum()  # seeded[R2]
''',
    # R3: shared-attr store without the owning lock (inline registry).
    "fix_r3.py": '''\
import threading


class Store:
    _inv_locks_ = {"items": ("_lock",), "count": ("_lock",)}

    def __init__(self):
        self._lock = threading.Lock()
        self.items = []
        self.count = 0

    def good(self, x):
        with self._lock:
            self.items.append(x)
            self.count += 1

    def bad(self, x):
        self.items = [x]  # seeded[R3]
        self.count += 1  # seeded[R3]
''',
    # R4: donated buffer read after the donating call.
    "fix_r4.py": '''\
import jax


def _impl(buf, x):
    return buf + x


step = jax.jit(_impl, donate_argnums=(0,))


def good(buf, x):
    out = step(buf, x)
    buf = out            # rebind before any read: fine
    return buf + 1


def bad(buf, x):
    out = step(buf, x)
    stale = buf + 1  # seeded[R4]
    return out, stale
''',
    # R5: pragma hygiene — stale and malformed pragmas are findings.
    "fix_r5.py": '''\
CLEAN = 1  # inv-ok[R1]: nothing on this line ever fired  # seeded[R5]
BROKEN = 2  # inv-ok[R9]: unknown rule id is malformed  # seeded[R5]
''',
}

FIXTURE_REGISTRY = SimpleNamespace(
    HOST_ENTRIES=(("fix_r1.py", "Engine.step"),),
    HOST_STOPS={("fix_r1.py", "Engine.warmup"): "control-plane fixture"},
    ATTR_TARGETS={},
    LOCK_RULES=(),
    LockRule=LockRule,
    DONATION_RULES=(),
    DONATION_REASSIGNERS={},
)


def seeded_expectations(sources: dict[str, str],
                        base: str) -> set[tuple[str, str, int]]:
    """(rule, path, line) for every ``# seeded[R#]`` marker."""
    out = set()
    for name, src in sources.items():
        for i, line in enumerate(src.splitlines(), start=1):
            for m in SEED_RE.finditer(line):
                out.add((m.group(1), os.path.join(base, name), i))
    return out


def run_selftest() -> tuple[bool, list[str]]:
    """Write the fixtures, run the pass, diff found vs seeded.

    Returns ``(ok, report_lines)``.
    """
    lines: list[str] = []
    with tempfile.TemporaryDirectory(prefix="inv_fixtures_") as tmp:
        for name, src in FIXTURES.items():
            with open(os.path.join(tmp, name), "w") as f:
                f.write(src)
        unsuppressed, _ = run_static([tmp], reg=FIXTURE_REGISTRY)
        found = {(f.rule, f.path, f.line) for f in unsuppressed}
        expected = seeded_expectations(FIXTURES, tmp)

        missing = expected - found
        extra = found - expected
        for rule, path, line in sorted(missing):
            lines.append(f"MISSED  {os.path.basename(path)}:{line} "
                         f"seeded {rule} did not fire")
        for rule, path, line in sorted(extra):
            lines.append(f"SPURIOUS {os.path.basename(path)}:{line} "
                         f"unseeded {rule} fired")
        by_rule = {r: sum(1 for (fr, _, _) in expected if fr == r)
                   for r in ("R1", "R2", "R3", "R4", "R5")}
        lines.append("selftest: " + "  ".join(
            f"{r}x{n}" for r, n in by_rule.items()))
        ok = not missing and not extra
        lines.append("selftest OK: every seeded violation fired, nothing "
                     "else did" if ok else "selftest FAILED")
    return ok, lines
