"""Suppression pragmas for the invariant checker.

Syntax (one per line, trailing comment)::

    expr   # inv-ok[R1]: why this is fine
    expr   # inv-ok[R1,R4]: one justification covering both rules

Design points:

* the justification string after the colon is MANDATORY — an empty one
  is itself a finding (R5), so suppressions always carry intent;
* a pragma that suppresses nothing is a *stale* finding (R5), so
  suppressions cannot rot when the flagged code is later fixed;
* deliberately not ``# noqa`` syntax, so ruff's RUF100 (unused noqa)
  and this checker never fight over each other's comments.

Pragmas are scanned from the raw source (tokenize), not the AST, so
they survive on lines the AST does not attribute exactly.
"""
from __future__ import annotations

import io
import re
import tokenize
from dataclasses import dataclass, field

PRAGMA_RE = re.compile(
    r"#\s*inv-ok\[(?P<rules>[A-Za-z0-9_,\s]*)\]\s*(?::\s*(?P<why>.*))?$"
)


@dataclass
class Pragma:
    """One ``# inv-ok[...]`` comment."""

    path: str
    line: int
    rules: tuple[str, ...]
    justification: str
    used_by: set[str] = field(default_factory=set)

    def covers(self, rule: str, line: int) -> bool:
        return line == self.line and rule in self.rules

    @property
    def malformed(self) -> str | None:
        """Return an R5 complaint string, or None if well-formed."""
        if not self.rules:
            return "pragma lists no rules"
        bad = [r for r in self.rules if not re.fullmatch(r"R[1-5]", r)]
        if bad:
            return f"unknown rule id(s): {', '.join(bad)}"
        if not self.justification.strip():
            return "justification string is mandatory after the colon"
        return None


def scan_pragmas(path: str, source: str) -> list[Pragma]:
    """Extract every inv-ok pragma in *source*, keyed by physical line."""
    out: list[Pragma] = []
    try:
        toks = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in toks:
            if tok.type != tokenize.COMMENT:
                continue
            m = PRAGMA_RE.search(tok.string)
            if not m:
                continue
            rules = tuple(
                r.strip() for r in m.group("rules").split(",") if r.strip()
            )
            out.append(Pragma(
                path=path,
                line=tok.start[0],
                rules=rules,
                justification=(m.group("why") or ""),
            ))
    except tokenize.TokenError:
        pass  # syntactically broken file: the AST pass reports it
    return out


class PragmaIndex:
    """Lookup + usage tracking across one checker run."""

    def __init__(self) -> None:
        self._by_file: dict[str, list[Pragma]] = {}

    def add_file(self, path: str, source: str) -> None:
        self._by_file[path] = scan_pragmas(path, source)

    def suppresses(self, path: str, rule: str, line: int) -> bool:
        for p in self._by_file.get(path, ()):
            if p.covers(rule, line):
                p.used_by.add(f"{rule}:{line}")
                return True
        return False

    def all_pragmas(self) -> list[Pragma]:
        return [p for ps in self._by_file.values() for p in ps]
