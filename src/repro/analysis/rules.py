"""The five invariant rules, as functions over a built ModuleIndex.

Each rule returns *raw* findings; pragma suppression and R5 hygiene
happen in the report layer so a suppressed finding still marks its
pragma as used.

Known static limits (deliberate — the registry names the hops that
matter, and the runtime sanitizer backstops the rest):

* calls through local aliases (``for fn, _ in runs: fn(...)``) are
  invisible to R4;
* mutation of *aliased* objects (``st = self.sched.slots[i];
  st.n_out += 1``) is invisible to R3 — only ``self.<attr>`` chains
  and registered mutator-method calls are tracked;
* nested functions defined inside a lock's ``with`` block are treated
  as running *without* the lock (they usually escape it).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass

from repro.analysis import registry as default_registry
from repro.analysis.callgraph import FuncInfo, FuncKey, ModuleIndex, attr_chain

RULE_IDS: tuple[str, ...] = ("R1", "R2", "R3", "R4", "R5")

_SYNC_NAMES = {
    "R1": "host-sync",
    "R2": "recompile-risk",
    "R3": "lock-discipline",
    "R4": "donation-safety",
    "R5": "pragma-hygiene",
}


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str

    @property
    def rule_name(self) -> str:
        return _SYNC_NAMES.get(self.rule, self.rule)


def _find_key(index: ModuleIndex, suffix: str, qual: str) -> FuncKey | None:
    for (path, qualname) in index.funcs:
        if qualname == qual and path.endswith(suffix):
            return (path, qualname)
    return None


def _np_call(chain: str | None) -> bool:
    return chain in {"np.asarray", "np.array", "numpy.asarray",
                     "numpy.array", "onp.asarray", "onp.array"}


def _shape_derived(node: ast.expr) -> bool:
    """Does the expression mention .shape/.ndim/.size/len() — i.e. is a
    host coercion of it trace-safe?"""
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and n.attr in ("shape", "ndim",
                                                       "size", "dtype"):
            return True
        if (isinstance(n, ast.Call) and isinstance(n.func, ast.Name)
                and n.func.id == "len"):
            return True
    return False


# --------------------------------------------------------------------------
# R1 — host syncs in the fused-step call graph
# --------------------------------------------------------------------------

def _sync_findings(info: FuncInfo, *, traced: bool) -> list[Finding]:
    out: list[Finding] = []
    # int()/float()/bool() tracedness is only *known* at a jit root's
    # own signature: deeper in the graph, params are often static
    # config ints threaded through (d_head, rank_grid, chunk), and
    # flagging those would drown the report in false positives
    coercible = (set(info.params) - {"self"} - _static_params(info)
                 if traced and info.jit_root else set())

    def refs_param(node: ast.expr) -> bool:
        return any(isinstance(n, ast.Name) and n.id in coercible
                   for n in ast.walk(node))

    def add(node: ast.AST, msg: str) -> None:
        out.append(Finding("R1", info.path, node.lineno, node.col_offset,
                           f"{msg} (in {info.qualname})"))

    for node in ast.walk(info.node):
        if not isinstance(node, ast.Call):
            continue
        chain = attr_chain(node.func)
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr == "item" and not node.args):
            add(node, ".item() forces a device->host sync")
        elif chain and chain.endswith("device_get"):
            add(node, "jax.device_get fetches to host")
        elif ((chain and chain.endswith("block_until_ready"))
              or (isinstance(node.func, ast.Attribute)
                  and node.func.attr == "block_until_ready")):
            add(node, "block_until_ready stalls the dispatch pipeline")
        elif _np_call(chain) and node.args:
            arg = node.args[0]
            # host-side graph: a bare Name is usually an already-
            # fetched host value; attribute/subscript/call args are the
            # device-resident reads that sync
            suspicious = isinstance(arg, (ast.Attribute, ast.Subscript,
                                          ast.Call))
            if traced or suspicious:
                add(node, f"{chain} of a device value copies to host")
        elif (isinstance(node.func, ast.Name)
              and node.func.id in ("int", "float", "bool")
              and len(node.args) == 1
              and refs_param(node.args[0])
              and not _shape_derived(node.args[0])):
            add(node, f"{node.func.id}() coercion of traced argument "
                      f"inside a jit scope syncs (or fails to trace)")
    return out


def rule_r1(index: ModuleIndex, reg=default_registry) -> list[Finding]:
    findings: list[Finding] = []
    entries = [k for e in reg.HOST_ENTRIES
               if (k := _find_key(index, *e)) is not None]
    stops = {k for s in reg.HOST_STOPS
             if (k := _find_key(index, *s)) is not None}
    jit_keys = index.reachable(index.jit_entries())
    # the host loop must not cross into traced bodies: those are the
    # jit graph's domain, scanned with the stricter traced rules
    host_keys = index.reachable(entries, stops | jit_keys)
    for key in host_keys - jit_keys:
        findings += _sync_findings(index.funcs[key], traced=False)
    for key in jit_keys:
        findings += _sync_findings(index.funcs[key], traced=True)
    return findings


# --------------------------------------------------------------------------
# R2 — recompile risk inside jit/pallas scopes
# --------------------------------------------------------------------------

def _static_params(info: FuncInfo) -> set[str]:
    """Literal static_argnames/static_argnums from a jit decorator."""
    static: set[str] = set()
    for dec in info.node.decorator_list:
        for n in ast.walk(dec):
            if not isinstance(n, ast.keyword):
                continue
            if n.arg == "static_argnames":
                for c in ast.walk(n.value):
                    if isinstance(c, ast.Constant) and isinstance(c.value, str):
                        static.add(c.value)
            elif n.arg == "static_argnums":
                for c in ast.walk(n.value):
                    if isinstance(c, ast.Constant) and isinstance(c.value, int):
                        if c.value < len(info.params):
                            static.add(info.params[c.value])
    return static


def _mutable_attrs(index: ModuleIndex, path: str, cls: str) -> set[str]:
    """Attributes of *cls* assigned via ``self.X = ...`` outside
    __init__ — reading them in a traced body bakes in a stale value."""
    out: set[str] = set()
    cnode = index.classes.get((path, cls))
    if cnode is None:
        return out
    for meth in cnode.body:
        if not isinstance(meth, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if meth.name == "__init__":
            continue
        for n in ast.walk(meth):
            targets: list[ast.expr] = []
            if isinstance(n, ast.Assign):
                targets = n.targets
            elif isinstance(n, (ast.AugAssign, ast.AnnAssign)):
                targets = [n.target]
            for t in targets:
                for el in (t.elts if isinstance(t, (ast.Tuple, ast.List))
                           else [t]):
                    if (isinstance(el, ast.Attribute)
                            and isinstance(el.value, ast.Name)
                            and el.value.id == "self"):
                        out.add(el.attr)
    return out


def rule_r2(index: ModuleIndex, reg=default_registry) -> list[Finding]:
    findings: list[Finding] = []
    jit_keys = index.reachable(index.jit_entries())
    mutable_cache: dict[tuple[str, str], set[str]] = {}
    for key in jit_keys:
        info = index.funcs[key]
        # param-shape checks need *known* tracedness — only a jit
        # root's own signature gives that; deeper functions receive
        # static config ints too.  Mutable-capture (below) applies to
        # every traced body.
        traced = (set(info.params) - {"self"} - _static_params(info)
                  if info.jit_root else set())

        def bare_traced(e: ast.expr) -> str | None:
            if isinstance(e, ast.Name) and e.id in traced:
                return e.id
            return None

        for node in ast.walk(info.node):
            if isinstance(node, ast.Call):
                chain = attr_chain(node.func)
                shapey = (chain in ("range", "arange", "np.arange")
                          or (chain or "").endswith((".arange", ".zeros",
                                                     ".ones", ".full")))
                if shapey:
                    for a in node.args[:1]:
                        p = bare_traced(a)
                        if p is not None:
                            findings.append(Finding(
                                "R2", info.path, node.lineno,
                                node.col_offset,
                                f"{chain}({p}) over traced value {p!r} "
                                f"recompiles per value (in "
                                f"{info.qualname})"))
            elif isinstance(node, ast.Subscript):
                sl = node.slice
                if isinstance(sl, ast.Slice):
                    for bound in (sl.lower, sl.upper):
                        p = bare_traced(bound) if bound is not None else None
                        if p is not None:
                            findings.append(Finding(
                                "R2", info.path, node.lineno,
                                node.col_offset,
                                f"slice bound {p!r} is a traced value: "
                                f"shape depends on it, recompiling per "
                                f"value (in {info.qualname})"))
            elif (isinstance(node, ast.Attribute)
                  and isinstance(node.ctx, ast.Load) and info.cls):
                ch = attr_chain(node)
                if ch and ch.startswith("self."):
                    attr = ch.split(".")[1]
                    mkey = (info.path, info.cls)
                    if mkey not in mutable_cache:
                        mutable_cache[mkey] = _mutable_attrs(index, *mkey)
                    if attr in mutable_cache[mkey]:
                        findings.append(Finding(
                            "R2", info.path, node.lineno, node.col_offset,
                            f"jitted closure reads self.{attr}, which is "
                            f"reassigned outside __init__: the executable "
                            f"captures a stale value (or silently "
                            f"retraces) (in {info.qualname})"))
    # drop duplicate reads on the same line (chained attributes)
    seen: set[tuple] = set()
    out = []
    for f in findings:
        k = (f.rule, f.path, f.line, f.message)
        if k not in seen:
            seen.add(k)
            out.append(f)
    return out


# --------------------------------------------------------------------------
# R3 — lock discipline
# --------------------------------------------------------------------------

def _inline_lock_rules(index: ModuleIndex, reg) -> list:
    """Classes can self-register via a ``_inv_locks_`` class attr
    (dict literal: attr -> tuple of lock names); fixtures use this."""
    rules = []
    for (path, cls), cnode in index.classes.items():
        locks: set[str] = set()
        attrs: list[str] = []
        for stmt in cnode.body:
            if not (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1):
                continue
            t = stmt.targets[0]
            if not (isinstance(t, ast.Name) and t.id == "_inv_locks_"):
                continue
            if not isinstance(stmt.value, ast.Dict):
                continue
            for k, v in zip(stmt.value.keys, stmt.value.values):
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    attrs.append(k.value)
                    for c in ast.walk(v):
                        if (isinstance(c, ast.Constant)
                                and isinstance(c.value, str)):
                            locks.add(c.value)
        if attrs:
            rules.append(reg.LockRule(
                path_suffix=path, cls=cls, locks=tuple(sorted(locks)),
                attrs=tuple(attrs)))
    return rules


def _with_held(stmt: ast.With, locks: tuple[str, ...]) -> bool:
    for item in stmt.items:
        ch = attr_chain(item.context_expr)
        if ch in {f"self.{lk}" for lk in locks}:
            return True
    return False


def rule_r3(index: ModuleIndex, reg=default_registry) -> list[Finding]:
    findings: list[Finding] = []
    rules = list(reg.LOCK_RULES) + _inline_lock_rules(index, reg)
    for rule in rules:
        matches = [
            (path, cls) for (path, cls) in index.classes
            if cls == rule.cls and path.endswith(rule.path_suffix)
        ]
        for path, cls in matches:
            findings += _check_lock_rule(index, rule, path, cls)
    return findings


def _check_lock_rule(index: ModuleIndex, rule, path: str,
                     cls: str) -> list[Finding]:
    findings: list[Finding] = []
    cnode = index.classes[(path, cls)]
    trusted = set(rule.assume_held) | set(rule.external) | {"__init__"}
    attrs = set(rule.attrs)
    mutators = set(rule.mutator_methods)

    for meth_name, why in rule.external.items():
        if not why.strip():
            findings.append(Finding(
                "R3", path, cnode.lineno, cnode.col_offset,
                f"external method {cls}.{meth_name} has no justification "
                f"in the registry"))

    def scan(node: ast.AST, held: bool, meth: str) -> None:
        if isinstance(node, ast.With):
            inner = held or _with_held(node, rule.locks)
            for s in node.body:
                scan(s, inner, meth)
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            # a closure defined under the lock usually escapes it
            for s in ast.iter_child_nodes(node):
                scan(s, False, meth)
            return
        _check_stmt(node, held, meth)
        for s in ast.iter_child_nodes(node):
            scan(s, held, meth)

    def _check_stmt(node: ast.AST, held: bool, meth: str) -> None:
        hits: list[tuple[ast.AST, str]] = []
        targets: list[ast.expr] = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        elif isinstance(node, ast.Delete):
            targets = list(node.targets)
        for t in targets:
            for el in (t.elts if isinstance(t, (ast.Tuple, ast.List))
                       else [t]):
                base = el
                # subscript store mutates the attr's value too
                while isinstance(base, ast.Subscript):
                    base = base.value
                ch = attr_chain(base)
                if ch and ch.startswith("self."):
                    a = ch.split(".")[1]
                    if a in attrs:
                        hits.append((node, f"store to self.{a}"))
        if isinstance(node, ast.Expr) and isinstance(node.value, ast.Call):
            ch = attr_chain(node.value.func)
            if ch and ch.startswith("self."):
                parts = ch.split(".")
                if (len(parts) >= 3 and parts[1] in attrs
                        and parts[-1] in mutators):
                    hits.append((node, f"{ch}() mutates self.{parts[1]}"))
        if hits and not held:
            lock_s = " or ".join(f"self.{lk}" for lk in rule.locks)
            for n, what in hits:
                findings.append(Finding(
                    "R3", path, n.lineno, n.col_offset,
                    f"{what} without holding {lock_s} "
                    f"(in {cls}.{meth})"))

    for meth in cnode.body:
        if not isinstance(meth, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if meth.name in trusted:
            continue
        for s in meth.body:
            scan(s, False, meth.name)

    # assume_held methods: every intra-class reference must sit under
    # the lock or inside another trusted method
    assumed = set(rule.assume_held)
    if assumed:
        for meth in cnode.body:
            if not isinstance(meth, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue

            def scan_refs(node: ast.AST, held: bool) -> None:
                if isinstance(node, ast.With):
                    inner = held or _with_held(node, rule.locks)
                    for s in node.body:
                        scan_refs(s, inner)
                    return
                ch = attr_chain(node) if isinstance(
                    node, ast.Attribute) else None
                if (ch and ch.startswith("self.")
                        and ch.split(".")[1] in assumed
                        and len(ch.split(".")) == 2):
                    if not held and meth.name not in trusted:
                        findings.append(Finding(
                            "R3", path, node.lineno, node.col_offset,
                            f"{ch} assumes {' or '.join(rule.locks)} is "
                            f"held, but this call site in "
                            f"{cls}.{meth.name} does not hold it"))
                    return
                for s in ast.iter_child_nodes(node):
                    scan_refs(s, held)

            for s in meth.body:
                scan_refs(s, False)
    return findings


# --------------------------------------------------------------------------
# R4 — donation safety
# --------------------------------------------------------------------------

def _donation_specs(index: ModuleIndex, reg):
    """(path predicate, binding name, donated positions) from both the
    registry and literal ``donate_argnums`` bindings the indexer found."""
    specs: list[tuple[str | None, str, tuple[int, ...]]] = []
    for rule in reg.DONATION_RULES:
        for b in rule.bindings:
            specs.append((rule.path_suffix, b, rule.positions))
    for d in index.donations:
        specs.append((d.path, d.binding, d.positions))
    return specs


def _trackable(node: ast.expr) -> str | None:
    """Donated arg expressions worth tracking: plain Name/Attribute
    chains (fresh temporaries can't be read again anyway)."""
    if isinstance(node, (ast.Name, ast.Attribute)):
        return attr_chain(node)
    return None


def rule_r4(index: ModuleIndex, reg=default_registry) -> list[Finding]:
    findings: list[Finding] = []
    specs = _donation_specs(index, reg)
    reassigners = dict(getattr(reg, "DONATION_REASSIGNERS", {}))
    for key, info in index.funcs.items():
        path = info.path
        local = [(b, pos) for (p, b, pos) in specs
                 if p is None or path.endswith(p) or path == p]
        if not local:
            continue
        findings += _scan_donations(info, dict_local={b: pos
                                                      for b, pos in local},
                                    reassigners=reassigners)
    return findings


def _scan_donations(info: FuncInfo, dict_local: dict[str, tuple[int, ...]],
                    reassigners: dict[str, tuple[str, ...]]) -> list[Finding]:
    findings: list[Finding] = []
    # active donated expressions: chain -> (binding, call line)
    active: dict[str, tuple[str, int]] = {}

    # resolve simple local aliases of donating bindings:
    #   step_fn = self._step_mixed if mid else self._step
    # calls through the alias donate the union of both positions
    def _alias_positions(expr: ast.expr) -> tuple[int, ...] | None:
        if isinstance(expr, ast.IfExp):
            a = _alias_positions(expr.body)
            b = _alias_positions(expr.orelse)
            if a is None or b is None:
                return a or b
            return tuple(sorted(set(a) | set(b)))
        ch = attr_chain(expr)
        if ch is not None:
            name = ch.split(".")[-1]
            if (ch in (name, f"self.{name}")) and name in dict_local:
                return dict_local[name]
        return None

    for n in ast.walk(info.node):
        if isinstance(n, ast.Assign) and len(n.targets) == 1 and isinstance(
                n.targets[0], ast.Name):
            pos = _alias_positions(n.value)
            if pos:
                dict_local[n.targets[0].id] = pos

    def chains_in(node: ast.AST, skip: set[int]) -> list[tuple[str, ast.AST]]:
        out = []
        stack = [node]
        while stack:
            n = stack.pop()
            if id(n) in skip:
                continue
            if isinstance(n, (ast.Name, ast.Attribute)):
                ch = attr_chain(n)
                if ch is not None:
                    out.append((ch, n))
                    continue  # don't descend into the chain's parts
            stack.extend(ast.iter_child_nodes(n))
        return out

    def donating_calls(node: ast.AST):
        for n in ast.walk(node):
            if not isinstance(n, ast.Call):
                continue
            ch = attr_chain(n.func)
            if ch is None:
                continue
            name = ch.split(".")[-1]
            base_ok = ch == name or ch == f"self.{name}"
            if base_ok and name in dict_local:
                yield n, name, dict_local[name]
            elif base_ok and name in reassigners:
                yield n, name, None  # reassigner call

    def stores_of(stmt: ast.AST) -> set[str]:
        out: set[str] = set()
        targets: list[ast.expr] = []
        if isinstance(stmt, ast.Assign):
            targets = stmt.targets
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            targets = [stmt.target]
        for t in targets:
            for el in (t.elts if isinstance(t, (ast.Tuple, ast.List))
                       else [t]):
                ch = _trackable(el)
                if ch:
                    out.add(ch)
        return out

    def process_stmt(stmt: ast.AST) -> None:
        calls = list(donating_calls(stmt))
        skip: set[int] = set()
        new_active: list[tuple[str, str, int]] = []
        cleared: set[str] = set()
        for call, name, positions in calls:
            if positions is None:  # reassigner: clears its listed exprs
                cleared |= set(reassigners[name])
                skip.add(id(call.func))
                continue
            for i in positions:
                if i < len(call.args):
                    ch = _trackable(call.args[i])
                    if ch:
                        new_active.append((ch, name, call.lineno))
                        skip.add(id(call.args[i]))
            skip.add(id(call.func))
        # reads of previously-donated exprs anywhere in this statement
        # (the donating call's own args are excluded via ``skip``)
        store_targets = stores_of(stmt)
        skip_targets: set[int] = set()
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                skip_targets.add(id(t))
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            skip_targets.add(id(stmt.target))
        for ch, node in chains_in(stmt, skip | skip_targets):
            if ch in active:
                binding, line = active[ch]
                findings.append(Finding(
                    "R4", info.path, node.lineno, node.col_offset,
                    f"{ch} was donated to self.{binding}(...) on line "
                    f"{line} and read afterwards: on a donating backend "
                    f"the buffer is already invalid "
                    f"(in {info.qualname})"))
        for ch in store_targets | cleared:
            active.pop(ch, None)
        for ch, name, line in new_active:
            active[ch] = (name, line)
        # a store in the same statement (tuple-unpack of the call's
        # results) immediately re-captures the donated buffer
        for ch in store_targets:
            active.pop(ch, None)

    def walk_block(stmts: list[ast.stmt]) -> None:
        # source order; branches share state (over-approximation: a
        # donation in one branch stays active in the next — reads
        # there are still suspicious)
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue  # nested defs analyzed as their own functions
            if isinstance(stmt, (ast.If, ast.While)):
                process_stmt(stmt.test)
                walk_block(stmt.body)
                walk_block(stmt.orelse)
            elif isinstance(stmt, (ast.For, ast.AsyncFor)):
                process_stmt(stmt.iter)
                for ch in stores_of(ast.Assign(targets=[stmt.target],
                                               value=stmt.iter)):
                    active.pop(ch, None)
                walk_block(stmt.body)
                walk_block(stmt.orelse)
            elif isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    process_stmt(item.context_expr)
                walk_block(stmt.body)
            elif isinstance(stmt, ast.Try):
                walk_block(stmt.body)
                for h in stmt.handlers:
                    walk_block(h.body)
                walk_block(stmt.orelse)
                walk_block(stmt.finalbody)
            else:
                process_stmt(stmt)

    walk_block(info.node.body)
    return findings
