"""Runtime sanitizer lane: transfer-guard + compile-count checks on the
serving engine.

Two dynamic invariants the static pass (repro.analysis.rules) cannot
prove are enforced here by actually running the serve smoke workload:

* **transfer guard** — once warm, every fused step executes under
  ``jax.transfer_guard("disallow")``: any implicit host<->device copy
  inside the step dispatch (a stray ``np.asarray`` on a traced output, a
  numpy arg silently uploaded per step) raises immediately instead of
  costing a hidden sync per token.  The guard wraps the compiled step
  callables only — the engine's sanctioned per-step accept/emission
  fetch (``jax.device_get``, an *explicit* transfer) stays legal, and
  control-plane phases (admission, warmup, reset) stay unguarded.

* **compile counting** — with ``jax.log_compiles``, every new XLA
  executable logs one ``"Compiling ..."`` record on the ``jax`` logger.
  The warm phase (construction + warmup + first full run) may compile
  freely; the steady phase then replays a *shape-identical* workload —
  same (prompt_len, max_new) multiset, different token content, seeds
  and sampling mixes — through the reset engine and asserts **zero** new
  executables.  Rank switches, draft/verify steps and mixed
  greedy/top-k/top-p batches must all ride the executables warmup
  already built; a recompile here is a latency cliff in production.

Scenarios:

* ``mixed_sampling`` — adaptive ranks, chunked prefill, greedy + top-k +
  nucleus rows in the same batch;
* ``speculative``   — self-speculative draft/verify with adaptive ranks
  (rank decisions fire mid-stream on both phases);
* ``learned_policy`` — ``mode="learned"``: the policy-net rank decision
  runs device-resident inside the jitted decide executable (untrained
  params — the check is about executables, not reward).

Run::

    PYTHONPATH=src python -m repro.analysis.sanitizer [--json]

Exit status is non-zero if any scenario compiles in steady state or
trips the transfer guard.  benchmarks/serve_bench.py runs this module as
a subprocess and lands the counts in BENCH_serve.json under
``compile_guard``, where benchmarks/check_bench.py gates them exactly.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import logging
import sys

import numpy as np

__all__ = ["CompileCounter", "guard_steps", "run_scenario", "main"]


class CompileCounter(logging.Handler):
    """Count new-executable compilations via the ``jax`` logger.

    Under ``jax.log_compiles(True)`` each cache-miss compilation emits a
    WARNING record whose message starts with ``"Compiling "`` (cache
    hits are silent), so the handler's count is exactly the number of
    new executables built while attached.
    """

    def __init__(self) -> None:
        super().__init__(level=logging.DEBUG)
        self.count = 0
        self.messages: list[str] = []

    def emit(self, record: logging.LogRecord) -> None:
        msg = record.getMessage()
        if msg.startswith("Compiling "):
            self.count += 1
            self.messages.append(msg.split("\n", 1)[0][:200])

    @contextlib.contextmanager
    def attached(self):
        import jax

        # log_compiles raises the relevant jax loggers to emit the
        # per-executable WARNING records; we only listen, never change
        # levels (raising "jax" to DEBUG floods stderr via jax's own
        # handler)
        logger = logging.getLogger("jax")
        logger.addHandler(self)
        try:
            with jax.log_compiles(True):
                yield self
        finally:
            logger.removeHandler(self)


def guard_steps(eng) -> None:
    """Wrap the engine's fused-step callables in a disallow transfer
    guard.  Arguments are evaluated at the call site — *outside* the
    guard — so only the dispatch + execution of the compiled step is
    policed, which is exactly the per-token hot path."""
    import jax

    def _guarded(fn):
        def wrapper(*args, **kwargs):
            with jax.transfer_guard("disallow"):
                return fn(*args, **kwargs)
        return wrapper

    for name in ("_step", "_step_mixed", "_step_spec"):
        fn = getattr(eng, name, None)
        if fn is not None:
            setattr(eng, name, _guarded(fn))


def _workload(n_requests: int, max_new: int, *, seed: int,
              sampling: bool) -> list[dict]:
    """Mixed prompt lengths; shape layout is seed-independent so two
    workloads with different seeds are executable-identical."""
    rnd = np.random.default_rng(seed)
    lens = [8, 12, 16, 24, 12, 16, 8, 24][:n_requests]
    out = []
    for i, ln in enumerate(lens):
        req = dict(rid=i, tokens=rnd.integers(0, 256, ln).astype(np.int32),
                   max_new=max_new, arrival=2 * i)
        if sampling:
            # greedy / top-k / nucleus rows interleaved in one batch
            kind = i % 3
            if kind == 1:
                req.update(temperature=0.8, top_k=8, seed=int(seed + i))
            elif kind == 2:
                req.update(temperature=0.9, top_p=0.9, seed=int(seed + i))
        out.append(req)
    return out


def run_scenario(name: str, *, n_requests: int = 6,
                 max_new: int = 12) -> dict:
    """Warm-then-steady run of one scenario; returns the count dict."""
    import jax

    from repro.configs import get_config
    from repro.configs.base import RankConfig
    from repro.models.api import get_model
    from repro.serve import Request, ServeEngine

    grid = (4, 8, 12, 16)
    mode = "learned" if name == "learned_policy" else "adaptive"
    cfg = get_config("drrl-paper", reduced=True).with_(
        rank=RankConfig(mode=mode, rank_grid=grid, segment_len=8))
    fns = get_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))

    policy_params = None
    if mode == "learned":
        # untrained policy net: executable identity is decided by shapes
        # and structure, not by the weights, so an init tree is exactly
        # as compile-prone as a trained checkpoint
        from repro.core.drrl import feat_dims
        from repro.core.policy import init_policy
        policy_params = init_policy(jax.random.PRNGKey(1),
                                    feat_dims(cfg.rank), len(grid))

    # the observability scenario is the mixed-sampling workload with
    # metrics + span/phase tracing ON: it must add ZERO new executables
    # and ZERO unsanctioned transfers relative to a bare steady loop —
    # the repro.obs contract that hooks are pure host Python
    sampling = name in ("mixed_sampling", "observability")
    kwargs = dict(n_slots=4, max_len=64, page_size=16, segment_len=8,
                  max_new_cap=max_new, prefill_chunk=8)
    if sampling:
        kwargs.update(sampling=True, nucleus=True)
    elif name == "speculative":
        kwargs.update(speculative=True, draft_k=3, draft_rank_frac=0.25)
    if name == "observability":
        kwargs.update(obs_trace=True)

    counter = CompileCounter()
    with counter.attached():
        eng = ServeEngine(cfg, params, policy_params, **kwargs)

        # warm phase: compiles are free here
        for w in _workload(n_requests, max_new, seed=0, sampling=sampling):
            eng.submit(Request(**w))
        eng.warmup()
        eng.run()
        warm = counter.count

        # steady phase: same shapes, different content/seeds/sampling
        # rows — and the fused step now runs under a transfer guard
        eng.reset()
        guard_steps(eng)
        for w in _workload(n_requests, max_new, seed=7, sampling=sampling):
            eng.submit(Request(**w))
        eng.run()
        if name == "observability":
            # the export/read side must be as quiet as the hooks: render
            # every exporter inside the counted steady region (the one
            # device read — rank_telemetry's batched veto fetch — is a
            # plain device_get, never a compile)
            eng.obs.snapshot()
            eng.obs.prometheus()
            eng.obs.chrome_trace()
            eng.obs.rank_telemetry(eng)
        steady = counter.count - warm

    return {
        "scenario": name,
        "warm_executables": warm,
        "steady_new_executables": steady,
        "transfer_guard": "disallow",
        "ok": steady == 0,
        "steady_compiles": counter.messages[warm:],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="serve-engine runtime sanitizer: transfer guard + "
                    "zero-steady-state-compile check")
    ap.add_argument("--json", action="store_true",
                    help="emit the result dict as JSON on stdout")
    ap.add_argument("--scenario",
                    choices=["mixed_sampling", "speculative",
                             "learned_policy", "observability"],
                    action="append",
                    help="run only the named scenario(s); default all")
    args = ap.parse_args(argv)

    scenarios = args.scenario or ["mixed_sampling", "speculative",
                                  "learned_policy", "observability"]
    results = []
    failed = False
    for name in scenarios:
        try:
            res = run_scenario(name)
        except Exception as e:  # transfer guard raises mid-step
            res = {"scenario": name, "ok": False, "error": repr(e)}
        results.append(res)
        failed = failed or not res["ok"]

    out = {"ok": not failed, "scenarios": results}
    if args.json:
        print(json.dumps(out, indent=2))
    else:
        for r in results:
            status = "ok" if r["ok"] else "FAIL"
            detail = (f"warm {r.get('warm_executables', '?')} executables, "
                      f"steady +{r.get('steady_new_executables', '?')}"
                      if "error" not in r else r["error"])
            print(f"{r['scenario']:16s} {status}  {detail}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
