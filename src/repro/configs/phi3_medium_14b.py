"""phi3-medium-14b [dense]: 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352 — RoPE SwiGLU GQA. [arXiv:2404.14219; unverified]"""
from repro.configs.base import ModelConfig, RankConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b", family="dense",
        num_layers=40, d_model=5120, num_heads=40, num_kv_heads=10,
        d_ff=17920, vocab_size=100352, head_dim=128,
        rope_theta=1e4, dtype="bfloat16", param_dtype="bfloat16",
        remat="dots", sharding="fsdp_tp",
        rank=RankConfig(mode="off"),
    )


def reduced_config() -> ModelConfig:
    return full_config().with_(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, dtype="float32", param_dtype="float32",
        remat="none", max_seq_len=128,
        rank=RankConfig(mode="off", rank_grid=(4, 8, 12, 16)),
    )
