"""deepseek-v3-671b [moe]: 61L d_model=7168 128H d_ff(expert)=2048
vocab=129280, MLA, 1 shared + 256 routed top-8, MTP. [arXiv:2412.19437; hf]

First 3 layers dense (d_ff 18432); MoE layers 1 shared + 256 routed experts
(top-8); MLA q_lora 1536 / kv_lora 512 / nope 128 / rope 64 / v 128."""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig, RankConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe",
        num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
        d_ff=2048, vocab_size=129280,
        moe=MoEConfig(num_experts=256, top_k=8, d_expert=2048,
                      num_shared_experts=1, d_shared=2048),
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        first_dense_layers=3, dense_d_ff=18432, mtp_depth=1,
        rope_theta=1e4, dtype="bfloat16", param_dtype="bfloat16",
        remat="full", sharding="fsdp_tp",
        rank=RankConfig(mode="off"),
    )


def reduced_config() -> ModelConfig:
    return full_config().with_(
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=32, vocab_size=256,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=32,
                      num_shared_experts=1, d_shared=32, capacity_factor=2.0),
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
        first_dense_layers=1, dense_d_ff=128, mtp_depth=1,
        dtype="float32", param_dtype="float32", remat="none", max_seq_len=128,
        rank=RankConfig(mode="off", rank_grid=(4, 8, 12, 16)),
    )
