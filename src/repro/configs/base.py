"""Config dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; the paper's
DR-RL technique is configured via ``RankConfig`` and composes with any
attention-bearing family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                  # ffn hidden size per expert
    num_shared_experts: int = 0
    d_shared: int = 0              # ffn hidden of the shared expert(s)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001  # load-balancing loss coefficient


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention dims."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) dims."""
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 128


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64           # rank of the data-dependent decay LoRA
    token_shift: bool = True
    chunk_size: int = 128


@dataclass(frozen=True)
class RankConfig:
    """DR-RL dynamic low-rank attention configuration (the paper's core).

    mode:
      'off'     — full-rank attention (paper baseline 1)
      'fixed'   — static rank ``fixed_rank`` (paper baseline 2, r=32)
      'adaptive'— energy-threshold Adaptive-SVD heuristic (paper baseline 3)
      'random'  — uniform random rank in the grid (paper baseline 4)
      'drrl'    — the RL policy picks the rank (the paper's method)
      'learned' — serving only: the drrl inference path with params trained
                  offline on recorded serving traces
                  (repro.train.serve_policy); requires policy params
    realisation:
      'masked'  — single executable, eigendirections beyond r are zeroed
                  (training / RL-rollout mode; differentiable)
      'static'  — rank baked into the lowered executable (serving buckets)
    """
    mode: str = "off"
    realisation: str = "masked"
    rank_grid: Tuple[int, ...] = (16, 24, 32, 40, 48, 56, 64)
    fixed_rank: int = 32
    energy_threshold: float = 0.90     # Adaptive-SVD NER target
    static_rank: Optional[int] = None  # rank for realisation='static'
    truncate_values: bool = False      # also low-rank the V factor
    segment_len: int = 512             # segment-level adaptation period T
    # perturbation guardrail (Eq. 9-11)
    guardrail: bool = True
    epsilon0: float = 1.0
    anneal_lambda: float = 1e-3
    # reward (Eq. 13)
    alpha: float = 1.0
    beta: float = 0.3
    gamma: float = 0.1
    power_iters: int = 3


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | rwkv | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5
    max_seq_len: int = 32768

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    rank: RankConfig = field(default_factory=RankConfig)

    # encoder-decoder
    num_encoder_layers: int = 0
    # hybrid (zamba2): how many ssm blocks between shared-attention calls
    hybrid_period: int = 2
    # dense layers at the bottom of a MoE stack (deepseek-v3: 3)
    first_dense_layers: int = 0
    dense_d_ff: int = 0
    # multi-token prediction depth (deepseek-v3 MTP)
    mtp_depth: int = 0
    # vlm / audio frontend stub: number of modality-embedding positions
    frontend_positions: int = 0
    mrope: bool = False            # qwen2-vl M-RoPE (3 position streams)
    mrope_sections: Tuple[int, ...] = (16, 24, 24)

    # numerics
    dtype: str = "float32"         # activation/compute dtype
    param_dtype: str = "float32"

    # distribution
    remat: str = "none"            # none | full | dots
    scan_layers: bool = True
    # sharding mode: 'dp' (replicated params), 'tp' (megatron), 'fsdp'
    # (params sharded over data too), 'fsdp_tp'
    sharding: str = "fsdp_tp"
    seq_shard: bool = False        # sequence parallelism for activations
    # perf knobs (hillclimbed in EXPERIMENTS.md §Perf)
    softmax_dtype: str = "float32"   # bf16 halves the s^2 score traffic
    seq_shard_attn: bool = False     # shard attention scores over seq x model
    mesh_axes: Tuple[str, ...] = ()  # ambient mesh axes for constraints
    cache_seq_shard: bool = False    # split-KV decode: cache M over 'model'

    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, h = self.d_model, self.resolved_head_dim()
        nq, nkv = self.num_heads, self.num_kv_heads
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "vlm", "hybrid", "encdec"):
            attn = d * h * (nq + 2 * nkv) + nq * h * d
            ffn = 3 * d * self.d_ff
            per_layer = attn + ffn + 2 * d
        if self.family == "moe":
            if self.mla is not None:
                m = self.mla
                attn = (d * m.q_lora_rank
                        + m.q_lora_rank * nq * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                        + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                        + m.kv_lora_rank * nq * (m.qk_nope_head_dim + m.v_head_dim)
                        + nq * m.v_head_dim * d)
            else:
                attn = d * h * (nq + 2 * nkv) + nq * h * d
            assert self.moe is not None
            moe = self.moe
            expert = 3 * d * moe.d_expert
            shared = 3 * d * moe.d_shared * moe.num_shared_experts
            router = d * moe.num_experts
            per_layer = attn + moe.num_experts * expert + shared + router + 2 * d
        if self.family in ("ssm", "rwkv"):
            # rwkv6-ish: time-mix (5 proj) + channel mix
            per_layer = 5 * d * d + 2 * d * self.d_ff + self.d_ff * d + 2 * d
        total = emb + self.num_layers * per_layer
        if self.family == "hybrid":
            # crude split: ssm blocks + one shared attn block
            assert self.ssm is not None
            d_in = self.ssm.expand * d
            ssm_layer = (2 * d * d_in + d_in * d
                         + 2 * self.ssm.n_groups * self.ssm.d_state * d)
            n_ssm = self.num_layers - self.num_layers // (self.hybrid_period + 1)
            shared = d * h * (nq + 2 * nkv) + nq * h * d + 3 * d * self.d_ff
            total = emb + n_ssm * ssm_layer + shared
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.family != "moe" or self.moe is None:
            return self.n_params()
        moe = self.moe
        expert = 3 * self.d_model * moe.d_expert
        inactive = (moe.num_experts - moe.top_k) * expert * (
            self.num_layers - self.first_dense_layers)
        return self.n_params() - inactive


@dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 32
    seq_len: int = 1024
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    schedule: str = "cosine"        # linear | cosine | constant
    microbatches: int = 1           # gradient accumulation
    grad_compression: str = "none"  # none | bf16
    seed: int = 0
    checkpoint_every: int = 200
    checkpoint_dir: str = "/tmp/repro_ckpt"
    async_checkpoint: bool = True
    log_every: int = 10


# ---------------------------------------------------------------------------
# Assigned input-shape cells (seq_len, global_batch, kind)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPE_CELLS = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)

# archs allowed to run the long_500k cell (sub-quadratic sequence mixing)
LONG_CONTEXT_ARCHS = ("zamba2-7b", "rwkv6-1.6b")
