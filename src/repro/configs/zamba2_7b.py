"""zamba2-7b [hybrid]: 81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000,
ssm_state=64 — Mamba2 + shared attn blocks. [arXiv:2411.15242; unverified]

Realised as 54 Mamba2 blocks + 27 shared-attention invocations (period 2),
total 81 'layers'; the shared block carries per-invocation LoRA adapters."""
from repro.configs.base import ModelConfig, RankConfig, SSMConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="hybrid",
        num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
        d_ff=14336, vocab_size=32000, head_dim=112, hybrid_period=2,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk_size=128),
        rope_theta=1e4, dtype="bfloat16", param_dtype="bfloat16",
        remat="dots", sharding="fsdp_tp",
        rank=RankConfig(mode="off"),
    )


def reduced_config() -> ModelConfig:
    return full_config().with_(
        num_layers=6, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, hybrid_period=2,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                      n_groups=1, chunk_size=16),
        dtype="float32", param_dtype="float32", remat="none", max_seq_len=128,
        rank=RankConfig(mode="off", rank_grid=(4, 8, 12, 16)),
    )
