"""The paper's own experimental config: a GPT-small-scale decoder used for
the Table-1/2/3 and Fig-2/4/5 reproductions (the paper trains on commodity
hardware; r_min=16, r_max=64)."""
from repro.configs.base import ModelConfig, RankConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="drrl-paper", family="dense",
        num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
        d_ff=3072, vocab_size=50257, head_dim=64,
        rope_theta=1e4, dtype="float32", param_dtype="float32",
        sharding="dp",
        rank=RankConfig(mode="drrl", rank_grid=(16, 24, 32, 40, 48, 56, 64),
                        fixed_rank=32, segment_len=512),
    )


def reduced_config() -> ModelConfig:
    return full_config().with_(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, max_seq_len=128,
        rank=RankConfig(mode="drrl", rank_grid=(4, 8, 12, 16), fixed_rank=8,
                        segment_len=32),
    )
