"""seamless-m4t-medium [audio]: 12L d_model=1024 16H (kv=16) d_ff=4096
vocab=256206 — enc-dec, multimodal. [arXiv:2308.11596; hf]

Audio frontend is a STUB per the assignment: input_specs() supplies
precomputed frame embeddings (b, src_len, d_model) to the 12L encoder; the
12L text decoder attends over the encoder memory."""
from repro.configs.base import ModelConfig, RankConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", family="encdec",
        num_layers=12, num_encoder_layers=12,
        d_model=1024, num_heads=16, num_kv_heads=16,
        d_ff=4096, vocab_size=256206, head_dim=64,
        frontend_positions=1024,      # audio frames seen by the encoder
        rope_theta=1e4, dtype="bfloat16", param_dtype="bfloat16",
        remat="dots", sharding="fsdp_tp",
        rank=RankConfig(mode="off"),
    )


def reduced_config() -> ModelConfig:
    return full_config().with_(
        num_layers=2, num_encoder_layers=2, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
        frontend_positions=16,
        dtype="float32", param_dtype="float32", remat="none", max_seq_len=128,
        rank=RankConfig(mode="off", rank_grid=(4, 8, 12, 16)),
    )
