"""internlm2-20b [dense]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544 — GQA. [arXiv:2403.17297; hf]"""
from repro.configs.base import ModelConfig, RankConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b", family="dense",
        num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=16384, vocab_size=92544, head_dim=128,
        rope_theta=1e6, dtype="bfloat16", param_dtype="bfloat16",
        remat="dots", sharding="fsdp_tp",
        rank=RankConfig(mode="off"),
    )


def reduced_config() -> ModelConfig:
    return full_config().with_(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, dtype="float32", param_dtype="float32",
        remat="none", max_seq_len=128,
        rank=RankConfig(mode="off", rank_grid=(4, 8, 12, 16)),
    )
