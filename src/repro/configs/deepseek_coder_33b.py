"""deepseek-coder-33b [dense]: 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256 — llama-arch. [arXiv:2401.14196; hf]"""
from repro.configs.base import ModelConfig, RankConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b", family="dense",
        num_layers=62, d_model=7168, num_heads=56, num_kv_heads=8,
        d_ff=19200, vocab_size=32256, head_dim=128,
        rope_theta=1e5, dtype="bfloat16", param_dtype="bfloat16",
        remat="dots", sharding="fsdp_tp",
        rank=RankConfig(mode="off"),
    )


def reduced_config() -> ModelConfig:
    return full_config().with_(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, dtype="float32", param_dtype="float32",
        remat="none", max_seq_len=128,
        rank=RankConfig(mode="off", rank_grid=(4, 8, 12, 16)),
    )
