"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.configs.base import MoEConfig, ModelConfig, RankConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe",
        num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
        d_ff=512, vocab_size=49155, head_dim=64,
        moe=MoEConfig(num_experts=40, top_k=8, d_expert=512),
        rope_theta=1e4, dtype="bfloat16", param_dtype="bfloat16",
        remat="dots", sharding="fsdp_tp",
        rank=RankConfig(mode="off", rank_grid=(8, 16, 24, 32, 40, 48, 56, 64)),
    )


def reduced_config() -> ModelConfig:
    return full_config().with_(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=32, vocab_size=256,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=32,
                      capacity_factor=2.0),
        dtype="float32", param_dtype="float32", remat="none", max_seq_len=128,
        rank=RankConfig(mode="off", rank_grid=(4, 8, 12, 16)),
    )
