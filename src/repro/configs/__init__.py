"""Config registry: get_config(arch_id[, reduced]) for every assigned arch."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (LONG_CONTEXT_ARCHS, SHAPE_CELLS, MLAConfig,
                                MoEConfig, ModelConfig, RankConfig, RWKVConfig,
                                ShapeCell, SSMConfig, TrainConfig)

_ARCH_MODULES: Dict[str, str] = {
    "zamba2-7b": "repro.configs.zamba2_7b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "qwen2.5-14b": "repro.configs.qwen2_5_14b",
    "deepseek-coder-33b": "repro.configs.deepseek_coder_33b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "phi3-medium-14b": "repro.configs.phi3_medium_14b",
    "rwkv6-1.6b": "repro.configs.rwkv6_1_6b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "drrl-paper": "repro.configs.drrl_paper",
}

ARCH_IDS = tuple(k for k in _ARCH_MODULES if k != "drrl-paper")


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    mod = importlib.import_module(_ARCH_MODULES[arch])
    return mod.reduced_config() if reduced else mod.full_config()


def cells_for(arch: str):
    """The assigned shape cells this arch actually runs (skips documented in
    DESIGN.md section 5): long_500k only for sub-quadratic mixers."""
    out = []
    for cell in SHAPE_CELLS:
        if cell.name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
            continue
        out.append(cell)
    return out
