"""rwkv6-1.6b [ssm]: 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536 —
Finch, data-dependent decay. [arXiv:2404.05892; unverified]

DR-RL is INAPPLICABLE (no QK^T score matrix) — implemented without the
technique per the assignment; see DESIGN.md section Arch-applicability."""
from repro.configs.base import ModelConfig, RankConfig, RWKVConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", family="rwkv",
        num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=7168, vocab_size=65536, head_dim=64,
        rwkv=RWKVConfig(head_dim=64, decay_lora=64, chunk_size=128),
        dtype="bfloat16", param_dtype="bfloat16",
        remat="dots", sharding="fsdp_tp",
        rank=RankConfig(mode="off"),
    )


def reduced_config() -> ModelConfig:
    return full_config().with_(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256,
        rwkv=RWKVConfig(head_dim=16, decay_lora=8, chunk_size=16),
        dtype="float32", param_dtype="float32", remat="none", max_seq_len=128,
        rank=RankConfig(mode="off", rank_grid=(4, 8, 12, 16)),
    )
