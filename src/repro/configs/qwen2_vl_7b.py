"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — M-RoPE, dynamic resolution. [arXiv:2409.12191; hf]

The vision frontend is a STUB per the assignment: input_specs() supplies
precomputed patch embeddings (b, n_patches, d_model) prepended to the text
tokens; positions are the 3-stream (t, h, w) M-RoPE ids."""
from repro.configs.base import ModelConfig, RankConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b", family="vlm",
        num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
        d_ff=18944, vocab_size=152064, head_dim=128, qkv_bias=True,
        rope_theta=1e6, mrope=True, mrope_sections=(16, 24, 24),
        frontend_positions=256,
        dtype="bfloat16", param_dtype="bfloat16",
        remat="dots", sharding="fsdp_tp",
        rank=RankConfig(mode="off"),
    )


def reduced_config() -> ModelConfig:
    return full_config().with_(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, frontend_positions=8,
        mrope_sections=(2, 3, 3),
        dtype="float32", param_dtype="float32", remat="none", max_seq_len=128,
        rank=RankConfig(mode="off", rank_grid=(4, 8, 12, 16)),
    )
