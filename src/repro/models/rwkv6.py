"""RWKV6 'Finch' — attention-free time-mix with data-dependent decay.

DR-RL is inapplicable here (no QK^T score matrix exists) — see DESIGN.md
section Arch-applicability. The sequence mixer is the wkv6 recurrence
  S_t = diag(w_t) S_{t-1} + k_t v_t^T,      y_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
computed in a chunked matmul form for TPU (naive scan oracle in wkv6_naive).
Token-shift mixing and the decay LoRA follow the Finch design.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ModelConfig


def init_rwkv_block(cfg: ModelConfig, rng, dtype) -> Dict[str, jnp.ndarray]:
    d = cfg.d_model
    r = cfg.rwkv.decay_lora
    ks = nn.split_keys(rng, 12)
    return {
        "ln1": jnp.ones((d,), dtype), "ln2": jnp.ones((d,), dtype),
        # token-shift interpolation weights for (r, k, v, w, g)
        "mu": (jax.random.uniform(ks[0], (5, d), jnp.float32) * 0.5).astype(dtype),
        "wr": nn.dense_init(ks[1], d, d, dtype),
        "wk": nn.dense_init(ks[2], d, d, dtype),
        "wv": nn.dense_init(ks[3], d, d, dtype),
        "wg": nn.dense_init(ks[4], d, d, dtype),
        "wo": nn.dense_init(ks[5], d, d, dtype),
        # data-dependent decay LoRA: w_t = exp(-exp(w0 + tanh(x A) B))
        "w0": jnp.full((d,), -2.0, jnp.float32),
        "wA": nn.dense_init(ks[6], d, r, dtype),
        "wB": nn.dense_init(ks[7], r, d, dtype, scale=0.01),
        "u": (jax.random.normal(ks[8], (d,), jnp.float32) * 0.1),
        "ln_x": jnp.ones((d,), dtype),
        # channel-mix
        "mu_c": (jax.random.uniform(ks[9], (2, d), jnp.float32) * 0.5).astype(dtype),
        "ck": nn.dense_init(ks[10], d, cfg.d_ff, dtype),
        "cv": nn.dense_init(ks[11], cfg.d_ff, d, dtype),
        "cr": nn.dense_init(jax.random.fold_in(ks[11], 1), d, d, dtype),
    }


def _token_shift(x, last=None):
    """shift right by one; `last` (b, 1, d) supplies the boundary token."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def wkv6_chunked(r, k, v, w_log, u, head_dim: int, chunk: int,
                 state0=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """r,k,v: (b, l, d); w_log: (b, l, d) = log w_t in (-inf, 0); u: (d,).
    Multi-head with dk = dv = head_dim. Returns (y (b, l, d), final state)."""
    b, l, d = r.shape
    hd = head_dim
    h = d // hd
    pad = (-l) % chunk
    if pad:
        z = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
        r, k, v, w_log = z(r), z(k), z(v), z(w_log)
    L = r.shape[1]
    nc = L // chunk
    shp = (b, nc, chunk, h, hd)
    rc = r.reshape(shp).astype(jnp.float32)
    kc = k.reshape(shp).astype(jnp.float32)
    vc = v.reshape(shp).astype(jnp.float32)
    wc = w_log.reshape(shp).astype(jnp.float32)
    uu = u.reshape(h, hd)

    # cumulative log-decay, exclusive of position i itself: the decay applied
    # between source j and target i (j < i) is sum_{m=j+1..i-1} logw ... the
    # recurrence applies w at each step *before* adding k_t v_t, so the factor
    # from j to i is prod_{m=j+1..i} w_m for the S-part read at time i+1; with
    # the RWKV convention y_t reads S_{t-1}: factor = prod_{m=j+1..t-1} w_m.
    cw = jnp.cumsum(wc, axis=2)                    # inclusive cumsum of logs
    # decay(i<-j) for j<i: exp(cw[i-1] - cw[j])
    cwi = jnp.concatenate([jnp.zeros_like(cw[:, :, :1]), cw[:, :, :-1]], axis=2)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)[None, None, :, :,
                                                          None, None]
    # mask BEFORE exp (see mamba2.ssd_chunked): avoids inf*0 NaN gradients
    delta = jnp.where(mask, cwi[:, :, :, None, :, :]
                      - cw[:, :, None, :, :, :], -jnp.inf)
    dec = jnp.where(mask, jnp.exp(delta), 0.0)     # (b, nc, qi, qj, h, hd)
    scores = jnp.einsum("bcihd,bcijhd,bcjhd->bcijh", rc, dec, kc)
    y_intra = jnp.einsum("bcijh,bcjhd->bcihd", scores, vc)
    # diagonal u-term: y_t += (r_t . (u*k_t)) v_t
    diag = jnp.einsum("bcihd,hd,bcihd->bcih", rc, uu, kc)
    y_intra = y_intra + diag[..., None] * vc

    # chunk state: S_chunk = sum_j diag(prod_{m=j+1..Q} w) k_j v_j^T
    sdec = jnp.exp(cw[:, :, -1:, :, :] - cw)       # (b, nc, q, h, hd)
    s_chunk = jnp.einsum("bcjhd,bcjhe->bchde", kc * sdec, vc)
    chunk_dec = jnp.exp(cw[:, :, -1])              # (b, nc, h, hd)

    def body(S, xs):
        s_c, dec_c = xs
        S_in = S
        S = S * dec_c[..., None] + s_c
        return S, S_in

    S0 = (jnp.zeros((b, h, hd, hd), jnp.float32) if state0 is None
          else state0.astype(jnp.float32))
    S_fin, S_in = jax.lax.scan(
        body, S0, (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(chunk_dec, 1, 0)))
    S_in = jnp.moveaxis(S_in, 0, 1)                # (b, nc, h, dk, dv)
    y_inter = jnp.einsum("bcihd,bchde->bcihe", rc * jnp.exp(cwi), S_in)
    y = (y_intra + y_inter).reshape(b, L, d)[:, :l]
    return y, S_fin


def wkv6_naive(r, k, v, w_log, u, head_dim: int, state0=None):
    """Step-by-step oracle."""
    b, l, d = r.shape
    h, hd = d // head_dim, head_dim
    rr = r.reshape(b, l, h, hd).astype(jnp.float32)
    kk = k.reshape(b, l, h, hd).astype(jnp.float32)
    vv = v.reshape(b, l, h, hd).astype(jnp.float32)
    ww = jnp.exp(w_log.reshape(b, l, h, hd).astype(jnp.float32))
    uu = u.reshape(h, hd)

    def body(S, xs):
        rt, kt, vt, wt = xs
        kv = jnp.einsum("bhd,bhe->bhde", kt, vt)
        y = jnp.einsum("bhd,bhde->bhe", rt, S + uu[None, :, :, None] * kv)
        S = S * wt[..., None] + kv
        return S, y

    S0 = (jnp.zeros((b, h, hd, hd), jnp.float32) if state0 is None
          else state0.astype(jnp.float32))
    S, ys = jax.lax.scan(body, S0, tuple(
        jnp.moveaxis(t, 1, 0) for t in (rr, kk, vv, ww)))
    return jnp.moveaxis(ys, 0, 1).reshape(b, l, d), S


def rwkv_block(cfg: ModelConfig, p, x, *, state=None, single_step=False):
    """x: (b, l, d). state: (shift1, wkv_state, shift2) or None.
    Returns (y, new_state)."""
    rw = cfg.rwkv
    b, l, d = x.shape
    s1 = state[0] if state is not None else None
    S0 = state[1] if state is not None else None
    s2 = state[2] if state is not None else None

    h = nn.rms_norm(x, p["ln1"], cfg.rms_eps)
    hs = _token_shift(h, s1)
    mu = p["mu"].astype(h.dtype)
    mix = lambda i: h * (1 - mu[i]) + hs * mu[i]
    r = nn.linear(mix(0), p["wr"])
    k = nn.linear(mix(1), p["wk"])
    v = nn.linear(mix(2), p["wv"])
    g = nn.linear(mix(4), p["wg"])
    w_log = -jnp.exp(p["w0"] + nn.linear(
        jnp.tanh(nn.linear(mix(3), p["wA"])), p["wB"]).astype(jnp.float32))
    w_log = jnp.clip(w_log, -8.0, -1e-4)

    if single_step:
        y, S = wkv6_naive(r, k, v, w_log, p["u"], rw.head_dim, S0)
    else:
        y, S = wkv6_chunked(r, k, v, w_log, p["u"], rw.head_dim,
                            rw.chunk_size, S0)
    y = nn.rms_norm(y.astype(x.dtype), p["ln_x"], cfg.rms_eps)
    x = x + nn.linear(y * jax.nn.silu(g), p["wo"])

    # channel mix
    h2 = nn.rms_norm(x, p["ln2"], cfg.rms_eps)
    h2s = _token_shift(h2, s2)
    mc = p["mu_c"].astype(h2.dtype)
    mixc = lambda i: h2 * (1 - mc[i]) + h2s * mc[i]
    kk = jnp.square(jax.nn.relu(nn.linear(mixc(0), p["ck"])))
    x = x + jax.nn.sigmoid(nn.linear(mixc(1), p["cr"])) * nn.linear(kk, p["cv"])
    new_state = (h[:, -1:], S, h2[:, -1:])
    return x, new_state


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype):
    d = cfg.d_model
    hd = cfg.rwkv.head_dim
    h = d // hd
    return (jnp.zeros((batch, 1, d), dtype),
            jnp.zeros((batch, h, hd, hd), jnp.float32),
            jnp.zeros((batch, 1, d), dtype))
