"""Zamba2 hybrid: Mamba2 backbone + a single *shared* attention block invoked
periodically (every ``hybrid_period`` mamba blocks) with per-invocation LoRA
adapters on its projections.

DR-RL drives the rank of the shared attention block only (the mamba blocks
are attention-free) — see DESIGN.md section 5. The '81L' layer count =
54 mamba blocks + 27 shared-attention invocations (period 2).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ModelConfig
from repro.models.attention import mhsa
from repro.models.common import scan_or_unroll
from repro.models.mamba2 import init_mamba_block, init_mamba_state, mamba_block
from repro.models.transformer import init_attn, init_ffn, make_rank_ctx
from repro.models import drrl_util


def n_blocks(cfg: ModelConfig) -> Tuple[int, int]:
    """(n_mamba, n_shared_invocations) with n_mamba + n_inv == num_layers."""
    n_inv = cfg.num_layers // (cfg.hybrid_period + 1)
    return cfg.num_layers - n_inv, n_inv


def init_zamba(cfg: ModelConfig, rng) -> Dict[str, Any]:
    dtype = nn.dt(cfg.param_dtype)
    n_mamba, n_inv = n_blocks(cfg)
    k_emb, k_m, k_s, k_l, k_h = jax.random.split(rng, 5)
    lora_rank = 64
    d, dh = cfg.d_model, cfg.resolved_head_dim()
    hq = cfg.num_heads

    def init_lora(k):
        k1, k2 = jax.random.split(k)
        return {
            "a": nn.dense_init(k1, d, lora_rank, dtype),
            "b": nn.dense_init(k2, lora_rank, hq * dh, dtype, scale=0.01),
        }

    return {
        "embed": nn.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "mamba": jax.vmap(lambda k: init_mamba_block(cfg, k, dtype))(
            jax.random.split(k_m, n_mamba)),
        "shared": {
            "attn": init_attn(cfg, k_s, dtype),
            "ffn": init_ffn(cfg, jax.random.fold_in(k_s, 1), dtype),
            "ln1": jnp.ones((d,), dtype),
            "ln2": jnp.ones((d,), dtype),
        },
        # per-invocation LoRA on the q projection (zamba2-style adapters)
        "lora": jax.vmap(init_lora)(jax.random.split(k_l, n_inv)),
        "ln_f": jnp.ones((d,), dtype),
        "lm_head": nn.dense_init(k_h, d, cfg.vocab_size, dtype),
    }


def _shared_attn(cfg, shared, lora, x, positions, rank_ctx, cache, chunked):
    """Shared block with this invocation's LoRA delta on wq."""
    p = dict(shared["attn"])
    p["wq"] = p["wq"] + jnp.einsum("dr,rf->df", lora["a"], lora["b"])
    h, new_cache, aux = mhsa(cfg, p, nn.rms_norm(x, shared["ln1"], cfg.rms_eps),
                             positions, rank_ctx=rank_ctx, cache=cache,
                             chunked=chunked)
    x = x + h
    x = x + nn.swiglu(nn.rms_norm(x, shared["ln2"], cfg.rms_eps),
                      shared["ffn"]["w_gate"], shared["ffn"]["w_up"],
                      shared["ffn"]["w_down"])
    return x, new_cache, aux


def forward_zamba(cfg: ModelConfig, params, tokens, *, positions=None,
                  policy_params=None, rank_rng=None, rl_t=0,
                  collect_aux: str = "none", chunked: bool = False,
                  cache: Optional[dict] = None):
    """Groups of (period mamba blocks + 1 shared-attn invocation), scanned.
    With ``cache`` set, runs a decode step (single/new tokens appended)."""
    dtype = nn.dt(cfg.dtype)
    x = params["embed"][tokens].astype(dtype)
    b, s, _ = x.shape
    n_mamba, n_inv = n_blocks(cfg)
    period = cfg.hybrid_period
    decode = cache is not None
    if positions is None:
        off = cache["len"] if decode else 0
        positions = jnp.broadcast_to(off + jnp.arange(s)[None], (b, s))

    rcfg = cfg.rank
    h_t = None
    if rcfg.mode == "drrl" and policy_params is not None:
        h_t = drrl_util.conv_feats(x, policy_params)
    rank_ctx0 = make_rank_ctx(cfg, policy_params=policy_params, rng=rank_rng,
                              t=rl_t, h_t=h_t)

    # group the stacked mamba params: (n_inv, period, ...)
    mamba_grouped = jax.tree_util.tree_map(
        lambda a: a[:n_inv * period].reshape((n_inv, period) + a.shape[1:]),
        params["mamba"])

    def group_body(carry, xs):
        x, prev_rank = carry
        mg, lora, gi, conv_st, ssm_st, ck, cv = xs

        def mamba_body(x, ms):
            mp, cst, sst = ms
            x, nc, ns = mamba_block(cfg, mp, x,
                                    conv_state=cst if decode else None,
                                    ssm_state=sst if decode else None,
                                    single_step=decode and s == 1)
            return x, (nc, ns)

        x, (ncs, nss) = scan_or_unroll(mamba_body, x, (mg, conv_st, ssm_st),
                                       unroll=not cfg.scan_layers)

        rank_ctx = None
        if rank_ctx0 is not None:
            rank_ctx = dict(rank_ctx0, prev_rank=prev_rank, layer_id=gi,
                            w_t=(drrl_util.wstats(params["shared"]["attn"],
                                                  rcfg.power_iters)
                                 if rcfg.mode == "drrl" else None))
        layer_cache = {"k": ck, "v": cv, "len": cache["len"]} if decode else None
        x, new_cache, aux = _shared_attn(cfg, params["shared"], lora, x,
                                         positions, rank_ctx, layer_cache,
                                         chunked)
        new_prev = aux.get("rank", prev_rank)
        ys = {"conv": ncs, "ssm": nss}
        if decode:
            ys["k"], ys["v"] = new_cache["k"], new_cache["v"]
        if collect_aux != "none" and "rank" in aux:
            ys["rank"] = aux["rank"]
        return (x, new_prev), ys

    if decode:
        conv_st, ssm_st = cache["conv"], cache["ssm"]
        ck, cv = cache["k"], cache["v"]
    else:
        c0, s0 = init_mamba_state(cfg, b, dtype)
        conv_st = jnp.broadcast_to(c0[None], (n_mamba,) + c0.shape)
        ssm_st = jnp.broadcast_to(s0[None], (n_mamba,) + s0.shape)
        dh = cfg.resolved_head_dim()
        ck = jnp.zeros((n_inv, b, 0, cfg.num_kv_heads, dh), dtype)
        cv = jnp.zeros((n_inv, b, 0, cfg.num_kv_heads, dh), dtype)

    conv_g = conv_st.reshape((n_inv, period) + conv_st.shape[1:])
    ssm_g = ssm_st.reshape((n_inv, period) + ssm_st.shape[1:])
    prev0 = jnp.full((b, cfg.num_kv_heads), rcfg.rank_grid[-1], jnp.int32)
    (x, _), ys = scan_or_unroll(
        group_body, (x, prev0),
        (mamba_grouped, params["lora"], jnp.arange(n_inv), conv_g, ssm_g,
         ck, cv), unroll=not cfg.scan_layers)

    x = nn.rms_norm(x, params["ln_f"], cfg.rms_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    new_cache = None
    if decode:
        new_cache = {
            "conv": ys["conv"].reshape(conv_st.shape),
            "ssm": ys["ssm"].reshape(ssm_st.shape),
            "k": ys["k"], "v": ys["v"], "len": cache["len"] + s,
        }
    return logits, {"cache": new_cache,
                    "ranks": ys.get("rank") if isinstance(ys, dict) else None}


def init_cache_zamba(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dtype = nn.dt(cfg.dtype)
    n_mamba, n_inv = n_blocks(cfg)
    c0, s0 = init_mamba_state(cfg, batch, dtype)
    dh = cfg.resolved_head_dim()
    return {
        "conv": jnp.broadcast_to(c0[None], (n_mamba,) + c0.shape),
        "ssm": jnp.broadcast_to(s0[None], (n_mamba,) + s0.shape),
        "k": jnp.zeros((n_inv, batch, max_len, cfg.num_kv_heads, dh), dtype),
        "v": jnp.zeros((n_inv, batch, max_len, cfg.num_kv_heads, dh), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def loss_zamba(cfg: ModelConfig, params, batch, **kw):
    from repro.dist.ctx import logits_spec
    logits, _ = forward_zamba(cfg, params, batch["tokens"], **kw)
    return nn.softmax_cross_entropy(logits, batch["labels"],
                                    batch.get("mask"),
                                    spec=logits_spec(cfg)), {}
