"""RWKV6 language model: stacked Finch blocks under scan."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ModelConfig
from repro.models.common import scan_or_unroll
from repro.models.rwkv6 import init_rwkv_block, init_rwkv_state, rwkv_block


def init_rwkv_lm(cfg: ModelConfig, rng) -> Dict[str, Any]:
    dtype = nn.dt(cfg.param_dtype)
    k_emb, k_l, k_h = jax.random.split(rng, 3)
    return {
        "embed": nn.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "layers": jax.vmap(lambda k: init_rwkv_block(cfg, k, dtype))(
            jax.random.split(k_l, cfg.num_layers)),
        "ln_f": jnp.ones((cfg.d_model,), dtype),
        "lm_head": nn.dense_init(k_h, cfg.d_model, cfg.vocab_size, dtype),
    }


def forward_rwkv(cfg: ModelConfig, params, tokens, *, state=None,
                 single_step=False, **_ignored):
    """state: stacked per-layer (shift1, S, shift2) or None (training)."""
    dtype = nn.dt(cfg.dtype)
    x = params["embed"][tokens].astype(dtype)

    def body(x, xs):
        lp, st = xs
        x, new_st = rwkv_block(cfg, lp, x, state=st, single_step=single_step)
        return x, new_st

    if state is None:
        b = tokens.shape[0]
        s0 = init_rwkv_state(cfg, b, dtype)
        state = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (cfg.num_layers,) + a.shape), s0)
    x, new_state = scan_or_unroll(body, x, (params["layers"], state),
                                  unroll=not cfg.scan_layers)
    x = nn.rms_norm(x, params["ln_f"], cfg.rms_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits, new_state


def loss_rwkv(cfg: ModelConfig, params, batch, **kw):
    from repro.dist.ctx import logits_spec
    logits, _ = forward_rwkv(cfg, params, batch["tokens"])
    return nn.softmax_cross_entropy(logits, batch["labels"],
                                    batch.get("mask"),
                                    spec=logits_spec(cfg)), {}


def init_cache_rwkv(cfg: ModelConfig, batch: int) -> Any:
    dtype = nn.dt(cfg.dtype)
    s0 = init_rwkv_state(cfg, batch, dtype)
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (cfg.num_layers,) + a.shape), s0)


def decode_step_rwkv(cfg: ModelConfig, params, cache, tokens):
    logits, new_state = forward_rwkv(cfg, params, tokens, state=cache,
                                     single_step=tokens.shape[1] == 1)
    return logits, new_state
