"""Dense decoder-only transformer family (llama/qwen/internlm/phi3 style),
plus the Qwen2-VL backbone (M-RoPE) — scan-over-layers with stacked params.

Also hosts the generic FFN/MoE block dispatch used by the MoE family.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ModelConfig
from repro.core import drrl
from repro.models import moe as moe_mod
from repro.models.attention import mhsa


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_attn(cfg: ModelConfig, rng, dtype) -> Dict[str, jnp.ndarray]:
    d, dh = cfg.d_model, cfg.resolved_head_dim()
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    ks = nn.split_keys(rng, 4)
    p = {
        "wq": nn.dense_init(ks[0], d, hq * dh, dtype),
        "wk": nn.dense_init(ks[1], d, hkv * dh, dtype),
        "wv": nn.dense_init(ks[2], d, hkv * dh, dtype),
        "wo": nn.dense_init(ks[3], hq * dh, d, dtype,
                            scale=(hq * dh) ** -0.5 / (2 * cfg.num_layers) ** 0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * dh,), dtype)
        p["bk"] = jnp.zeros((hkv * dh,), dtype)
        p["bv"] = jnp.zeros((hkv * dh,), dtype)
    return p


def init_ffn(cfg: ModelConfig, rng, dtype, d_ff: Optional[int] = None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = nn.split_keys(rng, 3)
    return {
        "w_gate": nn.dense_init(ks[0], d, f, dtype),
        "w_up": nn.dense_init(ks[1], d, f, dtype),
        "w_down": nn.dense_init(ks[2], f, d, dtype,
                                scale=f ** -0.5 / (2 * cfg.num_layers) ** 0.5),
    }


def init_layer(cfg: ModelConfig, rng, dtype) -> Dict[str, Any]:
    k1, k2 = jax.random.split(rng)
    p = {
        "attn": init_attn(cfg, k1, dtype),
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
    }
    if cfg.family == "moe" and cfg.moe is not None:
        p["moe"] = moe_mod.init_moe(cfg, k2, dtype)
    else:
        p["ffn"] = init_ffn(cfg, k2, dtype)
    return p


def init_dense(cfg: ModelConfig, rng) -> Dict[str, Any]:
    dtype = nn.dt(cfg.param_dtype)
    k_emb, k_layers, k_head = jax.random.split(rng, 3)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    # always stacked: scan consumes them directly; the unrolled path
    # (scan_layers=False, used by the roofline calibration) slices per layer
    layers = jax.vmap(lambda k: init_layer(cfg, k, dtype))(layer_keys)
    params = {
        "embed": nn.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "layers": layers,
        "ln_f": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = nn.dense_init(k_head, cfg.d_model, cfg.vocab_size, dtype)
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _block(cfg: ModelConfig, lp, x, positions, rank_ctx, cache, chunked):
    h, new_cache, aux = mhsa(cfg, lp["attn"], nn.rms_norm(x, lp["ln1"], cfg.rms_eps),
                             positions, rank_ctx=rank_ctx, cache=cache,
                             chunked=chunked)
    x = x + h
    if cfg.family == "moe" and cfg.moe is not None and "moe" in lp:
        f, moe_aux = moe_mod.moe_ffn(cfg, lp["moe"], nn.rms_norm(x, lp["ln2"], cfg.rms_eps))
        aux = {**aux, **moe_aux}
    else:
        f = nn.swiglu(nn.rms_norm(x, lp["ln2"], cfg.rms_eps),
                      lp["ffn"]["w_gate"], lp["ffn"]["w_up"], lp["ffn"]["w_down"])
    return x + f, new_cache, aux


def _aux_slim(aux: Dict[str, Any], collect: str) -> Dict[str, Any]:
    """Select which per-layer aux to stack through scan.
    collect: 'none' | 'ranks' | 'rl' (everything PPO needs)."""
    if collect == "none":
        return {}
    keep = {"rank", "delta_a_rel", "fidelity", "aux_loss"}
    if collect == "rl":
        keep |= {"action_idx", "logp", "value", "action_mask", "features",
                 "logits", "delta_a_grid", "delta_a_norm", "k_s2", "qkv",
                 "mass"}
    return {k: v for k, v in aux.items() if k in keep}


def make_rank_ctx(cfg: ModelConfig, *, policy_params=None, rng=None, t=0,
                  greedy=True, compute_fidelity=False, h_t=None,
                  collect_qkv=False, collect_mass=False, mass_q_len=None):
    """Build the per-forward rank context (None when mode == 'off', unless
    qkv/mass capture is requested — the serve prefill collects per-layer
    k/v and the per-key attention mass from the untouched full-rank
    forward)."""
    rcfg = cfg.rank
    if rcfg.mode == "off":
        if collect_qkv or collect_mass:
            return {"cfg": rcfg, "rng": rng, "t": t,
                    "compute_fidelity": False, "collect_qkv": collect_qkv,
                    "collect_mass": collect_mass, "mass_q_len": mass_q_len}
        return None
    ctx: Dict[str, Any] = {"cfg": rcfg, "rng": rng, "t": t,
                           "compute_fidelity": compute_fidelity,
                           "collect_qkv": collect_qkv,
                           "collect_mass": collect_mass,
                           "mass_q_len": mass_q_len}
    if rcfg.mode == "performer":
        from repro.core.baselines import orthogonal_proj
        dh = cfg.resolved_head_dim()
        m = max(2 * dh, 4 * rcfg.fixed_rank)
        ctx["proj"] = orthogonal_proj(jax.random.PRNGKey(42), cfg.num_heads,
                                      m, dh)
    if rcfg.mode == "drrl":
        assert policy_params is not None, "drrl mode needs policy params"
        if h_t is None:
            raise ValueError("drrl mode: pass h_t (conv features) via forward")
        ctx["action_fn"] = drrl.make_action_fn(policy_params, rcfg,
                                               h_t=h_t, greedy=greedy)
    return ctx


def forward_dense(cfg: ModelConfig, params, tokens, *, positions=None,
                  policy_params=None, rank_rng=None, rl_t=0, greedy=True,
                  compute_fidelity=False, collect_aux: str = "none",
                  chunked: bool = False, collect_qkv: bool = False,
                  collect_mass: bool = False, mass_q_len=None,
                  return_hidden: bool = False,
                  extra_embeddings: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """tokens: (b, s) int32 (or (b, s_txt) with extra_embeddings prepended for
    the VLM/audio stub). Returns (logits (b, s, V), aux)."""
    dtype = nn.dt(cfg.dtype)
    x = params["embed"][tokens].astype(dtype)
    if extra_embeddings is not None:
        x = jnp.concatenate([extra_embeddings.astype(dtype), x], axis=1)
    b, s, _ = x.shape
    if positions is None:
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        positions = jnp.broadcast_to(pos[:, None], (b, 3, s)) if cfg.mrope else pos

    rcfg = cfg.rank
    h_t = None
    if rcfg.mode == "drrl":
        h_t = drrl.conv_features(x, policy_params["conv"])
    rank_ctx0 = make_rank_ctx(cfg, policy_params=policy_params, rng=rank_rng,
                              t=rl_t, greedy=greedy,
                              compute_fidelity=compute_fidelity, h_t=h_t,
                              collect_qkv=collect_qkv,
                              collect_mass=collect_mass,
                              mass_q_len=mass_q_len)

    def body(carry, xs):
        x, prev_rank, key = carry
        lp, li = xs
        rank_ctx = None
        if rank_ctx0 is not None:
            sub = None
            if key is not None:
                key, sub = jax.random.split(key)
            rank_ctx = dict(rank_ctx0, prev_rank=prev_rank, layer_id=li,
                            rng=sub,
                            w_t=(drrl.weight_stats(lp["attn"], rcfg.power_iters)
                                 if rcfg.mode == "drrl" else None))
        x, _, aux = _block(cfg, lp, x, positions, rank_ctx, None, chunked)
        new_prev = aux.get("rank", prev_rank)
        return (x, new_prev, key), _aux_slim(aux, collect_aux)

    prev0 = jnp.full((b, cfg.num_kv_heads), rcfg.rank_grid[-1], jnp.int32)
    key0 = rank_rng
    body_fn = body
    if cfg.remat != "none":
        body_fn = jax.checkpoint(
            body, policy=(jax.checkpoint_policies.checkpoint_dots
                          if cfg.remat == "dots" else None))
    from repro.models.common import scan_or_unroll
    (x, _, _), aux_layers = scan_or_unroll(
        body_fn, (x, prev0, key0),
        (params["layers"], jnp.arange(cfg.num_layers)),
        unroll=not cfg.scan_layers)
    if aux_layers is None:
        aux_layers = {}

    x = nn.rms_norm(x, params["ln_f"], cfg.rms_eps)
    head = params.get("lm_head", None)
    logits = (jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
              if head is not None else
              jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype)))
    out_aux: Dict[str, Any] = {"layers": aux_layers}
    if return_hidden:
        out_aux["hidden"] = x
    return logits, out_aux


def loss_dense(cfg: ModelConfig, params, batch, **kw):
    from repro.dist.ctx import logits_spec
    logits, aux = forward_dense(cfg, params, batch["tokens"], **kw)
    n_txt = batch["labels"].shape[1]
    logits = logits[:, -n_txt:]
    loss = nn.softmax_cross_entropy(logits, batch["labels"],
                                    batch.get("mask"),
                                    spec=logits_spec(cfg))
    if aux["layers"] and "aux_loss" in aux["layers"]:
        loss = loss + jnp.mean(aux["layers"]["aux_loss"])
    return loss, aux


# ---------------------------------------------------------------------------
# Decode path (KV caches stacked over layers)
# ---------------------------------------------------------------------------

def init_cache_dense(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dtype = nn.dt(cfg.dtype)
    dh = cfg.resolved_head_dim()
    L = cfg.num_layers
    return {
        "k": jnp.zeros((L, batch, max_len, cfg.num_kv_heads, dh), dtype),
        "v": jnp.zeros((L, batch, max_len, cfg.num_kv_heads, dh), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def decode_step_dense(cfg: ModelConfig, params, cache, tokens, *,
                      positions=None, policy_params=None, rank_rng=None,
                      rl_t=0, chunked: bool = False):
    """One decode step: tokens (b, s_new) appended at cache['len'].
    Returns (logits (b, s_new, V), new_cache)."""
    dtype = nn.dt(cfg.dtype)
    x = params["embed"][tokens].astype(dtype)
    b, s, _ = x.shape
    if positions is None:
        pos = cache["len"] + jnp.arange(s)[None]
        pos = jnp.broadcast_to(pos, (b, s))
        positions = jnp.broadcast_to(pos[:, None], (b, 3, s)) if cfg.mrope else pos

    rcfg = cfg.rank
    h_t = None
    if rcfg.mode == "drrl" and policy_params is not None:
        h_t = drrl.conv_features(x, policy_params["conv"])
    rank_ctx0 = make_rank_ctx(cfg, policy_params=policy_params, rng=rank_rng,
                              t=rl_t, greedy=True, h_t=h_t)

    def body(carry, xs):
        x, prev_rank = carry
        lp, li, ck, cv = xs
        layer_cache = {"k": ck, "v": cv, "len": cache["len"]}
        rank_ctx = None
        if rank_ctx0 is not None:
            rank_ctx = dict(rank_ctx0, prev_rank=prev_rank, layer_id=li,
                            w_t=(drrl.weight_stats(lp["attn"], rcfg.power_iters)
                                 if rcfg.mode == "drrl" else None))
        x, new_cache, aux = _block(cfg, lp, x, positions, rank_ctx,
                                   layer_cache, chunked)
        return (x, aux.get("rank", prev_rank)), (new_cache["k"], new_cache["v"])

    prev0 = jnp.full((b, cfg.num_kv_heads), rcfg.rank_grid[-1], jnp.int32)
    from repro.models.common import scan_or_unroll
    (x, _), (nk, nv) = scan_or_unroll(
        body, (x, prev0),
        (params["layers"], jnp.arange(cfg.num_layers), cache["k"], cache["v"]),
        unroll=not cfg.scan_layers)
    x = nn.rms_norm(x, params["ln_f"], cfg.rms_eps)
    head = params.get("lm_head", None)
    logits = (jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
              if head is not None else
              jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype)))
    return logits, {"k": nk, "v": nv, "len": cache["len"] + s}


def decode_step_paged(cfg: ModelConfig, params, pool_k, pool_v, page_table,
                      tokens, *, slot_lens, slot_ranks=None, basis=None,
                      active=None, use_kernel: bool = False,
                      kt_pool=None, mass_pool=None,
                      q_lens=None, prefill_rows=None,
                      return_all_logits: bool = False,
                      mass_defer: bool = False):
    """One fused decode step over every serving slot of a slot-paged cache
    (repro.serve): heterogeneous streams share ONE executable.

    pool_k/pool_v: (L, P, page_size, hkv, dh) shared page pools;
    page_table: (n_slots, pages_per_slot) physical page ids (page 0 is the
    scratch page); tokens: (n_slots, C) int32 (C = 1 for pure decode);
    slot_lens: (n_slots,) valid prefix length per slot BEFORE this step;
    slot_ranks: (n_slots,) rank bucket per slot with basis
    (L, n_slots, hkv, dh, r_max) the per-slot segment eigenbases (both
    None only for rank mode 'off'); active: (n_slots,) bool — inactive
    rows write to the scratch page and their logits are garbage the
    engine ignores.

    **Chunked prefill** (repro.serve.api): with C > 1 each row carries a
    block of query tokens. ``q_lens`` (n_slots,) gives the number of valid
    queries per row (1 for decode rows, up to C for a mid-prefill row's
    prompt chunk) and ``prefill_rows`` (n_slots,) bool marks rows that are
    mid-prefill: those attend **full-rank dense** (their segment basis
    does not exist yet; one-shot-prefill parity requires the untouched
    forward), causally within the chunk, while decode rows in the same
    executable keep the factor-projected rank path — the two score reads
    are built at head-dim width (factor columns zero-padded, adding exact
    0.0 terms) and selected per row. Returned logits are the **last valid
    query's** per row: the next decode token for decode rows, the first
    generated token for a row finishing its prompt, garbage mid-prompt.

    Per-row dynamic shape is expressed statically: per-(row, query) kv_len
    feeds the attention mask (or the per-row flash-decode kernel when
    ``use_kernel``), and per-row rank is factor padding + rank masking —
    the projected q factors are padded to r_max columns with columns beyond
    the slot's rank zeroed, so the widened score contraction only adds
    exact zeros. No spectral solve happens here: the basis is refreshed by
    the segment decision (Eq. 12).

    ``kt_pool`` (L, n_slots + 1, M, hkv, r_max), when given, is the K
    cache in factor form kt = K . B_r under each slot's segment basis:
    the score contraction then reads the factors (r_max/d of the dense K
    bytes) instead of gathering + projecting dense K. Unlike K/V the
    factors are **slot-indexed**, not paged: they depend on the slot's
    own basis, so two slots sharing a physical prefix page (serve/prefix)
    hold different factors of the same keys. Row n_slots is a scratch row
    absorbing dead-lane / padding-column writes. New tokens' factors are
    appended in-graph; dense K is still written (basis refresh / drift
    need it) but not read there. A mid-prefill row's appended factors are
    placeholders — its first segment decision re-projects the whole slot
    before any factor read.

    ``mass_pool`` (L, n_slots, M, hkv), when given, accumulates each
    key's received softmax mass in-graph (group-mean over the q heads of
    each kv head): the weighted-Gram input of the next segment decision.
    Also slot-indexed: mass is per-*stream* state (which queries
    attended), so a shared prefix page receives different mass from each
    sharing slot. A prefill chunk's queries add their causal mass over
    the full prefix — chunk-by-chunk accumulation reproduces the one-shot
    prompt seed, so the weighted basis still sees the whole prompt's
    mass. A cell is reset in-graph the step its position is appended
    (before the add), so recycled slots never leak a previous occupant's
    mass; a prefix-hit slot's matched region is instead re-seeded from
    the tree snapshot at admission and only ever added to here.

    **Speculative verify** (repro.serve.spec): ``return_all_logits`` keeps
    every query's logits — (n_slots, C, V) instead of the last valid
    query's — so one chunked step can score a row's whole draft run.
    ``mass_defer`` replaces the in-graph mass accumulate with per-query
    contributions returned under pools["mass_q"] (L, n_slots, C, M, hkv):
    the caller applies only the accepted prefix's queries after the
    accept length is known, so rejected drafts never pollute the
    weighted-Gram state feeding the next segment decision (Eq. 9 veto
    must see accepted tokens only). Causality makes the deferred sum of
    accepted queries bitwise equal to the sequential one-token updates.

    Returns (logits (n_slots, 1, V), pools) with pools a dict holding the
    updated ``k``/``v`` pools plus ``kt``/``mass`` when those were given.
    """
    from repro.models.attention import attend
    from repro.models.common import apply_rope, repeat_kv
    if cfg.mrope:
        raise ValueError("paged decode does not support M-RoPE streams")
    if (slot_ranks is None) != (basis is None):
        raise ValueError("slot_ranks and basis must be given together")
    if (kt_pool is not None or mass_pool is not None) and slot_ranks is None:
        raise ValueError("kt_pool/mass_pool require the rank path")
    if mass_defer and slot_ranks is None:
        raise ValueError("mass_defer requires the rank path")
    if mass_defer and mass_pool is not None:
        raise ValueError("mass_defer and mass_pool are mutually exclusive: "
                         "deferred contributions are applied by the caller")
    dtype = nn.dt(cfg.dtype)
    x = params["embed"][tokens].astype(dtype)
    ns, C = tokens.shape
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    dh = cfg.resolved_head_dim()
    d = cfg.d_model
    n_rep = hq // hkv
    ps = pool_k.shape[2]
    n_pp = page_table.shape[1]
    M = n_pp * ps
    rcfg = cfg.rank
    # ``mixed`` is trace-time static: the pure-decode executable keeps the
    # lean factor-only read path; the mixed executable builds both score
    # reads and selects per row
    mixed = prefill_rows is not None
    if active is None:
        active = jnp.ones((ns,), bool)
    if q_lens is None:
        q_lens = jnp.ones((ns,), jnp.int32)
    is_pf = (jnp.zeros((ns,), bool) if prefill_rows is None
             else prefill_rows & active)
    j_idx = jnp.arange(C)[None, :]                            # (1, C)
    positions = slot_lens[:, None] + j_idx                    # (ns, C)
    # physical write coordinates for the new tokens (scratch for dead
    # lanes and for padding columns beyond a row's q_len)
    write_ok = (j_idx < q_lens[:, None]) & active[:, None]
    pg = jnp.minimum(positions // ps, n_pp - 1)
    phys = jnp.where(write_ok, jnp.take_along_axis(page_table, pg, axis=1), 0)
    off = jnp.where(write_ok, positions % ps, 0)
    kv_end = slot_lens + q_lens                               # keys after write
    # per-(row, query) visible length; padding queries clamp to the last
    # valid query's window so no softmax row is ever fully masked
    kv_len_q = (slot_lens[:, None]
                + jnp.minimum(j_idx, q_lens[:, None] - 1) + 1)  # (ns, C)
    valid = jnp.arange(M)[None, :] < kv_end[:, None]            # (ns, M)
    # slot-indexed write coordinates for the per-slot kt rows: padding
    # columns / dead lanes land on scratch row ns instead of a phys page
    slot_rows = jnp.where(write_ok, jnp.arange(ns)[:, None], ns)
    slot_pos = jnp.where(write_ok, jnp.minimum(positions, M - 1), 0)
    # a position's mass cell is reset exactly once — in the step that
    # appends it — so recycled slots never leak a previous occupant's
    # mass and admission needs no eager pool-wide zeroing
    new_cell = (valid & (jnp.arange(M)[None, :] >= slot_lens[:, None])
                & active[:, None])
    score_dtype = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[
        cfg.softmax_dtype]
    scale = dh ** -0.5
    if slot_ranks is not None:
        r_keep = basis.shape[-1]
        col_ok = (jnp.arange(r_keep)[None, :]
                  < jnp.minimum(slot_ranks, r_keep)[:, None]
                  ).astype(jnp.float32)             # (ns, r_keep)

    def body(x, xs):
        lp, kp, vp, basis_l, extra = xs
        ktp, mp = extra.get("kt"), extra.get("mass")
        p = lp["attn"]
        h = nn.rms_norm(x, lp["ln1"], cfg.rms_eps)
        q = jnp.einsum("bsd,dhf->bshf", h, p["wq"].reshape(d, hq, dh).astype(x.dtype))
        k = jnp.einsum("bsd,dhf->bshf", h, p["wk"].reshape(d, hkv, dh).astype(x.dtype))
        v = jnp.einsum("bsd,dhf->bshf", h, p["wv"].reshape(d, hkv, dh).astype(x.dtype))
        if cfg.qkv_bias:
            q = q + p["bq"].reshape(hq, dh).astype(x.dtype)
            k = k + p["bk"].reshape(hkv, dh).astype(x.dtype)
            v = v + p["bv"].reshape(hkv, dh).astype(x.dtype)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        kp = kp.at[phys, off].set(k.astype(kp.dtype))
        vp = vp.at[phys, off].set(v.astype(vp.dtype))
        vg = vp[page_table].reshape(ns, M, hkv, dh)
        if rcfg.mode == "off" or slot_ranks is None:
            kg = kp[page_table].reshape(ns, M, hkv, dh)
            # stale page contents (freed + re-issued pages) must not leak:
            # zero everything beyond the valid prefix
            q_use = q
            k_use = kg * valid[:, :, None, None].astype(kg.dtype)
        else:
            # project q onto the slot's cached segment eigenbasis; per-row
            # rank = zeroed q columns beyond the slot's bucket (the score
            # contraction then ignores the matching k-factor columns, so
            # the k side needs no mask)
            b_q = (jnp.repeat(basis_l, n_rep, axis=1) if n_rep > 1
                   else basis_l)                         # (ns, hq, d, r)
            q_proj = (jnp.einsum("bshd,bhdr->bshr", q.astype(jnp.float32),
                                 b_q)
                      * col_ok[:, None, None, :]).astype(x.dtype)
            if ktp is not None:
                # factor-form cache: append the new tokens' factors and
                # read the slot-indexed factors — r/d of the dense K bytes
                kt_new = jnp.einsum("bshd,bhdr->bshr",
                                    k.astype(jnp.float32), basis_l)
                ktp = ktp.at[slot_rows, slot_pos].set(
                    kt_new.astype(ktp.dtype))
                ktg = ktp[:ns]                        # (ns, M, hkv, r)
                k_fac = (ktg * valid[:, :, None, None].astype(ktg.dtype)
                         ).astype(x.dtype)
            else:
                kg = kp[page_table].reshape(ns, M, hkv, dh)
                k_masked = kg * valid[:, :, None, None].astype(kg.dtype)
                k_fac = jnp.einsum("bmhd,bhdr->bmhr",
                                   k_masked.astype(jnp.float32),
                                   basis_l).astype(x.dtype)
            if not mixed:
                q_use, k_use = q_proj, k_fac
            else:
                # mid-prefill rows attend full-rank dense; decode rows
                # keep the factor read. Pad the factor side to head-dim
                # width (exact zeros) and select per row.
                kg = kp[page_table].reshape(ns, M, hkv, dh)
                k_dense = kg * valid[:, :, None, None].astype(kg.dtype)
                pad = ((0, 0), (0, 0), (0, 0), (0, dh - r_keep))
                q_use = jnp.where(is_pf[:, None, None, None], q,
                                  jnp.pad(q_proj, pad))
                k_use = jnp.where(is_pf[:, None, None, None], k_dense,
                                  jnp.pad(k_fac, pad))
        probs = None
        want_probs = (mp is not None) or mass_defer
        if use_kernel:
            from repro.kernels.ops import decode_attention
            qk = jnp.swapaxes(q_use, 1, 2)               # (ns, hq, C, r)
            res = decode_attention(
                qk if mixed or C > 1 else qk[:, :, 0],
                jnp.swapaxes(k_use, 1, 2),               # (ns, hkv, M, r)
                jnp.swapaxes(vg, 1, 2),                  # (ns, hkv, M, dh)
                kv_end, scale=scale, q_start=slot_lens,
                return_probs=want_probs)
            if want_probs:
                o, probs = res                       # probs (ns, hq, [C,] M)
            else:
                o = res
            if o.ndim == 3:
                o, probs = o[:, :, None], (None if probs is None
                                           else probs[:, :, None])
            o = jnp.swapaxes(o, 1, 2)                    # (ns, C, hq, dh)
        else:
            res = attend(q_use, repeat_kv(k_use, n_rep), repeat_kv(vg, n_rep),
                         scale=scale, causal=False,
                         kv_len=kv_len_q[:, None, :, None],
                         score_dtype=score_dtype,
                         return_probs=want_probs)
            if want_probs:
                o, probs = res                           # probs (ns, hq, C, M)
            else:
                o = res
        if mp is not None:
            # per-key attention mass: group-mean over each kv head's q
            # heads, masked to live lanes and valid queries (dead lanes /
            # padding columns contribute exact zeros, so the slot-indexed
            # accumulate is a plain add — no scatter, no scratch row).
            # Cells appended this step are reset before the add, so a
            # recycled slot's stale mass dies the moment the position is
            # reused — no eager pool-wide zeroing at admission.
            from repro.models.common import kv_group_mean
            w = (probs.astype(jnp.float32)
                 * write_ok[:, None, :, None]).sum(axis=2)   # (ns, hq, M)
            w_tok = kv_group_mean(w, hkv)                    # (ns, hkv, M)
            mp = (jnp.where(new_cell[:, :, None], 0.0, mp)
                  + jnp.swapaxes(w_tok, 1, 2).astype(mp.dtype))
        mass_q = None
        if mass_defer:
            # per-query mass, NOT summed over the chunk: the caller masks
            # to the accepted queries before applying (spec verify)
            from repro.models.common import kv_group_mean
            wq = (probs.astype(jnp.float32)
                  * write_ok[:, None, :, None])              # (ns, hq, C, M)
            wq = kv_group_mean(jnp.swapaxes(wq, 1, 2), hkv)  # (ns, C, hkv, M)
            mass_q = jnp.swapaxes(wq, 2, 3)                  # (ns, C, M, hkv)
        x = x + jnp.einsum("bshf,hfd->bsd", o,
                           p["wo"].reshape(hq, dh, d).astype(x.dtype))
        if cfg.family == "moe" and cfg.moe is not None and "moe" in lp:
            f, _ = moe_mod.moe_ffn(cfg, lp["moe"],
                                   nn.rms_norm(x, lp["ln2"], cfg.rms_eps))
        else:
            f = nn.swiglu(nn.rms_norm(x, lp["ln2"], cfg.rms_eps),
                          lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                          lp["ffn"]["w_down"])
        new_extra = {}
        if ktp is not None:
            new_extra["kt"] = ktp
        if mp is not None:
            new_extra["mass"] = mp
        if mass_q is not None:
            new_extra["mass_q"] = mass_q
        return x + f, (kp, vp, new_extra)

    from repro.models.common import scan_or_unroll
    basis_xs = (basis if basis is not None else
                jnp.zeros((cfg.num_layers, ns, hkv, dh, 1), jnp.float32))
    extra_xs = {}
    if kt_pool is not None:
        extra_xs["kt"] = kt_pool
    if mass_pool is not None:
        extra_xs["mass"] = mass_pool
    x, (nk, nv, n_extra) = scan_or_unroll(
        body, x, (params["layers"], pool_k, pool_v, basis_xs, extra_xs),
        unroll=not cfg.scan_layers)
    if C > 1 and not return_all_logits:
        # only each row's last valid query feeds the LM head: the next
        # token for decode rows, token 0 for a row finishing its prompt
        x = jnp.take_along_axis(x, (q_lens - 1)[:, None, None], axis=1)
    x = nn.rms_norm(x, params["ln_f"], cfg.rms_eps)
    head = params.get("lm_head", None)
    logits = (jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
              if head is not None else
              jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype)))
    pools = {"k": nk, "v": nv, **n_extra}
    return logits, pools
