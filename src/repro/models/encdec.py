"""Encoder-decoder transformer (Seamless-M4T backbone).

The audio frontend is a stub per the assignment: ``input_specs()`` supplies
precomputed frame embeddings (b, src_len, d_model) to the encoder. The text
decoder has causal self-attention (DR-RL applies) + cross-attention over the
encoder memory (DR-RL applies there too: the score contraction q_dec k_enc^T
is spectrally truncated the same way).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ModelConfig
from repro.models.attention import mhsa
from repro.models.common import apply_rope, repeat_kv, scan_or_unroll
from repro.models.transformer import init_attn, init_ffn, make_rank_ctx
from repro.models import drrl_util


def _init_block(cfg, rng, dtype, cross: bool):
    k1, k2, k3 = jax.random.split(rng, 3)
    p = {
        "attn": init_attn(cfg, k1, dtype),
        "ffn": init_ffn(cfg, k2, dtype),
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
    }
    if cross:
        p["xattn"] = init_attn(cfg, k3, dtype)
        p["ln_x"] = jnp.ones((cfg.d_model,), dtype)
    return p


def init_encdec(cfg: ModelConfig, rng) -> Dict[str, Any]:
    dtype = nn.dt(cfg.param_dtype)
    ke, kd, kemb, kh = jax.random.split(rng, 4)
    n_enc = cfg.num_encoder_layers or cfg.num_layers
    return {
        "embed": nn.embed_init(kemb, cfg.vocab_size, cfg.d_model, dtype),
        "enc": jax.vmap(lambda k: _init_block(cfg, k, dtype, False))(
            jax.random.split(ke, n_enc)),
        "dec": jax.vmap(lambda k: _init_block(cfg, k, dtype, True))(
            jax.random.split(kd, cfg.num_layers)),
        "ln_enc": jnp.ones((cfg.d_model,), dtype),
        "ln_f": jnp.ones((cfg.d_model,), dtype),
        "lm_head": nn.dense_init(kh, cfg.d_model, cfg.vocab_size, dtype),
    }


def _cross_attend(cfg, p, x, memory, mem_kv=None):
    """Cross-attention: q from x, k/v from encoder memory (precomputable)."""
    b, s, d = x.shape
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    dh = cfg.resolved_head_dim()
    q = jnp.einsum("bsd,dhf->bshf", x, p["wq"].reshape(d, hq, dh).astype(x.dtype))
    if mem_kv is None:
        k = jnp.einsum("bsd,dhf->bshf", memory,
                       p["wk"].reshape(d, hkv, dh).astype(x.dtype))
        v = jnp.einsum("bsd,dhf->bshf", memory,
                       p["wv"].reshape(d, hkv, dh).astype(x.dtype))
    else:
        k, v = mem_kv
    n_rep = hq // hkv
    s_ = jnp.einsum("bqhd,bkhd->bhqk", q, repeat_kv(k, n_rep)) * dh ** -0.5
    a = jax.nn.softmax(s_.astype(jnp.float32), axis=-1).astype(x.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", a, repeat_kv(v, n_rep))
    return jnp.einsum("bshf,hfd->bsd", o,
                      p["wo"].reshape(hq, dh, d).astype(x.dtype))


def encode(cfg: ModelConfig, params, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (b, src, d_model) precomputed modality embeddings (stub)."""
    dtype = nn.dt(cfg.dtype)
    x = frames.astype(dtype)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(x, lp):
        # bidirectional self-attention: reuse mhsa without causal masking by
        # calling attend via a dummy 'cache' of the full sequence? simpler:
        # inline non-causal attention here.
        h = nn.rms_norm(x, lp["ln1"], cfg.rms_eps)
        hq, hkv = cfg.num_heads, cfg.num_kv_heads
        dh = cfg.resolved_head_dim()
        d = cfg.d_model
        q = jnp.einsum("bsd,dhf->bshf", h, lp["attn"]["wq"].reshape(d, hq, dh).astype(x.dtype))
        k = jnp.einsum("bsd,dhf->bshf", h, lp["attn"]["wk"].reshape(d, hkv, dh).astype(x.dtype))
        v = jnp.einsum("bsd,dhf->bshf", h, lp["attn"]["wv"].reshape(d, hkv, dh).astype(x.dtype))
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        n_rep = hq // hkv
        s_ = jnp.einsum("bqhd,bkhd->bhqk", q, repeat_kv(k, n_rep)) * dh ** -0.5
        a = jax.nn.softmax(s_.astype(jnp.float32), axis=-1).astype(x.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", a, repeat_kv(v, n_rep))
        x = x + jnp.einsum("bshf,hfd->bsd", o,
                           lp["attn"]["wo"].reshape(hq, dh, d).astype(x.dtype))
        x = x + nn.swiglu(nn.rms_norm(x, lp["ln2"], cfg.rms_eps),
                          lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                          lp["ffn"]["w_down"])
        return x, None

    x, _ = scan_or_unroll(body, x, params["enc"], unroll=not cfg.scan_layers)
    return nn.rms_norm(x, params["ln_enc"], cfg.rms_eps)


def forward_encdec(cfg: ModelConfig, params, frames, tokens, *,
                   policy_params=None, rank_rng=None, rl_t=0,
                   chunked: bool = False):
    """Teacher-forced training forward. Returns (logits, aux)."""
    memory = encode(cfg, params, frames)
    dtype = nn.dt(cfg.dtype)
    x = params["embed"][tokens].astype(dtype)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    rcfg = cfg.rank
    h_t = None
    if rcfg.mode == "drrl" and policy_params is not None:
        h_t = drrl_util.conv_feats(x, policy_params)
    rank_ctx0 = make_rank_ctx(cfg, policy_params=policy_params, rng=rank_rng,
                              t=rl_t, h_t=h_t)

    def body(carry, xs):
        x, prev_rank = carry
        lp, li = xs
        rank_ctx = None
        if rank_ctx0 is not None:
            rank_ctx = dict(rank_ctx0, prev_rank=prev_rank, layer_id=li,
                            w_t=(drrl_util.wstats(lp["attn"], rcfg.power_iters)
                                 if rcfg.mode == "drrl" else None))
        h, _, aux = mhsa(cfg, lp["attn"], nn.rms_norm(x, lp["ln1"], cfg.rms_eps),
                         positions, rank_ctx=rank_ctx, chunked=chunked)
        x = x + h
        x = x + _cross_attend(cfg, lp["xattn"],
                              nn.rms_norm(x, lp["ln_x"], cfg.rms_eps), memory)
        x = x + nn.swiglu(nn.rms_norm(x, lp["ln2"], cfg.rms_eps),
                          lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                          lp["ffn"]["w_down"])
        return (x, aux.get("rank", prev_rank)), None

    prev0 = jnp.full((b, cfg.num_kv_heads), rcfg.rank_grid[-1], jnp.int32)
    (x, _), _ = scan_or_unroll(body, (x, prev0),
                               (params["dec"], jnp.arange(cfg.num_layers)),
                               unroll=not cfg.scan_layers)
    x = nn.rms_norm(x, params["ln_f"], cfg.rms_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits, {}


def loss_encdec(cfg: ModelConfig, params, batch, **kw):
    from repro.dist.ctx import logits_spec
    logits, aux = forward_encdec(cfg, params, batch["frames"],
                                 batch["tokens"], **kw)
    return nn.softmax_cross_entropy(logits, batch["labels"],
                                    batch.get("mask"),
                                    spec=logits_spec(cfg)), aux


def init_cache_encdec(cfg: ModelConfig, batch: int, max_len: int,
                      src_len: int) -> dict:
    """Decode cache: self-attn KV per decoder layer + precomputed cross K/V."""
    dtype = nn.dt(cfg.dtype)
    dh = cfg.resolved_head_dim()
    L = cfg.num_layers
    return {
        "k": jnp.zeros((L, batch, max_len, cfg.num_kv_heads, dh), dtype),
        "v": jnp.zeros((L, batch, max_len, cfg.num_kv_heads, dh), dtype),
        "xk": jnp.zeros((L, batch, src_len, cfg.num_kv_heads, dh), dtype),
        "xv": jnp.zeros((L, batch, src_len, cfg.num_kv_heads, dh), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def prefill_cross(cfg: ModelConfig, params, memory: jnp.ndarray, cache: dict
                  ) -> dict:
    """Precompute cross-attention K/V for every decoder layer."""
    d = cfg.d_model
    hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim()

    def per_layer(lp):
        k = jnp.einsum("bsd,dhf->bshf", memory,
                       lp["xattn"]["wk"].reshape(d, hkv, dh).astype(memory.dtype))
        v = jnp.einsum("bsd,dhf->bshf", memory,
                       lp["xattn"]["wv"].reshape(d, hkv, dh).astype(memory.dtype))
        return k, v

    xk, xv = jax.vmap(per_layer)(params["dec"])
    return dict(cache, xk=xk.astype(cache["xk"].dtype),
                xv=xv.astype(cache["xv"].dtype))


def decode_step_encdec(cfg: ModelConfig, params, cache, tokens):
    dtype = nn.dt(cfg.dtype)
    x = params["embed"][tokens].astype(dtype)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(cache["len"] + jnp.arange(s)[None], (b, s))

    def body(x, xs):
        lp, ck, cv, xk, xv = xs
        layer_cache = {"k": ck, "v": cv, "len": cache["len"]}
        h, nc, _ = mhsa(cfg, lp["attn"], nn.rms_norm(x, lp["ln1"], cfg.rms_eps),
                        positions, cache=layer_cache)
        x = x + h
        x = x + _cross_attend(cfg, lp["xattn"],
                              nn.rms_norm(x, lp["ln_x"], cfg.rms_eps),
                              None, mem_kv=(xk, xv))
        x = x + nn.swiglu(nn.rms_norm(x, lp["ln2"], cfg.rms_eps),
                          lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                          lp["ffn"]["w_down"])
        return x, (nc["k"], nc["v"])

    x, (nk, nv) = scan_or_unroll(
        body, x, (params["dec"], cache["k"], cache["v"], cache["xk"],
                  cache["xv"]), unroll=not cfg.scan_layers)
    x = nn.rms_norm(x, params["ln_f"], cfg.rms_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits, dict(cache, k=nk, v=nv, len=cache["len"] + s)
