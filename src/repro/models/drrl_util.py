"""Thin indirection over repro.core.drrl used by model modules (avoids
import cycles between models and the RL controller)."""
from __future__ import annotations

from repro.core.drrl import conv_features, weight_stats


def conv_feats(x, policy_params):
    return conv_features(x, policy_params["conv"])


def wstats(p_attn, power_iters: int = 3):
    return weight_stats(p_attn, power_iters)
