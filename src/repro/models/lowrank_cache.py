"""Beyond-paper serving optimization: a rank-r KV cache.

The paper truncates the *score contraction* at serve time; the same spectral
machinery (Gram eigenbasis of K over the prompt, repro.core.lowrank) lets us
store the cache itself in factor form:

    k~ = K . E_r   (b, M, hkv, r)   instead of   K (b, M, hkv, d)

cutting decode cache memory AND read bandwidth by r/d — on the decode_32k
cell the KV cache is the dominant memory term after the §Perf split-KV fix,
so this directly attacks the remaining roofline bound. New tokens are
projected onto the prefill basis; the basis can be refreshed every segment with
incremental subspace extension (Eq. 12) — the AdaptiveServer re-decides the
bucket anyway, so a refresh is a bucket switch.

V is kept full here (scores drive the quality trade-off; value truncation is
available separately via RankConfig.truncate_values).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ModelConfig
from repro.core import lowrank as lr
from repro.models.attention import attend
from repro.models.common import apply_rope, repeat_kv


def init_lowrank_cache(cfg: ModelConfig, batch: int, max_len: int,
                       rank: int) -> Dict:
    dtype = nn.dt(cfg.dtype)
    dh = cfg.resolved_head_dim()
    L, hkv = cfg.num_layers, cfg.num_kv_heads
    return {
        "kt": jnp.zeros((L, batch, max_len, hkv, rank), dtype),
        "v": jnp.zeros((L, batch, max_len, hkv, dh), dtype),
        "basis": jnp.zeros((L, batch, hkv, dh, rank), jnp.float32),
        "len": jnp.zeros((), jnp.int32),
    }


def attention_mass(q: jnp.ndarray, k: jnp.ndarray,
                   q_len=None) -> jnp.ndarray:
    """Per-key attention mass of the prompt's causal self-attention,
    summed over queries and averaged over the q-heads of each kv group.

    q: (L, b, s, hq, d); k: (L, b, s, hkv, d). Returns (L, b, hkv, s)
    normalised so the weights sum to the number of contributing queries
    (scale-free for eigenvectors, but keeps the weighted Gram's trace
    comparable to the plain one, whose weights are 1 per key).

    ``q_len`` (scalar, may be traced) restricts the query average to
    positions < q_len: the serve prefill runs on a padded length bucket,
    and the garbage queries beyond the prompt would otherwise scatter
    score mass back onto real keys.

    Computed one layer at a time (lax.map) so the peak score tensor is
    (b, hq, s, s), matching the forward's own attention peak, instead of
    L times that."""
    L, b, s, hq, dh = q.shape
    hkv = k.shape[3]
    causal = jnp.arange(s)[:, None] >= jnp.arange(s)[None, :]
    n_q = jnp.asarray(s if q_len is None else q_len, jnp.float32)
    q_ok = (None if q_len is None
            else (jnp.arange(s) < q_len).astype(jnp.float32))

    def one_layer(qk):
        from repro.models.common import kv_group_mean
        q_l, k_l = qk                              # (b, s, hq|hkv, d)
        kr = (jnp.repeat(k_l, hq // hkv, axis=2) if hq != hkv else k_l)
        sc = jnp.einsum("bqhd,bkhd->bhqk", q_l.astype(jnp.float32),
                        kr.astype(jnp.float32)) * dh ** -0.5
        sc = jnp.where(causal[None, None], sc, -1e30)
        p = jax.nn.softmax(sc, axis=-1)
        if q_ok is not None:
            p = p * q_ok[None, None, :, None]
        return kv_group_mean(jnp.sum(p, axis=2), hkv)

    w = jax.lax.map(one_layer, (q, k))             # (L, b, hkv, s)
    return w * n_q / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-30)


def prefill_lowrank(cfg: ModelConfig, params, tokens: jnp.ndarray,
                    cache: Dict, rank: int, *,
                    weighted: bool = True) -> Tuple[jnp.ndarray, Dict]:
    """Run the prompt through the model, build per-(layer, head) bases from
    the prompt K-Grams, and store the truncated cache.

    ``weighted=True`` uses the softmax-weighted Gram G = K^T diag(w) K with
    w the prompt's per-key attention mass: the basis concentrates on the
    directions that actually receive score mass, instead of K's raw energy
    (which can sit where Q never looks — the failure mode recorded in
    ROADMAP for the plain prompt-K basis).

    Returns (last-token logits, filled cache)."""
    from repro.models import transformer as tr
    # capture per-layer K/V via the rl-collection path (any rank mode works;
    # 'adaptive' keeps the forward full-precision while exposing qkv)
    cfg_cap = cfg.with_(rank=cfg.rank.__class__(
        mode="adaptive", rank_grid=cfg.rank.rank_grid or (rank,),
        energy_threshold=1.0))
    logits, aux = tr.forward_dense(cfg_cap, params, tokens,
                                   collect_aux="rl", collect_qkv=True,
                                   rank_rng=jax.random.PRNGKey(0))
    qkv = aux["layers"]["qkv"]                     # k,v: (L, b, s, hkv, d)
    k, v = qkv["k"], qkv["v"]
    L, b, s, hkv, dh = k.shape
    if weighted:
        w = attention_mass(qkv["q"], k)            # (L, b, hkv, s)
        kf = k.astype(jnp.float32)
        gk = jnp.einsum("lbshd,lbhs,lbshe->lbhde", kf, w, kf)
        gk = gk.reshape(L * b * hkv, dh, dh)
    else:
        gk = lr.gram(jnp.moveaxis(k, 3, 2).reshape(L * b * hkv, s, dh))
    _, evecs = lr.gram_spectrum(gk)                # (Lbh, d, d)
    basis = evecs[..., :rank].reshape(L, b, hkv, dh, rank)
    kt = jnp.einsum("lbshd,lbhdr->lbshr", k.astype(jnp.float32), basis)
    kt_full = jax.lax.dynamic_update_slice(
        cache["kt"], kt.astype(cache["kt"].dtype), (0, 0, 0, 0, 0))
    v_full = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    return logits[:, -1:], {
        "kt": kt_full, "v": v_full, "basis": basis,
        "len": jnp.asarray(s, jnp.int32),
    }


def decode_step_lowrank(cfg: ModelConfig, params, cache: Dict,
                        tokens: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
    """One decode step against the rank-r cache: q and the new k are
    projected onto the stored basis; the score contraction runs over r."""
    dtype = nn.dt(cfg.dtype)
    x = params["embed"][tokens].astype(dtype)
    b, s, d = x.shape
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    dh = cfg.resolved_head_dim()
    n_rep = hq // hkv
    positions = jnp.broadcast_to(cache["len"] + jnp.arange(s)[None], (b, s))

    def body(x, xs):
        lp, kt_l, v_l, basis_l = xs
        p = lp["attn"]
        h = nn.rms_norm(x, lp["ln1"], cfg.rms_eps)
        q = jnp.einsum("bsd,dhf->bshf", h, p["wq"].reshape(d, hq, dh).astype(x.dtype))
        k = jnp.einsum("bsd,dhf->bshf", h, p["wk"].reshape(d, hkv, dh).astype(x.dtype))
        v = jnp.einsum("bsd,dhf->bshf", h, p["wv"].reshape(d, hkv, dh).astype(x.dtype))
        if cfg.qkv_bias:
            q = q + p["bq"].reshape(hq, dh).astype(x.dtype)
            k = k + p["bk"].reshape(hkv, dh).astype(x.dtype)
            v = v + p["bv"].reshape(hkv, dh).astype(x.dtype)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        # project onto the prefill basis
        basis_q = jnp.repeat(basis_l, n_rep, axis=1)          # (b, hq, d, r)
        qt = jnp.einsum("bshf,bhfr->bshr", q.astype(jnp.float32), basis_q)
        kt_new = jnp.einsum("bshf,bhfr->bshr", k.astype(jnp.float32), basis_l)
        idx = cache["len"]
        kt_l = jax.lax.dynamic_update_slice(
            kt_l, kt_new.astype(kt_l.dtype), (0, idx, 0, 0))
        v_l = jax.lax.dynamic_update_slice(
            v_l, v.astype(v_l.dtype), (0, idx, 0, 0))
        kv_len = idx + s
        o = attend(qt.astype(x.dtype), repeat_kv(kt_l, n_rep),
                   repeat_kv(v_l, n_rep), scale=dh ** -0.5, causal=True,
                   q_offset=idx, kv_len=kv_len)
        x = x + jnp.einsum("bshf,hfd->bsd", o,
                           p["wo"].reshape(hq, dh, d).astype(x.dtype))
        ffn = lp["ffn"]
        x = x + nn.swiglu(nn.rms_norm(x, lp["ln2"], cfg.rms_eps),
                          ffn["w_gate"], ffn["w_up"], ffn["w_down"])
        return x, (kt_l, v_l)

    from repro.models.common import scan_or_unroll
    x, (kt, v) = scan_or_unroll(
        body, x, (params["layers"], cache["kt"], cache["v"], cache["basis"]),
        unroll=not cfg.scan_layers)
    x = nn.rms_norm(x, params["ln_f"], cfg.rms_eps)
    head = params.get("lm_head")
    logits = (jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
              if head is not None else
              jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype)))
    return logits, dict(cache, kt=kt, v=v, len=cache["len"] + s)
