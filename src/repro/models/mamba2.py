"""Mamba2 (SSD) blocks — chunked, matmul-dominant formulation (TPU-friendly).

The chunked algorithm splits the sequence into chunks of Q tokens; the
intra-chunk term is a (Q x Q) decay-masked attention-like matmul and the
inter-chunk term is a tiny recurrent state pass (scan over chunks) — exactly
the structure the MXU wants. A naive O(L) recurrence lives in ssd_naive()
as the test oracle.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ModelConfig


def init_mamba_block(cfg: ModelConfig, rng, dtype) -> Dict[str, jnp.ndarray]:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    H = d_in // s.head_dim
    G, N = s.n_groups, s.d_state
    conv_dim = d_in + 2 * G * N
    ks = nn.split_keys(rng, 4)
    return {
        "in_proj": nn.dense_init(ks[0], d, 2 * d_in + 2 * G * N + H, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_dim), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm_g": jnp.ones((d_in,), dtype),
        "out_proj": nn.dense_init(ks[2], d_in, d, dtype),
        "ln": jnp.ones((d,), dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: jnp.ndarray = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv. x: (b, l, c), w: (k, c). Returns (y, new_state)
    where state carries the last k-1 inputs for decoding."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(k))
    new_state = xp[:, -(k - 1):]
    return y + b.astype(x.dtype), new_state


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """SSD: y[t] = C_t^T ( sum_{s<=t} prod_{u=s+1..t} exp(dtA_u) dt_s B_s x_s^T ).

    x: (b, l, h, p); dt: (b, l, h); A: (h,) negative; B, C: (b, l, g, n).
    Returns (y (b, l, h, p), final_state (b, h, n, p))."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    L = x.shape[1]
    nc = L // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)
    Bh = jnp.repeat(Bc, rep, axis=3)               # (b, nc, q, h, n)
    Ch = jnp.repeat(Cc, rep, axis=3)

    dta = dtc * A[None, None, None, :]             # (b, nc, q, h) negative
    a_cs = jnp.cumsum(dta, axis=2)                 # inclusive cumsum
    a_last = a_cs[:, :, -1:]                       # (b, nc, 1, h)

    # ---- intra-chunk (quadratic within chunk) ----
    li = a_cs[:, :, :, None, :]                    # i index
    lj = a_cs[:, :, None, :, :]                    # j index
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    # mask BEFORE exp: exp of the (positive) upper-triangle would overflow
    # and poison gradients through the where (inf * 0 -> NaN in the VJP)
    decay = jnp.exp(jnp.where(mask, li - lj, -jnp.inf))
    decay = jnp.where(mask, decay, 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Ch.astype(jnp.float32),
                        Bh.astype(jnp.float32))
    w = scores * decay * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xc.astype(jnp.float32))

    # ---- chunk states ----
    sdecay = jnp.exp(a_last - a_cs)                # (b, nc, q, h)
    s_chunk = jnp.einsum("bcqh,bcqhn,bcqhp->bchnp",
                         sdecay * dtc, Bh.astype(jnp.float32),
                         xc.astype(jnp.float32))

    # ---- inter-chunk scan ----
    chunk_decay = jnp.exp(a_last[:, :, 0])         # (b, nc, h)

    def body(S, xs):
        s_c, dec = xs                              # (b, h, n, p), (b, h)
        y_state = S                                 # state entering this chunk
        S = S * dec[:, :, None, None] + s_c
        return S, y_state

    S0 = jnp.zeros((b, h, n, p), jnp.float32)
    S_final, S_in = jax.lax.scan(
        body, S0, (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    S_in = jnp.moveaxis(S_in, 0, 1)                # (b, nc, h, n, p)

    in_decay = jnp.exp(a_cs)                       # (b, nc, q, h)
    y_inter = jnp.einsum("bcqhn,bchnp,bcqh->bcqhp",
                         Ch.astype(jnp.float32), S_in, in_decay)
    y = (y_intra + y_inter).reshape(b, L, h, p)[:, :l]
    return y.astype(x.dtype), S_final


def ssd_naive(x, dt, A, B, C):
    """O(L) recurrence oracle (tests only)."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def body(S, xs):
        xt, dtt, Bt, Ct = xs
        dec = jnp.exp(dtt * A)[:, :, None, None]
        S = S * dec + jnp.einsum("bh,bhn,bhp->bhnp", dtt, Bt, xt.astype(jnp.float32))
        y = jnp.einsum("bhn,bhnp->bhp", Ct, S)
        return S, y

    S0 = jnp.zeros((b, h, n, p), jnp.float32)
    _, ys = jax.lax.scan(body, S0,
                         (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dtf, 1, 0),
                          jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0)))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)


def _split_proj(cfg: ModelConfig, proj):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    G, N = s.n_groups, s.d_state
    z, xBC, dt = jnp.split(proj, [d_in, d_in + d_in + 2 * G * N], axis=-1)
    return z, xBC, dt, (d_in, H, G, N)


def mamba_block(cfg: ModelConfig, p, x, *, conv_state=None, ssm_state=None,
                single_step: bool = False):
    """x: (b, l, d) -> (y (b, l, d), new_conv_state, new_ssm_state)."""
    s = cfg.ssm
    res = x
    x = nn.rms_norm(x, p["ln"], cfg.rms_eps)
    proj = nn.linear(x, p["in_proj"])
    z, xBC, dt, (d_in, H, G, N) = _split_proj(cfg, proj)
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    xBC = jax.nn.silu(xBC)
    xc, B, C = jnp.split(xBC, [d_in, d_in + G * N], axis=-1)
    b, l = x.shape[0], x.shape[1]
    xh = xc.reshape(b, l, H, s.head_dim)
    Bh = B.reshape(b, l, G, N)
    Ch = C.reshape(b, l, G, N)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    if single_step:
        rep = H // G
        Bt = jnp.repeat(Bh[:, 0], rep, axis=1).astype(jnp.float32)
        Ct = jnp.repeat(Ch[:, 0], rep, axis=1).astype(jnp.float32)
        dtt = dtv[:, 0]
        dec = jnp.exp(dtt * A)[:, :, None, None]
        S = ssm_state * dec + jnp.einsum("bh,bhn,bhp->bhnp", dtt, Bt,
                                         xh[:, 0].astype(jnp.float32))
        y = jnp.einsum("bhn,bhnp->bhp", Ct, S)[:, None]
        new_ssm = S
    else:
        y, new_ssm = ssd_chunked(xh, dtv, A, Bh, Ch, s.chunk_size)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, l, d_in).astype(x.dtype)
    y = nn.rms_norm(y * jax.nn.silu(z), p["norm_g"], cfg.rms_eps)
    return res + nn.linear(y, p["out_proj"]), new_conv, new_ssm


def init_mamba_state(cfg: ModelConfig, batch: int, dtype):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    G, N = s.n_groups, s.d_state
    conv_dim = d_in + 2 * G * N
    return (jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
            jnp.zeros((batch, H, N, s.head_dim), jnp.float32))
