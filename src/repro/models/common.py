"""Shared model components: RoPE (incl. M-RoPE), masks, caches."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def scan_or_unroll(body, carry, xs, unroll: bool = False):
    """lax.scan, or a python-unrolled equivalent.

    The unrolled form exists for the dry-run calibration: XLA's
    cost_analysis counts a while-loop body ONCE, so roofline FLOPs/bytes are
    extracted from small unrolled depths and extrapolated linearly in L
    (see benchmarks/roofline.py)."""
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    length = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        xi = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and jax.tree_util.tree_leaves(ys[0]):
        ys = jax.tree_util.tree_map(lambda *z: jnp.stack(z), *ys)
    else:
        ys = ys[0] if ys else None
    return carry, ys


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """x: (b, s, h, d); positions: (b, s) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (b, s, d/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, sections: Tuple[int, ...],
                theta: float = 10000.0) -> jnp.ndarray:
    """Qwen2-VL M-RoPE. x: (b, s, h, d); positions3: (b, 3, s) for (t, h, w).

    ``sections`` gives the number of *frequency pairs* per position stream and
    must sum to d/2.
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    freqs = rope_freqs(d, theta)                       # (d/2,)
    # build per-frequency position selection: first sections[0] pairs follow t,
    # next sections[1] follow h, last follow w.
    sel = jnp.concatenate([jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)])
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),                # (b, 3, s)
        jnp.broadcast_to(sel[None, :, None], (x.shape[0], d // 2, x.shape[1])),
        axis=1,
    )                                                  # (b, d/2, s)
    angles = jnp.transpose(pos, (0, 2, 1)) * freqs     # (b, s, d/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def causal_mask(q_len: int, kv_len: int, q_offset=0) -> jnp.ndarray:
    """Boolean (q_len, kv_len) mask; True = attend."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    k_pos = jnp.arange(kv_len)[None, :]
    return k_pos <= q_pos


def repeat_kv(x: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(b, s, kv, d) -> (b, s, kv*n_rep, d) for GQA."""
    if n_rep == 1:
        return x
    b, s, kv, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, kv, n_rep, d)).reshape(b, s, kv * n_rep, d)


def kv_group_mean(w: jnp.ndarray, hkv: int) -> jnp.ndarray:
    """(..., hq, K) per-q-head key weights -> (..., hkv, K) mean per kv
    group. The inverse reduction of :func:`repeat_kv`: consecutive q heads
    share a kv head, so every per-key attention-mass consumer (serve
    prefill seed, fused decode accumulator, lowrank prefill basis) reduces
    through here and stays consistent with one GQA head layout."""
    hq, K = w.shape[-2], w.shape[-1]
    return w.reshape(*w.shape[:-2], hkv, hq // hkv, K).mean(-2)


def make_kv_cache(batch: int, max_len: int, n_kv: int, head_dim: int, dtype) -> dict:
    return {
        "k": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def cache_update(cache: dict, k_new: jnp.ndarray, v_new: jnp.ndarray) -> dict:
    """Append k/v (b, s_new, kv, d) at cache['len']."""
    idx = cache["len"]
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, idx, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, idx, 0, 0))
    return {"k": k, "v": v, "len": idx + k_new.shape[1]}
