"""Multi-head attention with DR-RL dynamic low-rank score contraction.

Three realisations of the paper's technique live here:
  * full-rank reference (rank.mode == 'off')
  * 'masked' — rank expressed by zeroing eigendirections; single executable,
    differentiable, used for RL training/rollouts and the heuristic baselines
  * 'static' — rank baked into the program (serving buckets; the Pallas
    lowrank_flash kernel consumes the rank-r factors)

Spectral quantities come from the Gram route in repro.core.lowrank; the
perturbation guardrail from repro.core.perturbation.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RankConfig
from repro.core import lowrank as lr
from repro.core import perturbation as pert
from repro.models.common import apply_mrope, apply_rope, repeat_kv


# ---------------------------------------------------------------------------
# Score/softmax/value core
# ---------------------------------------------------------------------------

def attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
           scale: float, causal: bool, q_offset: int | jnp.ndarray = 0,
           kv_len: Optional[jnp.ndarray] = None,
           chunked: bool = False, chunk: int = 1024,
           score_dtype=jnp.float32,
           score_spec=None, return_probs: bool = False) -> jnp.ndarray:
    """softmax(q k^T * scale) v.

    ``return_probs`` also returns the probability tensor (b, h, sq, skv)
    (the serving engine's attention-mass feed); unsupported on the
    chunked path, which never materialises it.

    q: (b, sq, h, dq)  k: (b, skv, h, dq)  v: (b, skv, h, dv).
    ``dq`` may be a truncated rank r — the caller supplies the proper scale
    (always 1/sqrt(d_head_original), per the paper's Eq. 1).
    kv_len masks out cache positions >= kv_len. ``chunked`` streams over KV
    blocks with a running softmax (flash semantics in pure XLA).

    Perf knobs (EXPERIMENTS.md §Perf): ``score_dtype=bf16`` stores the s^2
    score/prob tensors in bf16 (denominator still accumulated in f32);
    ``score_spec`` applies a sharding constraint to the score tensor
    (sequence-parallel attention: P(dp, None, 'model', None)).
    """
    if chunked and k.shape[1] > chunk:
        if return_probs:
            raise ValueError("return_probs is unsupported on the chunked "
                             "path (probs are never materialised)")
        return _attend_chunked(q, k, v, scale=scale, causal=causal,
                               q_offset=q_offset, kv_len=kv_len, chunk=chunk)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(score_dtype) * scale
    sq, skv = q.shape[1], k.shape[1]
    neg = jnp.asarray(-1e30, score_dtype)
    if causal:
        q_pos = jnp.arange(sq)[:, None] + q_offset
        k_pos = jnp.arange(skv)[None, :]
        s = jnp.where((k_pos <= q_pos)[None, None], s, neg)
    if kv_len is not None:
        valid = jnp.arange(skv)[None, None, None, :] < kv_len
        s = jnp.where(valid, s, neg)
    if score_spec is not None:
        s = jax.lax.with_sharding_constraint(s, score_spec)
    if score_dtype == jnp.float32:
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    else:
        # bf16 score chain: elementwise ops stay bf16 (halving the dominant
        # s^2 HBM traffic); the sum is accumulated in f32 (small tensor)
        m = jnp.max(s, axis=-1, keepdims=True)
        e = jnp.exp(s - m)
        denom = jnp.sum(e.astype(jnp.float32), axis=-1, keepdims=True)
        p = (e / jnp.maximum(denom, 1e-30).astype(score_dtype)).astype(v.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    return (out, p) if return_probs else out


def _attend_chunked(q, k, v, *, scale, causal, q_offset, kv_len, chunk):
    """Streaming-softmax attention over KV chunks (never materialises the
    full (sq, skv) score matrix in HBM — XLA analogue of flash attention)."""
    b, sq, h, dq = q.shape
    skv, dv = k.shape[1], v.shape[-1]
    n_chunks = (skv + chunk - 1) // chunk
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, chunk, h, dq)
    vc = v.reshape(b, n_chunks, chunk, h, dv)

    q_pos = jnp.arange(sq)[:, None] + q_offset

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, ci = xs
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kb).astype(jnp.float32) * scale
        k_pos = ci * chunk + jnp.arange(chunk)[None, :]
        mask = k_pos < (kv_len if kv_len is not None else skv)
        if causal:
            mask = mask & (k_pos <= q_pos)
        s = jnp.where(mask[None, None] if mask.ndim == 2 else mask, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(vb.dtype), vb).astype(jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.transpose(out, (0, 2, 1, 3)).astype(v.dtype)


# ---------------------------------------------------------------------------
# Rank decision + projection
# ---------------------------------------------------------------------------

def spectral_ctx(q: jnp.ndarray, k: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Per-head Gram spectra of q (b,s,hq,d) and k (b,s,hkv,d).

    Shapes: sigmas (b, h, d) descending; evecs (b, h, d, d)."""
    gq = lr.gram(jnp.swapaxes(q, 1, 2))            # (b, hq, d, d)
    gk = lr.gram(jnp.swapaxes(k, 1, 2))
    q_s2, q_e = lr.gram_spectrum(gq)
    k_s2, k_e = lr.gram_spectrum(gk)
    return {"q_s2": q_s2, "q_e": q_e, "k_s2": k_s2, "k_e": k_e}


def grid_array(rank_cfg: RankConfig) -> jnp.ndarray:
    return jnp.asarray(rank_cfg.rank_grid, jnp.int32)


def heuristic_rank(rank_cfg: RankConfig, ctx: Dict[str, jnp.ndarray],
                   rng: Optional[jax.Array]) -> jnp.ndarray:
    """Rank per (b, hkv) for the non-RL modes (fixed/adaptive/random)."""
    k_s2 = ctx["k_s2"]
    b, h = k_s2.shape[0], k_s2.shape[1]
    grid = rank_cfg.rank_grid
    if rank_cfg.mode == "fixed":
        return jnp.full((b, h), rank_cfg.fixed_rank, jnp.int32)
    if rank_cfg.mode == "adaptive":
        return lr.rank_for_energy(k_s2, rank_cfg.energy_threshold,
                                  grid[0], grid[-1])
    if rank_cfg.mode == "random":
        assert rng is not None, "random mode needs a PRNG key"
        idx = jax.random.randint(rng, (b, h), 0, len(grid))
        return jnp.asarray(grid, jnp.int32)[idx]
    raise ValueError(rank_cfg.mode)


def apply_rank_masked(q, k, ctx, rank_q: jnp.ndarray, rank_k: jnp.ndarray):
    """Project q/k onto their top-rank eigendirections ('masked' realisation).

    rank_q: (b, hq); rank_k: (b, hkv) traced ints."""
    d = q.shape[-1]
    mq = (jnp.arange(d)[None, None, :] < rank_q[..., None]).astype(jnp.float32)
    mk = (jnp.arange(d)[None, None, :] < rank_k[..., None]).astype(jnp.float32)
    qh = jnp.swapaxes(q, 1, 2)                      # (b, h, s, d)
    kh = jnp.swapaxes(k, 1, 2)
    q_r = lr.project_masked(qh, ctx["q_e"], mq)
    k_r = lr.project_masked(kh, ctx["k_e"], mk)
    return jnp.swapaxes(q_r, 1, 2), jnp.swapaxes(k_r, 1, 2)


def apply_rank_static(q, k, ctx, r: int):
    """Rank-r factors for the serving bucket: returns q~ (b,s,hq,r),
    k~ (b,s,hkv,r) such that q~ k~^T == Q_r K_r^T (both sides truncated)."""
    qh = jnp.swapaxes(q, 1, 2)
    kh = jnp.swapaxes(k, 1, 2)
    n_rep = q.shape[2] // k.shape[2]
    eq, ek = ctx["q_e"], ctx["k_e"]
    ek_rep = jnp.repeat(ek, n_rep, axis=1) if n_rep > 1 else ek
    m = lr.mixing_matrix(eq, ek_rep, r)             # (b, hq, r, r)
    q_t = lr.project_static(qh, eq, r)              # (b, hq, s, r)
    q_t = jnp.einsum("bhsr,bhrt->bhst", q_t.astype(jnp.float32), m).astype(q.dtype)
    k_t = lr.project_static(kh, ek, r)              # (b, hkv, s, r)
    return jnp.swapaxes(q_t, 1, 2), jnp.swapaxes(k_t, 1, 2)


# ---------------------------------------------------------------------------
# Full MHSA layer (projection + rope + rank logic + attend + output proj)
# ---------------------------------------------------------------------------

def mhsa(cfg: ModelConfig, p: Dict[str, Any], x: jnp.ndarray,
         positions: jnp.ndarray, *,
         rank_ctx: Optional[Dict[str, Any]] = None,
         cache: Optional[dict] = None,
         chunked: bool = False) -> Tuple[jnp.ndarray, Optional[dict], Dict[str, Any]]:
    """Standard/GQA MHSA with optional dynamic low-rank scores.

    rank_ctx (None = full rank): {
       'cfg': RankConfig, 'rng': key|None,
       'action_fn': callable(features)->(rank_q, rank_k, aux) for drrl mode,
       'prev_rank': (b, hkv) carry, 't': rl step for the annealed guardrail }
    Returns (output, new_cache, aux).
    """
    b, s, d = x.shape
    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    dh = cfg.resolved_head_dim()
    q = jnp.einsum("bsd,dhf->bshf", x, p["wq"].reshape(d, hq, dh).astype(x.dtype))
    k = jnp.einsum("bsd,dhf->bshf", x, p["wk"].reshape(d, hkv, dh).astype(x.dtype))
    v = jnp.einsum("bsd,dhf->bshf", x, p["wv"].reshape(d, hkv, dh).astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(hq, dh).astype(x.dtype)
        k = k + p["bk"].reshape(hkv, dh).astype(x.dtype)
        v = v + p["bv"].reshape(hkv, dh).astype(x.dtype)

    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    q_offset, kv_len, new_cache = 0, None, None
    if cache is not None:
        from repro.models.common import cache_update
        new_cache = cache_update(cache, k, v)
        k_full, v_full = new_cache["k"], new_cache["v"]
        q_offset, kv_len = cache["len"], new_cache["len"]
        if cfg.cache_seq_shard and cfg.mesh_axes:
            # split-KV decode: keep the cache in its stored layout — context
            # dim M sharded over 'model' — all the way through attention;
            # the partial-softmax combine is the only cross-shard traffic
            from jax.sharding import PartitionSpec as P
            dp = tuple(a for a in cfg.mesh_axes if a != "model")
            dp = dp if len(dp) > 1 else (dp[0] if dp else None)
            k_full = jax.lax.with_sharding_constraint(
                k_full, P(dp, "model", None, None))
            v_full = jax.lax.with_sharding_constraint(
                v_full, P(dp, "model", None, None))
    else:
        k_full, v_full = k, v

    aux: Dict[str, Any] = {}
    scale = dh ** -0.5
    rcfg = rank_ctx["cfg"] if rank_ctx else None
    if rank_ctx is not None and rank_ctx.get("collect_qkv", False):
        # qkv capture works in every rank mode, including 'off' (the serve
        # prefill captures per-layer q/k/v to seed the attention-mass pool
        # without perturbing the full-rank forward)
        aux["qkv"] = {"q": q, "k": k_full, "v": v_full}

    score_dtype = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[
        cfg.softmax_dtype]
    score_spec = None
    if cfg.cache_seq_shard and cfg.mesh_axes and cache is not None:
        from jax.sharding import PartitionSpec as P
        dp = tuple(a for a in cfg.mesh_axes if a != "model")
        dp = dp if len(dp) > 1 else (dp[0] if dp else None)
        score_spec = P(dp, None, None, "model")
    if cfg.seq_shard_attn and cfg.mesh_axes and cache is None:
        # sequence-parallel attention: scores (b, h, sq, skv) sharded over
        # (data..., model) on (batch, query-seq). Robust for every arch:
        # sq % 16 == 0 even when num_heads % 16 != 0 (the case that forced
        # GSPMD to gather the batch — see EXPERIMENTS.md §Perf).
        from jax.sharding import PartitionSpec as P
        dp = tuple(a for a in cfg.mesh_axes if a != "model")
        dp = dp if len(dp) > 1 else (dp[0] if dp else None)
        q = jax.lax.with_sharding_constraint(q, P(dp, "model", None, None))
        score_spec = P(dp, None, "model", None)

    if rcfg is not None and rcfg.mode in ("performer", "nystrom"):
        # static linear-attention baselines (paper Table 1/3 comparison set)
        from repro.core.baselines import nystrom_attention, performer_attention
        n_rep = hq // hkv
        kr, vr = repeat_kv(k_full, n_rep), repeat_kv(v_full, n_rep)
        if rcfg.mode == "performer":
            o = performer_attention(q, kr, vr, proj=rank_ctx["proj"],
                                    causal=cache is None)
        else:
            o = nystrom_attention(q, kr, vr,
                                  n_landmarks=rcfg.fixed_rank,
                                  causal=cache is None)
        out = jnp.einsum("bshf,hfd->bsd", o,
                         p["wo"].reshape(hq, dh, d).astype(x.dtype))
        return out, new_cache, aux

    if rcfg is None or rcfg.mode == "off":
        q_use, k_use = q, k_full
    else:
        ctx = spectral_ctx(q, k_full)
        aux["k_s2"] = ctx["k_s2"]
        if rcfg.mode == "drrl":
            rank_k, drrl_aux = rank_ctx["action_fn"](ctx, rank_ctx)
            aux.update(drrl_aux)
        else:
            rank_k = heuristic_rank(rcfg, ctx, rank_ctx.get("rng"))
        n_rep = hq // hkv
        rank_q = jnp.repeat(rank_k, n_rep, axis=1) if n_rep > 1 else rank_k
        aux["rank"] = rank_k
        q_s2_kv = (ctx["q_s2"].reshape(b, hkv, hq // hkv, dh).mean(2)
                   if hq != hkv else ctx["q_s2"])
        bounds, norm = pert.guardrail_report(q_s2_kv, ctx["k_s2"],
                                             rcfg.rank_grid, dh)
        aux["delta_a_grid"] = bounds
        aux["delta_a_norm"] = norm
        if rcfg.realisation == "static":
            r = rcfg.static_rank or int(rcfg.rank_grid[-1])
            q_use, k_use = apply_rank_static(q, k_full, ctx, r)
        else:
            q_use, k_use = apply_rank_masked(q, k_full, ctx, rank_q, rank_k)
        if rcfg.truncate_values and rcfg.realisation == "masked":
            # value-side truncation (paper Eq. 5/10 analysis): V projected
            # onto its own top-rank eigenbasis; cuts the n^2 d_v term too
            gv = lr.gram(jnp.swapaxes(v_full, 1, 2))
            v_s2, v_e = lr.gram_spectrum(gv)
            mv = (jnp.arange(v_full.shape[-1])[None, None, :]
                  < rank_k[..., None]).astype(jnp.float32)
            v_full = jnp.swapaxes(
                lr.project_masked(jnp.swapaxes(v_full, 1, 2), v_e, mv), 1, 2)
        if rank_ctx.get("compute_fidelity", False):
            # cosine similarity between full-rank and low-rank outputs (Eq. 8)
            o_full = attend(q, repeat_kv(k_full, hq // hkv),
                            repeat_kv(v_full, hq // hkv), scale=scale,
                            causal=True, q_offset=q_offset,
                            kv_len=kv_len, chunked=chunked)
            aux["_o_full"] = o_full

    n_rep = hq // hkv
    k_use_r = repeat_kv(k_use, n_rep)
    v_use = repeat_kv(v_full, n_rep)
    if rank_ctx is not None and rank_ctx.get("collect_mass", False):
        # per-key attention mass off the same softmax chain the output
        # uses (no second score pass, honours score_dtype): summed over
        # valid queries, group-meaned over each kv head's q heads. The
        # serve prefill seeds its paged mass accumulator with this.
        o, pr = attend(q_use, k_use_r, v_use, scale=scale, causal=True,
                       q_offset=q_offset, kv_len=kv_len, chunked=chunked,
                       score_dtype=score_dtype, score_spec=score_spec,
                       return_probs=True)
        prf = pr.astype(jnp.float32)               # (b, hq, sq, skv)
        mql = rank_ctx.get("mass_q_len")
        if mql is not None:
            # padded-bucket prefill: garbage queries beyond the prompt
            # must not scatter mass back onto real keys
            q_ok = (jnp.arange(prf.shape[2]) < mql).astype(jnp.float32)
            prf = prf * q_ok[None, None, :, None]
        from repro.models.common import kv_group_mean
        aux["mass"] = kv_group_mean(jnp.sum(prf, axis=2), hkv)
    else:
        o = attend(q_use, k_use_r, v_use, scale=scale, causal=True,
                   q_offset=q_offset, kv_len=kv_len, chunked=chunked,
                   score_dtype=score_dtype, score_spec=score_spec)
    if "_o_full" in aux:
        of, ol = aux.pop("_o_full"), o
        num = jnp.sum(of.astype(jnp.float32) * ol.astype(jnp.float32), axis=(1, 3))
        den = (jnp.linalg.norm(of.astype(jnp.float32), axis=(1, 3))
               * jnp.linalg.norm(ol.astype(jnp.float32), axis=(1, 3)) + 1e-30)
        aux["fidelity"] = num / den                # (b, hq) cosine sim
    out = jnp.einsum("bshf,hfd->bsd", o, p["wo"].reshape(hq, dh, d).astype(x.dtype))
    return out, new_cache, aux


def attention_flops(seq: int, kv: int, hq: int, dh: int, dv: int, rank=None) -> float:
    """MAC-counted (x2) attention score+value FLOPs per sequence per head set.
    With rank-r scores the QK^T contraction runs over r instead of dh."""
    c = rank if rank is not None else dh
    return 2.0 * hq * (seq * kv * c + seq * kv * dv)
