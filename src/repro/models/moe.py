"""Mixture-of-Experts FFN with sort-based grouped dispatch.

Static-shape, GSPMD-friendly: tokens' (token, expert) pairs are sorted by
expert id, placed into a fixed-capacity (E, C, d) buffer (overflow dropped),
run through batched expert SwiGLUs (one einsum over the expert dim — the
expert dim is sharded over the `model` mesh axis => expert parallelism), and
scattered back with gate weighting. Supports shared experts (DeepSeek-V3) and
a load-balancing auxiliary loss.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ModelConfig


@jax.custom_jvp
def _grad_barrier(x):
    # optimization_barrier has no AD rule on the pinned jax; the barrier is
    # an identity, so its tangent passes straight through
    return jax.lax.optimization_barrier(x)


@_grad_barrier.defjvp
def _grad_barrier_jvp(primals, tangents):
    return _grad_barrier(primals[0]), tangents[0]


def init_moe(cfg: ModelConfig, rng, dtype) -> Dict[str, jnp.ndarray]:
    assert cfg.moe is not None
    m, d = cfg.moe, cfg.d_model
    ks = nn.split_keys(rng, 5)
    p = {
        "router": nn.dense_init(ks[0], d, m.num_experts, jnp.float32, scale=0.02),
        # stacked expert weights: (E, d, f) / (E, f, d)
        "w_gate": jax.vmap(lambda k: nn.dense_init(k, d, m.d_expert, dtype))(
            jax.random.split(ks[1], m.num_experts)),
        "w_up": jax.vmap(lambda k: nn.dense_init(k, d, m.d_expert, dtype))(
            jax.random.split(ks[2], m.num_experts)),
        "w_down": jax.vmap(lambda k: nn.dense_init(k, m.d_expert, d, dtype))(
            jax.random.split(ks[3], m.num_experts)),
    }
    if m.num_shared_experts:
        f = m.d_shared * m.num_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": nn.dense_init(k1, d, f, dtype),
            "w_up": nn.dense_init(k2, d, f, dtype),
            "w_down": nn.dense_init(k3, f, d, dtype),
        }
    return p


def moe_ffn(cfg: ModelConfig, p: Dict[str, jnp.ndarray], x: jnp.ndarray
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x: (b, s, d) -> (y, aux{'aux_loss'})."""
    m = cfg.moe
    b, s, d = x.shape
    T = b * s
    E, K = m.num_experts, m.top_k
    C = max(int(T * K / E * m.capacity_factor), 1)

    xf = x.reshape(T, d)
    # router: bf16 operands with f32 accumulation — a full f32 copy of xf
    # would get reused by XLA as the dispatch-gather source, running the
    # (T*K, d) 240 GB/op chain in f32 (EXPERIMENTS.md §Perf H5)
    logits = jnp.einsum("td,de->te", xf, p["router"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    # barrier: keep the gather source pinned to the bf16 value
    xf = _grad_barrier(xf)
    probs = jax.nn.softmax(logits, axis=-1)                       # (T, E)
    gates, idx = jax.lax.top_k(probs, K)                          # (T, K)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # ---- load-balancing auxiliary loss (Switch-style) ----
    me = jnp.mean(probs, axis=0)                                  # (E,)
    ce = jnp.mean(jax.nn.one_hot(idx, E).sum(1), axis=0)          # (E,)
    aux_loss = m.router_aux_coef * E * jnp.sum(me * ce)

    # ---- sort-based dispatch ----
    flat_e = idx.reshape(-1)                                      # (T*K,)
    order = jnp.argsort(flat_e)                                   # stable
    se = flat_e[order]                                            # sorted experts
    tok = order // K                                              # source token
    counts = jax.ops.segment_sum(jnp.ones_like(flat_e), flat_e, num_segments=E)
    starts = jnp.cumsum(counts) - counts                          # exclusive
    pos = jnp.arange(T * K) - starts[se]                          # slot in expert
    keep = pos < C
    slot = jnp.where(keep, se * C + jnp.clip(pos, 0, C - 1), E * C)  # E*C = trash

    buf = jnp.zeros((E * C + 1, d), x.dtype).at[slot].set(xf[tok])
    h = buf[:E * C].reshape(E, C, d)

    # ---- batched expert SwiGLU (expert dim shardable over 'model') ----
    g = jnp.einsum("ecd,edf->ecf", h, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", h, p["w_up"].astype(x.dtype))
    o = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                   p["w_down"].astype(x.dtype))

    # ---- combine ----
    # NB: keep the (T*K, d) gather/scatter chain in the activation dtype —
    # an f32 gate multiply here promotes a 240 GB/op fusion chain to f32 on
    # the deepseek-v3 train cell (EXPERIMENTS.md §Perf H5)
    o_slots = o.reshape(E * C, d)
    gate_sorted = (gates.reshape(-1)[order] * keep).astype(x.dtype)
    contrib = o_slots[jnp.clip(slot, 0, E * C - 1)] * gate_sorted[:, None]
    y = jnp.zeros((T, d), x.dtype).at[tok].add(contrib)

    if "shared" in p:
        sp = p["shared"]
        y = y + nn.swiglu(xf, sp["w_gate"], sp["w_up"], sp["w_down"])
    return y.reshape(b, s, d), {"aux_loss": aux_loss}


def moe_ffn_dense_fallback(cfg: ModelConfig, p, x):
    """Reference (oracle) implementation: every expert on every token, then
    gate-weighted sum. O(T*E) compute — used only in tests."""
    m = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    g = jnp.einsum("td,edf->tef", xf, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("td,edf->tef", xf, p["w_up"].astype(x.dtype))
    o = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * u, p["w_down"].astype(x.dtype))
    w = jnp.zeros(probs.shape, x.dtype)
    w = jax.vmap(lambda wi, ii, gi: wi.at[ii].set(gi.astype(x.dtype)))(w, idx, gates)
    y = jnp.einsum("te,ted->td", w, o)
    if "shared" in p:
        sp = p["shared"]
        y = y + nn.swiglu(xf, sp["w_gate"], sp["w_up"], sp["w_down"])
    return y.reshape(b, s, d)
