"""DeepSeek-V3 family: MLA attention, 1 shared + 256 routed experts (top-8),
first 3 layers dense, multi-token prediction (MTP) head.

Layout: the 3 dense-bottom layers are unrolled (heterogeneous params); the
58 MoE layers run under scan with stacked params.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ModelConfig
from repro.models import moe as moe_mod
from repro.models.mla import init_mla, init_mla_cache, mla_decode, mla_train
from repro.models.transformer import init_ffn


def _init_block(cfg: ModelConfig, rng, dtype, dense: bool):
    k1, k2 = jax.random.split(rng)
    p = {
        "attn": init_mla(cfg, k1, dtype),
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
    }
    if dense:
        p["ffn"] = init_ffn(cfg, k2, dtype, d_ff=cfg.dense_d_ff or cfg.d_ff)
    else:
        p["moe"] = moe_mod.init_moe(cfg, k2, dtype)
    return p


def init_deepseek(cfg: ModelConfig, rng) -> Dict[str, Any]:
    dtype = nn.dt(cfg.param_dtype)
    n_dense = cfg.first_dense_layers
    n_moe = cfg.num_layers - n_dense
    k_emb, k_dense, k_moe, k_head, k_mtp = jax.random.split(rng, 5)
    params: Dict[str, Any] = {
        "embed": nn.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "dense_layers": [_init_block(cfg, k, dtype, True)
                         for k in jax.random.split(k_dense, max(n_dense, 1))][:n_dense],
        "moe_layers": jax.vmap(lambda k: _init_block(cfg, k, dtype, False))(
            jax.random.split(k_moe, n_moe)),
        "ln_f": jnp.ones((cfg.d_model,), dtype),
        "lm_head": nn.dense_init(k_head, cfg.d_model, cfg.vocab_size, dtype),
    }
    if cfg.mtp_depth > 0:
        km1, km2 = jax.random.split(k_mtp)
        params["mtp"] = {
            "proj": nn.dense_init(km1, 2 * cfg.d_model, cfg.d_model, dtype),
            "block": _init_block(cfg, km2, dtype, False),
            "ln_h": jnp.ones((cfg.d_model,), dtype),
            "ln_e": jnp.ones((cfg.d_model,), dtype),
        }
    return params


def _block(cfg: ModelConfig, lp, x, positions, rank_ctx, chunked):
    h, aux = mla_train(cfg, lp["attn"], nn.rms_norm(x, lp["ln1"], cfg.rms_eps),
                       positions, rank_ctx=rank_ctx, chunked=chunked)
    x = x + h
    xin = nn.rms_norm(x, lp["ln2"], cfg.rms_eps)
    if "moe" in lp:
        f, moe_aux = moe_mod.moe_ffn(cfg, lp["moe"], xin)
        aux = {**aux, **moe_aux}
    else:
        f = nn.swiglu(xin, lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                      lp["ffn"]["w_down"])
    return x + f, aux


def forward_deepseek(cfg: ModelConfig, params, tokens, *, positions=None,
                     rank_ctx0=None, collect_aux: str = "none",
                     chunked: bool = False) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    dtype = nn.dt(cfg.dtype)
    x = params["embed"][tokens].astype(dtype)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    for lp in params["dense_layers"]:
        x, aux = _block(cfg, lp, x, positions, rank_ctx0, chunked)

    def body(carry, lp):
        x = carry
        x, aux = _block(cfg, lp, x, positions, rank_ctx0, chunked)
        return x, aux.get("aux_loss", jnp.zeros((), jnp.float32))

    body_fn = body
    if cfg.remat != "none":
        body_fn = jax.checkpoint(
            body, policy=(jax.checkpoint_policies.checkpoint_dots
                          if cfg.remat == "dots" else None))
    from repro.models.common import scan_or_unroll
    x, moe_aux = scan_or_unroll(body_fn, x, params["moe_layers"],
                                unroll=not cfg.scan_layers)
    h_final = nn.rms_norm(x, params["ln_f"], cfg.rms_eps)
    logits = jnp.einsum("bsd,dv->bsv", h_final,
                        params["lm_head"].astype(x.dtype))
    aux_out: Dict[str, Any] = {"aux_loss": jnp.sum(moe_aux)}

    if cfg.mtp_depth > 0 and "mtp" in params:
        # MTP depth 1: predict token t+2 from [h_t ; emb(token_{t+1})]
        mtp = params["mtp"]
        emb_next = params["embed"][tokens[:, 1:]].astype(dtype)   # (b, s-1, d)
        h_in = jnp.concatenate(
            [nn.rms_norm(x[:, :-1], mtp["ln_h"], cfg.rms_eps),
             nn.rms_norm(emb_next, mtp["ln_e"], cfg.rms_eps)], axis=-1)
        h_mtp = nn.linear(h_in, mtp["proj"])
        h_mtp, mtp_aux = _block(cfg, mtp["block"], h_mtp, positions[:, :-1],
                                rank_ctx0, chunked)
        mtp_logits = jnp.einsum("bsd,dv->bsv",
                                nn.rms_norm(h_mtp, params["ln_f"], cfg.rms_eps),
                                params["lm_head"].astype(x.dtype))
        aux_out["mtp_logits"] = mtp_logits
        aux_out["aux_loss"] = aux_out["aux_loss"] + mtp_aux.get(
            "aux_loss", jnp.zeros(()))
    return logits, aux_out


def loss_deepseek(cfg: ModelConfig, params, batch, *, mtp_weight: float = 0.3,
                  **kw):
    from repro.dist.ctx import logits_spec
    spec = logits_spec(cfg)
    logits, aux = forward_deepseek(cfg, params, batch["tokens"], **kw)
    loss = nn.softmax_cross_entropy(logits, batch["labels"],
                                    batch.get("mask"), spec=spec)
    if "mtp_logits" in aux:
        # labels for t+2 prediction: shift labels by one more step
        mtp_labels = batch["labels"][:, 1:]
        loss = loss + mtp_weight * nn.softmax_cross_entropy(
            aux["mtp_logits"], mtp_labels, spec=spec)
    return loss + aux["aux_loss"], aux


def init_cache_deepseek(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dtype = nn.dt(cfg.dtype)
    cache = init_mla_cache(cfg, batch, max_len, cfg.num_layers, dtype)
    return cache


def decode_step_deepseek(cfg: ModelConfig, params, cache, tokens, *,
                         positions=None):
    """One decode step with the absorbed-MLA latent cache.

    cache ckv/krope are stacked (L, b, M, ...); dense-bottom layers use
    slices [0:n_dense], MoE layers the rest (scanned)."""
    dtype = nn.dt(cfg.dtype)
    x = params["embed"][tokens].astype(dtype)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(cache["len"] + jnp.arange(s)[None], (b, s))
    n_dense = cfg.first_dense_layers

    new_ckv, new_krope = [], []
    for li, lp in enumerate(params["dense_layers"]):
        lc = {"ckv": cache["ckv"][li], "krope": cache["krope"][li],
              "len": cache["len"]}
        h, nc = mla_decode(cfg, lp["attn"],
                           nn.rms_norm(x, lp["ln1"], cfg.rms_eps), positions, lc)
        x = x + h
        xin = nn.rms_norm(x, lp["ln2"], cfg.rms_eps)
        x = x + nn.swiglu(xin, lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                          lp["ffn"]["w_down"])
        new_ckv.append(nc["ckv"])
        new_krope.append(nc["krope"])

    def body(carry, xs):
        x = carry
        lp, ckv_l, krope_l = xs
        lc = {"ckv": ckv_l, "krope": krope_l, "len": cache["len"]}
        h, nc = mla_decode(cfg, lp["attn"],
                           nn.rms_norm(x, lp["ln1"], cfg.rms_eps), positions, lc)
        x = x + h
        f, _ = moe_mod.moe_ffn(cfg, lp["moe"],
                               nn.rms_norm(x, lp["ln2"], cfg.rms_eps))
        return x + f, (nc["ckv"], nc["krope"])

    from repro.models.common import scan_or_unroll
    x, (moe_ckv, moe_krope) = scan_or_unroll(
        body, x, (params["moe_layers"], cache["ckv"][n_dense:],
                  cache["krope"][n_dense:]), unroll=not cfg.scan_layers)
    x = nn.rms_norm(x, params["ln_f"], cfg.rms_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    ckv = (jnp.concatenate([jnp.stack(new_ckv), moe_ckv]) if new_ckv else moe_ckv)
    krope = (jnp.concatenate([jnp.stack(new_krope), moe_krope])
             if new_krope else moe_krope)
    return logits, {"ckv": ckv, "krope": krope, "len": cache["len"] + s}
