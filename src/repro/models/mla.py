"""Multi-head Latent Attention (DeepSeek-V3) with optional DR-RL composition.

MLA is itself a *static* low-rank compression of the KV path (kv_lora_rank).
DR-RL composes on top by dynamically truncating the score contraction of the
assembled per-head q/k (dim qk_nope+qk_rope) — see DESIGN.md section 5.
Decode uses the absorbed formulation: the cache holds only the (kv_lora +
rope) latent per token.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ModelConfig
from repro.models.attention import (apply_rank_masked, attend, heuristic_rank,
                                    spectral_ctx)
from repro.models.common import apply_rope


def init_mla(cfg: ModelConfig, rng, dtype) -> Dict[str, jnp.ndarray]:
    m, d, h = cfg.mla, cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = nn.split_keys(rng, 5)
    return {
        "wq_a": nn.dense_init(ks[0], d, m.q_lora_rank, dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "wq_b": nn.dense_init(ks[1], m.q_lora_rank, h * qk, dtype),
        "wkv_a": nn.dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "wkv_b": nn.dense_init(ks[3], m.kv_lora_rank,
                               h * (m.qk_nope_head_dim + m.v_head_dim), dtype),
        "wo": nn.dense_init(ks[4], h * m.v_head_dim, d, dtype,
                            scale=(h * m.v_head_dim) ** -0.5
                            / (2 * cfg.num_layers) ** 0.5),
    }


def _project_q(cfg: ModelConfig, p, x, positions):
    m, h = cfg.mla, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    b, s, _ = x.shape
    q_lat = nn.rms_norm(nn.linear(x, p["wq_a"]), p["q_norm"], cfg.rms_eps)
    q = nn.linear(q_lat, p["wq_b"]).reshape(b, s, h, qk)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_train(cfg: ModelConfig, p, x, positions, *,
              rank_ctx: Optional[Dict[str, Any]] = None,
              chunked: bool = False) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Training/prefill path (non-absorbed): materialise per-head k/v."""
    m, h = cfg.mla, cfg.num_heads
    b, s, _ = x.shape
    q_nope, q_rope = _project_q(cfg, p, x, positions)

    kv = nn.linear(x, p["wkv_a"])
    c_kv, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    c_kv = nn.rms_norm(c_kv, p["kv_norm"], cfg.rms_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)

    kvb = nn.linear(c_kv, p["wkv_b"]).reshape(
        b, s, h, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kvb, [m.qk_nope_head_dim], axis=-1)

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope, k_nope.shape[:3] + (m.qk_rope_head_dim,))],
                        axis=-1)
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    scale = qk ** -0.5
    aux: Dict[str, Any] = {}
    rcfg = rank_ctx["cfg"] if rank_ctx else None
    if rcfg is not None and rcfg.mode != "off":
        ctx = spectral_ctx(q, k)
        aux["k_s2"] = ctx["k_s2"]
        if rcfg.mode == "drrl":
            rank_k, drrl_aux = rank_ctx["action_fn"](ctx, rank_ctx)
            aux.update(drrl_aux)
        else:
            rank_k = heuristic_rank(rcfg, ctx, rank_ctx.get("rng"))
        aux["rank"] = rank_k
        q, k = apply_rank_masked(q, k, ctx, rank_k, rank_k)
    score_dtype = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[
        cfg.softmax_dtype]
    score_spec = None
    if cfg.seq_shard_attn and cfg.mesh_axes:
        from jax.sharding import PartitionSpec as P
        dp = tuple(a for a in cfg.mesh_axes if a != "model")
        dp = dp if len(dp) > 1 else (dp[0] if dp else None)
        q = jax.lax.with_sharding_constraint(q, P(dp, "model", None, None))
        score_spec = P(dp, None, "model", None)
    o = attend(q, k, v, scale=scale, causal=True, chunked=chunked,
               score_dtype=score_dtype, score_spec=score_spec)
    out = jnp.einsum("bshf,hfd->bsd", o,
                     p["wo"].reshape(h, m.v_head_dim, cfg.d_model).astype(x.dtype))
    return out, aux


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int,
                   dtype) -> dict:
    m = cfg.mla
    return {
        "ckv": jnp.zeros((n_layers, batch, max_len, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((n_layers, batch, max_len, m.qk_rope_head_dim), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def mla_decode(cfg: ModelConfig, p, x, positions, layer_cache: dict
               ) -> Tuple[jnp.ndarray, dict]:
    """Absorbed decode: scores and values computed against the latent cache.
    layer_cache: {'ckv': (b, M, kv_lora), 'krope': (b, M, rope), 'len'}."""
    m, h = cfg.mla, cfg.num_heads
    b, s, _ = x.shape
    q_nope, q_rope = _project_q(cfg, p, x, positions)

    kv = nn.linear(x, p["wkv_a"])
    c_kv_new, k_rope_new = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    c_kv_new = nn.rms_norm(c_kv_new, p["kv_norm"], cfg.rms_eps)
    k_rope_new = apply_rope(k_rope_new[:, :, None, :], positions,
                            cfg.rope_theta)[:, :, 0, :]

    idx = layer_cache["len"]
    ckv = jax.lax.dynamic_update_slice(
        layer_cache["ckv"], c_kv_new.astype(layer_cache["ckv"].dtype), (0, idx, 0))
    krope = jax.lax.dynamic_update_slice(
        layer_cache["krope"], k_rope_new.astype(layer_cache["krope"].dtype), (0, idx, 0))
    kv_len = idx + s

    # absorb W_uk into q: q_abs (b, s, h, kv_lora)
    wkv_b = p["wkv_b"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim)
    w_uk = wkv_b[..., :m.qk_nope_head_dim]          # (kv_lora, h, nope)
    w_uv = wkv_b[..., m.qk_nope_head_dim:]          # (kv_lora, h, v)
    q_abs = jnp.einsum("bshn,chn->bshc", q_nope, w_uk.astype(x.dtype))

    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    scale = qk ** -0.5
    scores = (jnp.einsum("bshc,bmc->bhsm", q_abs, ckv)
              + jnp.einsum("bshr,bmr->bhsm", q_rope, krope)
              ).astype(jnp.float32) * scale
    q_pos = idx + jnp.arange(s)[:, None]
    k_pos = jnp.arange(ckv.shape[1])[None, :]
    mask = (k_pos <= q_pos) & (k_pos < kv_len)
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o_c = jnp.einsum("bhsm,bmc->bshc", probs, ckv)  # latent-space output
    o = jnp.einsum("bshc,chv->bshv", o_c, w_uv.astype(x.dtype))
    out = jnp.einsum("bshv,hvd->bsd", o,
                     p["wo"].reshape(h, m.v_head_dim, cfg.d_model).astype(x.dtype))
    return out, {"ckv": ckv, "krope": krope, "len": kv_len}
