"""Unified model API: family-dispatched init / loss / decode / input_specs.

Every assigned architecture runs through this interface; the launch layer
(dryrun/train/serve) and the benchmarks never touch family modules directly.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.base import ModelConfig, ShapeCell


class ModelFns(NamedTuple):
    init: Callable[[jax.Array], Any]
    loss: Callable[..., Any]                    # (params, batch, **kw) -> (loss, aux)
    init_cache: Callable[..., Any]              # (batch, max_len) -> cache
    decode_step: Callable[..., Any]             # (params, cache, tokens) -> (logits, cache)
    input_specs: Callable[[ShapeCell], Dict[str, Any]]
    # continuous-batching fused step over a slot-paged cache (repro.serve):
    # per-row kv_len/rank, and per-row query chunks (q_lens/prefill_rows)
    # so chunked prefill interleaves into the same executable; None for
    # families the serving engine does not cover yet
    decode_step_paged: Optional[Callable[..., Any]] = None


def get_model(cfg: ModelConfig) -> ModelFns:
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return _dense_fns(cfg)
    if fam == "moe" and cfg.mla is not None:
        return _deepseek_fns(cfg)
    if fam == "moe":
        return _dense_fns(cfg)                   # granite: dense attn + moe ffn
    if fam == "hybrid":
        return _zamba_fns(cfg)
    if fam == "rwkv":
        return _rwkv_fns(cfg)
    if fam == "encdec":
        return _encdec_fns(cfg)
    raise ValueError(f"unknown family {fam}")


def _batch_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for a train/prefill batch."""
    b, s = cell.global_batch, cell.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if cfg.family == "vlm":
        # stubbed modality frontend: patch embeddings prepended; positions are
        # the 3-stream M-RoPE ids
        n_patch = cfg.frontend_positions
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, n_patch, cfg.d_model), nn.dt(cfg.dtype))
        specs["positions"] = jax.ShapeDtypeStruct((b, 3, s + n_patch), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((b, s + n_patch), jnp.int32)
    if cfg.family == "encdec":
        src = cfg.frontend_positions or s
        specs["frames"] = jax.ShapeDtypeStruct((b, src, cfg.d_model),
                                               nn.dt(cfg.dtype))
    return specs


# ---------------------------------------------------------------------------

def _dense_fns(cfg: ModelConfig) -> ModelFns:
    from repro.models import transformer as tr

    def loss(params, batch, **kw):
        extra = batch.get("patch_embeds")
        return tr.loss_dense(cfg, params, batch,
                             positions=batch.get("positions"),
                             extra_embeddings=extra, **kw)

    def input_specs(cell: ShapeCell) -> Dict[str, Any]:
        if cell.kind in ("train", "prefill"):
            return {"batch": _batch_specs(cfg, cell)}
        b = cell.global_batch
        cache = jax.eval_shape(lambda: tr.init_cache_dense(cfg, b, cell.seq_len))
        cache = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), cache)
        return {"cache": cache,
                "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}

    return ModelFns(
        init=lambda rng: tr.init_dense(cfg, rng),
        loss=loss,
        init_cache=lambda b, m: tr.init_cache_dense(cfg, b, m),
        decode_step=lambda params, cache, tokens, **kw:
            tr.decode_step_dense(cfg, params, cache, tokens, **kw),
        input_specs=input_specs,
        decode_step_paged=(None if cfg.mrope else
                           lambda params, *a, **kw:
                           tr.decode_step_paged(cfg, params, *a, **kw)),
    )


def _deepseek_fns(cfg: ModelConfig) -> ModelFns:
    from repro.models import deepseek_v3 as ds

    def input_specs(cell: ShapeCell) -> Dict[str, Any]:
        if cell.kind in ("train", "prefill"):
            return {"batch": _batch_specs(cfg, cell)}
        b = cell.global_batch
        cache = jax.eval_shape(lambda: ds.init_cache_deepseek(cfg, b, cell.seq_len))
        cache = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), cache)
        return {"cache": cache,
                "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}

    return ModelFns(
        init=lambda rng: ds.init_deepseek(cfg, rng),
        loss=lambda params, batch, **kw: ds.loss_deepseek(cfg, params, batch, **kw),
        init_cache=lambda b, m: ds.init_cache_deepseek(cfg, b, m),
        decode_step=lambda params, cache, tokens, **kw:
            ds.decode_step_deepseek(cfg, params, cache, tokens, **kw),
        input_specs=input_specs,
    )


def _zamba_fns(cfg: ModelConfig) -> ModelFns:
    from repro.models import zamba2 as zb

    def decode_step(params, cache, tokens, **kw):
        logits, aux = zb.forward_zamba(cfg, params, tokens, cache=cache, **kw)
        return logits, aux["cache"]

    def input_specs(cell: ShapeCell) -> Dict[str, Any]:
        if cell.kind in ("train", "prefill"):
            return {"batch": _batch_specs(cfg, cell)}
        b = cell.global_batch
        cache = jax.eval_shape(lambda: zb.init_cache_zamba(cfg, b, cell.seq_len))
        cache = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), cache)
        return {"cache": cache,
                "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}

    return ModelFns(
        init=lambda rng: zb.init_zamba(cfg, rng),
        loss=lambda params, batch, **kw: zb.loss_zamba(cfg, params, batch, **kw),
        init_cache=lambda b, m: zb.init_cache_zamba(cfg, b, m),
        decode_step=decode_step,
        input_specs=input_specs,
    )


def _rwkv_fns(cfg: ModelConfig) -> ModelFns:
    from repro.models import rwkv_lm as rk

    def input_specs(cell: ShapeCell) -> Dict[str, Any]:
        if cell.kind in ("train", "prefill"):
            return {"batch": _batch_specs(cfg, cell)}
        b = cell.global_batch
        cache = jax.eval_shape(lambda: rk.init_cache_rwkv(cfg, b))
        cache = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), cache)
        return {"cache": cache,
                "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}

    return ModelFns(
        init=lambda rng: rk.init_rwkv_lm(cfg, rng),
        loss=lambda params, batch, **kw: rk.loss_rwkv(cfg, params, batch, **kw),
        init_cache=lambda b, m: rk.init_cache_rwkv(cfg, b),
        decode_step=lambda params, cache, tokens, **kw:
            rk.decode_step_rwkv(cfg, params, cache, tokens),
        input_specs=input_specs,
    )


def _encdec_fns(cfg: ModelConfig) -> ModelFns:
    from repro.models import encdec as ed

    def input_specs(cell: ShapeCell) -> Dict[str, Any]:
        if cell.kind in ("train", "prefill"):
            return {"batch": _batch_specs(cfg, cell)}
        b = cell.global_batch
        src = cfg.frontend_positions or 1024
        cache = jax.eval_shape(
            lambda: ed.init_cache_encdec(cfg, b, cell.seq_len, src))
        cache = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), cache)
        return {"cache": cache,
                "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}

    return ModelFns(
        init=lambda rng: ed.init_encdec(cfg, rng),
        loss=lambda params, batch, **kw: ed.loss_encdec(cfg, params, batch, **kw),
        init_cache=lambda b, m: ed.init_cache_encdec(
            cfg, b, m, cfg.frontend_positions or 1024),
        decode_step=lambda params, cache, tokens, **kw:
            ed.decode_step_encdec(cfg, params, cache, tokens),
        input_specs=input_specs,
    )
