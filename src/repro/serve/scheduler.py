"""Request queue + slot admission/eviction for the serving engine.

Control plane only: everything here is host-side Python over tiny arrays.
The data plane (pools, fused step) lives in kv_cache.py / engine.py.

Admission is FIFO over *arrived* requests: a request joins a free slot as
soon as one exists, its arrival step has passed, and the page pool can
cover ``prompt_len + max_new`` tokens (under prefix caching the
``can_allocate`` hook also matches the prompt against the radix tree and
shares the hit's pages). Prefill lengths are bucketed (powers of two by
default) so the prefill executable compiles once per bucket, not once per
prompt length. Eviction happens on EOS or when ``max_new`` tokens have
been decoded; releasing a slot *decrements* its pages' refcounts — a page
returns to the pool when its last reference (sharing slot or cached
prefix) drops.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class Request:
    """One generation request. ``tokens`` is the prompt (1-D int array).

    ``temperature``/``top_k``/``top_p``/``seed`` are the in-graph sampling
    knobs (repro.serve.api.SamplingParams maps onto them): temperature 0
    is greedy argmax; top_k 0 samples the full vocabulary; top_p 1
    disables the nucleus cut; the seed keys a per-token PRNG fold so a
    stream's draw sequence is reproducible regardless of engine
    batching."""
    rid: int
    tokens: np.ndarray
    max_new: int
    arrival: int = 0                 # engine step at which it may be admitted
    eos_id: Optional[int] = None
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens, np.int32).reshape(-1)
        if self.tokens.size == 0:
            raise ValueError("empty prompt")
        if self.max_new < 1:
            raise ValueError("max_new must be >= 1")
        if self.arrival < 0:
            raise ValueError(f"negative arrival step {self.arrival}")
        if self.temperature < 0.0:
            raise ValueError(f"negative temperature {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"negative top_k {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")


@dataclass
class SlotState:
    req: Optional[Request] = None
    prompt_len: int = 0
    prefilled: int = 0     # prompt tokens written so far (chunked prefill)
    decode_i: int = 0      # fused decode steps taken for this stream
    t: int = 0             # segment counter (annealed-threshold clock)
    n_out: int = 0         # tokens produced so far (prefill token included)
    last_tok: Optional[int] = None   # synced from device only when eos_id set
    # wall-clock per-token latencies (filled by the engine when timing)
    latencies: List[float] = field(default_factory=list)
    admit_s: float = 0.0   # perf_counter at admission (TTFT reference)
    # speculative decode: accepted run length (incl. the free verify
    # token) of each fused step this stream decoded in — 1 means every
    # draft was rejected, draft_k + 1 means all survived
    accept_lens: List[int] = field(default_factory=list)

    @property
    def active(self) -> bool:
        return self.req is not None

    @property
    def mid_prefill(self) -> bool:
        """True while a chunked prompt is still being consumed. A slot in
        this state owns its pages and its queue identity: it must never be
        double-admitted (``active`` covers that) nor evicted early — it has
        produced no token yet, so neither EOS nor max_new can apply."""
        return self.req is not None and self.prefilled < self.prompt_len


def prefill_buckets(max_prompt: int, floor: int = 8) -> Tuple[int, ...]:
    """Power-of-two length buckets covering [1, max_prompt].

    The top bucket is clamped to ``max_prompt``: for non-power-of-two
    maxima (e.g. 100) the unclamped doubling would emit a bucket (128)
    larger than any slot can hold, compiling a prefill executable and
    cache no request is ever allowed to fill."""
    out, b = [], floor
    while b < max_prompt:
        out.append(b)
        b *= 2
    out.append(min(b, max_prompt))
    return tuple(out)


def bucket_for(length: int, buckets: Sequence[int]) -> int:
    for b in buckets:
        if length <= b:
            return b
    raise ValueError(f"prompt length {length} exceeds largest bucket "
                     f"{buckets[-1]}")


class Scheduler:
    """FIFO admission over n_slots decode lanes."""

    def __init__(self, n_slots: int, buckets: Sequence[int]):
        self.n_slots = n_slots
        self.buckets = tuple(buckets)
        self.pending: Deque[Request] = deque()
        self.slots = [SlotState() for _ in range(n_slots)]
        self.finished: List[Tuple[Request, List[int]]] = []

    def submit(self, req: Request) -> None:
        bucket_for(len(req.tokens), self.buckets)   # validate early
        self.pending.append(req)

    # -- admission -------------------------------------------------------

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.active]

    def admit(self, now: int, can_allocate) -> List[Tuple[int, Request, int]]:
        """Assign arrived requests to free slots, FIFO. ``can_allocate(slot,
        total_len) -> bool`` is the page-pool reservation hook. Returns
        [(slot, request, padded_prefill_bucket)]. A head-of-queue request
        that cannot be placed (pages exhausted) blocks the queue — FIFO, no
        starvation via overtaking."""
        placed = []
        free = self.free_slots()
        while free and self.pending and self.pending[0].arrival <= now:
            req = self.pending[0]
            slot = free[0]
            if not can_allocate(slot, len(req.tokens) + req.max_new):
                break
            self.pending.popleft()
            free.pop(0)
            st = self.slots[slot]
            st.req, st.prompt_len = req, len(req.tokens)
            st.prefilled = 0
            st.decode_i, st.t = 0, 0
            st.n_out, st.last_tok = 0, None
            st.latencies = []
            st.accept_lens = []
            placed.append((slot, req, bucket_for(len(req.tokens), self.buckets)))
        return placed

    def cancel_pending(self, rid: int) -> bool:
        """Drop a not-yet-admitted request from the queue. Returns True
        if it was found (and removed); an admitted request is the
        engine's to cancel — its slot and pages must be released too."""
        for i, req in enumerate(self.pending):
            if req.rid == rid:
                del self.pending[i]
                return True
        return False

    def depth(self) -> int:
        """Requests in the system: queued + admitted (live slots). The
        router's load signal; the engine also samples ``len(pending)``
        and :meth:`n_live` into the ``queue.depth`` / ``slots.live``
        observability gauges at admission time (repro.obs)."""
        return len(self.pending) + self.n_live()

    # -- eviction --------------------------------------------------------

    def should_evict(self, slot: int) -> bool:
        st = self.slots[slot]
        if not st.active:
            return False
        if st.mid_prefill:
            # a chunked prompt still in flight: no token exists yet, so
            # EOS / max_new cannot have fired — and a stale ``last_tok``
            # from a previous occupant must never evict the new stream
            return False
        if st.n_out >= st.req.max_new:
            return True
        eos = st.req.eos_id
        return eos is not None and st.last_tok == eos

    def evict(self, slot: int, release, outputs: List[int]) -> Request:
        """Finish the stream in ``slot``; ``release(slot)`` frees pages."""
        st = self.slots[slot]
        req = st.req
        self.finished.append((req, list(outputs)))
        release(slot)
        st.req = None
        return req

    def n_live(self) -> int:
        return sum(s.active for s in self.slots)

    def done(self) -> bool:
        return not self.pending and self.n_live() == 0
