"""Shared-prefix KV reuse: a token-level radix tree over page-granularity
prefixes, with refcounted page sharing, low-rank state snapshots, and
copy-on-write.

Real multi-tenant traffic is dominated by shared prompt prefixes (system
prompts, few-shot templates, multi-turn chat). The K/V values of a
position are a pure function of the token prefix, so once one stream has
prefilled a prompt, every later stream whose prompt starts with the same
tokens can point its leading page-table entries at the **same physical
pages** (kv_cache refcounts) and enter chunked prefill at the divergence
point — no attention is re-run over the matched prefix.

What cannot be shared is the DR-RL per-stream low-rank state: the
attention-mass accumulator feeding the weighted-Gram basis (PAPER.md
Eq. 12) and the factor cache ``kt = K . B_r`` are functions of *which
queries attended* and of the slot's own basis, so they live slot-indexed
in the cache (not paged). Instead the tree snapshots, per cached prefix,
the **cumulative prompt attention mass** — the mass over positions
``[0, m)`` from queries ``[0, m)`` exactly — and a prefix hit rehydrates
its slot's mass row from the snapshot. The hit slot's first segment
decision then builds the same weighted-Gram basis, Eq. 9 veto state and
(re-projected) kt row a cold admission would have built: prefix-hit
admission stays token-for-token identical to cold admission.

Exactness dictates where reuse points live: a cumulative mass snapshot
at position ``m`` can only be captured when the engine's chunked prefill
pauses exactly at ``m`` (the in-graph accumulator then holds queries
``[0, m)`` and nothing more). The engine captures one snapshot at every
page-aligned chunk boundary plus one at the prompt end, and ``match``
snaps reuse down to the deepest such point — matching is token-granular,
reuse is snapshot-granular. (Run ``prefill_chunk`` as a multiple of
``page_size`` — the serve default — for a snapshot at every page.)

Memory: a chain of nodes for a P-token prompt stores cumulative
snapshots of sizes ps, 2·ps, …, P — O(P²/ps) float32 mass cells per
cached prompt (vs O(P·d) for its K/V pages; at serve-scale prompts the
ratio is roughly P/(2·ps·2·dh)). The cost is bounded by the same LRU
that bounds page residency — evicting a node frees its snapshot — and
is the price of *exact* rehydration: mass at a position keeps receiving
contributions from every later prompt query, so per-node deltas are just
as dense and only the cut density (one snapshot per page) is tunable.

Node structure: each node owns an edge label (token run), the physical
pages whose first token falls inside its span (as ``{page_index: phys}``
— a deeper node's entry overrides an ancestor's, which is how a branch
created at a mid-page divergence carries its own copy of the straddling
page), the mass snapshot valid at its end position (``snap_ok``), and an
LRU stamp. Splitting a node invalidates the cut point's snapshot (the
aggregate mass cannot be decomposed by query range) but keeps the deeper
half's; a later insertion ending exactly at an unsnapshotted node heals
it. Eviction is leaf-first LRU: dropping a node unrefs its pages, and a
page returns to the free list when no slot shares it either ("zero live
refs => reclaimable").

Copy-on-write: a reuse point at a prompt end need not be page-aligned,
so a hit may share a **partially-filled tail page**. Shared pages are
immutable to slots — the hit slot would append its divergent tokens into
that page — so admission gives the slot a private copy of the tail page
(``PagedKVCache.copy_page``) and the shared original stays pristine.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.serve.kv_cache import PagedKVCache


class RadixNode:
    """One edge of the prefix tree; the path from the root spells the
    cached token prefix ``[0, end)``."""

    __slots__ = ("tokens", "end", "pages", "children", "parent",
                 "snap_ok", "snap_mass", "snap_spectra", "last_used")

    def __init__(self, tokens: np.ndarray, end: int,
                 parent: Optional["RadixNode"] = None):
        self.tokens = np.asarray(tokens, np.int32)
        self.end = end                     # prefix length at this node
        self.pages: Dict[int, int] = {}    # page_index -> physical page id
        self.children: Dict[int, "RadixNode"] = {}
        self.parent = parent
        self.snap_ok = False               # end is an exact reuse point
        self.snap_mass: Optional[Any] = None      # (L, end, hkv) or None
        self.snap_spectra: Optional[Any] = None   # (hkv, dh), lazy
        self.last_used = 0

    @property
    def start(self) -> int:
        return self.end - len(self.tokens)


@dataclass
class MatchResult:
    """A prefix lookup: ``reuse_len`` tokens (< prompt length) whose K/V
    live in ``pages``; ``cow_src`` is the shared partially-filled tail
    page to copy-on-write (None when the reuse point is page-aligned);
    ``mass``/``spectra`` are the snapshot to rehydrate the slot's
    low-rank state from; ``nodes`` is the matched path (LRU-protected
    while the admission that looked it up is in flight)."""
    reuse_len: int = 0
    pages: List[int] = field(default_factory=list)
    cow_src: Optional[int] = None
    mass: Optional[Any] = None
    spectra: Optional[Any] = None
    nodes: List[RadixNode] = field(default_factory=list)


class PrefixCache:
    """Radix tree over cached prompt prefixes, sharing pages of one
    :class:`PagedKVCache` via its refcounts."""

    def __init__(self, cache: PagedKVCache):
        self.cache = cache
        self.ps = cache.page_size
        self.root = RadixNode(np.zeros((0,), np.int32), 0)
        self._clock = 0
        self.n_nodes = 0

    def _touch(self, node: RadixNode) -> None:
        node.last_used = self._clock
        self._clock += 1

    def touch_path(self, nodes: Sequence[RadixNode]) -> None:
        """Advance the LRU stamp of a committed match's path."""
        for n in nodes:
            self._touch(n)

    # -- lookup ----------------------------------------------------------

    def match(self, tokens: np.ndarray) -> MatchResult:
        """Longest reusable prefix of ``tokens``: the deepest fully-matched
        node with a valid snapshot at most ``len(tokens) - 1`` deep (at
        least one prompt token must be computed to produce the first
        logits). Read-only — LRU stamps are advanced by ``touch_path``
        only when the caller actually commits to the hit, so a request
        blocked on page pressure re-matching every step does not inflate
        its path's recency over genuinely served prefixes."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        P = len(tokens)
        node, i = self.root, 0
        pages: Dict[int, int] = {}
        path: List[RadixNode] = []
        best: Optional[RadixNode] = None
        best_pages: Optional[Dict[int, int]] = None
        while i < P:
            child = node.children.get(int(tokens[i]))
            if child is None:
                break
            e = len(child.tokens)
            if e > P - i or not np.array_equal(child.tokens, tokens[i:i + e]):
                break                      # divergence mid-edge: no deeper
            node = child                   # reuse point can complete
            i += e
            pages.update(child.pages)      # deeper copies override
            path.append(child)
            if child.snap_ok and child.end <= P - 1:
                best, best_pages = child, dict(pages)
        if best is None:
            return MatchResult(nodes=path)
        m = best.end
        plist = []
        for f in range(-(-m // self.ps)):
            assert f in best_pages, \
                f"prefix tree path to depth {m} is missing page {f}"
            plist.append(best_pages[f])
        cow = plist[-1] if m % self.ps else None
        return MatchResult(reuse_len=m, pages=plist, cow_src=cow,
                           mass=best.snap_mass, spectra=best.snap_spectra,
                           nodes=path)

    def probe(self, tokens: np.ndarray) -> int:
        """Longest *snapshotted* reusable prefix length for ``tokens`` —
        the same depth a :meth:`match` at this instant would reuse — as a
        pure read: no page assembly, no LRU movement, no refcounts.

        This is the router's affinity score (repro.serve.frontend): a
        prompt is dispatched to the replica whose tree already holds its
        longest prefix, so the probe must be cheap enough to run against
        every replica per submit and side-effect-free so losing replicas
        keep their LRU order untouched."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        P = len(tokens)
        node, i, best = self.root, 0, 0
        while i < P:
            child = node.children.get(int(tokens[i]))
            if child is None:
                break
            e = len(child.tokens)
            if e > P - i or not np.array_equal(child.tokens, tokens[i:i + e]):
                break
            node = child
            i += e
            if child.snap_ok and child.end <= P - 1:
                best = child.end
        return best

    # -- insertion -------------------------------------------------------

    def _split(self, node: RadixNode, j: int) -> None:
        """Cut ``node``'s edge after ``j`` tokens: ``node`` keeps the top
        half (its snapshot is invalidated — the aggregate mass cannot be
        decomposed at an arbitrary cut), a new child keeps the bottom
        half, the original children, the snapshot, and the pages whose
        first token moved below the cut."""
        cut = node.start + j
        bottom = RadixNode(node.tokens[j:], node.end, parent=node)
        bottom.children = node.children
        for ch in bottom.children.values():
            ch.parent = bottom
        bottom.snap_ok, bottom.snap_mass = node.snap_ok, node.snap_mass
        bottom.snap_spectra = node.snap_spectra
        bottom.last_used = node.last_used
        bottom.pages = {f: p for f, p in node.pages.items()
                        if f * self.ps >= cut}
        node.pages = {f: p for f, p in node.pages.items()
                      if f * self.ps < cut}
        node.tokens = node.tokens[:j]
        node.end = cut
        node.snap_ok, node.snap_mass, node.snap_spectra = False, None, None
        node.children = {int(bottom.tokens[0]): bottom}
        self.n_nodes += 1

    def _heal(self, node: RadixNode, snaps: Dict[int, Any]) -> None:
        """An insertion ending exactly at an unsnapshotted node (e.g. the
        top half of an old split) makes its end an exact reuse point."""
        if not node.snap_ok and node.end in snaps and node.end > 0:
            node.snap_mass = snaps[node.end]
            node.snap_ok = True

    def insert(self, tokens: np.ndarray, pages: Sequence[int],
               snaps: Dict[int, Any]) -> Optional[RadixNode]:
        """Cache a fully-prefilled prompt. ``pages`` are the inserting
        slot's physical pages for page indices ``0..ceil(P/ps)-1``;
        ``snaps`` maps exact snapshot positions (page-aligned chunk
        boundaries and the prompt end) to the cumulative mass captured
        there (None on the rank-off path — the position is still an exact
        reuse point). New nodes are cut at snapshot positions so every
        future hit lands on one; their pages gain a tree reference.
        Returns the deepest node of this prompt (for the engine's lazy
        spectra capture), or None when the prompt added nothing new."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        P = len(tokens)
        node, i = self.root, 0
        while i < P:
            child = node.children.get(int(tokens[i]))
            if child is None:
                break
            e = len(child.tokens)
            n = min(e, P - i)
            j = 0
            while j < n and child.tokens[j] == tokens[i + j]:
                j += 1
            if j == e:                       # full edge match
                node = child
                i += e
                self._touch(child)
                self._heal(child, snaps)
                continue
            if j > 0:                        # diverged (or ended) mid-edge
                self._split(child, j)
                node = child
                i += j
                self._touch(child)
                self._heal(child, snaps)
            break
        if i >= P:
            return node if node is not self.root else None
        # extend with a chain cut at the exact snapshot positions, so each
        # new node's end is a valid reuse point. The first segment owns its
        # (private) copy of a page straddling a mid-page start; later cuts
        # are page-aligned by construction.
        cuts = sorted({p for p in snaps
                       if i < p < P and p % self.ps == 0} | {P})
        start = i
        for c in cuts:
            nn = RadixNode(tokens[start:c], c, parent=node)
            # floor(start/ps): a mid-page start claims the (private) copy
            # of the straddling page; aligned starts claim from their own
            # first page (floor == ceil there)
            nn.pages = {f: int(pages[f])
                        for f in range(start // self.ps, -(-c // self.ps))}
            self.cache.retain(nn.pages.values())
            nn.snap_ok = c in snaps
            nn.snap_mass = snaps.get(c)
            node.children[int(tokens[start])] = nn
            self._touch(nn)
            self.n_nodes += 1
            node, start = nn, c
        return node

    # -- eviction --------------------------------------------------------

    def all_pages(self) -> List[int]:
        """Every physical page the tree references (invariant checks)."""
        out, stack = [], [self.root]
        while stack:
            n = stack.pop()
            out.extend(n.pages.values())
            stack.extend(n.children.values())
        return out

    def _leaves(self) -> List[RadixNode]:
        out, stack = [], list(self.root.children.values())
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            else:
                out.append(n)
        return out

    def evict_lru(self, n_pages_needed: int,
                  protect: Sequence[RadixNode] = ()) -> int:
        """Drop least-recently-used leaves until ``n_pages_needed`` pages
        actually returned to the free list. Only leaves that would free
        at least one page (some page solely tree-referenced) — or that
        own no pages at all (split residue that would otherwise block
        its ancestors forever) — are victims: dropping a leaf whose
        pages are all still held by live slots frees nothing now and
        loses future reuse, so when no leaf can free anything the tree
        is left intact and the caller's allocation simply fails.
        ``protect`` pins the path of an in-flight admission. Returns the
        number of pages freed."""
        pinned = {id(n) for n in protect}
        freed = 0
        while freed < n_pages_needed:
            victims = [n for n in self._leaves() if id(n) not in pinned
                       and (not n.pages
                            or any(int(self.cache.ref[p]) == 1
                                   for p in n.pages.values()))]
            if not victims:
                break
            victim = min(victims, key=lambda n: n.last_used)
            before = self.cache.free_pages
            self.cache.unref(victim.pages.values())
            del victim.parent.children[int(victim.tokens[0])]
            self.n_nodes -= 1
            freed += self.cache.free_pages - before
        return freed

