"""Async serving front door + multi-replica router.

This is the "millions of users" layer over the continuous-batching core:

- :class:`FrontEnd` — one background **stepping thread** per
  :class:`repro.serve.api.Engine`, driving ``step()`` continuously so
  the accelerator never idles while the host admits, streams, or simply
  has no consumer attached. Handles returned by ``submit`` are the
  ordinary :class:`repro.serve.api.RequestHandle` — with a front end
  attached their iterators (``for tok in h.tokens()``,
  ``async for tok in h``) and ``result()`` *wait for delivery* instead
  of stepping the engine themselves. The thread parks on an event when
  the engine runs dry and wakes on the next submit; ``shutdown()``
  stops, drains in-flight device work and joins, marking unfinished
  handles stopped so no consumer blocks forever
  (:class:`repro.serve.api.EngineStopped`).

- :class:`Router` — owns N engine replicas (one :class:`FrontEnd`
  each) and dispatches every ``submit()`` with **prefix-cache
  affinity**: the prompt is probed (read-only) against every replica's
  radix tree and routes to the replica already holding its longest
  cached prefix, so shared-system-prompt traffic keeps landing where
  the prefix is warm instead of being sprayed across the fleet and
  re-prefilled N times. Prompts with no useful prefix — and affinity
  hits whose replica is overloaded beyond ``depth_slack`` — fall back
  to least-loaded by queue depth. Per-replica and aggregate stats
  (``depth``, ``hit_rate``, ``stall_s``, ``tok_per_s``) come from
  :meth:`Router.stats`.

- :class:`FleetConfig` — the one runtime-options surface for a fleet
  (engine knobs x replica count x routing knobs), after Alpa's
  ``GlobalConfig`` idiom: every option lives in one flat, documented
  object that is validated up front and threaded through construction,
  instead of a kwarg pile per layer.

    fleet = FleetConfig(engine=EngineConfig(n_slots=4, prefix_cache=True),
                        n_replicas=2)
    router = Router(cfg, params, fleet=fleet)
    h = router.submit(prompt_ids, SamplingParams(max_new=64))
    async for tok in h:          # or: for tok in h.tokens()
        ...
    router.shutdown()
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.obs import aggregate
from repro.serve.api import (Engine, EngineConfig, EngineStopped,
                             RequestHandle, SamplingParams)

ROUTING_MODES = ("affinity", "least_loaded", "round_robin")


@dataclass(frozen=True)
class FleetConfig:
    """Runtime options for an engine fleet (Alpa ``GlobalConfig`` idiom:
    one validated options object instead of per-layer kwarg piles).

    ``engine`` is the per-replica :class:`EngineConfig` (every replica
    is identical — heterogeneous fleets would break token parity across
    routing decisions). ``routing`` picks the dispatch policy:

    - ``"affinity"`` (default): longest cached-prefix match wins when it
      reuses at least ``affinity_min_tokens`` tokens AND that replica's
      queue depth is within ``depth_slack`` of the shallowest — cache
      locality is worth a short wait, not a convoy; otherwise fall back
      to least-loaded. Without ``engine.prefix_cache`` this degrades to
      least-loaded.
    - ``"least_loaded"``: minimum queue depth (pending + admitted),
      first-index tiebreak (bursts self-spread: every dispatch deepens
      its replica).
    - ``"round_robin"``: strict rotation (the affinity baseline).

    ``idle_poll_s`` bounds how long a parked stepping thread sleeps
    between wake checks; ``warmup`` compiles each replica's executables
    at construction (before its thread starts) so first tokens are not
    billed compile time."""
    engine: EngineConfig = field(default_factory=EngineConfig)
    n_replicas: int = 2
    routing: str = "affinity"
    affinity_min_tokens: int = 8
    depth_slack: int = 4
    idle_poll_s: float = 0.05
    warmup: bool = True

    def __post_init__(self):
        if self.n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {self.n_replicas}")
        if self.routing not in ROUTING_MODES:
            raise ValueError(f"routing must be one of {ROUTING_MODES}, "
                             f"got {self.routing!r}")
        if self.affinity_min_tokens < 1:
            raise ValueError("affinity_min_tokens must be >= 1")
        if self.depth_slack < 0:
            raise ValueError("depth_slack must be >= 0")
        if self.idle_poll_s <= 0:
            raise ValueError("idle_poll_s must be > 0")


class FrontEnd:
    """Background stepping thread over one :class:`Engine`.

    The thread loops ``engine.step()`` while work remains, then parks on
    a wake event; ``submit()`` (and ``Engine.submit`` directly — the
    engine wakes its driver) unparks it. All handle consumption becomes
    passive: iterators and ``result()`` wait on the per-handle delivery
    condition instead of stepping.

    Lifecycle: the thread starts in the constructor (after an optional
    warmup compile) and runs until ``shutdown()``. A step that raises
    stores the error, marks every unfinished handle stopped (consumers
    get :class:`EngineStopped`, never a silent hang) and exits the
    thread; ``drain()``/``submit()`` re-raise the stored error."""

    _SEQ = 0

    def __init__(self, engine: Engine, *, idle_poll_s: float = 0.05,
                 warmup: bool = True, name: Optional[str] = None):
        self.engine = engine
        self.idle_poll_s = idle_poll_s
        self._stop = threading.Event()
        self._wake = threading.Event()
        self._idle_cv = threading.Condition()
        self._error: Optional[BaseException] = None
        if warmup:
            engine.warmup()          # thread not started yet: no race
        FrontEnd._SEQ += 1
        self._thread = threading.Thread(
            target=self._loop, daemon=True,
            name=name or f"serve-frontend-{FrontEnd._SEQ}")
        engine._driver = self
        self._thread.start()

    # -- stepping thread -------------------------------------------------

    def _loop(self) -> None:
        while not self._stop.is_set():
            self._wake.clear()
            try:
                busy = self.engine.step()
            # deliberately BaseException, not Exception: the loop must
            # never die silently — record the error, strand no consumer
            except BaseException as e:
                self._error = e
                # dump the flight ring first: the crash context (the
                # events leading up to the failing step) must land on
                # disk before handles observe EngineStopped
                self.engine.core.obs.flight_dump("step_exception", error=e)
                self._abort_handles()
                with self._idle_cv:
                    self._idle_cv.notify_all()
                return
            if not busy:
                with self._idle_cv:
                    self._idle_cv.notify_all()
                # park until the next submit (the timed wait re-checks
                # stop so shutdown never waits a full poll interval)
                self._wake.wait(timeout=self.idle_poll_s)
        with self._idle_cv:
            self._idle_cv.notify_all()

    def wake(self) -> None:
        """Unpark the stepping thread (called on every submit)."""
        self._wake.set()

    @property
    def alive(self) -> bool:
        return self._thread.is_alive() and not self._stop.is_set()

    def _raise_if_dead(self) -> None:
        if self._error is not None:
            raise EngineStopped(
                "front-end stepping thread died") from self._error
        if not self.alive:
            raise EngineStopped("front end is shut down")

    # -- request plane ---------------------------------------------------

    def submit(self, prompt, params: Optional[SamplingParams] = None, *,
               arrival: int = 0,
               on_token: Optional[Callable[[int, int], None]] = None
               ) -> RequestHandle:
        """Enqueue a prompt and wake the stepping thread. Same contract
        (and fail-fast validation) as :meth:`Engine.submit`."""
        self._raise_if_dead()
        return self.engine.submit(prompt, params, arrival=arrival,
                                  on_token=on_token)

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until the engine has no queued or admitted request.
        Returns False on timeout; raises :class:`EngineStopped` if the
        stepping thread died (or was shut down) with work in flight."""
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._idle_cv:
            while not self.engine.core.sched.done():
                self._raise_if_dead()
                left = (None if deadline is None
                        else deadline - time.perf_counter())
                if left is not None and left <= 0:
                    return False
                self._idle_cv.wait(min(self.idle_poll_s,
                                       left or self.idle_poll_s))
        return True

    def shutdown(self, drain: bool = True,
                 timeout: Optional[float] = None) -> None:
        """Stop the stepping thread: optionally drain first, then signal
        stop, join, and mark every unfinished handle stopped so blocked
        consumers raise :class:`EngineStopped` instead of hanging.
        Idempotent."""
        if drain and self.alive:
            try:
                self.drain(timeout)
            except EngineStopped:
                pass                       # already dead: still join below
        self._stop.set()
        self._wake.set()
        self._thread.join(timeout=30.0)
        # last-breath state (only when a dump dir is configured; a step
        # exception already dumped — this records the shutdown marker)
        self.engine.core.obs.record_event("shutdown")
        self.engine.core.obs.flight_dump("shutdown")
        self._abort_handles()

    def _abort_handles(self) -> None:
        with self.engine._submit_lock:
            handles = list(self.engine._handles.values())
        for h in handles:
            h._mark_stopped()

    def __enter__(self) -> "FrontEnd":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown(drain=exc == (None, None, None))

    # -- introspection ---------------------------------------------------

    def stats(self) -> Dict:
        """Live serving stats: queue ``depth``, prefix ``hit_rate``,
        admission ``stall_s``, decode ``tok_per_s``, speculative
        ``spec_accept_rate`` / ``spec_mean_accept`` (0 on a
        non-speculative engine), plus the raw engine counters under
        ``"engine"``."""
        s = dict(self.engine.stats)
        looked = s["prefix_hits"] + s["prefix_misses"]
        return {
            "depth": self.engine.depth,
            "hit_rate": s["prefix_hits"] / max(looked, 1),
            "stall_s": s["stall_s"],
            "tok_per_s": s["tokens_decoded"] / max(s["decode_s"], 1e-9),
            "tokens_decoded": s["tokens_decoded"],
            "spec_accept_rate":
                s["spec_accepted"] / max(s["spec_drafted"], 1),
            "spec_mean_accept":
                s["spec_tokens"]
                / max(s["spec_tokens"] - s["spec_accepted"], 1),
            "alive": self.alive,
            "engine": s,
        }


class Router:
    """N engine replicas behind one ``submit()``.

    Dispatch is by queue depth with prefix-cache affinity (see
    :class:`FleetConfig.routing`): each submit probes every replica's
    radix tree read-only for the prompt's longest cached prefix and
    routes to the warm replica when the reuse is worth it, otherwise to
    the least-loaded. Replicas are data-parallel and independent — one
    process here, but nothing in the dispatch path reads replica
    internals other than ``depth`` and the prefix probe, both cheap and
    lock-protected, so replicas can move behind a device/process
    boundary without touching the fused step."""

    def __init__(self, cfg: ModelConfig, params, policy_params=None, *,
                 fleet: Optional[FleetConfig] = None):
        self.fleet = fleet or FleetConfig()
        f = self.fleet
        self.replicas: List[FrontEnd] = [
            FrontEnd(Engine(cfg, params, policy_params, config=f.engine),
                     idle_poll_s=f.idle_poll_s, warmup=f.warmup,
                     name=f"serve-replica-{i}")
            for i in range(f.n_replicas)]
        self._rr = 0                      # round-robin cursor
        self._lock = threading.Lock()     # dispatch decision is atomic
        self.routed: List[int] = [0] * f.n_replicas
        self.route_kinds = {"affinity": 0, "least_loaded": 0,
                            "round_robin": 0}

    # -- dispatch --------------------------------------------------------

    def _pick(self, prompt) -> tuple:
        f = self.fleet
        depths = [fe.engine.depth for fe in self.replicas]
        if f.routing == "round_robin":
            i = self._rr
            self._rr = (self._rr + 1) % len(self.replicas)
            return i, "round_robin"
        if f.routing == "affinity" and f.engine.prefix_cache:
            best, best_len = -1, 0
            for i, fe in enumerate(self.replicas):
                n = fe.engine.prefix_probe(prompt)
                # longer prefix wins; equal prefixes go to the shallower
                # queue
                if n > best_len or (n == best_len and n > 0
                                    and depths[i] < depths[best]):
                    best, best_len = i, n
            if (best_len >= f.affinity_min_tokens
                    and depths[best] <= min(depths) + f.depth_slack):
                return best, "affinity"
        return int(np.argmin(depths)), "least_loaded"

    def submit(self, prompt, params: Optional[SamplingParams] = None, *,
               arrival: int = 0,
               on_token: Optional[Callable[[int, int], None]] = None
               ) -> RequestHandle:
        """Route ``prompt`` to a replica and submit it there. The handle
        remembers its replica index (``handle.replica``)."""
        with self._lock:
            idx, kind = self._pick(prompt)
            self.routed[idx] += 1
            self.route_kinds[kind] += 1
        h = self.replicas[idx].submit(prompt, params, arrival=arrival,
                                      on_token=on_token)
        h.replica = idx
        return h

    # -- lifecycle -------------------------------------------------------

    def drain(self, timeout: Optional[float] = None) -> bool:
        deadline = None if timeout is None else time.perf_counter() + timeout
        for fe in self.replicas:
            left = (None if deadline is None
                    else max(deadline - time.perf_counter(), 0.0))
            if not fe.drain(left):
                return False
        return True

    def shutdown(self, drain: bool = True,
                 timeout: Optional[float] = None) -> None:
        for fe in self.replicas:
            fe.shutdown(drain=drain, timeout=timeout)

    def reset(self) -> None:
        """Reset every replica (handles stopped, prefix trees cleared);
        the stepping threads stay up and park until the next submit."""
        for fe in self.replicas:
            fe.engine.reset()
        with self._lock:
            self._rr = 0
            self.routed = [0] * len(self.replicas)
            for k in self.route_kinds:
                self.route_kinds[k] = 0

    def __enter__(self) -> "Router":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown(drain=exc == (None, None, None))

    # -- introspection ---------------------------------------------------

    def stats(self) -> Dict:
        """Per-replica stats plus fleet aggregates. ``tok_per_s`` sums
        replica decode rates (each replica's decode clock runs only while
        it steps); wall-clock fleet throughput is total tokens over the
        caller's own wall interval."""
        per = [fe.stats() for fe in self.replicas]
        tokens = sum(p["tokens_decoded"] for p in per)
        looked = sum(p["engine"]["prefix_hits"] + p["engine"]["prefix_misses"]
                     for p in per)
        hits = sum(p["engine"]["prefix_hits"] for p in per)
        return {
            "replicas": per,
            "aggregate": {
                "depth": sum(p["depth"] for p in per),
                "tokens_decoded": tokens,
                "hit_rate": hits / max(looked, 1),
                "stall_s": sum(p["stall_s"] for p in per),
                "tok_per_s": sum(p["tok_per_s"] for p in per),
            },
            "routed": list(self.routed),
            "route_kinds": dict(self.route_kinds),
        }

    def obs_snapshot(self) -> Dict:
        """Fleet-level metrics rollup: every replica's registry shard
        merged at read time (counters/gauges sum, histograms merge
        bucket-wise), plus the per-replica snapshots. Read-side only —
        no replica lock is taken and no step loop is touched."""
        per = [fe.engine.obs.snapshot() for fe in self.replicas]
        return {
            "fleet": aggregate([fe.engine.obs.registry
                                for fe in self.replicas]),
            "replicas": per,
        }

    def prometheus(self, namespace: str = "repro") -> str:
        """Prometheus text exposition for the whole fleet (merged
        registries; one scrape endpoint per router)."""
        from repro.obs.metrics import aggregate_registry
        merged = aggregate_registry([fe.engine.obs.registry
                                     for fe in self.replicas])
        return merged.prometheus_text(namespace)
