"""Deterministic scenario workload suite for the serving stack.

Named, seeded generators covering the traffic shapes the trace subsystem
trains and evaluates on (ROADMAP item 4): **bursty** arrival clumps,
**long_context** prompts near the slot capacity, **shared_prefix** chat
turns over a handful of system prompts, and **mixed_sampling** batches
interleaving greedy / top-k / nucleus rows. Every generator is a pure
function of ``(seed, scale knobs)`` — arrivals are scheduler ticks, never
wall clock — so a workload replays bit-identically across runs, which is
what lets the same suite serve three masters:

* **trace generation** — ``repro.serve.traces.TraceRecorder`` records the
  per-segment rank decisions the offline trainer learns from;
* **replay benchmarking** — ``benchmarks/serve_bench.py``'s
  ``learned_policy`` section replays the named suite under each rank mode
  and compares reward / kept rank / agreement on identical traffic;
* **regression testing** — seed-reproducibility is asserted in
  tests/test_serve_traces.py.

Each spec is a list of request dicts (the kwargs of
``repro.serve.Request`` minus ``rid``) plus the engine knob overrides the
scenario needs (e.g. shared_prefix wants a prefix cache); ``build()``
turns one into submit-ready ``Request`` objects.
"""
from __future__ import annotations

from typing import Callable, Dict, List, NamedTuple, Tuple

import numpy as np

from repro.serve.scheduler import Request

__all__ = ["WorkloadSpec", "WORKLOADS", "make_workload", "workload_names"]


class WorkloadSpec(NamedTuple):
    """One named scenario: request kwargs + engine knob overrides."""
    name: str
    requests: List[dict]
    engine_overrides: Dict


def _bursty(seed: int, n: int, max_new: int, vocab: int,
            max_prompt: int) -> Tuple[List[dict], Dict]:
    """Arrival clumps: requests land in bursts of 2-4 at the same tick
    with idle gaps between bursts — the admission/queue-pressure shape."""
    rnd = np.random.default_rng(seed)
    out, tick, i = [], 0, 0
    while i < n:
        burst = int(rnd.integers(2, 5))
        for _ in range(min(burst, n - i)):
            ln = int(rnd.integers(8, max(min(max_prompt, 40), 9)))
            out.append(dict(
                tokens=rnd.integers(0, vocab, ln).astype(np.int32),
                max_new=max_new, arrival=tick))
            i += 1
        tick += int(rnd.integers(4, 10))
    return out, {}


def _long_context(seed: int, n: int, max_new: int, vocab: int,
                  max_prompt: int) -> Tuple[List[dict], Dict]:
    """Prompts near the slot capacity: the regime where the factor cache's
    r/d read cut matters and spectra carry real signal."""
    rnd = np.random.default_rng(seed)
    lo = max(max_prompt // 2, 8)
    out = []
    for i in range(n):
        ln = int(rnd.integers(lo, max_prompt + 1))
        out.append(dict(tokens=rnd.integers(0, vocab, ln).astype(np.int32),
                        max_new=max_new, arrival=2 * i))
    return out, {}


def _shared_prefix(seed: int, n: int, max_new: int, vocab: int,
                   max_prompt: int) -> Tuple[List[dict], Dict]:
    """Chat-style turns over a few shared system prompts: most requests
    start with one of 2 cached prefixes plus a short unique tail."""
    rnd = np.random.default_rng(seed)
    pfx_len = max(min(max_prompt - 8, 24), 8)
    prefixes = [rnd.integers(0, vocab, pfx_len).astype(np.int32)
                for _ in range(2)]
    out = []
    for i in range(n):
        tail = rnd.integers(0, vocab, int(rnd.integers(4, 9)))
        p = prefixes[int(rnd.integers(0, len(prefixes)))]
        toks = np.concatenate([p, tail.astype(np.int32)])[:max_prompt]
        out.append(dict(tokens=toks, max_new=max_new, arrival=i))
    return out, {"prefix_cache": True}


def _mixed_sampling(seed: int, n: int, max_new: int, vocab: int,
                    max_prompt: int) -> Tuple[List[dict], Dict]:
    """Greedy / top-k / nucleus rows interleaved in one batch (the
    sampler-mix scenario the sanitizer also guards)."""
    rnd = np.random.default_rng(seed)
    out = []
    for i in range(n):
        ln = int(rnd.integers(8, max(min(max_prompt, 32), 9)))
        req = dict(tokens=rnd.integers(0, vocab, ln).astype(np.int32),
                   max_new=max_new, arrival=2 * i)
        kind = i % 3
        if kind == 1:
            req.update(temperature=0.8, top_k=8, seed=seed + i)
        elif kind == 2:
            req.update(temperature=0.9, top_p=0.9, seed=seed + i)
        out.append(req)
    return out, {"sampling": True, "nucleus": True}


_GENERATORS: Dict[str, Callable] = {
    "bursty": _bursty,
    "long_context": _long_context,
    "shared_prefix": _shared_prefix,
    "mixed_sampling": _mixed_sampling,
}


def workload_names() -> List[str]:
    return list(_GENERATORS)


def make_workload(name: str, *, seed: int = 0, n_requests: int = 6,
                  max_new: int = 12, vocab: int = 256,
                  max_prompt: int = 48) -> WorkloadSpec:
    """Build one named scenario. Deterministic in all arguments; rids are
    assigned 0..n-1 in submission order."""
    gen = _GENERATORS.get(name)
    if gen is None:
        raise ValueError(f"unknown workload {name!r}; "
                         f"have {sorted(_GENERATORS)}")
    reqs, overrides = gen(seed, n_requests, max_new, vocab, max_prompt)
    for i, r in enumerate(reqs):
        r["rid"] = i
    return WorkloadSpec(name=name, requests=reqs,
                        engine_overrides=overrides)


def build(spec: WorkloadSpec) -> List[Request]:
    """Submit-ready Request objects for a spec."""
    return [Request(**r) for r in spec.requests]


WORKLOADS = tuple(_GENERATORS)
