"""Slot-paged KV cache for the continuous-batching engine.

Layout: one shared physical page pool per layer stack,

    k_pool, v_pool: (L, n_pages, page_size, hkv, dh)

plus a per-slot page table ``(n_slots, pages_per_slot)`` of physical page
ids. A *slot* is a decode lane in the fused step executable; a slot's
logical sequence dim is the concatenation of its pages, so admission only
needs ``ceil(need / page_size)`` free pages anywhere in the pool — no
contiguous-region allocation, no per-request max_len reservation in one
monolithic ``{"k","v","len"}`` buffer.

Physical page 0 is reserved as a scratch page: inactive slots point every
page-table entry at it, so the fused step (which always runs all n_slots
rows — static shapes) can scatter its dead-lane writes somewhere harmless
instead of corrupting pages that were freed and re-issued to live streams.

Per-slot serving state carried here besides the pool:
  * ``lens``   — host-mirrored valid prefix length per slot (int64 np);
                 the device copy is an input of every fused step, so the
                 decode loop never does an ``int(cache["len"])`` sync.
  * ``ranks``  — per-slot rank bucket, device-resident (jnp int32).
  * ``basis``  — per-slot per-layer K eigenbasis (top r_max columns) from
                 the last segment decision. The fused decode step projects
                 q and the K view onto this cached basis (factor padding +
                 per-row rank masking), so the eigh cost is paid once per
                 segment — paper Eq. 12's refresh — and the layer-0 slice
                 also feeds the drift trigger.
  * ``spectra``— per-slot layer-0 K spectra (sigma^2, descending) persisted
                 from the last segment decision: the "before" side of the
                 Eq. 9 transition veto, so the veto measures the actual
                 segment-to-segment transition instead of comparing the
                 current spectra against themselves.
  * ``mass_pool`` — per-key accumulated softmax attention mass, paged like
                 K/V but per (layer, position, kv-head): seeded by the
                 prefill's causal attention mass and advanced in-graph by
                 every fused decode step. The segment decision builds its
                 eigenbasis from the *weighted* Gram K^T diag(w) K, so the
                 basis concentrates on directions that actually receive
                 score mass — the same softmax-weighted fix that closed the
                 prefill-path low-rank quality gap in models/lowrank_cache.
  * ``kt_pool``— the paged K cache in factor form, kt = K . B_r (top r_max
                 columns of the slot's segment basis): written for the
                 whole slot when a decision refreshes the basis, appended
                 per token by the fused step. The decode score contraction
                 reads kt (r_max/d of the dense K bytes) instead of K;
                 dense K stays resident only for basis refresh and drift.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.configs.base import ModelConfig


class PagedKVCache:
    """Page pool + page tables + per-slot serving state."""

    def __init__(self, cfg: ModelConfig, n_slots: int, max_len: int,
                 page_size: int = 16, n_pages: Optional[int] = None,
                 factored: Optional[bool] = None):
        self.cfg = cfg
        self.n_slots = n_slots
        self.page_size = page_size
        self.pages_per_slot = -(-max_len // page_size)
        self.max_len = self.pages_per_slot * page_size   # logical view M
        # +1 for the reserved scratch page 0
        self.n_pages = (n_pages if n_pages is not None
                        else n_slots * self.pages_per_slot + 1)
        dtype = nn.dt(cfg.dtype)
        dh = cfg.resolved_head_dim()
        L, hkv = cfg.num_layers, cfg.num_kv_heads
        self.k_pool = jnp.zeros((L, self.n_pages, page_size, hkv, dh), dtype)
        self.v_pool = jnp.zeros((L, self.n_pages, page_size, hkv, dh), dtype)
        self.page_table = np.zeros((n_slots, self.pages_per_slot), np.int32)
        self._free: List[int] = list(range(self.n_pages - 1, 0, -1))  # not 0
        self.lens = np.zeros((n_slots,), np.int64)
        self.rank_on = cfg.rank.mode != "off"
        r_max = int(cfg.rank.rank_grid[-1]) if self.rank_on else dh
        self.r_keep = min(r_max, dh)
        if factored and not self.rank_on:
            raise ValueError("factor-form K cache requires a rank mode: "
                             "kt = K . B_r needs a segment basis to "
                             "project onto")
        # default: factor form only when it actually cuts read bytes
        # (r_max < dh); at r_keep == dh the factor pool costs a full extra
        # K-sized pool + per-token appends for a 1.0 read ratio. Explicit
        # factored=True still opts in (the bench's full-rank parity check).
        self.factored = (self.rank_on and self.r_keep < dh
                         if factored is None else bool(factored))
        self.ranks = jnp.full((n_slots,), r_max, jnp.int32)
        self.basis = jnp.zeros((L, n_slots, hkv, dh, self.r_keep),
                               jnp.float32)
        # weighted-Gram + veto state only exist on the rank path; the
        # factor pool additionally needs the engine to opt in (it trades
        # r_max/d of the K bytes for r_max/d extra cache memory)
        self.mass_pool = (jnp.zeros((L, self.n_pages, page_size, hkv),
                                    jnp.float32) if self.rank_on else None)
        self.spectra = (jnp.zeros((n_slots, hkv, dh), jnp.float32)
                        if self.rank_on else None)
        self.kt_pool = (jnp.zeros((L, self.n_pages, page_size, hkv,
                                   self.r_keep), dtype)
                        if self.factored else None)

    # -- host-side page accounting --------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_needed(self, total_len: int) -> int:
        return -(-total_len // self.page_size)

    def allocate(self, slot: int, total_len: int) -> bool:
        """Reserve pages covering ``total_len`` tokens for ``slot``.
        Returns False (no mutation) when the pool can't cover it."""
        need = self.pages_needed(total_len)
        if need > self.pages_per_slot or need > len(self._free):
            return False
        pages = [self._free.pop() for _ in range(need)]
        self.page_table[slot, :] = 0
        self.page_table[slot, :need] = pages
        self.lens[slot] = 0
        return True

    def release(self, slot: int) -> None:
        """Return the slot's pages to the pool and park it on scratch."""
        for p in self.page_table[slot]:
            if p != 0:
                self._free.append(int(p))
        self.page_table[slot, :] = 0
        self.lens[slot] = 0

    def live_pages(self) -> Dict[int, List[int]]:
        """slot -> owned physical pages (for invariant checks)."""
        return {s: [int(p) for p in row if p != 0]
                for s, row in enumerate(self.page_table)}

    # -- device-side prefill write --------------------------------------

    def write_prefill(self, slot: int, k_layers: jnp.ndarray,
                      v_layers: jnp.ndarray,
                      mass_layers: Optional[jnp.ndarray] = None) -> None:
        """Scatter a prefilled (L, s, hkv, dh) K/V run into the slot's pages
        and set its length. ``mass_layers`` (L, s, hkv), when given, seeds
        the slot's attention-mass accumulator with the prompt's per-key
        causal attention mass. Control-plane op (one dispatch per
        admission)."""
        s = k_layers.shape[1]
        pos = np.arange(s)
        phys = jnp.asarray(self.page_table[slot][pos // self.page_size])
        off = jnp.asarray(pos % self.page_size)
        self.k_pool = self.k_pool.at[:, phys, off].set(
            k_layers.astype(self.k_pool.dtype))
        self.v_pool = self.v_pool.at[:, phys, off].set(
            v_layers.astype(self.v_pool.dtype))
        if mass_layers is not None and self.mass_pool is not None:
            self.mass_pool = self.mass_pool.at[:, phys, off].set(
                mass_layers.astype(self.mass_pool.dtype))
        self.lens[slot] = s

    # -- logical views ---------------------------------------------------

    def gather_slot(self, slot: int):
        """(L, max_len, hkv, dh) contiguous K/V view of one slot (testing /
        debugging; the fused step gathers all slots in-graph)."""
        pt = jnp.asarray(self.page_table[slot])
        def view(pool):
            g = pool[:, pt]                           # (L, pages, ps, hkv, dh)
            return g.reshape(g.shape[0], -1, *g.shape[3:])
        return view(self.k_pool), view(self.v_pool)


def gather_views(k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                 page_table: jnp.ndarray):
    """In-graph gather of every slot's logical K/V view.

    k_pool/v_pool: (L, P, ps, hkv, dh); page_table: (n_slots, pages).
    Returns (L, n_slots, M, hkv, dh) x2 with M = pages * ps."""
    def view(pool):
        g = pool[:, page_table]              # (L, n_slots, pages, ps, hkv, dh)
        L, ns = g.shape[0], g.shape[1]
        return g.reshape(L, ns, -1, *g.shape[4:])
    return view(k_pool), view(v_pool)
