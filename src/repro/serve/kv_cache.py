"""Slot-paged KV cache for the continuous-batching engine.

Layout: one shared physical page pool per layer stack,

    k_pool, v_pool: (L, n_pages, page_size, hkv, dh)

plus a per-slot page table ``(n_slots, pages_per_slot)`` of physical page
ids. A *slot* is a decode lane in the fused step executable; a slot's
logical sequence dim is the concatenation of its pages, so admission only
needs ``ceil(need / page_size)`` free pages anywhere in the pool — no
contiguous-region allocation, no per-request max_len reservation in one
monolithic ``{"k","v","len"}`` buffer.

Physical page 0 is reserved as a scratch page: inactive slots point every
page-table entry at it, so the fused step (which always runs all n_slots
rows — static shapes) can scatter its dead-lane writes somewhere harmless
instead of corrupting pages that were freed and re-issued to live streams.

**Pages are refcounted** (`ref`): the prefix cache (serve/prefix.py)
shares one physical page between every slot whose prompt starts with the
same tokens, and keeps finished prompts' pages resident for future reuse.
``allocate`` takes an optional leading run of already-filled shared pages
(ref + 1 each), ``release`` *decrements* instead of freeing, and a page
returns to the free list exactly when its last reference — slot or prefix
tree — drops. The page-leak invariant generalises: ``ref[p] == (# slot
page-table entries pointing at p) + (1 if the prefix tree caches p)``,
and ``ref == 0  <=>  p is on the free list`` (``check_refs``). Shared
pages are immutable to slots: admission places them strictly *before* a
slot's first written position, and a partially-filled shared tail page is
copied first (``copy_page`` — copy-on-write at admission).

Per-slot serving state carried here besides the pool:
  * ``lens``   — host-mirrored valid prefix length per slot (int64 np);
                 the device copy is an input of every fused step, so the
                 decode loop never does an ``int(cache["len"])`` sync.
  * ``ranks``  — per-slot rank bucket, device-resident (jnp int32).
  * ``basis``  — per-slot per-layer K eigenbasis (top r_max columns) from
                 the last segment decision. The fused decode step projects
                 q and the K view onto this cached basis (factor padding +
                 per-row rank masking), so the eigh cost is paid once per
                 segment — paper Eq. 12's refresh — and the layer-0 slice
                 also feeds the drift trigger.
  * ``spectra``— per-slot layer-0 K spectra (sigma^2, descending) persisted
                 from the last segment decision: the "before" side of the
                 Eq. 9 transition veto, so the veto measures the actual
                 segment-to-segment transition instead of comparing the
                 current spectra against themselves.
  * ``mass_pool`` — per-key accumulated softmax attention mass,
                 **slot-indexed** ``(L, n_slots, max_len, hkv)``: seeded by
                 the prefill's causal attention mass and advanced in-graph
                 by every fused decode step. The segment decision builds
                 its eigenbasis from the *weighted* Gram K^T diag(w) K, so
                 the basis concentrates on directions that actually
                 receive score mass. Mass is per-*stream* state (which
                 queries attended), not per-page state — a physical page
                 shared between two prefix-hit slots receives different
                 mass from each — so unlike K/V it is NOT paged; the row
                 is zeroed at admission and, on a prefix hit, re-seeded
                 from the tree's snapshot.
  * ``kt_pool``— the K cache in factor form, kt = K . B_r (top r_max
                 columns of the slot's segment basis), **slot-indexed**
                 ``(L, n_slots + 1, max_len, hkv, r_keep)`` (+1 scratch
                 row for dead-lane writes): rewritten for the whole slot
                 when a decision refreshes the basis, appended per token
                 by the fused step. Like the basis it factors against, kt
                 is per-slot state — two slots sharing prefix K pages hold
                 different bases, so their factors of the *same* physical
                 page differ. The decode score contraction reads kt
                 (r_max/d of the dense K bytes); dense K stays resident
                 only for basis refresh and drift.
"""
from __future__ import annotations

from collections import Counter
from typing import Dict, Iterable, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.configs.base import ModelConfig


class PagedKVCache:
    """Refcounted page pool + page tables + per-slot serving state."""

    def __init__(self, cfg: ModelConfig, n_slots: int, max_len: int,
                 page_size: int = 16, n_pages: Optional[int] = None,
                 factored: Optional[bool] = None):
        self.cfg = cfg
        self.n_slots = n_slots
        self.page_size = page_size
        self.pages_per_slot = -(-max_len // page_size)
        self.max_len = self.pages_per_slot * page_size   # logical view M
        # +1 for the reserved scratch page 0
        self.n_pages = (n_pages if n_pages is not None
                        else n_slots * self.pages_per_slot + 1)
        dtype = nn.dt(cfg.dtype)
        dh = cfg.resolved_head_dim()
        L, hkv = cfg.num_layers, cfg.num_kv_heads
        self.k_pool = jnp.zeros((L, self.n_pages, page_size, hkv, dh), dtype)
        self.v_pool = jnp.zeros((L, self.n_pages, page_size, hkv, dh), dtype)
        self.page_table = np.zeros((n_slots, self.pages_per_slot), np.int32)
        self._free: List[int] = list(range(self.n_pages - 1, 0, -1))  # not 0
        self.ref = np.zeros((self.n_pages,), np.int32)
        self.lens = np.zeros((n_slots,), np.int64)
        self.rank_on = cfg.rank.mode != "off"
        r_max = int(cfg.rank.rank_grid[-1]) if self.rank_on else dh
        self.r_keep = min(r_max, dh)
        if factored and not self.rank_on:
            raise ValueError("factor-form K cache requires a rank mode: "
                             "kt = K . B_r needs a segment basis to "
                             "project onto")
        # default: factor form only when it actually cuts read bytes
        # (r_max < dh); at r_keep == dh the factor pool costs a full extra
        # K-sized pool + per-token appends for a 1.0 read ratio. Explicit
        # factored=True still opts in (the bench's full-rank parity check).
        self.factored = (self.rank_on and self.r_keep < dh
                         if factored is None else bool(factored))
        self.ranks = jnp.full((n_slots,), r_max, jnp.int32)
        self.basis = jnp.zeros((L, n_slots, hkv, dh, self.r_keep),
                               jnp.float32)
        # weighted-Gram + veto state only exist on the rank path; the
        # factor pool additionally needs the engine to opt in (it trades
        # r_max/d of the K bytes for r_max/d extra cache memory)
        self.mass_pool = (jnp.zeros((L, n_slots, self.max_len, hkv),
                                    jnp.float32) if self.rank_on else None)
        self.spectra = (jnp.zeros((n_slots, hkv, dh), jnp.float32)
                        if self.rank_on else None)
        self.kt_pool = (jnp.zeros((L, n_slots + 1, self.max_len, hkv,
                                   self.r_keep), dtype)
                        if self.factored else None)

    # -- host-side page accounting --------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_needed(self, total_len: int) -> int:
        return -(-total_len // self.page_size)

    def retain(self, pages: Iterable[int]) -> None:
        """Add one reference to each page (prefix-tree insertion)."""
        for p in pages:
            if p == 0:
                raise ValueError("cannot retain the scratch page")
            self.ref[p] += 1

    def unref(self, pages: Iterable[int]) -> None:
        """Drop one reference per page; a page whose last reference drops
        returns to the free list."""
        for p in pages:
            r = int(self.ref[p]) - 1
            if r < 0:
                raise AssertionError(f"refcount underflow on page {p}")
            self.ref[p] = r
            if r == 0:
                self._free.append(int(p))

    def allocate(self, slot: int, total_len: int,
                 prefix_pages: Sequence[int] = ()) -> bool:
        """Reserve pages covering ``total_len`` tokens for ``slot``.

        ``prefix_pages`` is a leading run of already-filled shared pages
        (a prefix-cache hit): they become the slot's first page-table
        entries with ref + 1 each, and only the remainder is drawn from
        the free list. Returns False (no mutation) when the free pool
        can't cover the fresh remainder."""
        need = self.pages_needed(total_len)
        fresh = need - len(prefix_pages)
        if need > self.pages_per_slot or fresh < 0 or fresh > len(self._free):
            return False
        pages = list(prefix_pages) + [self._free.pop() for _ in range(fresh)]
        self.retain(prefix_pages)
        for p in pages[len(prefix_pages):]:
            self.ref[p] += 1            # fresh pages: 0 -> 1
        self.page_table[slot, :] = 0
        self.page_table[slot, :need] = pages
        self.lens[slot] = 0
        return True

    def release(self, slot: int) -> None:
        """Drop the slot's references and park it on scratch. Pages still
        held by the prefix tree (or another sharing slot) stay out of the
        free list until their last reference drops."""
        self.unref(int(p) for p in self.page_table[slot] if p != 0)
        self.page_table[slot, :] = 0
        self.lens[slot] = 0

    def shared_floor(self, slot: int) -> int:
        """First logical position in ``slot`` whose page is private
        (ref == 1): everything before it lives on pages shared with the
        prefix tree or another slot and is immutable to this slot.

        This is the rewind floor for speculative decoding: a draft/verify
        step writes (and a rejection logically rewinds, by not advancing
        ``lens`` past the accepted prefix) only positions >= this floor.
        The invariant holds by construction — shared pages are placed
        strictly before the slot's first written position and a partial
        shared tail page is COWed at admission — so speculative writes at
        positions >= lens can never land on a shared page; the engine
        asserts it per step rather than trusting the construction."""
        floor = 0
        for p in self.page_table[slot]:
            if p == 0 or int(self.ref[p]) <= 1:
                break
            floor += self.page_size
        return floor

    def live_pages(self) -> Dict[int, List[int]]:
        """slot -> referenced physical pages (for invariant checks)."""
        return {s: [int(p) for p in row if p != 0]
                for s, row in enumerate(self.page_table)}

    def check_refs(self, tree_pages: Iterable[int] = ()) -> None:
        """Assert the refcount invariant: every page's refcount equals its
        slot page-table references plus its prefix-tree references, free
        pages are exactly the zero-ref pages, each listed once."""
        counts: Counter = Counter()
        for row in self.page_table:
            for p in row:
                if p:
                    counts[int(p)] += 1
        for p in tree_pages:
            counts[int(p)] += 1
        free = Counter(self._free)
        assert 0 not in counts and 0 not in free, "scratch page referenced"
        assert all(v == 1 for v in free.values()), "free-list duplicate"
        for p in range(1, self.n_pages):
            expect = counts.get(p, 0)
            got = int(self.ref[p])
            assert got == expect, \
                f"page {p}: refcount {got} != {expect} references"
            assert (free.get(p, 0) == 1) == (expect == 0), \
                f"page {p}: ref {expect} but free-list presence " \
                f"{free.get(p, 0)}"

    # -- device-side page ops -------------------------------------------

    def copy_page(self, dst: int, src: int) -> None:
        """Copy one physical page's K/V contents (copy-on-write: a prefix
        hit whose shared tail page is only partially filled gets a private
        copy to append into — the shared original stays immutable)."""
        self.k_pool = self.k_pool.at[:, dst].set(self.k_pool[:, src])
        self.v_pool = self.v_pool.at[:, dst].set(self.v_pool[:, src])

    def write_prefill(self, slot: int, k_layers: jnp.ndarray,
                      v_layers: jnp.ndarray,
                      mass_layers: Optional[jnp.ndarray] = None) -> None:
        """Scatter a prefilled (L, s, hkv, dh) K/V run into the slot's pages
        and set its length. The slot's attention-mass row is zeroed (a
        recycled slot must not keep its previous occupant's mass) and,
        when ``mass_layers`` (L, s, hkv) is given, re-seeded with the
        prompt's per-key causal attention mass. Control-plane op (one
        dispatch per admission)."""
        s = k_layers.shape[1]
        pos = np.arange(s)
        phys = jnp.asarray(self.page_table[slot][pos // self.page_size])
        off = jnp.asarray(pos % self.page_size)
        self.k_pool = self.k_pool.at[:, phys, off].set(
            k_layers.astype(self.k_pool.dtype))
        self.v_pool = self.v_pool.at[:, phys, off].set(
            v_layers.astype(self.v_pool.dtype))
        if self.mass_pool is not None:
            mp = self.mass_pool.at[:, slot].set(0.0)
            if mass_layers is not None:
                mp = mp.at[:, slot, :s].set(
                    mass_layers.astype(self.mass_pool.dtype))
            self.mass_pool = mp
        self.lens[slot] = s

    # -- logical views ---------------------------------------------------

    def gather_slot(self, slot: int):
        """(L, max_len, hkv, dh) contiguous K/V view of one slot (testing /
        debugging; the fused step gathers all slots in-graph)."""
        pt = jnp.asarray(self.page_table[slot])
        def view(pool):
            g = pool[:, pt]                           # (L, pages, ps, hkv, dh)
            return g.reshape(g.shape[0], -1, *g.shape[3:])
        return view(self.k_pool), view(self.v_pool)


def gather_views(k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                 page_table: jnp.ndarray):
    """In-graph gather of every slot's logical K/V view.

    k_pool/v_pool: (L, P, ps, hkv, dh); page_table: (n_slots, pages).
    Returns (L, n_slots, M, hkv, dh) x2 with M = pages * ps."""
    def view(pool):
        g = pool[:, page_table]              # (L, n_slots, pages, ps, hkv, dh)
        L, ns = g.shape[0], g.shape[1]
        return g.reshape(L, ns, -1, *g.shape[4:])
    return view(k_pool), view(v_pool)
