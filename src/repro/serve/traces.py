"""Serving-trace recorder + replay reader (ROADMAP item 4).

With ``EngineConfig(record_traces=dir)`` the engine hooks a
:class:`TraceRecorder` into its per-segment rank-decision path. One trace
**record** is one (slot, segment) decision and its outcome:

* **decision features** — the slot's mass-weighted layer-0 spectra at the
  decision, the previous segment's spectra (the Eq. 9 "before" side), the
  kv length, the previous and chosen rank buckets, the segment clock and
  the layer index. These are exactly the inputs ``serve.policy.decide()``
  consumed, so the offline trainer (repro.train.serve_policy) can rebuild
  the policy-net features bit-compatibly with serving-time inference.
* **outcomes** — accumulated until the slot's next decision (or its
  eviction): tokens decoded in the segment, summed step latency (0 when
  the engine runs without ``time_per_token``), speculative accept stats,
  the factor-read bytes/token implied by the chosen rank, and a
  mass-weighted agreement proxy (head-mean retained spectral energy at
  the chosen rank — the serving-time stand-in for the fidelity term of
  the Eq. 13 reward).

Recording costs one small host fetch per *decision* (segment cadence,
never per token): the spectra/rank the decide call just wrote back. The
step loop's sync-free discipline is untouched — outcome accumulation
reuses numbers the host already has (the accept fetch, the host lens
mirror, eviction-time latencies).

On-disk format (versioned; readers reject unknown versions):

    <dir>/manifest.json             {"version": 1, "dh": ..., ...}
    <dir>/shard_0000.npz            column arrays, ``shard_size`` records
    <dir>/shard_0001.npz            ...

:class:`TraceReader` concatenates the shards back into column arrays.
Round-tripping is exact (tests/test_serve_traces.py).
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional

import numpy as np

__all__ = ["TRACE_SCHEMA_VERSION", "TraceRecorder", "TraceReader"]

TRACE_SCHEMA_VERSION = 1

# column name -> (dtype, per-record shape suffix); spectra columns get
# their (hkv, dh) suffix from the model config at write time
_SCALAR_COLUMNS = {
    "rid": np.int32, "slot": np.int32, "seg_t": np.int32,
    "kv_len": np.int32, "layer_id": np.int32, "prev_rank": np.int32,
    "chosen_rank": np.int32, "has_prev": np.bool_,
    "n_tokens": np.int32, "latency_s": np.float32,
    "spec_accepted": np.int32, "spec_drafted": np.int32,
    "read_bytes_per_token": np.float32, "agreement": np.float32,
}


class _OpenRecord:
    """A decision whose outcome window is still accumulating."""

    __slots__ = ("fields", "s2", "prev_s2")

    def __init__(self, fields: Dict, s2: np.ndarray, prev_s2: np.ndarray):
        self.fields = fields
        self.s2 = s2
        self.prev_s2 = prev_s2


class TraceRecorder:
    """Collects per-segment decision records and writes npz shards.

    The engine owns exactly one recorder (``ServeEngine.trace``) and
    calls ``on_decision`` / ``on_step`` / ``on_evict`` from its step
    loop; callers call :meth:`flush` once serving is done to commit the
    tail shard and the manifest. Not thread-safe on its own — the step
    loop is the sole caller by the engine's threading contract."""

    def __init__(self, directory, cfg, *, shard_size: int = 512,
                 scenario: Optional[str] = None):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.cfg = cfg
        self.shard_size = int(shard_size)
        if self.shard_size < 1:
            raise ValueError(f"shard_size must be >= 1, got {shard_size}")
        self.scenario = scenario
        self._open: Dict[int, _OpenRecord] = {}     # slot -> open record
        self._last: Dict[int, tuple] = {}   # slot -> (s2, rank) of last dec
        self._closed: List[_OpenRecord] = []
        self._shards: List[str] = []
        self._n_records = 0
        self._dh = cfg.resolved_head_dim()
        self._hkv = cfg.num_kv_heads
        self._g_hi = int(cfg.rank.rank_grid[-1])

    # -- engine hooks ----------------------------------------------------

    def on_decision(self, slot: int, rid: int, seg_t: int, kv_len: int,
                    chosen_rank: int, s2: np.ndarray, *,
                    has_prev: bool, layer_id: int = 0) -> None:
        """A decide() call just rewrote ``slot``'s rank/spectra. Closes
        the slot's previous record (its outcome window ends here) and
        opens the new one. ``s2`` is the slot's freshly written layer-0
        spectra (hkv, dh); the previous segment's spectra/rank come from
        the recorder's own last record for this slot — decide() is the
        only spectra writer, so this mirrors the device-side "before"
        state exactly. A first decision (``has_prev=False``) mirrors
        decide()'s fresh-slot semantics: prev_s2 = s2, prev_rank =
        r_max, veto off."""
        self._close(slot)
        s2 = np.asarray(s2, np.float32)
        prev = self._last.get(slot)
        if has_prev and prev is not None:
            prev_s2, prev_rank = prev
        else:
            prev_s2, prev_rank = s2, self._g_hi
        tot = np.maximum(s2.sum(axis=-1), 1e-30)
        kept = s2[:, :int(chosen_rank)].sum(axis=-1)
        agreement = float(np.mean(kept / tot))
        # factor-read bytes per decode token at the decision state:
        # every layer reads kv_len rows of r-column fp32 factors per head
        read_bpt = float(self.cfg.num_layers * int(kv_len)
                         * self._hkv * int(chosen_rank) * 4)
        self._open[slot] = _OpenRecord(
            dict(rid=int(rid), slot=int(slot), seg_t=int(seg_t),
                 kv_len=int(kv_len), layer_id=int(layer_id),
                 prev_rank=int(prev_rank), chosen_rank=int(chosen_rank),
                 has_prev=bool(has_prev and prev is not None),
                 n_tokens=0, latency_s=0.0, spec_accepted=0,
                 spec_drafted=0, read_bytes_per_token=read_bpt,
                 agreement=agreement),
            s2, np.asarray(prev_s2, np.float32))
        self._last[slot] = (s2, int(chosen_rank))

    def on_step(self, slot: int, n_tokens: int, dt: Optional[float],
                accepted: int = 0, drafted: int = 0) -> None:
        """Accumulate one step's outcome into the slot's open window."""
        rec = self._open.get(slot)
        if rec is None:
            return
        f = rec.fields
        f["n_tokens"] += int(n_tokens)
        if dt is not None:
            f["latency_s"] += float(dt)
        f["spec_accepted"] += int(accepted)
        f["spec_drafted"] += int(drafted)

    def on_evict(self, slot: int) -> None:
        """The slot's stream ended: close its outcome window and forget
        its previous-segment state (the next occupant starts fresh)."""
        self._close(slot)
        self._last.pop(slot, None)

    # -- persistence -----------------------------------------------------

    def _close(self, slot: int) -> None:
        rec = self._open.pop(slot, None)
        if rec is None:
            return
        self._closed.append(rec)
        self._n_records += 1
        if len(self._closed) >= self.shard_size:
            self._write_shard()

    def _write_shard(self) -> None:
        if not self._closed:
            return
        cols = {name: np.array([r.fields[name] for r in self._closed],
                               dtype)
                for name, dtype in _SCALAR_COLUMNS.items()}
        cols["s2"] = np.stack([r.s2 for r in self._closed])
        cols["prev_s2"] = np.stack([r.prev_s2 for r in self._closed])
        fname = f"shard_{len(self._shards):04d}.npz"
        np.savez_compressed(self.dir / fname, **cols)
        self._shards.append(fname)
        self._closed = []

    def flush(self) -> dict:
        """Close every open window, write the tail shard and the
        manifest. Idempotent; returns the manifest dict."""
        for slot in list(self._open):
            self._close(slot)
        self._write_shard()
        manifest = {
            "version": TRACE_SCHEMA_VERSION,
            "scenario": self.scenario,
            "n_records": self._n_records,
            "shards": list(self._shards),
            "dh": int(self._dh),
            "hkv": int(self._hkv),
            "num_layers": int(self.cfg.num_layers),
            "rank_grid": [int(r) for r in self.cfg.rank.rank_grid],
        }
        (self.dir / "manifest.json").write_text(json.dumps(manifest))
        return manifest


class TraceReader:
    """Replay a recorded trace directory back into column arrays.

    Validates the schema version (unknown versions are rejected — the
    format is versioned precisely so stale readers fail loudly) and
    concatenates all shards. ``records[name]`` is the full column;
    spectra columns are (N, hkv, dh)."""

    def __init__(self, directory):
        self.dir = pathlib.Path(directory)
        mpath = self.dir / "manifest.json"
        if not mpath.exists():
            raise FileNotFoundError(f"no trace manifest in {self.dir}")
        self.manifest = json.loads(mpath.read_text())
        version = self.manifest.get("version")
        if version != TRACE_SCHEMA_VERSION:
            raise ValueError(
                f"trace schema version {version!r} is not supported "
                f"(reader supports {TRACE_SCHEMA_VERSION})")
        parts: List[Dict[str, np.ndarray]] = []
        for fname in self.manifest["shards"]:
            with np.load(self.dir / fname) as z:
                parts.append({k: z[k] for k in z.files})
        if parts:
            self.records = {k: np.concatenate([p[k] for p in parts])
                            for k in parts[0]}
        else:
            self.records = {}

    def __len__(self) -> int:
        return int(self.manifest["n_records"])
