"""Continuous-batching serving engine (paper section 4.5.2 at scale).

- kv_cache:  slot-paged KV cache — a shared page pool + per-slot page
             tables, per-slot valid lengths / rank buckets / eigenbasis.
- scheduler: request queue, admission (prefill on free slots), eviction.
- policy:    slot-indexed segment-level rank decision + eigenbasis refresh
             (ported from the old AdaptiveServer._decide_rank, no host
             syncs).
- engine:    the step loop — one fused decode executable over all live
             slots with per-row kv_len and per-row rank.
"""
from repro.serve.engine import ServeEngine
from repro.serve.kv_cache import PagedKVCache
from repro.serve.scheduler import Request, Scheduler
