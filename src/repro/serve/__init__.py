"""Continuous-batching serving engine (paper section 4.5.2 at scale).

- api:       the public surface — EngineConfig + SamplingParams +
             Engine.submit(prompt, params) -> RequestHandle with
             incremental token streaming and per-request TTFT.
- kv_cache:  slot-paged KV cache — a shared page pool + per-slot page
             tables, per-slot valid lengths / rank buckets / eigenbasis.
- scheduler: request queue, admission (free slots + page reservation,
             chunked prompts tracked mid-prefill), eviction.
- policy:    slot-indexed segment-level rank decision + eigenbasis refresh
             (ported from the old AdaptiveServer._decide_rank, no host
             syncs).
- prefix:    shared-prefix KV reuse — a token-level radix tree over
             page-granularity prefixes with refcounted page sharing,
             exact attention-mass snapshots, LRU eviction and
             copy-on-write of partially-filled shared tail pages.
- engine:    the step loop core — one fused decode executable over all
             live slots with per-row kv_len, per-row rank, and chunked
             prefill interleaved into the same step.
- frontend:  the async front door — a background stepping thread per
             engine (FrontEnd), awaitable/streaming handles with
             cancellation, and a Router that load-balances N replicas
             by queue depth with prefix-cache affinity, configured
             through one FleetConfig.
- traces:    serving-trace recording (per-segment rank-decision features
             + outcomes, versioned npz shards) and the replay reader the
             offline policy trainer (repro.train.serve_policy) consumes.
- workloads: deterministic named scenario generators (bursty arrivals,
             long-context, shared-prefix chat, mixed sampling) used for
             trace generation and replay benchmarking.
"""
from repro.serve.api import (Engine, EngineConfig, EngineStopped,
                             RequestHandle, SamplingParams, make_engine)
from repro.serve.engine import ServeEngine
from repro.serve.frontend import FleetConfig, FrontEnd, Router
from repro.serve.kv_cache import PagedKVCache
from repro.serve.prefix import PrefixCache, RadixNode
from repro.serve.scheduler import Request, Scheduler
from repro.serve.traces import (TRACE_SCHEMA_VERSION, TraceReader,
                                TraceRecorder)
from repro.serve.workloads import WorkloadSpec, make_workload, workload_names

__all__ = ["Engine", "EngineConfig", "EngineStopped", "RequestHandle",
           "SamplingParams", "make_engine", "ServeEngine", "FleetConfig",
           "FrontEnd", "Router", "PagedKVCache", "PrefixCache",
           "RadixNode", "Request", "Scheduler", "TRACE_SCHEMA_VERSION",
           "TraceReader", "TraceRecorder", "WorkloadSpec", "make_workload",
           "workload_names"]
