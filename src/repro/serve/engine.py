"""Continuous-batching serving engine.

One engine = one slot-paged KV cache + one scheduler + three executables:

  * a length-bucketed **prefill** (full-rank forward over the padded
    prompt that also captures per-layer q/k/v; one compile per bucket,
    reused across requests) — the captured q/k seed the slot's per-key
    attention-mass accumulator,
  * a slot-indexed **segment decision** (serve.policy) that re-picks a
    boundary slot's rank bucket from its live softmax-weighted layer-0 K
    spectra, refreshes its cached per-layer eigenbasis, and (in factor
    form) re-projects its paged K factors — one executable, one dispatch
    per boundary crossing,
  * ONE fused **decode step** over all slots (models.transformer.
    decode_step_paged): per-row kv_len, per-row rank via factor padding +
    rank masking, in-graph attention-mass accumulation, and (by default)
    a factor-form score read ``kt = K . B_r`` that touches r_max/d of the
    dense K bytes — heterogeneous streams never force a recompile.

The step loop is host-side control only; lengths / ranks / tokens stay on
device between steps (token values are synced per step only when a live
request carries an ``eos_id``).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.api import get_model
from repro.serve.kv_cache import PagedKVCache
from repro.serve.policy import basis_drift, make_decide_fn
from repro.serve.scheduler import (Request, Scheduler, bucket_for,
                                   prefill_buckets)


class ServeEngine:
    """Continuous-batching decode over ``n_slots`` concurrent streams."""

    def __init__(self, cfg: ModelConfig, params, policy_params=None, *,
                 n_slots: int = 4, max_len: int = 256, page_size: int = 16,
                 segment_len: Optional[int] = None,
                 buckets: Optional[Sequence[int]] = None,
                 max_new_cap: int = 256, use_kernel: bool = False,
                 drift_threshold: Optional[float] = None,
                 time_per_token: bool = False,
                 factor_cache: Optional[bool] = None):
        self.cfg, self.params, self.policy = cfg, params, policy_params
        self.seg = int(segment_len or cfg.rank.segment_len)
        self.n_slots = n_slots
        self.max_new_cap = max_new_cap
        self.use_kernel = use_kernel
        self.drift_threshold = drift_threshold
        self.time_per_token = time_per_token
        # factor_cache=None -> factor form whenever the rank path is on
        # AND the widest bucket is below the head dim (otherwise the
        # factor pool saves nothing). True forces it on (error without a
        # rank mode — there is no basis to factor against), False forces
        # the dense-K read; the benchmark uses both for the comparison.
        self.cache = PagedKVCache(cfg, n_slots, max_len, page_size,
                                  factored=factor_cache)
        self._buckets = tuple(buckets) if buckets else prefill_buckets(max_len)
        self.sched = Scheduler(n_slots, self._buckets)
        self.fns = get_model(cfg)
        if self.fns.decode_step_paged is None:
            raise ValueError(
                f"family {cfg.family!r} has no paged decode step")
        self._pf_cfg = cfg.with_(rank=cfg.rank.__class__(mode="off"))
        self._prefill = jax.jit(self._prefill_impl)
        self._decide = (make_decide_fn(cfg, policy_params)
                        if cfg.rank.mode != "off" else None)
        # donate the pools + out_buf so XLA updates them in place instead
        # of materialising a full copy per step (CPU ignores donation and
        # would warn, so only donate on real accelerators); warmup must
        # then re-capture the outputs — see warmup()
        donate = (() if jax.default_backend() == "cpu"
                  else (1, 2, 3, 4, 11))
        self._step = jax.jit(self._step_impl, donate_argnums=donate)
        self._drift = (jax.jit(basis_drift)
                       if drift_threshold is not None else None)
        self._reset_state()

    def _reset_state(self):
        ns = self.n_slots
        self.tokens = jnp.zeros((ns, 1), jnp.int32)
        # +1 scratch row: dead lanes park their garbage writes there
        self.out_buf = jnp.zeros((ns + 1, self.max_new_cap), jnp.int32)
        self.has_rank = np.zeros((ns,), bool)
        self.force_decide = np.zeros((ns,), bool)
        self.now = 0
        # device-resident control state: pushed only on admission/eviction
        # events (dirty flag), never per step — lens advances in-graph
        self._dirty = True
        self._pt_dev = None
        self._active_dev = None
        self._plen_dev = None
        self._lens_dev = None
        self.stats = {"compile_s": 0.0, "prefill_s": 0.0, "decode_s": 0.0,
                      "steps": 0, "tokens_decoded": 0, "prefills": 0,
                      "decides": 0}
        self.rank_history: List[Tuple[int, jnp.ndarray, np.ndarray]] = []
        # harvested at eviction: decode-step wall time per token (needs
        # time_per_token=True) and first-token (prefill) latency per request
        self.token_latencies: List[float] = []
        self.first_token_s: List[float] = []

    def reset(self):
        """Clear all serving state but keep the compiled executables."""
        cfg, c = self.cfg, self.cache
        self.cache = PagedKVCache(cfg, self.n_slots, c.max_len, c.page_size,
                                  n_pages=c.n_pages, factored=c.factored)
        self.sched = Scheduler(self.n_slots, self._buckets)
        self._reset_state()

    # -- request plane ---------------------------------------------------

    def submit(self, req: Request) -> None:
        if req.max_new > self.max_new_cap:
            raise ValueError(f"max_new {req.max_new} > engine cap "
                             f"{self.max_new_cap}")
        if (self.cache.pages_needed(len(req.tokens) + req.max_new)
                > self.cache.pages_per_slot):
            raise ValueError(
                f"request needs {len(req.tokens) + req.max_new} cache "
                f"positions but a slot holds only {self.cache.max_len}")
        self.sched.submit(req)

    def warmup(self) -> float:
        """Compile (and run once, results discarded) every executable the
        queued requests will need; the elapsed time lands in
        stats['compile_s'] so throughput numbers stay compile-free."""
        t0 = time.perf_counter()
        ns = self.n_slots
        need = {bucket_for(len(r.tokens), self._buckets)
                for r in self.sched.pending}
        for bucket in sorted(need):
            out = self._prefill(self.params,
                                jnp.zeros((1, bucket), jnp.int32),
                                np.int32(bucket))
            jax.block_until_ready(out[0])
        self._sync_control()
        if self._decide is not None:
            # donated args (basis/spectra/kt) must be re-captured; the
            # warm decision runs on the empty slot 0 whose state the
            # admission-time re-decision overwrites before any read
            (self.cache.ranks, self.cache.basis, self.cache.spectra,
             self.cache.kt_pool) = self._decide(
                self.cache.k_pool, self.cache.mass_pool, self.cache.kt_pool,
                self._pt_dev, self._lens_dev, self.cache.ranks,
                self.cache.basis, self.cache.spectra,
                np.int32(0), np.bool_(False), np.int32(0))
            jax.block_until_ready(self.cache.basis)
        # all-lanes-inactive step: writes land on the scratch page / row,
        # so re-capturing the donated pools and out_buf is value-neutral
        pools, tok, ob, _ = self._step(
            self.params, self.cache.k_pool, self.cache.v_pool,
            self.cache.kt_pool, self.cache.mass_pool,
            self._pt_dev, self.tokens, self._lens_dev,
            self.cache.ranks, self.cache.basis,
            jnp.zeros((ns,), bool), self.out_buf,
            self._plen_dev)
        self.cache.k_pool, self.cache.v_pool = pools["k"], pools["v"]
        self.cache.kt_pool = pools.get("kt", self.cache.kt_pool)
        self.cache.mass_pool = pools.get("mass", self.cache.mass_pool)
        self.out_buf = ob
        jax.block_until_ready(tok)
        dt = time.perf_counter() - t0
        self.stats["compile_s"] += dt
        return dt

    # -- data plane ------------------------------------------------------

    def _prefill_impl(self, params, tokens, q_len):
        """Full-rank prefill over the padded bucket that also captures the
        per-layer k/v and the prompt's per-key attention mass off the
        forward's own softmax chain (queries beyond ``q_len`` are padding
        and excluded from the mass)."""
        from repro.models import transformer as tr
        logits, aux = tr.forward_dense(self._pf_cfg, params, tokens,
                                       collect_aux="rl", collect_qkv=True,
                                       collect_mass=self.cache.rank_on,
                                       mass_q_len=q_len)
        qkv = aux["layers"]["qkv"]
        mass = aux["layers"]["mass"] if self.cache.rank_on else None
        return logits, qkv["k"], qkv["v"], mass

    def _step_impl(self, params, pool_k, pool_v, kt_pool, mass_pool,
                   page_table, tokens, lens, ranks, basis, active, out_buf,
                   prompt_lens):
        ns = tokens.shape[0]
        off = self.cfg.rank.mode == "off"
        logits, pools = self.fns.decode_step_paged(
            params, pool_k, pool_v, page_table, tokens,
            slot_lens=lens, slot_ranks=None if off else ranks,
            basis=None if off else basis, active=active,
            use_kernel=self.use_kernel,
            kt_pool=None if off else kt_pool,
            mass_pool=None if off else mass_pool)
        tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)[:, None]
        tok = jnp.where(active[:, None], tok, tokens)     # greedy
        row = jnp.where(active, jnp.arange(ns), ns)       # dead -> scratch row
        out_idx = jnp.where(active, jnp.minimum(lens - prompt_lens + 1,
                                                self.max_new_cap - 1), 0)
        out_buf = out_buf.at[row, out_idx].set(tok[:, 0])
        lens = lens + active.astype(lens.dtype)
        return pools, tok, out_buf, lens

    def _sync_control(self) -> None:
        """Push host control state to device after admission/eviction; the
        steady-state decode loop reuses these arrays without any transfer."""
        if not self._dirty:
            return
        self._pt_dev = jnp.asarray(self.cache.page_table)
        self._active_dev = jnp.asarray(
            np.array([s.active for s in self.sched.slots]))
        self._plen_dev = jnp.asarray(
            np.array([s.prompt_len if s.active else 0
                      for s in self.sched.slots], np.int32))
        self._lens_dev = jnp.asarray(self.cache.lens, jnp.int32)
        self._dirty = False

    def _admit(self) -> List[int]:
        placed = self.sched.admit(self.now, self.cache.allocate)
        for slot, req, bucket in placed:
            t0 = time.perf_counter()
            s = len(req.tokens)
            padded = np.zeros((1, bucket), np.int32)
            padded[0, :s] = req.tokens
            logits, k_l, v_l, mass_l = self._prefill(
                self.params, jnp.asarray(padded), np.int32(s))
            tok0 = jnp.argmax(logits[0, s - 1]).astype(jnp.int32)
            mass = (None if mass_l is None else
                    jnp.swapaxes(mass_l[:, 0], 1, 2)[:, :s])  # (L, s, hkv)
            self.cache.write_prefill(slot, k_l[:, 0, :s], v_l[:, 0, :s],
                                     mass_layers=mass)
            self.tokens = self.tokens.at[slot, 0].set(tok0)
            self.out_buf = self.out_buf.at[slot, 0].set(tok0)
            st = self.sched.slots[slot]
            st.n_out = 1
            # a recycled slot must not inherit its previous occupant's
            # rank state: first decision is veto-free, fresh clock
            self.has_rank[slot] = False
            self.force_decide[slot] = False
            if req.eos_id is not None:
                st.last_tok = int(tok0)
            jax.block_until_ready(self.cache.k_pool)
            dt = time.perf_counter() - t0
            self.stats["prefill_s"] += dt
            self.stats["prefills"] += 1
            st.latencies.append(dt)               # first-token latency
        if placed:
            self._dirty = True
        return [slot for slot, _, _ in placed]

    def _maybe_decide(self) -> None:
        if self._decide is None:
            return
        active = np.array([s.active for s in self.sched.slots])
        at_seg = np.array([s.decode_i % self.seg == 0
                           for s in self.sched.slots])
        boundary = active & (at_seg | self.force_decide)
        if not boundary.any():
            return
        self._sync_control()
        # per-slot decision, slot index traced: streams hit segment
        # boundaries on their own staggered clocks, so an all-slots batched
        # decide would redo every slot's spectral solve at the union of
        # boundaries — n_slots times the work a per-stream server pays.
        # One dispatch per boundary crossing, one executable for all slots.
        for i in np.nonzero(boundary)[0]:
            st = self.sched.slots[i]
            (self.cache.ranks, self.cache.basis, self.cache.spectra,
             self.cache.kt_pool) = self._decide(
                self.cache.k_pool, self.cache.mass_pool, self.cache.kt_pool,
                self._pt_dev, self._lens_dev, self.cache.ranks,
                self.cache.basis, self.cache.spectra, np.int32(i),
                np.bool_(self.has_rank[i]), np.int32(st.t))
            st.t += 1
            self.stats["decides"] += 1
        self.has_rank |= boundary
        self.force_decide &= ~boundary

    def _check_drift(self, live: List[int]) -> None:
        ns, ps = self.n_slots, self.cache.page_size
        pos = np.maximum(self.cache.lens - 1, 0)
        phys = self.cache.page_table[np.arange(ns), pos // ps]
        k_tok = self.cache.k_pool[0][jnp.asarray(phys),
                                     jnp.asarray(pos % ps)]
        drift = np.asarray(self._drift(k_tok, self.cache.basis[0],
                                       self.cache.ranks))
        for i in live:
            if self.has_rank[i] and drift[i] > self.drift_threshold:
                self.force_decide[i] = True

    def _evict_finished(self) -> None:
        for i, st in enumerate(self.sched.slots):
            if st.active and self.sched.should_evict(i):
                outputs = np.asarray(self.out_buf[i, :st.n_out]).tolist()
                if st.latencies:
                    self.first_token_s.append(st.latencies[0])
                    self.token_latencies.extend(st.latencies[1:])
                self.sched.evict(i, self.cache.release, outputs)
                self._dirty = True

    def step(self) -> None:
        """One engine iteration: admit -> decide -> fused decode -> evict."""
        self._admit()
        self._evict_finished()                    # max_new == 1 / instant EOS
        live = [i for i, s in enumerate(self.sched.slots) if s.active]
        if live:
            # the timer starts before the segment decision: tokens decoded
            # in a boundary step really do wait on the decide dispatch
            t0 = time.perf_counter() if self.time_per_token else None
            self._maybe_decide()
            if self.cache.factored:
                # a factored slot's kt pages are only consistent after its
                # first decision re-projects them (write_prefill seeds
                # dense K/mass, not kt); decode_i == 0 is always a segment
                # boundary so this holds — keep it explicit in case the
                # decide trigger ever changes
                assert all(self.has_rank[i] for i in live), \
                    "factored slot would read unseeded kt pages"
            self._sync_control()
            self.rank_history.append(
                (self.stats["steps"], self.cache.ranks,
                 np.array([s.active for s in self.sched.slots])))
            pools, tok, ob, lens = self._step(
                self.params, self.cache.k_pool, self.cache.v_pool,
                self.cache.kt_pool, self.cache.mass_pool,
                self._pt_dev, self.tokens, self._lens_dev, self.cache.ranks,
                self.cache.basis, self._active_dev, self.out_buf,
                self._plen_dev)
            self.cache.k_pool, self.cache.v_pool = pools["k"], pools["v"]
            self.cache.kt_pool = pools.get("kt", self.cache.kt_pool)
            self.cache.mass_pool = pools.get("mass", self.cache.mass_pool)
            self.tokens, self.out_buf, self._lens_dev = tok, ob, lens
            dt = None
            if self.time_per_token:
                jax.block_until_ready(tok)
                dt = time.perf_counter() - t0
            need_tok = any(self.sched.slots[i].req.eos_id is not None
                           for i in live)
            tok_host = np.asarray(tok[:, 0]) if need_tok else None
            for i in live:
                st = self.sched.slots[i]
                st.decode_i += 1
                st.n_out += 1
                self.cache.lens[i] += 1           # host mirror of _lens_dev
                if tok_host is not None:
                    st.last_tok = int(tok_host[i])
                if dt is not None:
                    st.latencies.append(dt)
            self.stats["steps"] += 1
            self.stats["tokens_decoded"] += len(live)
            if self._drift is not None:
                self._check_drift(live)
            self._evict_finished()
        self.now += 1

    def run(self, max_steps: Optional[int] = None) -> Dict:
        """Drive the loop until every request finished. Returns
        {rid: np.ndarray of generated tokens}."""
        p0 = self.stats["prefill_s"]
        t0 = time.perf_counter()
        steps = 0
        while not self.sched.done():
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        jax.block_until_ready(self.out_buf)
        wall = time.perf_counter() - t0
        self.stats["decode_s"] += max(
            wall - (self.stats["prefill_s"] - p0), 0.0)
        return self.results()

    def results(self) -> Dict[int, np.ndarray]:
        return {req.rid: np.asarray(out, np.int32)
                for req, out in self.sched.finished}

    def ranks_per_step(self) -> List[np.ndarray]:
        """Host copy of the per-step (ranks, active) record; -1 marks dead
        lanes AND full-rank decode (rank mode 'off'), where the cache's
        r_max placeholder is not a real bucket."""
        if self.cfg.rank.mode == "off":
            return [np.full(a.shape, -1) for _, _, a in self.rank_history]
        return [np.where(a, np.asarray(r), -1)
                for _, r, a in self.rank_history]
