"""Continuous-batching serving engine.

One engine = one slot-paged KV cache + one scheduler + a small set of
compiled executables:

  * a slot-indexed **segment decision** (serve.policy) that re-picks a
    boundary slot's rank bucket from its live softmax-weighted layer-0 K
    spectra, refreshes its cached per-layer eigenbasis, and (in factor
    form) re-projects its paged K factors — one executable, one dispatch
    per boundary crossing,
  * ONE fused **decode step** over all slots (models.transformer.
    decode_step_paged): per-row kv_len, per-row rank via factor padding +
    rank masking, in-graph attention-mass accumulation, in-graph
    temperature/top-k sampling, and (by default) a factor-form score read
    ``kt = K . B_r`` that touches r_max/d of the dense K bytes —
    heterogeneous streams never force a recompile,
  * prompt admission, in one of two modes:
      - **chunked prefill** (``prefill_chunk=C``, the repro.serve.api
        default): prompts are consumed C tokens at a time *inside* a
        mixed fused step that carries the live decode rows alongside —
        admission never stalls decoding, prompts of any length share one
        executable (no compile per length bucket), and the chunk's causal
        attention mass accumulates into the slot's mass pool so the
        weighted-Gram basis still sees the full prompt mass;
      - **one-shot** (``prefill_chunk=None``, the legacy default): a
        length-bucketed full-rank prefill forward (one compile per
        bucket) runs at admission, blocking the loop while it prefills.

With ``prefix_cache=True`` (chunked mode only) finished prompts stay
cached in a radix tree (serve.prefix): admission matches the new prompt
against it, shares the hit's pages (refcounted, copy-on-write for a
partial tail page), rehydrates the slot's attention-mass row from the
prefix snapshot, and enters chunked prefill at the reuse point — token
output is identical to a cold admission that prefilled the whole prompt.

The step loop is host-side control only; lengths / ranks / tokens stay on
device between steps (token values are synced per step only when a live
request carries an ``eos_id`` or a streaming consumer is attached).
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.api import get_model
from repro.obs import NULL_PHASES, Observability, Stopwatch
from repro.serve.kv_cache import PagedKVCache
from repro.serve.policy import basis_drift, make_decide_fn
from repro.serve.prefix import MatchResult, PrefixCache
from repro.serve.spec import host_accept_stats
from repro.serve.scheduler import (Request, Scheduler, bucket_for,
                                   prefill_buckets)


class ServeEngine:
    """Continuous-batching decode over ``n_slots`` concurrent streams."""

    # cadence of recovery-probe spec steps while eff_k is collapsed to 0
    _DRAFT_PROBE_EVERY = 8
    # EWMA smoothing for the adaptive-draft accept-fraction signal
    _DRAFT_EWMA_ALPHA = 0.4

    def __init__(self, cfg: ModelConfig, params, policy_params=None, *,
                 n_slots: int = 4, max_len: int = 256, page_size: int = 16,
                 segment_len: Optional[int] = None,
                 buckets: Optional[Sequence[int]] = None,
                 max_new_cap: int = 256, use_kernel: bool = False,
                 drift_threshold: Optional[float] = None,
                 time_per_token: bool = False,
                 factor_cache: Optional[bool] = None,
                 prefill_chunk: Optional[int] = None,
                 sampling: bool = False, nucleus: bool = False,
                 top_k_cap: int = 64,
                 prefix_cache: bool = False,
                 prefix_pages: Optional[int] = None,
                 speculative: bool = False, draft_k: int = 4,
                 draft_rank_frac: float = 0.25,
                 snapshot_every: int = 1,
                 adaptive_draft: bool = False,
                 draft_shrink_below: float = 0.35,
                 draft_grow_above: float = 0.6,
                 record_traces: Optional[str] = None,
                 obs_trace: bool = False,
                 flight_dir: Optional[str] = None,
                 flight_capacity: int = 256):
        self.cfg, self.params, self.policy = cfg, params, policy_params
        self.seg = int(segment_len or cfg.rank.segment_len)
        self.n_slots = n_slots
        self.max_new_cap = max_new_cap
        self.use_kernel = use_kernel
        self.drift_threshold = drift_threshold
        self.time_per_token = time_per_token
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
        self.chunk = prefill_chunk
        if prefix_cache and prefill_chunk is None:
            # exact mass snapshots are captured where chunked prefill
            # pauses; the one-shot path has no such cut points
            raise ValueError("prefix_cache requires chunked prefill "
                             "(prefill_chunk is None)")
        # speculative self-drafting (repro.serve.spec): draft_k cheap
        # low-rank tokens per fused step, verified in one chunked block.
        # The verify pass IS the chunked-query step, so chunked prefill
        # is required; the step's chunk width covers both the prefill
        # chunk and the draft run.
        self.speculative = bool(speculative)
        self.draft_k = int(draft_k)
        self.draft_rank_frac = float(draft_rank_frac)
        self.snapshot_every = int(snapshot_every)
        if self.speculative and self.chunk is None:
            raise ValueError("speculative decode requires chunked prefill "
                             "(the verify pass is the chunked-query step)")
        if self.speculative and self.draft_k < 1:
            raise ValueError(f"draft_k must be >= 1, got {draft_k}")
        if not 0.0 < self.draft_rank_frac <= 1.0:
            raise ValueError(f"draft_rank_frac must be in (0, 1], got "
                             f"{draft_rank_frac}")
        if self.snapshot_every < 1:
            raise ValueError(f"snapshot_every must be >= 1, got "
                             f"{snapshot_every}")
        # adaptive draft length: an EWMA of the per-step accept fraction
        # drives an effective draft length eff_k in [0, draft_k]. The
        # fused executables are shape-static (draft_k forwards compile
        # in), so intermediate eff_k values only shorten the accept caps;
        # the real saving is eff_k == 0, where decode steps route through
        # the mixed step and skip the draft forwards entirely. A probe
        # spec step every _DRAFT_PROBE_EVERY steps samples the accept
        # signal so a recovered stream grows eff_k back.
        self.adaptive_draft = bool(adaptive_draft)
        self.draft_shrink_below = float(draft_shrink_below)
        self.draft_grow_above = float(draft_grow_above)
        if self.adaptive_draft and not self.speculative:
            raise ValueError("adaptive_draft requires speculative=True")
        self.trace = None
        if record_traces:
            from repro.serve.traces import TraceRecorder
            # a TraceRecorder instance may be shared across sequential
            # engines (one dataset over a whole workload suite); a fresh
            # path gets its own recorder
            self.trace = (record_traces
                          if isinstance(record_traces, TraceRecorder)
                          else TraceRecorder(record_traces, cfg))
        # observability bundle (repro.obs): the metrics registry shard is
        # always on (every stat below lives in it, via the StatsView);
        # span/phase tracing (obs_trace) and flight dumps (flight_dir)
        # are opt-in. Every hook the loop calls is host-only Python —
        # observability ON adds no device syncs and no executables.
        self.obs = Observability(trace=obs_trace, flight_dir=flight_dir,
                                 flight_capacity=flight_capacity)
        self.spec_chunk = (max(self.chunk, self.draft_k + 1)
                           if self.speculative else None)
        # sampling=True compiles the temperature/top-k/gumbel tail into the
        # fused step (static flag: greedy-only engines keep the plain
        # argmax executable). Greedy rows (temperature 0) stay bitwise
        # identical either way. nucleus=True additionally compiles the
        # top-p cut — a full-vocab softmax + sort per step, so engines
        # that never serve top_p < 1 should leave it off.
        self.sampling = sampling
        self.nucleus = bool(nucleus)
        if self.nucleus and not sampling:
            raise ValueError("nucleus (top-p) requires sampling=True")
        self.top_k_cap = int(top_k_cap)
        # factor_cache=None -> factor form whenever the rank path is on
        # AND the widest bucket is below the head dim (otherwise the
        # factor pool saves nothing). True forces it on (error without a
        # rank mode — there is no basis to factor against), False forces
        # the dense-K read; the benchmark uses both for the comparison.
        # prefix_cache grows the pool by ``prefix_pages`` (default: one
        # extra slot-set) so cached prefixes don't starve admissions.
        pps = -(-max_len // page_size)
        self._n_pages = None
        if prefix_cache:
            extra = n_slots * pps if prefix_pages is None else prefix_pages
            self._n_pages = n_slots * pps + 1 + extra
        self.cache = PagedKVCache(cfg, n_slots, max_len, page_size,
                                  n_pages=self._n_pages,
                                  factored=factor_cache)
        # static draft width: the basis / kt pool are sliced to r_cap
        # columns for the draft forwards (a real byte cut); per-row draft
        # ranks (policy.draft_ranks) stay within [grid floor, r_cap]
        self._draft_cap = None
        self._grid_lo = None
        if self.speculative and self.cache.rank_on:
            # captured here, NOT read off cfg inside the traced body:
            # the jit closure must only see init-time immutables
            g_lo = int(cfg.rank.rank_grid[0])
            want = int(np.ceil(self.cache.r_keep * self.draft_rank_frac))
            self._draft_cap = min(max(g_lo, want, 1), self.cache.r_keep)
            self._grid_lo = g_lo
        self.prefix = PrefixCache(self.cache) if prefix_cache else None
        # submit() and admission (scheduler pop + device staging) may run
        # on different threads; one lock covers both critical sections
        self._lock = threading.Lock()
        self._buckets = tuple(buckets) if buckets else prefill_buckets(max_len)
        self.sched = Scheduler(n_slots, self._buckets)
        self.fns = get_model(cfg)
        if self.fns.decode_step_paged is None:
            raise ValueError(
                f"family {cfg.family!r} has no paged decode step")
        self._pf_cfg = cfg.with_(rank=cfg.rank.__class__(mode="off"))
        # init-time capture for the jitted prefill closure: reset()
        # swaps self.cache, and a traced body must never read through a
        # reassignable attribute (stale capture / silent retrace)
        self._pf_collect_mass = self.cache.rank_on
        self._prefill = jax.jit(self._prefill_impl)
        self._decide = (make_decide_fn(cfg, policy_params)
                        if cfg.rank.mode != "off" else None)
        # donate the pools + out_buf so XLA updates them in place instead
        # of materialising a full copy per step (CPU ignores donation and
        # would warn, so only donate on real accelerators); warmup must
        # then re-capture the outputs — see warmup()
        donate = (() if jax.default_backend() == "cpu"
                  else (1, 2, 3, 4, 11))
        self._step = jax.jit(self._step_impl, donate_argnums=donate)
        self._step_mixed = (jax.jit(self._step_mixed_impl,
                                    donate_argnums=donate)
                            if self.chunk is not None else None)
        self._step_spec = (jax.jit(self._step_spec_impl,
                                   donate_argnums=donate)
                           if self.speculative else None)
        # token-0 selection for one-shot admission: the same in-graph
        # sampling math the fused step applies, on the prefill's last
        # prompt logits — a sampled stream draws identically whether its
        # token 0 comes from a bucketed prefill or a finishing chunk
        self._select1 = jax.jit(lambda lg, t, k, p, sd: self._select_token(
            lg[None], jnp.zeros((1,), jnp.int32), t[None], k[None],
            p[None], sd[None])[0])
        self._drift = (jax.jit(basis_drift)
                       if drift_threshold is not None else None)
        self._reset_state()

    def _reset_state(self):
        ns = self.n_slots
        self.tokens = jnp.zeros((ns, 1), jnp.int32)
        # +1 scratch row: dead lanes park their garbage writes there
        self.out_buf = jnp.zeros((ns + 1, self.max_new_cap), jnp.int32)
        self.has_rank = np.zeros((ns,), bool)
        self.force_decide = np.zeros((ns,), bool)
        self.now = 0
        # device-resident control state: pushed only on admission/eviction
        # events (dirty flag), never per step — lens advances in-graph
        self._dirty = True
        self._pt_dev = None
        self._active_dev = None
        self._plen_dev = None
        self._lens_dev = None
        # per-slot sampling state (host mirrors; device copies pushed with
        # the control sync on admission)
        self._temp = np.zeros((ns,), np.float32)
        self._topk = np.zeros((ns,), np.int32)
        self._topp = np.ones((ns,), np.float32)
        self._seed = np.zeros((ns,), np.uint32)
        self._temp_dev = self._topk_dev = self._topp_dev = None
        self._seed_dev = None
        self._eos_dev = None
        self.prompt_buf = (jnp.zeros((ns, self.cache.max_len), jnp.int32)
                           if self.chunk is not None else None)
        # prefix-cache bookkeeping: the hit looked up at allocation time
        # (applied when the placement lands), the per-slot exact mass
        # snapshots captured during chunked prefill, and the inserted
        # nodes awaiting their lazy layer-0 spectra capture
        self._hits: Dict[int, MatchResult] = {}
        self._snaps: Dict[int, Dict[int, Optional[jnp.ndarray]]] = {}
        self._spectra_pending: Dict[int, object] = {}
        self.request_prefix_hit: Dict[int, bool] = {}
        # the historical stats dict as a view over the obs registry: same
        # keys, same dict semantics (reads, += writes, dict() copies),
        # but the registry is the single accumulation point and the
        # exporters see these values for free. Re-binding zeroes the
        # backing metrics — the old "fresh dict per reset" semantics.
        self.stats = self.obs.stats_view(
            {"compile_s": 0.0, "prefill_s": 0.0, "decode_s": 0.0,
             "steps": 0, "tokens_decoded": 0, "prefills": 0,
             "decides": 0, "mixed_steps": 0, "stall_s": 0.0,
             "prefill_tokens": 0, "prefix_hits": 0,
             "prefix_misses": 0, "prefix_reused_tokens": 0,
             "prefix_cow": 0, "prefix_evictions": 0,
             "spec_steps": 0, "spec_drafted": 0,
             "spec_accepted": 0, "spec_tokens": 0,
             "eff_draft_k": self.draft_k if self.speculative else 0})
        self.obs.reset_run()
        # per-decision Eq. 9 veto flags, banked as UNFETCHED device bools
        # — obs.rank_telemetry() fetches them in one batch at export
        # time, so veto observability costs the loop nothing (R1)
        self._veto_pending: List[jnp.ndarray] = []
        # adaptive-draft controller state (host-only; never traced)
        self._eff_k = self.draft_k if self.speculative else 0
        self._accept_ewma = 1.0
        self._probe_i = 0
        if self.trace is not None:
            # a reset ends every live stream: close their outcome windows
            for slot in range(ns):
                self.trace.on_evict(slot)
        # rid -> accepted run length of every speculative step the
        # request decoded in (harvested at eviction/cancel)
        self.request_accept_lens: Dict[int, List[int]] = {}
        self.rank_history: List[Tuple[int, jnp.ndarray, np.ndarray]] = []
        # harvested at eviction: decode-step wall time per token (needs
        # time_per_token=True) and first-token (prefill) latency per request
        self.token_latencies: List[float] = []
        self.first_token_s: List[float] = []
        # absolute perf_counter at each request's token-0 emission (the
        # api layer turns this into submit-relative TTFT)
        self.request_first_tok_t: Dict[int, float] = {}
        # (rid, out_index, token) triples of the last step, filled only
        # when the step synced token values (eos or _stream_sync)
        self.last_emitted: List[Tuple[int, int, int]] = []
        # streaming plane (repro.serve.api): when set, every step syncs
        # the emitted tokens to host and records them in ``last_emitted``
        # (the api layer turns it off again when the last streaming
        # consumer finishes, restoring the sync-free loop)
        self._stream_sync = False

    def reset(self):
        """Clear all serving state — including every cached prefix — but
        keep the compiled executables. Takes the engine lock: a submit
        racing a reset either lands before (and is discarded with the old
        scheduler's queue) or after (and is served) — never silently
        orphaned in a swapped-out scheduler."""
        with self._lock:
            cfg, c = self.cfg, self.cache
            self.cache = PagedKVCache(cfg, self.n_slots, c.max_len,
                                      c.page_size, n_pages=c.n_pages,
                                      factored=c.factored)
            if self.prefix is not None:
                self.prefix = PrefixCache(self.cache)
            self.sched = Scheduler(self.n_slots, self._buckets)
            self._reset_state()

    # -- request plane ---------------------------------------------------

    def submit(self, req: Request) -> None:
        """Enqueue a request. Thread-safe: the queue append is serialised
        against the step loop's admission (scheduler pop + device staging)
        by the engine lock, so a server thread may submit while another
        drives step()/run() — the stepping stone to a fully async API."""
        if req.max_new > self.max_new_cap:
            raise ValueError(f"max_new {req.max_new} > engine cap "
                             f"{self.max_new_cap}")
        if (self.cache.pages_needed(len(req.tokens) + req.max_new)
                > self.cache.pages_per_slot):
            raise ValueError(
                f"request needs {len(req.tokens) + req.max_new} cache "
                f"positions but a slot holds only {self.cache.max_len}")
        if ((req.temperature > 0 or req.top_k > 0 or req.top_p < 1.0)
                and not self.sampling):
            raise ValueError("request asks for sampling but the engine was "
                             "built with sampling=False (greedy executable)")
        if req.top_p < 1.0 and not self.nucleus:
            raise ValueError("request asks for top_p but the engine was "
                             "built with nucleus=False (the top-p cut is "
                             "a compiled-in full-vocab sort per step; "
                             "build the engine with nucleus=True)")
        if req.top_k > self.top_k_cap:
            raise ValueError(f"top_k {req.top_k} > engine top_k_cap "
                             f"{self.top_k_cap}")
        with self._lock:
            self.sched.submit(req)

    def cancel(self, rid: int) -> bool:
        """Abort a request mid-flight: a queued request is dropped, an
        admitted one (decoding OR mid-prefill) is evicted and its pages
        released (refcounted: pages shared with the prefix tree or
        another slot stay resident). Returns False when ``rid`` is not
        live (already finished, already cancelled, or never submitted).

        Callers driving a concurrent step loop must serialise this
        against step() (repro.serve.api.Engine.cancel holds the step
        lock) — the engine lock here only guards the queue and the page
        accounting against a racing submit/admission."""
        with self._lock:
            if self.sched.cancel_pending(rid):
                return True
            for i, st in enumerate(self.sched.slots):
                if st.active and st.req.rid == rid:
                    outputs = np.asarray(self.out_buf[i, :st.n_out]).tolist()
                    if st.accept_lens:
                        self.request_accept_lens[rid] = list(st.accept_lens)
                    if self.trace is not None:
                        self.trace.on_evict(i)
                    self.obs.on_finish(rid, i, st.n_out, "cancel")
                    self.sched.evict(i, self.cache.release, outputs)
                    # a mid-prefill cancel leaves no prefix insertion and
                    # no pending spectra capture for this slot
                    self._hits.pop(i, None)
                    self._snaps.pop(i, None)
                    self._spectra_pending.pop(i, None)
                    self._dirty = True
                    return True
        return False

    @property
    def depth(self) -> int:
        """Queue depth: pending + admitted requests (the router's
        load-balancing signal)."""
        with self._lock:
            return self.sched.depth()

    def prefix_probe(self, tokens) -> int:
        """Longest cached-prefix length this engine could reuse for
        ``tokens`` right now (0 without a prefix cache). Read-only — the
        router scores every replica with this before dispatching."""
        if self.prefix is None:
            return 0
        with self._lock:
            return self.prefix.probe(tokens)

    def _adopt_pools(self, pools) -> None:
        """Re-capture the fused step's (donated) pool outputs.  The
        optional factor/mass pools are adopted only when the step
        returned them — never by re-reading the donated input as a
        fallback, which a donating backend may already have
        invalidated."""
        self.cache.k_pool, self.cache.v_pool = pools["k"], pools["v"]
        if "kt" in pools:
            self.cache.kt_pool = pools["kt"]
        if "mass" in pools:
            self.cache.mass_pool = pools["mass"]

    def warmup(self) -> float:
        """Compile (and run once, results discarded) every executable the
        queued requests will need; the elapsed time lands in
        stats['compile_s'] so throughput numbers stay compile-free."""
        sw = Stopwatch()
        ns = self.n_slots
        if self.chunk is None:
            need = {bucket_for(len(r.tokens), self._buckets)
                    for r in self.sched.pending}
            for bucket in sorted(need):
                out = self._prefill(self.params,
                                    jnp.zeros((1, bucket), jnp.int32),
                                    np.int32(bucket))
                jax.block_until_ready(out[0])
        self._sync_control()
        if self._decide is not None:
            # donated args (basis/spectra/kt) must be re-captured; the
            # warm decision runs on the empty slot 0 whose state the
            # admission-time re-decision overwrites before any read
            # (the warm veto flag is meaningless and not banked)
            (self.cache.ranks, self.cache.basis, self.cache.spectra,
             self.cache.kt_pool, _veto) = self._decide(
                self.cache.k_pool, self.cache.mass_pool, self.cache.kt_pool,
                self._pt_dev, self._lens_dev, self.cache.ranks,
                self.cache.basis, self.cache.spectra,
                np.int32(0), np.bool_(False), np.int32(0))
            jax.block_until_ready(self.cache.basis)
        # all-lanes-inactive step: writes land on the scratch page / row,
        # so re-capturing the donated pools and out_buf is value-neutral
        if self.speculative:
            # the pure-prefill phase routes through the mixed step (see
            # step()); the plain decode step is never dispatched
            pools, tok, ob, _ = self._step_mixed(
                self.params, self.cache.k_pool, self.cache.v_pool,
                self.cache.kt_pool, self.cache.mass_pool,
                self._pt_dev, self.tokens, self._lens_dev,
                self.cache.ranks, self.cache.basis,
                jnp.zeros((ns,), bool), self.out_buf,
                self._plen_dev, self._temp_dev, self._topk_dev,
                self._topp_dev, self._seed_dev, self.prompt_buf)
            self._adopt_pools(pools)
            self.out_buf = ob
            jax.block_until_ready(tok)
            pools, tok, ob, _, _, _, _ = self._step_spec(
                self.params, self.cache.k_pool, self.cache.v_pool,
                self.cache.kt_pool, self.cache.mass_pool,
                self._pt_dev, self.tokens, self._lens_dev,
                self.cache.ranks, self.cache.basis,
                jnp.zeros((ns,), bool), self.out_buf,
                self._plen_dev, self._temp_dev, self._topk_dev,
                self._topp_dev, self._seed_dev, self.prompt_buf,
                self.cache.spectra, jnp.ones((ns,), jnp.int32),
                jnp.full((ns,), -1, jnp.int32))
            self._adopt_pools(pools)
            self.out_buf = ob
            jax.block_until_ready(tok)
        else:
            runs = [(self._step, ())] + (
                [(self._step_mixed, (self.prompt_buf,))]
                if self._step_mixed is not None else [])
            for fn, extra in runs:
                pools, tok, ob, _ = fn(
                    self.params, self.cache.k_pool, self.cache.v_pool,
                    self.cache.kt_pool, self.cache.mass_pool,
                    self._pt_dev, self.tokens, self._lens_dev,
                    self.cache.ranks, self.cache.basis,
                    jnp.zeros((ns,), bool), self.out_buf,
                    self._plen_dev, self._temp_dev, self._topk_dev,
                    self._topp_dev, self._seed_dev, *extra)
                self._adopt_pools(pools)
                self.out_buf = ob
                jax.block_until_ready(tok)
        dt = sw.stop()
        self.stats["compile_s"] += dt
        return dt

    # -- data plane ------------------------------------------------------

    def _prefill_impl(self, params, tokens, q_len):
        """Full-rank prefill over the padded bucket that also captures the
        per-layer k/v and the prompt's per-key attention mass off the
        forward's own softmax chain (queries beyond ``q_len`` are padding
        and excluded from the mass)."""
        from repro.models import transformer as tr
        logits, aux = tr.forward_dense(self._pf_cfg, params, tokens,
                                       collect_aux="rl", collect_qkv=True,
                                       collect_mass=self._pf_collect_mass,
                                       mass_q_len=q_len)
        qkv = aux["layers"]["qkv"]
        mass = aux["layers"]["mass"] if self._pf_collect_mass else None
        return logits, qkv["k"], qkv["v"], mass

    def _select_token(self, logits, out_pos, temps, topks, topps, seeds):
        """Next token per row from (ns, V) logits. ``out_pos`` is each
        row's output index (0 = first generated token): the sampling PRNG
        folds (per-request seed, out_pos), so a stream's draw sequence is
        a pure function of the request — identical under any batching,
        admission mode, or chunking. Greedy rows (temperature 0) take the
        plain argmax, bitwise identical to the sampling-free executable.

        Filter order matches the common stack: temperature scale -> top-k
        -> top-p (nucleus: the smallest probability-sorted set whose mass
        reaches ``top_p``; at least one token survives; probability ties
        at the cut all stay in). ``top_p == 1`` rows bypass the nucleus
        mask bitwise, so greedy / top-k / top-p streams mix in ONE
        executable — but the cut itself (full-vocab softmax + sort per
        step) is only compiled in when the engine was built with
        ``nucleus=True``."""
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if not self.sampling:
            return greedy
        ns, V = logits.shape
        kcap = min(self.top_k_cap, V)
        kth = jax.lax.top_k(logits, kcap)[0]                  # (ns, kcap)
        sel = jnp.clip(topks - 1, 0, kcap - 1)
        thr = jnp.take_along_axis(kth, sel[:, None], 1)
        masked = jnp.where((topks[:, None] > 0) & (logits < thr),
                           -jnp.inf, logits)
        t = jnp.maximum(temps, 1e-6)[:, None]
        scaled = masked / t
        if self.nucleus:
            # nucleus cut: keep tokens whose probability is >= the
            # smallest probability still inside the top_p mass
            # (sorted-cumsum rule)
            pr = jax.nn.softmax(scaled, axis=-1)
            srt = jnp.sort(pr, axis=-1)[:, ::-1]
            cum = jnp.cumsum(srt, axis=-1)
            keep = (cum - srt) < topps[:, None]   # mass before token < p
            p_min = jnp.min(jnp.where(keep, srt, jnp.inf), axis=-1,
                            keepdims=True)
            scaled = jnp.where((topps[:, None] < 1.0) & (pr < p_min),
                               -jnp.inf, scaled)
        keys = jax.vmap(lambda s, p: jax.random.fold_in(
            jax.random.PRNGKey(s), p))(seeds, out_pos.astype(jnp.uint32))
        g = jax.vmap(lambda k: jax.random.gumbel(k, (V,)))(keys)
        sampled = jnp.argmax(scaled + g, axis=-1).astype(jnp.int32)
        return jnp.where(temps > 0, sampled, greedy)

    def _step_impl(self, params, pool_k, pool_v, kt_pool, mass_pool,
                   page_table, tokens, lens, ranks, basis, active, out_buf,
                   prompt_lens, temps, topks, topps, seeds):
        ns = tokens.shape[0]
        off = self.cfg.rank.mode == "off"
        logits, pools = self.fns.decode_step_paged(
            params, pool_k, pool_v, page_table, tokens,
            slot_lens=lens, slot_ranks=None if off else ranks,
            basis=None if off else basis, active=active,
            use_kernel=self.use_kernel,
            kt_pool=None if off else kt_pool,
            mass_pool=None if off else mass_pool)
        out_idx = jnp.where(active, jnp.minimum(lens - prompt_lens + 1,
                                                self.max_new_cap - 1), 0)
        tok = self._select_token(logits[:, 0], out_idx,
                                 temps, topks, topps, seeds)[:, None]
        tok = jnp.where(active[:, None], tok, tokens)
        row = jnp.where(active, jnp.arange(ns), ns)       # dead -> scratch row
        out_buf = out_buf.at[row, out_idx].set(tok[:, 0])
        lens = lens + active.astype(lens.dtype)
        return pools, tok, out_buf, lens

    def _step_mixed_impl(self, params, pool_k, pool_v, kt_pool, mass_pool,
                         page_table, tokens, lens, ranks, basis, active,
                         out_buf, prompt_lens, temps, topks, topps, seeds,
                         prompt_buf):
        """One mixed fused step: live decode rows advance one token while
        mid-prefill rows consume the next ``chunk`` tokens of their prompt
        from the device-resident ``prompt_buf`` — chunked prefill
        interleaved into the decode step, no host work in between."""
        ns, C = tokens.shape[0], self.chunk
        off = self.cfg.rank.mode == "off"
        is_pf = active & (lens < prompt_lens)
        q_lens = jnp.where(is_pf, jnp.minimum(C, prompt_lens - lens),
                           1).astype(jnp.int32)
        idx = jnp.clip(lens[:, None] + jnp.arange(C)[None, :], 0,
                       prompt_buf.shape[1] - 1)
        chunk_toks = jnp.take_along_axis(prompt_buf, idx, axis=1)
        toks_in = jnp.where(is_pf[:, None], chunk_toks,
                            jnp.broadcast_to(tokens, (ns, C)))
        logits, pools = self.fns.decode_step_paged(
            params, pool_k, pool_v, page_table, toks_in,
            slot_lens=lens, q_lens=q_lens, prefill_rows=is_pf,
            slot_ranks=None if off else ranks,
            basis=None if off else basis, active=active,
            use_kernel=self.use_kernel,
            kt_pool=None if off else kt_pool,
            mass_pool=None if off else mass_pool)
        lens_after = lens + jnp.where(active, q_lens, 0)
        finishing = is_pf & (lens_after >= prompt_lens)
        emit = active & (finishing | ~is_pf)
        out_idx = jnp.where(emit, jnp.clip(lens_after - prompt_lens, 0,
                                           self.max_new_cap - 1), 0)
        tok = self._select_token(logits[:, 0], out_idx,
                                 temps, topks, topps, seeds)[:, None]
        tok = jnp.where(emit[:, None], tok, tokens)
        row = jnp.where(emit, jnp.arange(ns), ns)         # no-emit -> scratch
        out_buf = out_buf.at[row, out_idx].set(tok[:, 0])
        return pools, tok, out_buf, lens_after

    def _step_spec_impl(self, params, pool_k, pool_v, kt_pool, mass_pool,
                        page_table, tokens, lens, ranks, basis, active,
                        out_buf, prompt_lens, temps, topks, topps, seeds,
                        prompt_buf, spectra, caps, eos_ids):
        """One fused speculative step (repro.serve.spec): ``draft_k``
        single-token forwards at an aggressive per-row draft rank over a
        statically narrowed basis / factor slice, then ONE chunked verify
        block per row at the slot's current rank, longest-prefix accept
        with EOS / budget / segment-boundary clamps, and an in-graph
        logical rollback — ``lens`` advances past accepted tokens only;
        rejected positions are masked garbage the next step overwrites.
        Mid-prefill rows ride along exactly as in the mixed step. Deferred
        per-query mass contributions are applied for accepted queries
        only, in query order (bitwise the sequential accumulation).

        Returns (pools, tok, out_buf, lens_after, accepts, n_emit,
        emitted): ``accepts`` (ns,) the accepted run length per
        speculative row (0 elsewhere), ``n_emit`` (ns,) tokens emitted
        per row this step, ``emitted`` (ns, draft_k + 1) their values
        (col 0 = token 0 for a row finishing its prompt)."""
        from repro.serve import spec as spec_mod
        from repro.serve.policy import draft_ranks
        ns = tokens.shape[0]
        off = self.cfg.rank.mode == "off"
        k_d = self.draft_k
        Cd = k_d + 1
        C = self.spec_chunk
        cap = self.max_new_cap
        is_pf = active & (lens < prompt_lens)
        spec_rows = active & ~is_pf
        base_out = jnp.clip(lens - prompt_lens + 1, 0, cap - 1)

        # -- draft: k_d cheap forwards. Draft K/V writes land in the live
        # pages — every one of them sits in the verify block's write range
        # [lens, lens + Cd) and is overwritten there with authoritative
        # values. Draft factor appends go into the sliced transient copy
        # (discarded); the mass pool is never touched by drafts, so the
        # Eq. 9 veto state only ever sees accepted tokens.
        if off:
            d_ranks = d_basis = d_kt = None
        else:
            d_ranks = draft_ranks(ranks, spectra,
                                  frac=self.draft_rank_frac,
                                  grid_lo=self._grid_lo,
                                  r_cap=self._draft_cap)
            d_basis = basis[..., :self._draft_cap]
            d_kt = (None if kt_pool is None
                    else kt_pool[..., :self._draft_cap])
        pk, pv = pool_k, pool_v
        d_tok = tokens
        drafts = []
        for i in range(k_d):
            dlg, dpools = self.fns.decode_step_paged(
                params, pk, pv, page_table, d_tok,
                slot_lens=lens + i, slot_ranks=d_ranks, basis=d_basis,
                active=spec_rows, use_kernel=self.use_kernel,
                kt_pool=d_kt, mass_pool=None)
            pk, pv = dpools["k"], dpools["v"]
            d_kt = dpools.get("kt", d_kt)
            opos = jnp.minimum(base_out + i, cap - 1)
            d_tok = self._select_token(dlg[:, 0], opos,
                                       temps, topks, topps, seeds)[:, None]
            drafts.append(d_tok[:, 0])
        drafts = jnp.stack(drafts, axis=1)                   # (ns, k_d)

        # -- verify: one causal chunk [t_0, d_1..d_k] per speculative row
        # (the next prompt chunk for mid-prefill rows) at the slot's
        # CURRENT rank — the same read plain decode would have done, so
        # every accepted token is exact by construction
        q_lens = jnp.where(is_pf, jnp.minimum(C, prompt_lens - lens),
                           Cd).astype(jnp.int32)
        idx = jnp.clip(lens[:, None] + jnp.arange(C)[None, :], 0,
                       prompt_buf.shape[1] - 1)
        chunk_toks = jnp.take_along_axis(prompt_buf, idx, axis=1)
        spec_toks = jnp.concatenate([tokens, drafts], axis=1)    # (ns, Cd)
        if C > Cd:
            spec_toks = jnp.pad(spec_toks, ((0, 0), (0, C - Cd)))
        toks_in = jnp.where(is_pf[:, None], chunk_toks, spec_toks)
        defer = (not off) and (mass_pool is not None)
        logits, pools = self.fns.decode_step_paged(
            params, pk, pv, page_table, toks_in,
            slot_lens=lens, q_lens=q_lens, prefill_rows=is_pf,
            slot_ranks=None if off else ranks,
            basis=None if off else basis, active=active,
            use_kernel=self.use_kernel,
            kt_pool=None if off else kt_pool,
            mass_pool=None, return_all_logits=True, mass_defer=defer)
        # target tokens at every position, same (seed, out position) fold
        # as plain decode — the sampler is deterministic per position, so
        # "accept while draft == target" reproduces plain decode exactly.
        # A finishing prefill row emits output index 0 (only its final
        # query's sample is ever read), matching the mixed step's fold.
        opos = jnp.where(is_pf[:, None], 0,
                         jnp.minimum(base_out[:, None]
                                     + jnp.arange(C)[None, :],
                                     cap - 1))                   # (ns, C)
        g = jax.vmap(self._select_token,
                     in_axes=(1, 1, None, None, None, None),
                     out_axes=1)(logits, opos, temps, topks, topps, seeds)

        tgt = g[:, :Cd]
        a = spec_mod.accept_counts(drafts, tgt)
        a = spec_mod.clamp_to_eos(a, tgt, eos_ids)
        a = jnp.minimum(a, caps)
        a = jnp.where(spec_rows, a, 0)

        lens_after = lens + jnp.where(is_pf, q_lens, 0) + a
        finishing = is_pf & (lens_after >= prompt_lens)
        n_emit = a + finishing.astype(a.dtype)
        fin_tok = jnp.take_along_axis(g, (q_lens - 1)[:, None], axis=1)
        src = jnp.where(finishing[:, None],
                        jnp.broadcast_to(fin_tok, tgt.shape), tgt)
        emit_ok = jnp.arange(Cd)[None, :] < n_emit[:, None]
        rows = jnp.where(emit_ok, jnp.arange(ns)[:, None], ns)
        col0 = jnp.where(finishing, 0, base_out)
        cols = jnp.clip(col0[:, None] + jnp.arange(Cd)[None, :], 0, cap - 1)
        out_buf = out_buf.at[rows, cols].set(src)
        last = jnp.take_along_axis(
            src, jnp.clip(n_emit - 1, 0, Cd - 1)[:, None], axis=1)
        tok = jnp.where(n_emit[:, None] > 0, last, tokens)

        if defer:
            contrib = pools.pop("mass_q")
            n_q = jnp.where(spec_rows, a, jnp.where(is_pf, q_lens, 0))
            pools["mass"] = spec_mod.apply_deferred_mass(
                mass_pool, contrib, lens, n_q)
        return pools, tok, out_buf, lens_after, a, n_emit, src

    def _sync_control(self) -> None:
        """Push host control state to device after admission/eviction; the
        steady-state decode loop reuses these arrays without any transfer."""
        if not self._dirty:
            return
        self._pt_dev = jnp.asarray(self.cache.page_table)
        self._active_dev = jnp.asarray(
            np.array([s.active for s in self.sched.slots]))
        self._plen_dev = jnp.asarray(
            np.array([s.prompt_len if s.active else 0
                      for s in self.sched.slots], np.int32))
        self._lens_dev = jnp.asarray(self.cache.lens, jnp.int32)
        self._temp_dev = jnp.asarray(self._temp)
        self._topk_dev = jnp.asarray(self._topk)
        self._topp_dev = jnp.asarray(self._topp)
        self._seed_dev = jnp.asarray(self._seed)
        self._eos_dev = jnp.asarray(
            np.array([s.req.eos_id
                      if (s.active and s.req.eos_id is not None) else -1
                      for s in self.sched.slots], np.int32))
        self._dirty = False

    def _can_allocate(self, slot: int, total_len: int) -> bool:
        """Page-reservation hook for the scheduler, called for the head of
        the pending queue. With a prefix cache, the head request's prompt
        is matched first: a hit's shared pages become the slot's leading
        page-table entries (ref + 1, no prefill over them), under pool
        pressure the tree evicts LRU leaves (the matched path is pinned),
        and the hit is stashed for the placement that follows."""
        if self.prefix is None:
            return self.cache.allocate(slot, total_len)
        req = self.sched.pending[0]
        hit = self.prefix.match(req.tokens)
        # a partially-filled shared tail page is copied, not shared: the
        # slot appends into it from the reuse point (copy-on-write), so
        # allocation must draw its replacement from the free list
        shared = hit.pages[:-1] if hit.cow_src is not None else hit.pages
        shortfall = (self.cache.pages_needed(total_len) - len(shared)
                     - self.cache.free_pages)
        if shortfall > 0:
            # stats count PAGES evicted from the tree (evict_lru's return)
            self.stats["prefix_evictions"] += self.prefix.evict_lru(
                shortfall, protect=hit.nodes)
        if not self.cache.allocate(slot, total_len, prefix_pages=shared):
            return False
        # LRU recency advances only for a committed HIT — neither a head
        # request re-matching every step while blocked on pages, nor a
        # miss that merely grazed the path, may inflate it
        if hit.reuse_len > 0:
            self.prefix.touch_path(hit.nodes)
        self._hits[slot] = hit
        return True

    def _apply_prefix_hit(self, slot: int, req: Request) -> int:
        """Rehydrate a prefix hit at admission: COW the shared tail page if
        partial, mark the matched tokens prefilled, and re-seed the slot's
        per-stream low-rank state (mass row, spectra) from the snapshot so
        the first segment decision is identical to a cold admission's.
        Returns the number of reused prompt tokens."""
        hit = self._hits.pop(slot, None)
        st = self.sched.slots[slot]
        m = 0 if hit is None else hit.reuse_len
        if hit is not None:
            self.request_prefix_hit[req.rid] = m > 0
            self.stats["prefix_hits" if m > 0 else "prefix_misses"] += 1
            self.stats["prefix_reused_tokens"] += m
        if m > 0:
            if hit.cow_src is not None:
                dst = int(self.cache.page_table[slot,
                                                m // self.cache.page_size])
                self.cache.copy_page(dst, hit.cow_src)
                self.stats["prefix_cow"] += 1
            st.prefilled = m
            self.cache.lens[slot] = m
            if hit.spectra is not None and self.cache.spectra is not None:
                # informational warm start; the first decision overwrites
                # it (veto disabled via has_rank), so parity is untouched
                self.cache.spectra = self.cache.spectra.at[slot].set(
                    hit.spectra)
        if m > 0 and hit.mass is not None and self.cache.mass_pool is not None:
            # re-seed the matched prefix from the snapshot (exact: the
            # cumulative mass of queries [0, m) over positions [0, m)).
            # Cells beyond m need no zeroing — the fused step resets each
            # cell in-graph the step its position is appended.
            self.cache.mass_pool = self.cache.mass_pool.at[:, slot, :m].set(
                hit.mass)
        return m

    def _admit(self) -> List[int]:
        with self._lock:
            return self._admit_locked()

    def _admit_locked(self) -> List[int]:
        placed = self.sched.admit(self.now, self._can_allocate)
        any_other_live = self.sched.n_live() > len(placed)
        for slot, req, bucket in placed:
            st = self.sched.slots[slot]
            st.admit_s = time.perf_counter()
            # a recycled slot must not inherit its previous occupant's
            # rank state: first decision is veto-free, fresh clock
            self.has_rank[slot] = False
            self.force_decide[slot] = False
            self._spectra_pending.pop(slot, None)
            self._temp[slot] = req.temperature
            self._topk[slot] = req.top_k
            self._topp[slot] = req.top_p
            self._seed[slot] = np.uint32(req.seed)
            if self.chunk is not None:
                # chunked admission: stage the prompt on device and let the
                # mixed fused steps consume it — no model work here, the
                # loop never stalls on a monolithic prefill. A prefix hit
                # skips its reused tokens: chunked prefill starts at the
                # reuse point.
                buf = np.zeros((self.cache.max_len,), np.int32)
                buf[:len(req.tokens)] = req.tokens
                self.prompt_buf = self.prompt_buf.at[slot].set(
                    jnp.asarray(buf))
                m = self._apply_prefix_hit(slot, req)
                self._snaps[slot] = {}
                self.stats["prefill_tokens"] += st.prompt_len - m
                self.obs.on_admit(req.rid, slot, st.prompt_len, reused=m,
                                  queued=len(self.sched.pending),
                                  live=self.sched.n_live())
                continue
            self.obs.on_admit(req.rid, slot, st.prompt_len,
                              queued=len(self.sched.pending),
                              live=self.sched.n_live())
            sw = Stopwatch()
            s = len(req.tokens)
            padded = np.zeros((1, bucket), np.int32)
            padded[0, :s] = req.tokens
            logits, k_l, v_l, mass_l = self._prefill(
                self.params, jnp.asarray(padded), np.int32(s))
            if self.sampling and (req.temperature > 0 or req.top_k > 0
                                  or req.top_p < 1.0):
                tok0 = self._select1(logits[0, s - 1],
                                     np.float32(req.temperature),
                                     np.int32(req.top_k),
                                     np.float32(req.top_p),
                                     np.uint32(req.seed))
            else:
                tok0 = jnp.argmax(logits[0, s - 1]).astype(jnp.int32)
            mass = (None if mass_l is None else
                    jnp.swapaxes(mass_l[:, 0], 1, 2)[:, :s])  # (L, s, hkv)
            self.cache.write_prefill(slot, k_l[:, 0, :s], v_l[:, 0, :s],
                                     mass_layers=mass)
            self.tokens = self.tokens.at[slot, 0].set(tok0)
            self.out_buf = self.out_buf.at[slot, 0].set(tok0)
            st.prefilled = s
            if req.eos_id is not None:
                st.last_tok = int(tok0)
            if self._stream_sync:
                # one-shot admission emits token 0 outside the fused step:
                # a streaming consumer must still see it in order
                self.last_emitted.append((req.rid, 0, int(tok0)))
            jax.block_until_ready(self.cache.k_pool)
            dt = sw.stop()
            self.stats["prefill_s"] += dt
            self.stats["prefill_tokens"] += s
            if any_other_live:
                # blocking admission: this prefill ran while other streams
                # had decode work pending — the stall chunked mode removes
                self.stats["stall_s"] += dt
            self._stamp_first_token(slot, st, time.perf_counter(), dt)
        if placed:
            self._dirty = True
        return [slot for slot, _, _ in placed]

    def _stamp_first_token(self, i: int, st, now_t: float,
                           ttft_s: float) -> None:
        """Shared first-token bookkeeping for the three prefill-completion
        paths (one-shot admission, chunked mixed step, chunked spec
        step): output count, TTFT latency, stats, and the obs hook."""
        st.n_out = 1                              # token 0 emitted
        st.latencies.append(ttft_s)               # first-token latency
        self.stats["prefills"] += 1
        self.request_first_tok_t[st.req.rid] = now_t
        self.obs.on_first_token(st.req.rid, i, ttft_s)

    def _maybe_decide(self) -> None:
        if self._decide is None:
            return
        # mid-prefill slots are excluded: their prompt mass / K run is
        # still incomplete, and decode_i == 0 will still be a boundary at
        # their first decode step
        active = np.array([s.active and not s.mid_prefill
                           for s in self.sched.slots])
        at_seg = np.array([s.decode_i % self.seg == 0
                           for s in self.sched.slots])
        boundary = active & (at_seg | self.force_decide)
        if not boundary.any():
            return
        self._sync_control()
        # per-slot decision, slot index traced: streams hit segment
        # boundaries on their own staggered clocks, so an all-slots batched
        # decide would redo every slot's spectral solve at the union of
        # boundaries — n_slots times the work a per-stream server pays.
        # One dispatch per boundary crossing, one executable for all slots.
        for i in np.nonzero(boundary)[0]:
            st = self.sched.slots[i]
            first = not self.has_rank[i]
            forced = bool(self.force_decide[i])
            (self.cache.ranks, self.cache.basis, self.cache.spectra,
             self.cache.kt_pool, vetoed) = self._decide(
                self.cache.k_pool, self.cache.mass_pool, self.cache.kt_pool,
                self._pt_dev, self._lens_dev, self.cache.ranks,
                self.cache.basis, self.cache.spectra, np.int32(i),
                np.bool_(self.has_rank[i]), np.int32(st.t))
            # the Eq. 9 veto flag is a device bool: bank it UNFETCHED —
            # obs.rank_telemetry() reads the whole batch in one
            # device_get at export time, so veto telemetry adds no sync
            # to the loop
            self._veto_pending.append(vetoed)
            st.t += 1
            self.stats["decides"] += 1
            self.obs.on_decide(int(i), st.t - 1, forced=forced)
            if self.trace is not None:
                s2_h, rank_h = jax.device_get(  # inv-ok[R1]: trace recording fetches the decision's spectra/rank once per segment boundary (the decide cadence), never per decode step
                    (self.cache.spectra[i], self.cache.ranks[i]))
                self.trace.on_decision(
                    int(i), st.req.rid, st.t - 1,
                    int(self.cache.lens[i]), int(rank_h),
                    np.asarray(s2_h), has_prev=not first)
            if first:
                # lazy prefix-snapshot completion: the slot's first
                # decision is the prompt decision — persist its layer-0
                # spectra on the cached prefix node (informational warm
                # start for future hits; parity-neutral)
                node = self._spectra_pending.pop(i, None)
                if node is not None:
                    node.snap_spectra = self.cache.spectra[i]
        self.has_rank |= boundary
        self.force_decide &= ~boundary

    def _check_drift(self, live: List[int]) -> None:
        """Early re-decision trigger: measure the newest K token's residual
        energy outside each live slot's stored basis and set
        ``force_decide`` where it exceeds ``drift_threshold``.

        Clock semantics under speculation (tested in
        tests/test_serve_spec.py): the check fires once per fused step —
        i.e. once per *accepted run*, not once per token — and always
        against the **post-accept position**: both call sites run after
        ``cache.lens`` has advanced past the accepted tokens, so the K
        token inspected is the last one the verify pass actually wrote.
        A drifting stream therefore re-decides at most one accepted run
        (<= draft_k tokens) later than plain decode would, and the forced
        re-decision lands at the next step's ``_maybe_decide`` — before
        that step's fused dispatch. Token streams may legally diverge
        from plain decode under drift + speculation (the re-decision
        clock is coarser); with drift off (the default) speculation stays
        bitwise exact."""
        ns, ps = self.n_slots, self.cache.page_size
        pos = np.maximum(self.cache.lens - 1, 0)
        phys = self.cache.page_table[np.arange(ns), pos // ps]
        k_tok = self.cache.k_pool[0][jnp.asarray(phys),
                                     jnp.asarray(pos % ps)]
        drift = np.asarray(  # inv-ok[R1]: drift check runs on the decide cadence (every decide_every steps), one small-vector fetch, never per decode step
            self._drift(k_tok, self.cache.basis[0], self.cache.ranks))
        for i in live:
            if self.has_rank[i] and drift[i] > self.drift_threshold:
                self.force_decide[i] = True
                self.obs.on_drift(int(i), float(drift[i]))

    def _maybe_snapshot(self, i: int, st, done_pf: bool) -> None:
        """Capture a cumulative-mass snapshot for the prefix cache. The
        accumulator holds queries [0, prefilled) and nothing more because
        chunked prefill paused exactly here. ``snapshot_every`` throttles
        density: only every k-th page boundary is kept (plus the prompt
        end, which anchors the full-prompt node); prefix probe/match fall
        back to the nearest earlier snapshot, trading a slightly shorter
        hit for O(P^2 / (k * ps)) snapshot bytes per prompt."""
        if self.prefix is None:
            return
        ps = self.cache.page_size
        at_page = st.prefilled % ps == 0
        kept = at_page and (st.prefilled // ps) % self.snapshot_every == 0
        if done_pf or kept:
            self._snaps[i][st.prefilled] = (
                None if self.cache.mass_pool is None else
                self.cache.mass_pool[:, i, :st.prefilled])

    def _insert_prefix(self, i: int, st) -> None:
        """Publish a finished prompt's pages + snapshots to the radix
        tree; the node waits for its spectra at the next decision."""
        if self.prefix is None:
            return
        n_pg = self.cache.pages_needed(st.prompt_len)
        node = self.prefix.insert(
            st.req.tokens,
            [int(p) for p in self.cache.page_table[i, :n_pg]],
            self._snaps.pop(i, {}))
        if node is not None and self._decide is not None:
            self._spectra_pending[i] = node
        # gauge cadence: once per finished prompt (pages counted as
        # distinct physical ids — COW shares collapse)
        self.obs.set_prefix_size(
            self.prefix.n_nodes, len(set(self.prefix.all_pages())))

    def _step_live_spec(self, live: List[int], ph=NULL_PHASES) -> None:
        """Host side of one speculative engine iteration (the fused body
        is _step_spec_impl). ``ph`` is the step's phase recorder (a no-op
        unless obs tracing is on). Differs from the plain path in three ways:
        decode rows advance by their accepted run length ``a`` (1..
        draft_k + 1) instead of 1; the per-step accept/emission fetch IS
        the token stream (handles get every accepted token, not just the
        newest); and the host caps each row's accepts so max_new, and —
        in adaptive mode — segment boundaries, fire at the exact token
        counts plain decode would hit (decode_i never skips a multiple of
        segment_len, so rank decisions see identical clocks)."""
        slots = self.sched.slots
        mid = [i for i in live if slots[i].mid_prefill]
        decoding = [i for i in live if not slots[i].mid_prefill]
        q_host = {i: min(self.spec_chunk, slots[i].prompt_len
                         - slots[i].prefilled) for i in mid}
        sw = Stopwatch(self.time_per_token)
        with ph("decide"):
            self._maybe_decide()
        if self.cache.factored and decoding:
            assert all(self.has_rank[i] for i in decoding), \
                "factored slot would read unseeded kt pages"
        if __debug__:
            for i in decoding:
                # speculative writes start at lens >= prompt_len, past any
                # prefix-shared page (the tail page was COWed at
                # admission) — rollback never rewinds into shared state
                assert self.cache.lens[i] >= self.cache.shared_floor(i), \
                    f"slot {i}: speculative write below shared-page floor"
        with ph("dispatch"):
            self._sync_control()
            active_dec = np.array([s.active and not s.mid_prefill
                                   for s in self.sched.slots])
            self.rank_history.append(
                (self.stats["steps"], self.cache.ranks, active_dec))
            # adaptive draft: the accept cap honours the controller's
            # current effective draft length (>= 1 here — a fully
            # collapsed stream only reaches this path on recovery-probe
            # steps)
            k_eff = (max(self._eff_k, 1) if self.adaptive_draft
                     else self.draft_k)
            caps = np.ones((self.n_slots,), np.int32)
            for i in decoding:
                st = slots[i]
                c = min(k_eff + 1, st.req.max_new - st.n_out)
                if self._decide is not None:
                    c = min(c, self.seg - st.decode_i % self.seg)
                caps[i] = max(c, 1)
            pools, tok, ob, lens, acc, n_emit, emitted = self._step_spec(
                self.params, self.cache.k_pool, self.cache.v_pool,
                self.cache.kt_pool, self.cache.mass_pool,
                self._pt_dev, self.tokens, self._lens_dev, self.cache.ranks,
                self.cache.basis, self._active_dev, self.out_buf,
                self._plen_dev, self._temp_dev, self._topk_dev,
                self._topp_dev, self._seed_dev, self.prompt_buf,
                self.cache.spectra, jnp.asarray(caps), self._eos_dev)
            self._adopt_pools(pools)
            self.tokens, self.out_buf, self._lens_dev = tok, ob, lens
        # the accept fetch doubles as the emission sync: streaming handles
        # need every accepted token this step anyway, so this is the same
        # one-host-sync-per-step budget as the plain path's tok fetch
        with ph("fetch"):
            acc_h, emit_h = jax.device_get((acc, emitted))  # inv-ok[R1]: the one sanctioned per-step sync — the accept/emission fetch doubles as the streaming emit
        dt = sw.stop()
        now_t = time.perf_counter()
        with ph("deliver"):
            for i in live:
                st = slots[i]
                if i in q_host:                   # mid-prefill row
                    q = q_host[i]
                    st.prefilled += q
                    self.cache.lens[i] += q       # host mirror of _lens_dev
                    done_pf = st.prefilled == st.prompt_len
                    self._maybe_snapshot(i, st, done_pf)
                    self.obs.on_prefill_chunk(i, st.req.rid, q, st.prefilled)
                    if done_pf:
                        self._stamp_first_token(i, st, now_t,
                                                now_t - st.admit_s)
                        st.last_tok = int(emit_h[i, 0])
                        self.last_emitted.append(
                            (st.req.rid, 0, int(emit_h[i, 0])))
                        self._insert_prefix(i, st)
                    continue
                a = int(acc_h[i])
                base = st.n_out
                st.decode_i += a
                st.n_out += a
                self.cache.lens[i] += a           # host mirror of _lens_dev
                st.accept_lens.append(a)
                self.obs.on_spec_accept(i, a, int(caps[i]) - 1)
                if self.trace is not None:
                    self.trace.on_step(i, a, dt, accepted=a - 1,
                                       drafted=int(caps[i]) - 1)
                st.last_tok = int(emit_h[i, a - 1])
                self.last_emitted.extend(
                    (st.req.rid, base + t, int(emit_h[i, t]))
                    for t in range(a))
                if dt is not None:
                    st.latencies.extend([dt / a] * a)
                    for _ in range(a):
                        self.obs.on_token_latency(dt / a)
        self.stats["steps"] += 1
        if decoding:
            tot, n_acc, n_drafted = host_accept_stats(
                acc_h, caps, decoding, self.draft_k)
            self.stats["spec_steps"] += 1
            self.stats["tokens_decoded"] += tot
            self.stats["spec_tokens"] += tot
            self.stats["spec_accepted"] += n_acc
            self.stats["spec_drafted"] += n_drafted
        if self.adaptive_draft and decoding:
            denom = sum(int(caps[i]) - 1 for i in decoding)
            if denom > 0:
                num = sum(int(acc_h[i]) - 1 for i in decoding)
                al = self._DRAFT_EWMA_ALPHA
                self._accept_ewma = ((1.0 - al) * self._accept_ewma
                                     + al * num / denom)
                if self._accept_ewma < self.draft_shrink_below:
                    self._eff_k //= 2
                elif self._accept_ewma > self.draft_grow_above:
                    self._eff_k = min(self.draft_k,
                                      max(1, self._eff_k) * 2)
                self.stats["eff_draft_k"] = self._eff_k
        if mid:
            self.stats["mixed_steps"] += 1
        if self._drift is not None and decoding:
            self._check_drift(decoding)
        self._evict_finished()

    def _evict_finished(self) -> None:
        for i, st in enumerate(self.sched.slots):
            if st.active and self.sched.should_evict(i):
                outputs = np.asarray(self.out_buf[i, :st.n_out]).tolist()  # inv-ok[R1]: one-shot fetch of a finished request's output at eviction, not per-step
                if st.latencies:
                    self.first_token_s.append(st.latencies[0])
                    self.token_latencies.extend(st.latencies[1:])
                if st.accept_lens:
                    self.request_accept_lens[st.req.rid] = list(st.accept_lens)
                if self.trace is not None:
                    self.trace.on_evict(i)
                reason = ("eos" if (st.req.eos_id is not None
                                    and st.last_tok == st.req.eos_id)
                          else "max_new")
                self.obs.on_finish(st.req.rid, i, st.n_out, reason)
                self.sched.evict(i, self.cache.release, outputs)
                self._dirty = True

    def step(self) -> None:
        """One engine iteration: admit -> decide -> fused decode -> evict."""
        self.last_emitted = []
        ph = self.obs.step_phases(self.stats["steps"])
        with ph("admit"):
            self._admit()                         # may emit tok0 (one-shot)
        with ph("schedule"):
            self._evict_finished()                # max_new == 1 / instant EOS
            live = [i for i, s in enumerate(self.sched.slots) if s.active]
        if live and self.speculative and any(
                not self.sched.slots[i].mid_prefill for i in live):
            # at least one row has a token to extend; pure-prefill steps
            # fall through to the mixed step instead — drafting there
            # would run draft_k dead forwards per step for nothing
            spec_now = True
            if self.adaptive_draft and self._eff_k == 0:
                # collapsed draft length: decode rides the mixed step
                # (no draft forwards at all); a probe spec step every
                # _DRAFT_PROBE_EVERY iterations keeps sampling the
                # accept signal so a recovered stream grows eff_k back
                spec_now = self._probe_i % self._DRAFT_PROBE_EVERY == 0
                self._probe_i += 1
            if spec_now:
                self._step_live_spec(live, ph)
                live = []
        if live:
            slots = self.sched.slots
            mid = [i for i in live if slots[i].mid_prefill]
            decoding = [i for i in live if not slots[i].mid_prefill]
            # chunk consumed per slot this step (host mirror of the mixed
            # step's in-graph q_lens; 0 for decode rows here)
            q_host = {i: min(self.chunk, slots[i].prompt_len
                             - slots[i].prefilled) for i in mid}
            finishing = [i for i in mid
                         if slots[i].prefilled + q_host[i]
                         == slots[i].prompt_len]
            # the timer starts before the segment decision: tokens decoded
            # in a boundary step really do wait on the decide dispatch
            sw = Stopwatch(self.time_per_token)
            with ph("decide"):
                self._maybe_decide()
            if self.cache.factored and decoding:
                # a factored slot's kt pages are only consistent after its
                # first decision re-projects them; decode_i == 0 is always
                # a segment boundary so this holds — keep it explicit in
                # case the decide trigger ever changes. Mid-prefill rows
                # read dense K, so they are exempt.
                assert all(self.has_rank[i] for i in decoding), \
                    "factored slot would read unseeded kt pages"
            with ph("dispatch"):
                self._sync_control()
                active_dec = np.array([s.active and not s.mid_prefill
                                       for s in self.sched.slots])
                self.rank_history.append(
                    (self.stats["steps"], self.cache.ranks, active_dec))
                # a speculative engine never warms the plain decode step
                # (its decode-only shape rides _step_mixed with
                # q_lens == 1), so a collapsed adaptive draft must route
                # through the mixed step too — dispatching _step here
                # would compile in steady state
                use_mixed = bool(mid) or self.speculative
                step_fn = self._step_mixed if use_mixed else self._step
                extra = (self.prompt_buf,) if use_mixed else ()
                pools, tok, ob, lens = step_fn(
                    self.params, self.cache.k_pool, self.cache.v_pool,
                    self.cache.kt_pool, self.cache.mass_pool,
                    self._pt_dev, self.tokens, self._lens_dev,
                    self.cache.ranks, self.cache.basis, self._active_dev,
                    self.out_buf, self._plen_dev, self._temp_dev,
                    self._topk_dev, self._topp_dev, self._seed_dev, *extra)
                self._adopt_pools(pools)
                self.tokens, self.out_buf, self._lens_dev = tok, ob, lens
                if self.time_per_token:
                    jax.block_until_ready(tok)  # inv-ok[R1]: opt-in timing mode deliberately syncs to attribute per-step latency
            dt = sw.stop()
            emitting = decoding + finishing
            need_tok = (self._stream_sync and emitting) or any(
                self.sched.slots[i].req.eos_id is not None for i in emitting)
            with ph("fetch"):
                tok_host = np.asarray(tok[:, 0]) if need_tok else None  # inv-ok[R1]: the plain path's one sanctioned per-step sync — EOS detection and streaming need this step's token
            now_t = time.perf_counter()
            with ph("deliver"):
                for i in live:
                    st = self.sched.slots[i]
                    if i in q_host:               # mid-prefill row
                        q = q_host[i]
                        st.prefilled += q
                        self.cache.lens[i] += q   # host mirror of _lens_dev
                        done_pf = st.prefilled == st.prompt_len
                        self._maybe_snapshot(i, st, done_pf)
                        self.obs.on_prefill_chunk(i, st.req.rid, q,
                                                  st.prefilled)
                        if done_pf:
                            self._stamp_first_token(i, st, now_t,
                                                    now_t - st.admit_s)
                            if tok_host is not None:
                                st.last_tok = int(tok_host[i])
                            self._insert_prefix(i, st)
                        continue
                    st.decode_i += 1
                    st.n_out += 1
                    self.cache.lens[i] += 1       # host mirror of _lens_dev
                    if self.trace is not None:
                        self.trace.on_step(i, 1, dt)
                    if tok_host is not None:
                        st.last_tok = int(tok_host[i])
                    if dt is not None:
                        st.latencies.append(dt)
                        self.obs.on_token_latency(dt)
                if tok_host is not None:
                    self.last_emitted.extend(
                        (self.sched.slots[i].req.rid,
                         self.sched.slots[i].n_out - 1, int(tok_host[i]))
                        for i in emitting)
            self.stats["steps"] += 1
            self.stats["tokens_decoded"] += len(decoding)
            if mid:
                self.stats["mixed_steps"] += 1
            if self._drift is not None and decoding:
                self._check_drift(decoding)
            self._evict_finished()
        self.now += 1

    def run(self, max_steps: Optional[int] = None) -> Dict:
        """Drive the loop until every request finished. Returns
        {rid: np.ndarray of generated tokens}."""
        p0 = self.stats["prefill_s"]
        sw = Stopwatch()
        steps = 0
        while not self.sched.done():
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        jax.block_until_ready(self.out_buf)  # inv-ok[R1]: end-of-run drain before the wall clock is read
        wall = sw.stop()
        self.stats["decode_s"] += max(
            wall - (self.stats["prefill_s"] - p0), 0.0)
        return self.results()

    def results(self) -> Dict[int, np.ndarray]:
        return {req.rid: np.asarray(out, np.int32)
                for req, out in self.sched.finished}

    def ranks_per_step(self) -> List[np.ndarray]:
        """Host copy of the per-step (ranks, active) record; -1 marks dead
        lanes, mid-prefill lanes AND full-rank decode (rank mode 'off'),
        where the cache's r_max placeholder is not a real bucket."""
        if self.cfg.rank.mode == "off":
            return [np.full(a.shape, -1) for _, _, a in self.rank_history]
        return [np.where(a, np.asarray(r), -1)
                for _, r, a in self.rank_history]
