"""Per-slot segment-level rank decision (slot-indexed, device-resident).

Port of the old ``AdaptiveServer._decide_rank`` (launch/serve.py) from a
whole-batch host-side decision to a jitted slot-indexed call: the slot id
is a traced scalar, so ONE executable serves every slot; the spectral
solve runs over that slot's live K view for all layers, the guardrail veto
and annealed threshold apply per slot — and crucially no
``int(cache["len"])`` host syncs: lengths, previous ranks, bases and
spectra live on device and the chosen rank / basis / factor pages are
written back with dynamic-index updates, feeding straight into the fused
decode step's rank masks.

The eigenbasis comes from the **softmax-weighted Gram** G = K^T diag(w) K,
with w the slot's accumulated per-key attention mass (seeded at prefill,
advanced in-graph by every decode step). The plain K Gram spends rank on
directions Q never looks at — the serve-time incarnation of the quality
gap the weighted basis already closed on the prefill path
(models/lowrank_cache.py:attention_mass). A slot whose mass accumulator is
all zero (direct cache writes in tests) falls back to uniform weights,
which is exactly the plain Gram.

Decision rules per slot (same semantics the lock-step server had):
  * kv_len < 8            -> r_max (too little signal; no veto)
  * mode == 'fixed'       -> fixed_rank
  * mode == 'adaptive'    -> NER-threshold rank per head, median over heads,
                             snapped to the compiled grid
  * mode == 'drrl'        -> policy logits per (slot, head) with the Eq. 11
                             safety mask, head-mean argmax per slot
  * mode == 'learned'     -> same inference path as 'drrl', loaded from a
                             checkpoint trained offline on recorded serving
                             traces (repro.train.serve_policy)
  * mode == 'random'      -> uniform grid draw keyed by (slot, clock)
  * transition veto       -> Eq. 9 relative bound at the chosen bucket vs
                             the slot's annealed eps_t, with the "before"
                             side taken from the slot's persisted
                             previous-segment spectra — the veto measures
                             the actual transition

When the cache runs in factor form, a decision also rewrites the slot's
``kt_pool`` pages as K . B_r under the refreshed basis, so the fused step
keeps reading consistent factors across the basis switch.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import lowrank as lr
from repro.core import perturbation as pert


def make_decide_fn(cfg: ModelConfig, policy_params=None) -> Callable:
    """Returns jitted ``decide(k_pool, mass_pool, kt_pool, page_table,
    lens, ranks, basis, spectra, slot, has_rank, t) -> (ranks', basis',
    spectra', kt_pool', vetoed)``.

    One call re-decides ONE slot (``slot`` is a traced scalar index — a
    single executable serves every slot): it gathers that slot's K and
    attention-mass pages, takes the weighted spectral solve for all
    layers, picks the rank bucket from the layer-0 spectra (same rules the
    old lock-step server used), applies the Eq. 9/11 transition veto
    against the slot's previous-segment spectra, and writes the slot's new
    rank, per-layer K eigenbasis, layer-0 spectra and (in factor form) its
    re-projected kt pages back into the device-resident state with
    dynamic-index updates. The fused decode step only *projects* onto the
    cached basis / reads the cached factors, so the eigh cost is paid once
    per segment, not once per token (paper Eq. 12's segment-level refresh)
    — and per-slot calls keep the spectral work proportional to the number
    of boundary crossings, exactly what a per-stream server would pay,
    instead of n_slots times the union.

    ``kt_pool`` may be None (dense-K serving): the returned kt_pool is
    then None as well. ``vetoed`` is a device bool scalar — True iff the
    Eq. 9 transition veto overrode the policy's choice this call. The
    engine banks it *unfetched* for export-time rank telemetry
    (repro.obs), so observing veto fires costs the loop nothing.
    """
    rcfg = cfg.rank
    if rcfg.mode == "off":
        raise ValueError("decide fn is undefined for rank mode 'off'")
    if rcfg.mode in ("drrl", "learned") and policy_params is None:
        # used to fall back silently to 'random' — a misconfigured policy
        # engine must fail at construction, not serve noise
        raise ValueError(
            f"rank mode {rcfg.mode!r} needs policy params: pass them as the "
            "third positional arg (ServeEngine(cfg, params, policy_params) "
            "/ Engine(cfg, params, policy_params, config=...)); 'learned' "
            "params come from repro.train.serve_policy.load_policy()")
    grid = jnp.asarray(rcfg.rank_grid, jnp.int32)
    g_lo, g_hi = int(rcfg.rank_grid[0]), int(rcfg.rank_grid[-1])
    dh = cfg.resolved_head_dim()
    r_keep = min(g_hi, dh)
    # donate the state this call rewrites (kt_pool especially — a full
    # K-sized pool copied per boundary crossing otherwise). ranks are NOT
    # donated: the engine's rank_history keeps references to past rank
    # arrays that a later decide would invalidate. CPU ignores donation
    # and warns, so donate on real accelerators only.
    donate = () if jax.default_backend() == "cpu" else (2, 6, 7)

    @functools.partial(jax.jit, donate_argnums=donate)
    def decide(k_pool, mass_pool, kt_pool, page_table, lens, ranks, basis,
               spectra, slot, has_rank, t):
        pt_row = jax.lax.dynamic_slice_in_dim(page_table, slot, 1, 0)[0]
        kv_len = jax.lax.dynamic_slice_in_dim(lens, slot, 1, 0)[0]
        prev_rank = jax.lax.dynamic_slice_in_dim(ranks, slot, 1, 0)[0]
        # a recycled slot's first decision must not see the previous
        # occupant's rank (the drrl feature path reads it even though the
        # veto is disabled): fall back to the fresh-slot default r_max
        prev_rank = jnp.where(has_rank, prev_rank, jnp.int32(g_hi))
        gathered = k_pool[:, pt_row]           # (L, pages, ps, h, d)
        L = gathered.shape[0]
        kv = gathered.reshape(L, -1, *gathered.shape[3:])
        M = kv.shape[1]
        valid = (jnp.arange(M) < kv_len).astype(jnp.float32)
        kk = jnp.swapaxes(kv, 1, 2).astype(jnp.float32) \
            * valid[None, None, :, None]                  # (L, h, M, d)
        # softmax-weighted Gram: w is the accumulated per-key attention
        # mass, normalised to sum kv_len so the spectra stay on the plain
        # Gram's scale (weights 1 per key); zero mass (state written
        # outside the engine) degrades to uniform weights == plain Gram.
        # mass is slot-indexed (per-stream state, not per-page — shared
        # prefix pages receive different mass from each sharing slot), so
        # the gather is a plain row slice, no page indirection
        w_row = jax.lax.dynamic_slice_in_dim(mass_pool, slot, 1, 1)[:, 0]
        w = jnp.swapaxes(w_row, 1, 2)                     # (L, h, M)
        w = jnp.maximum(w, 0.0) * valid[None, None, :]    # (L, h, M)
        tot = jnp.sum(w, axis=-1, keepdims=True)
        n_valid = jnp.maximum(kv_len.astype(jnp.float32), 1.0)
        w = jnp.where(tot > 0.0, w * n_valid / jnp.maximum(tot, 1e-30),
                      valid[None, None, :])
        gk = jnp.einsum("lhmd,lhm,lhme->lhde", kk, w, kk)
        s2_l, evecs_l = lr.gram_spectrum(gk)              # (L, h, d[, d])
        s2 = s2_l[0]                 # layer-0 spectra drive the decision
        h = s2.shape[0]
        eps_t = pert.annealed_threshold(rcfg.epsilon0, rcfg.anneal_lambda, t)
        # "before" side of the transition: the spectra persisted at the
        # slot's previous decision (first decision: no transition yet —
        # compare against itself, and the veto is disabled via has_rank)
        prev_s2 = jax.lax.dynamic_slice_in_dim(spectra, slot, 1, 0)[0]
        prev_s2 = jnp.where(has_rank, prev_s2, s2)

        if rcfg.mode == "fixed":
            chosen = jnp.int32(rcfg.fixed_rank)
        elif rcfg.mode == "adaptive":
            r = lr.rank_for_energy(s2, rcfg.energy_threshold, g_lo, g_hi)
            med = jnp.median(r.astype(jnp.float32))
            chosen = grid[jnp.argmin(jnp.abs(grid.astype(jnp.float32) - med))]
        elif rcfg.mode in ("drrl", "learned"):
            # 'learned' is the same device-resident inference path with
            # params trained offline on serving traces — the trainer
            # (repro.train.serve_policy) builds its features through this
            # very recipe (zero h_t/w_t, layer 0, spectra-only ctx), so
            # checkpointed params transfer without translation
            from repro.core.drrl import build_features
            from repro.core.policy import policy_apply
            h_t = jnp.zeros((1, 8), jnp.float32)
            w_t = jnp.zeros((9,), jnp.float32)
            prev = jnp.full((1, h), prev_rank, jnp.int32)
            ctx = {"k_s2": s2[None], "q_s2": prev_s2[None]}
            feats, (_, _, bounds_rel, _) = build_features(
                rcfg, ctx, h_t, w_t, 0, prev)
            logits, _ = policy_apply(policy_params, feats)     # (h, G)
            G = logits.shape[-1]
            ok = pert.safety_mask(bounds_rel.reshape(-1, G), eps_t)
            logits = jnp.where(ok, logits, -1e30)
            chosen = grid[jnp.argmax(jnp.mean(logits, axis=0))]
        else:                                     # 'random'
            # fold BOTH the slot id and its segment clock into the key:
            # folding only t made every slot at the same clock draw the
            # same bucket, and made draws repeat across runs
            key = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(17),
                                   t.astype(jnp.int32)),
                slot.astype(jnp.int32))
            chosen = grid[jax.random.randint(key, (), 0, grid.shape[0])]

        # transition veto (Eq. 9): head-mean relative bound at the chosen
        # bucket must clear the slot's annealed threshold. The bound's dQ
        # side uses the previous-segment spectra, so it estimates the
        # actual segment-to-segment score perturbation.
        bounds, norm = pert.guardrail_report(prev_s2, s2, rcfg.rank_grid, dh)
        rel = jnp.mean(bounds / jnp.maximum(norm[..., None], 1e-30), axis=0)
        rel_c = rel[jnp.argmin(jnp.abs(grid - chosen))]
        switching = has_rank & (chosen != prev_rank)
        vetoed = switching & (rel_c > eps_t)
        chosen = jnp.where(vetoed, prev_rank, chosen)
        chosen = jnp.where(kv_len < 8, g_hi, chosen)
        # the short-context override is not a veto fire (docstring: "too
        # little signal; no veto")
        vetoed = vetoed & (kv_len >= 8)

        ranks = jax.lax.dynamic_update_slice_in_dim(
            ranks, chosen[None], slot, 0)
        basis = jax.lax.dynamic_update_slice(
            basis, evecs_l[:, None, :, :, :r_keep],
            (0, slot, 0, 0, 0))
        spectra = jax.lax.dynamic_update_slice(
            spectra, s2[None], (slot, 0, 0))
        if kt_pool is not None:
            # factor-form refresh: re-project the slot's whole K run onto
            # the new basis so the fused step's factor reads stay
            # consistent across the basis switch (positions beyond kv_len
            # are already zeroed in kk). kt is slot-indexed — the factors
            # depend on this slot's basis, so a shared prefix page's keys
            # are re-projected into the slot's OWN row, never into state
            # another slot reads
            kt = jnp.einsum("lhmd,lhdr->lmhr", kk, evecs_l[..., :r_keep])
            kt_pool = jax.lax.dynamic_update_slice(
                kt_pool, kt[:, None].astype(kt_pool.dtype),
                (0, slot, 0, 0, 0))
        return ranks, basis, spectra, kt_pool, vetoed

    return decide


def draft_ranks(ranks: jnp.ndarray, spectra: jnp.ndarray, *,
                frac: float, grid_lo: int, r_cap: int,
                energy: float = 0.5) -> jnp.ndarray:
    """Per-slot draft rank for self-speculative decoding: (n_slots,) int32.

    The draft forward reads the factor cache at an aggressive fraction of
    each slot's current rank (``ceil(frac * rank)``), floor-clamped by the
    slot's own cached layer-0 spectra: a slot whose spectral mass is NOT
    concentrated never drafts below the rank that retains ``energy`` of it
    (head max — conservative), and never below the policy grid's floor
    ``grid_lo``. ``r_cap`` is the static draft width the engine sliced the
    basis/factor pools to, so the result is always representable there.
    Fresh slots with all-zero spectra (no decision yet, or state written
    directly in tests) degrade to the grid floor. Never exceeds the slot's
    current rank: the draft is a strictly cheaper read of the same basis.
    """
    r_e = lr.rank_for_energy(spectra, energy, 1, r_cap)   # (ns, hkv)
    has_sig = jnp.any(spectra > 0.0, axis=(1, 2))         # (ns,)
    floor = jnp.where(has_sig, jnp.max(r_e, axis=1), grid_lo)
    floor = jnp.clip(floor, grid_lo, r_cap)
    rd = jnp.ceil(frac * ranks.astype(jnp.float32)).astype(jnp.int32)
    rd = jnp.maximum(rd, floor.astype(jnp.int32))
    return jnp.minimum(jnp.minimum(rd, jnp.int32(r_cap)), ranks)


def basis_drift(k_tok: jnp.ndarray, basis: jnp.ndarray,
                ranks: jnp.ndarray) -> jnp.ndarray:
    """Residual energy of the newest K token outside each slot's stored
    layer-0 eigenbasis (first ``rank`` columns): (n_slots,) in [0, 1]. High
    drift means the segment's subspace went stale — the engine can trigger
    an early re-decision instead of waiting out the segment.

    k_tok: (n_slots, hkv, dh); basis: (n_slots, hkv, dh, r_keep)."""
    r_keep = basis.shape[-1]
    col_ok = (jnp.arange(r_keep)[None, :]
              < jnp.minimum(ranks[:, None], r_keep)).astype(jnp.float32)
    b = basis * col_ok[:, None, None, :]
    kf = k_tok.astype(jnp.float32)
    proj = jnp.einsum("shd,shdr,sher->she", kf, b, b)
    num = jnp.sum((kf - proj) ** 2, axis=(1, 2))
    den = jnp.maximum(jnp.sum(kf ** 2, axis=(1, 2)), 1e-30)
    return num / den
