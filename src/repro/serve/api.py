"""Unified streaming serving API (paper §4.5.2 as a request/response
surface).

The engine-construction knobs live in one :class:`EngineConfig`
(replacing the historical ``ServeEngine(...)`` kwarg pile), per-request
generation knobs in :class:`SamplingParams` (greedy by default; seeded
temperature / top-k run **in-graph** in the fused step — one executable
regardless of the mix of greedy and sampled streams), and
:meth:`Engine.submit` returns a :class:`RequestHandle` that streams
tokens incrementally (iterator or callback) and records per-request TTFT.

By default prompts are admitted via **chunked prefill**
(``EngineConfig.prefill_chunk``): the prompt is consumed a fixed-size
chunk at a time *inside* the fused decode step, alongside the live decode
rows — admission never stalls decoding, and prompts of any length share
one executable instead of one compile per length bucket
(``prefill_chunk=None`` restores the legacy blocking bucketed prefill).
Chunked and one-shot admission are token-for-token identical
(tests/test_serve_chunked.py).

    cfg = EngineConfig(n_slots=4, max_len=256)
    eng = Engine(model_cfg, params, config=cfg)
    h = eng.submit(prompt_ids, SamplingParams(max_new=64))
    for tok in h.tokens():          # drives eng.step() as needed
        ...
    # or: eng.run(); h.result()

The explicit step loop (``eng.step()`` / ``eng.run()``) stays available
for servers that multiplex many handles.
"""
from __future__ import annotations

import threading
import time
import warnings
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Request


@dataclass(frozen=True)
class SamplingParams:
    """Per-request generation knobs.

    ``temperature == 0`` (the default) is greedy argmax; ``top_k == 0``
    samples the full vocabulary; ``top_p == 1`` disables the nucleus cut
    (``top_p < 1`` keeps the smallest probability-sorted set whose mass
    reaches ``top_p`` — composable with ``top_k``, applied after it, and
    requires an engine built with ``EngineConfig(nucleus=True)``).
    ``seed`` keys a per-token PRNG fold — a stream's draw sequence is a
    pure function of (seed, token index), reproducible under any
    batching/admission interleaving. Greedy, top-k and top-p streams all
    share ONE fused-step executable."""
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    max_new: int = 64
    eos_id: Optional[int] = None

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"negative temperature {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"negative top_k {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {self.max_new}")


@dataclass(frozen=True)
class EngineConfig:
    """Engine-level serving knobs (one compile scope).

    ``prefill_chunk``: prompt tokens consumed per fused step while a
    stream is mid-prefill (chunked prefill interleaved into decode);
    ``None`` = legacy blocking length-bucketed prefill at admission.
    ``sampling=False`` compiles the lean greedy-only step (requests with
    temperature/top_k/top_p then fail fast at submit); ``nucleus=True``
    additionally compiles the top-p cut — a full-vocab softmax + sort in
    every fused step, so leave it off unless streams use ``top_p < 1``
    (such requests fail fast on a nucleus=False engine).
    ``prefix_cache=True`` turns on shared-prefix KV reuse
    (``repro.serve.prefix``): finished prompts stay cached in a radix
    tree, and a request whose prompt starts with a cached prefix shares
    those pages and prefills only from the divergence point — token
    parity with cold admission is preserved. Requires chunked prefill;
    run ``prefill_chunk`` as a multiple of ``page_size`` for a reuse
    point at every page. ``prefix_pages`` sizes the extra pool headroom
    kept for cached prefixes (default: one extra slot-set of pages).
    ``speculative=True`` turns on low-rank self-speculative decoding
    (``repro.serve.spec``): each fused step drafts ``draft_k`` tokens
    ahead reading the factor cache at roughly ``draft_rank_frac`` of
    each row's live rank, verifies all of them in one chunked step at
    the full current rank, and accepts the longest matching prefix —
    token-identical to plain decode (greedy and seeded sampling), only
    faster. Requires chunked prefill. ``snapshot_every`` throttles
    prefix-cache mass snapshots to every k-th page boundary (probe /
    match fall back to the nearest earlier snapshot).
    ``adaptive_draft=True`` (speculative engines only) lets an EWMA of
    the accept fraction shrink the effective draft length when accept
    runs collapse (below ``draft_shrink_below``) and restore it when
    they recover (above ``draft_grow_above``); a fully collapsed stream
    skips the draft forwards entirely, and ``stats['eff_draft_k']``
    exposes the live value. Token streams stay exactly identical to
    plain decode either way. ``record_traces=<dir>`` hooks a
    :class:`repro.serve.traces.TraceRecorder` into the rank-decision
    path: per-segment decision features and outcomes land in versioned
    npz shards for offline policy training
    (``repro.train.serve_policy``); call ``engine.core.trace.flush()``
    when serving is done.

    ``obs_trace=True`` turns on :mod:`repro.obs` span/phase tracing
    (per-request spans + per-step phase timeline, exported as Chrome
    trace-event JSON via ``engine.obs.chrome_trace()``); the metrics
    registry itself is always on and costs the loop nothing beyond the
    host-side counter adds it already did. ``flight_dir=<dir>`` enables
    flight-recorder dumps: a bounded ring of recent engine events is
    written there on step exceptions, front-end shutdown and
    ``reset()`` with requests still in flight."""
    n_slots: int = 4
    max_len: int = 256
    page_size: int = 16
    segment_len: Optional[int] = None
    max_new_cap: int = 256
    prefill_chunk: Optional[int] = 16
    use_kernel: bool = False
    drift_threshold: Optional[float] = None
    factor_cache: Optional[bool] = None
    prefix_cache: bool = False
    prefix_pages: Optional[int] = None
    time_per_token: bool = False
    sampling: bool = True
    nucleus: bool = False
    top_k_cap: int = 64
    buckets: Optional[Sequence[int]] = None
    speculative: bool = False
    draft_k: int = 4
    draft_rank_frac: float = 0.25
    snapshot_every: int = 1
    adaptive_draft: bool = False
    draft_shrink_below: float = 0.35
    draft_grow_above: float = 0.6
    record_traces: Optional[str] = None
    obs_trace: bool = False
    flight_dir: Optional[str] = None
    flight_capacity: int = 256

    def __post_init__(self):
        if self.flight_capacity < 1:
            raise ValueError(f"flight_capacity must be >= 1, got "
                             f"{self.flight_capacity}")
        if self.prefill_chunk is not None and self.prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1, got {self.prefill_chunk}")
        if self.max_len < 1 or self.n_slots < 1 or self.page_size < 1:
            raise ValueError("n_slots/max_len/page_size must be >= 1")
        if self.prefix_cache and self.prefill_chunk is None:
            raise ValueError("prefix_cache requires chunked prefill "
                             "(set prefill_chunk)")
        if self.speculative and self.prefill_chunk is None:
            raise ValueError("speculative decode requires chunked prefill "
                             "(set prefill_chunk)")
        if self.speculative and self.draft_k < 1:
            raise ValueError(f"draft_k must be >= 1, got {self.draft_k}")
        if not 0.0 < self.draft_rank_frac <= 1.0:
            raise ValueError(f"draft_rank_frac must be in (0, 1], got "
                             f"{self.draft_rank_frac}")
        if self.snapshot_every < 1:
            raise ValueError(f"snapshot_every must be >= 1, got "
                             f"{self.snapshot_every}")
        if self.adaptive_draft and not self.speculative:
            raise ValueError("adaptive_draft requires speculative=True")


class EngineStopped(RuntimeError):
    """The engine serving a handle died or was shut down mid-stream.

    Raised from ``RequestHandle`` iterators / ``result()`` instead of
    blocking forever: a front-end stepping thread that crashed, a
    ``FrontEnd.shutdown()``, or an ``Engine.reset()`` that discarded the
    request all mark their unfinished handles stopped."""


@dataclass
class RequestHandle:
    """One submitted request: incremental tokens + completion state.

    Tokens arrive through a per-request in-order queue (``_toks`` +
    condition variable): with a background stepping thread attached
    (repro.serve.frontend.FrontEnd) consumers block on the condition,
    without one they drive ``engine.step()`` themselves — the same
    handle supports ``for tok in h.tokens()``, ``async for tok in h``,
    ``h.result()`` and the ``on_token`` callback. ``cancel()`` aborts
    the request mid-stream (slot evicted, pages released); no token is
    delivered after it returns."""
    rid: int
    prompt_len: int
    params: SamplingParams
    _engine: "Engine"
    _submit_s: float
    on_token: Optional[Callable[[int, int], None]] = None
    _toks: List[int] = field(default_factory=list)
    _result: Optional[np.ndarray] = None
    ttft_s: Optional[float] = None   # submit() -> first-token wall time
    done_s: Optional[float] = None   # submit() -> completion wall time
    replica: Optional[int] = None    # set by Router.submit
    cancelled: bool = False
    _stopped: bool = False
    _cv: threading.Condition = field(default_factory=threading.Condition)

    @property
    def done(self) -> bool:
        return self._result is not None

    def _check_stopped(self) -> None:
        if self._stopped:
            raise EngineStopped(
                f"request {self.rid}: engine stopped after "
                f"{len(self._toks)} token(s)")

    def _advance(self, i: int, poll_s: float = 0.05) -> None:
        """Block until token ``i`` exists (or the stream ended): wait on
        the delivery condition while a background thread is stepping the
        engine, drive ``engine.step()`` ourselves otherwise."""
        if self._engine.driver_alive:
            with self._cv:
                if i >= len(self._toks) and not self.done \
                        and not self._stopped:
                    # timed wait: a driver that dies without marking its
                    # handles (hard kill) still unblocks us to re-check
                    self._cv.wait(poll_s)
        else:
            self._check_stopped()
            self._engine.step()

    def tokens(self):
        """Generator of generated token ids, in order. Without a front-end
        stepping thread it drives ``engine.step()`` whenever it runs dry;
        with one it blocks until the thread delivers. Attaching a consumer
        makes the engine sync emitted token values each step (the same
        per-step sync an ``eos_id`` request already pays); handles that
        never stream keep the sync-free loop and read results at
        eviction. Raises :class:`EngineStopped` if the engine dies
        mid-stream; a ``cancel()`` ends the iteration cleanly."""
        self._engine._ensure_streaming(self)
        i = 0
        while True:
            while i < len(self._toks):
                yield self._toks[i]
                i += 1
            if self.done or self.cancelled:
                return
            self._check_stopped()
            self._advance(i)

    def __aiter__(self):
        """``async for tok in handle`` — the blocking wait runs in a
        worker thread (asyncio.to_thread) so the event loop stays free to
        consume other handles concurrently."""
        self._engine._ensure_streaming(self)
        return self._agen()

    async def _agen(self):
        import asyncio
        i = 0
        while True:
            tok = await asyncio.to_thread(self._next_blocking, i)
            if tok is None:
                return
            yield tok
            i += 1

    def _next_blocking(self, i: int) -> Optional[int]:
        """Token ``i`` (blocking), or None when the stream is over."""
        while True:
            if i < len(self._toks):
                return self._toks[i]
            if self.done or self.cancelled:
                return None
            self._check_stopped()
            self._advance(i)

    def result(self) -> np.ndarray:
        """Block until this request finishes; returns its generated ids
        (the partial output if it was cancelled). Raises
        :class:`EngineStopped` if the engine dies first."""
        while not self.done:
            self._check_stopped()
            self._advance(len(self._toks))
        return self._result

    def cancel(self) -> bool:
        """Abort this request: queued -> dropped, decoding/mid-prefill ->
        slot evicted and pages released. ``result()`` then returns the
        tokens delivered so far; iterators end cleanly. No token is
        delivered after cancel() returns. Returns False if the request
        had already finished."""
        return self._engine.cancel(self.rid)

    # -- called by Engine ------------------------------------------------

    def _feed(self, idx: int, tok: int) -> None:
        """Deliver token ``idx``. Strictly in-order: anything already
        delivered is ignored, and a gap (idx beyond the next slot) is
        refused — the engine backfills from the device buffer first, so a
        consumer never sees a garbled sequence. A cancelled or stopped
        handle refuses delivery outright."""
        if idx != len(self._toks) or self.cancelled or self._stopped:
            return
        with self._cv:
            self._toks.append(tok)
            if self.ttft_s is None and idx == 0:
                self.ttft_s = time.perf_counter() - self._submit_s
            self._cv.notify_all()
        # user callback runs outside the lock: it may block or re-enter
        if self.on_token is not None:
            self.on_token(idx, tok)

    def _finish(self, out: np.ndarray, first_tok_t: Optional[float]) -> None:
        # TTFT first: the backfill below would otherwise stamp token 0
        # with completion time on a handle that never streamed
        if first_tok_t is not None:
            with self._cv:
                if self.ttft_s is None:
                    self.ttft_s = first_tok_t - self._submit_s
        for i in range(len(self._toks), len(out)):
            self._feed(i, int(out[i]))
        with self._cv:
            self._result = np.asarray(out, np.int32)
            self.done_s = time.perf_counter() - self._submit_s
            self._cv.notify_all()

    def _mark_cancelled(self) -> None:
        """Seal the handle after an engine-level cancel: the result is
        whatever was delivered before the cut."""
        with self._cv:
            self.cancelled = True
            self._result = np.asarray(self._toks, np.int32)
            self.done_s = time.perf_counter() - self._submit_s
            self._cv.notify_all()

    def _mark_stopped(self) -> None:
        """The engine died / was reset with this request unfinished:
        unblock every consumer with EngineStopped instead of hanging."""
        if self.done:
            return
        with self._cv:
            self._stopped = True
            self._cv.notify_all()


class Engine:
    """Streaming request/response front-end over the continuous-batching
    core (:class:`repro.serve.ServeEngine`): ``submit() -> RequestHandle``,
    an explicit ``step()``/``run()`` loop, incremental token delivery and
    per-request TTFT."""

    def __init__(self, cfg: ModelConfig, params, policy_params=None, *,
                 config: Optional[EngineConfig] = None):
        self.config = config or EngineConfig()
        c = self.config
        self.core = ServeEngine(
            cfg, params, policy_params,
            n_slots=c.n_slots, max_len=c.max_len, page_size=c.page_size,
            segment_len=c.segment_len, buckets=c.buckets,
            max_new_cap=c.max_new_cap, use_kernel=c.use_kernel,
            drift_threshold=c.drift_threshold,
            time_per_token=c.time_per_token, factor_cache=c.factor_cache,
            prefill_chunk=c.prefill_chunk, sampling=c.sampling,
            nucleus=c.nucleus, top_k_cap=c.top_k_cap,
            prefix_cache=c.prefix_cache, prefix_pages=c.prefix_pages,
            speculative=c.speculative, draft_k=c.draft_k,
            draft_rank_frac=c.draft_rank_frac,
            snapshot_every=c.snapshot_every,
            adaptive_draft=c.adaptive_draft,
            draft_shrink_below=c.draft_shrink_below,
            draft_grow_above=c.draft_grow_above,
            record_traces=c.record_traces, obs_trace=c.obs_trace,
            flight_dir=c.flight_dir, flight_capacity=c.flight_capacity)
        self._handles: Dict[int, RequestHandle] = {}
        self._next_rid = 0
        self._finished_seen = 0
        self._streaming: set = set()     # rids with an attached consumer
        # background stepping thread (repro.serve.frontend.FrontEnd)
        # driving this engine, if any: handles then wait for delivery
        # instead of stepping, and reset() must strand no consumer
        self._driver = None
        # submit() may run on a non-loop thread: rid assignment, handle
        # registration and the core queue append form one critical section
        self._submit_lock = threading.Lock()
        # handles drive step() from whatever thread calls result()/
        # tokens(): whole engine iterations are serialised so concurrent
        # consumers interleave steps instead of racing the core state.
        # Reentrant: an on_token callback fires under this lock and may
        # itself drive the engine (handle.result() on a follow-up
        # request), which recurses on the same thread instead of
        # deadlocking.
        self._step_lock = threading.RLock()

    # -- request plane ---------------------------------------------------

    def submit(self, prompt, params: Optional[SamplingParams] = None, *,
               arrival: int = 0,
               on_token: Optional[Callable[[int, int], None]] = None
               ) -> RequestHandle:
        """Enqueue ``prompt`` (1-D int ids). Validation is fail-fast: a
        request that could never be served (prompt + max_new beyond a
        slot's capacity, max_new beyond the engine cap, negative arrival,
        top_k beyond the compiled cap, sampling on a greedy-only engine)
        raises here instead of queueing forever.

        Thread-safe: may be called from a thread other than the one
        driving step()/run() — submission is serialised against both
        concurrent submits and the step loop's admission."""
        params = params or SamplingParams()
        with self._submit_lock:
            rid = self._next_rid
            req = Request(rid=rid, tokens=np.asarray(prompt, np.int32),
                          max_new=params.max_new, arrival=arrival,
                          eos_id=params.eos_id,
                          temperature=params.temperature,
                          top_k=params.top_k, top_p=params.top_p,
                          seed=params.seed)
            self.core.submit(req)             # may raise — rid not consumed
            self._next_rid += 1
            h = RequestHandle(rid=rid, prompt_len=len(req.tokens),
                              params=params, _engine=self,
                              _submit_s=time.perf_counter(),
                              on_token=on_token)
            self._handles[rid] = h
            if on_token is not None:
                self._streaming.add(rid)
                self.core._stream_sync = True
        drv = self._driver
        if drv is not None:
            drv.wake()                   # a parked stepping thread resumes
        return h

    @property
    def driver_alive(self) -> bool:
        """True while a background stepping thread owns the step loop."""
        drv = self._driver
        return drv is not None and drv.alive

    def cancel(self, rid: int) -> bool:
        """Abort a submitted request (see :meth:`RequestHandle.cancel`).
        Serialised against the step loop: no fused step is in flight
        while the slot is evicted, and no token is delivered after the
        handle is sealed. Returns False if already finished/cancelled."""
        with self._step_lock:
            h = self._handles.get(rid)
            if h is None or h.done:
                return False
            self.core.cancel(rid)
            h._mark_cancelled()   # sealed under the step lock: no feed races
            self._streaming.discard(rid)
            if not self._streaming:
                self.core._stream_sync = False
            return True

    def _ensure_streaming(self, handle: RequestHandle) -> None:
        if handle.done:
            return        # tokens already delivered; nothing left to sync
        # the backfill reads scheduler/device state a concurrent stepping
        # thread mutates: take a whole-iteration slice of the step lock
        with self._step_lock:
            self._streaming.add(handle.rid)
            self.core._stream_sync = True
            self._backfill(handle)

    def _backfill(self, handle: RequestHandle) -> None:
        """Deliver any tokens this handle's slot emitted before (or
        between) streamed steps, straight from the device output buffer —
        keeps delivery contiguous when a consumer attaches mid-run."""
        for i, st in enumerate(self.core.sched.slots):
            if st.active and st.req.rid == handle.rid:
                if st.n_out > len(handle._toks):
                    out = np.asarray(self.core.out_buf[i, :st.n_out])  # inv-ok[R1]: one-off gap closure when a consumer attaches mid-stream, not on the step path
                    for j in range(len(handle._toks), st.n_out):
                        handle._feed(j, int(out[j]))
                return

    # -- step loop -------------------------------------------------------

    def warmup(self) -> float:
        # under the step lock: warmup touches the pools a concurrent
        # stepping thread would otherwise race
        with self._step_lock:
            dt = self.core.warmup()
            # compile time is reported separately (stats['compile_s']); a
            # handle submitted before warmup should not charge it to TTFT
            now = time.perf_counter()
            for h in self._handles.values():
                if not h.done and h.ttft_s is None:
                    h._submit_s = max(h._submit_s, now)
            return dt

    def step(self) -> bool:
        """One engine iteration; returns True while work remains.

        Every step accrues its wall time (minus any in-loop prefill) into
        ``stats['decode_s']``, so throughput stays honest no matter what
        drives the loop — ``run()``, a ``RequestHandle`` iterator, or an
        external server loop. Thread-safe: handles on different threads
        (each blocking in ``result()``/``tokens()``) interleave whole
        iterations under one lock instead of racing the core state."""
        with self._step_lock:
            stats = self.core.stats
            p0 = stats["prefill_s"]
            t0 = time.perf_counter()
            self.core.step()
            stats["decode_s"] += max(
                time.perf_counter() - t0 - (stats["prefill_s"] - p0), 0.0)
            for rid, idx, tok in self.core.last_emitted:
                h = self._handles.get(rid)
                if h is not None:
                    if idx > len(h._toks):
                        self._backfill(h)  # close the gap before delivering
                    h._feed(idx, tok)
            finished = self.core.sched.finished
            for req, out in finished[self._finished_seen:]:
                h = self._handles.get(req.rid)
                if h is not None and not h.done:
                    h._finish(np.asarray(out, np.int32),
                              self.core.request_first_tok_t.get(req.rid))
                self._streaming.discard(req.rid)
            self._finished_seen = len(finished)
            if not self._streaming:
                # last streaming consumer done: restore the sync-free loop
                self.core._stream_sync = False
            return not self.core.sched.done()

    def run(self, max_steps: Optional[int] = None) -> Dict[int, np.ndarray]:
        """Drive the loop until every submitted request finished."""
        import jax
        steps = 0
        while self.step():
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        # attribute the tail of in-flight device work to decode time
        t0 = time.perf_counter()
        jax.block_until_ready(self.core.out_buf)  # inv-ok[R1]: end-of-run drain before wall-clock accounting
        self.core.stats["decode_s"] += time.perf_counter() - t0
        # snapshot under the submit lock: another thread may be inserting
        # handles while this one drains
        with self._submit_lock:
            handles = list(self._handles.items())
        return {rid: h._result for rid, h in handles if h.done}

    def reset(self) -> None:
        """Drop all requests/handles but keep the compiled executables.
        Serialised against concurrent step()/submit() callers — safe with
        a live front-end stepping thread: the thread is between
        iterations while we hold the step lock, every unfinished handle
        is marked stopped first (its consumers unblock with
        :class:`EngineStopped` instead of waiting on tokens that will
        never come), and the thread's next step() sees an empty engine
        and parks."""
        with self._step_lock, self._submit_lock:
            stranded = sum(1 for h in self._handles.values() if not h.done)
            if stranded:
                # post-mortem breadcrumb before the state is torn down
                self.core.obs.flight_dump("reset_with_live_requests")
            for h in self._handles.values():
                h._mark_stopped()
            self.core.reset()
            self._handles.clear()
            self._finished_seen = 0
            self._streaming.clear()

    def drain(self) -> None:
        """Block until every submitted request has finished (driving the
        loop here only when no stepping thread owns it)."""
        with self._submit_lock:
            handles = list(self._handles.values())
        for h in handles:
            if not h.cancelled:
                h.result()

    # -- introspection ---------------------------------------------------

    @property
    def stats(self) -> Dict:
        return self.core.stats

    @property
    def obs(self):
        """The core engine's :class:`repro.obs.Observability` bundle
        (metrics registry, span tracer, flight recorder, exporters)."""
        return self.core.obs

    @property
    def depth(self) -> int:
        """Requests in the system (queued + admitted) — the router's
        load signal."""
        return self.core.depth

    def prefix_probe(self, prompt) -> int:
        """Longest cached-prefix length this engine could reuse for
        ``prompt`` right now (0 without a prefix cache); read-only."""
        return self.core.prefix_probe(prompt)

    def accept_lens(self) -> Dict[int, List[int]]:
        """Per-request speculative accept-run lengths: rid -> list of
        accepted tokens per fused step (1 = all drafts rejected,
        draft_k + 1 = all survived). Finished or cancelled requests only;
        empty on a non-speculative engine."""
        return {rid: list(v)
                for rid, v in self.core.request_accept_lens.items()}

    def ttft(self) -> Dict[int, float]:
        """Per-request submit()->first-token wall seconds (finished or
        streaming requests only)."""
        with self._submit_lock:
            handles = list(self._handles.items())
        return {rid: h.ttft_s for rid, h in handles
                if h.ttft_s is not None}


def make_engine(cfg: ModelConfig, params, policy_params=None,
                **knobs) -> Engine:
    """Convenience: ``make_engine(cfg, params, n_slots=8, max_len=512)``
    builds the EngineConfig from keyword overrides."""
    return Engine(cfg, params, policy_params, config=EngineConfig(**knobs))


class AdaptiveServer:
    """DEPRECATED lock-step front-end, kept as a compatibility shim over
    :class:`Engine`: a (b, s0) prompt batch becomes b concurrent streams
    admitted at step 0, decoded greedily for ``n_tokens`` each, via the
    legacy one-shot bucketed prefill (token-for-token identical to the
    chunked default). New code should construct :class:`Engine` with an
    :class:`EngineConfig` and use ``submit``/``RequestHandle``."""

    def __init__(self, cfg: ModelConfig, params, policy_params=None,
                 max_len: int = 2048, page_size: int = 16,
                 use_kernel: bool = False, time_per_token: bool = False,
                 factor_cache: Optional[bool] = None):
        warnings.warn(
            "AdaptiveServer is deprecated; use repro.serve.api.Engine "
            "(EngineConfig + submit/RequestHandle) instead",
            DeprecationWarning, stacklevel=2)
        self.cfg = cfg
        self.params = params
        self.policy = policy_params
        self.max_len = max_len
        self.page_size = page_size
        self.use_kernel = use_kernel
        self.time_per_token = time_per_token
        self.factor_cache = factor_cache
        self._engines: Dict[tuple, Engine] = {}

    def _engine(self, n_slots: int, seg: int, max_new: int) -> Engine:
        key = (n_slots, seg, max_new)
        eng = self._engines.get(key)
        if eng is None:
            eng = Engine(self.cfg, self.params, self.policy,
                         config=EngineConfig(
                             n_slots=n_slots, max_len=self.max_len,
                             page_size=self.page_size, segment_len=seg,
                             max_new_cap=max_new, prefill_chunk=None,
                             sampling=False, use_kernel=self.use_kernel,
                             time_per_token=self.time_per_token,
                             factor_cache=self.factor_cache))
            self._engines[key] = eng
        else:
            eng.reset()
        return eng

    def generate(self, prompts, n_tokens: int,
                 segment_len: Optional[int] = None) -> Dict:
        """prompts: (b, s0) int32. Greedy decode of n_tokens per stream.

        Returns tokens (b, n_tokens), the per-step per-stream rank record,
        warm-decode ``tok_per_s`` and the separated ``compile_s`` /
        ``prefill_s`` costs."""
        seg = segment_len or self.cfg.rank.segment_len
        prompts_np = np.asarray(prompts, np.int32)
        b = prompts_np.shape[0]
        eng = self._engine(b, seg, n_tokens)
        handles = [eng.submit(prompts_np[i],
                              SamplingParams(max_new=n_tokens))
                   for i in range(b)]
        eng.warmup()
        eng.run()
        tokens = np.stack([h.result() for h in handles])
        core = eng.core
        s = core.stats
        return {
            "tokens": tokens,
            "ranks": [r.tolist() for r in core.ranks_per_step()],
            "tok_per_s": s["tokens_decoded"] / max(s["decode_s"], 1e-9),
            "compile_s": s["compile_s"],
            "prefill_s": s["prefill_s"],
            "token_lat_s": list(core.token_latencies),  # [] unless timed
            "ttft_s": [h.ttft_s for h in handles],
            "stats": dict(s),
        }
