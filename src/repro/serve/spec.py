"""Low-rank self-speculative decoding: pure in-graph helpers.

The factor cache is a free draft model. Each fused speculative step
(engine.ServeEngine._step_spec_impl) runs three phases, all inside ONE
jitted executable:

  1. **Draft**: ``draft_k`` cheap single-token forwards that read the
     factor pool at an aggressive per-row rank (``draft_ranks`` in
     serve.policy — ceil(frac * rank), floor-clamped by the slot's cached
     spectra). The basis / kt pool are *statically* sliced to the draft
     width r_cap, so the draft's score contraction genuinely reads fewer
     bytes, not masked-out zeros. Draft K/V writes land in the real pages
     (the verify pass overwrites every one of them with authoritative
     values); draft factor appends go into the sliced transient copy and
     are discarded; the mass pool is untouched.
  2. **Verify**: ONE chunked-query forward over [t_0, d_1 .. d_k] at the
     slot's full current rank — exactly the chunked-prefill causal-block
     shape from decode_step_paged (q_lens = draft_k + 1) with
     ``return_all_logits`` keeping every query's logits. Target tokens
     g_0..g_k are drawn with the same (seed, absolute out position) PRNG
     fold plain decode uses, which makes each target a *deterministic*
     function of (logits, position): "accept while d_{i+1} == g_i" then
     reproduces plain decode's token stream exactly, for greedy AND
     seeded sampling — no rejection-sampling correction needed.
  3. **Accept / roll back**: ``accept_counts`` takes the longest matching
     prefix (+1 for the verify step's own bonus token), clamped by EOS,
     by the remaining max_new budget, and by the distance to the next
     segment boundary (so adaptive-rank decisions fire at the identical
     token counts as non-speculative decode). The rollback is purely
     logical and in-graph: ``lens`` advances only past accepted tokens;
     K/V/kt rows beyond it are dead weight that the valid-length masks
     hide and the next step overwrites. Deferred per-query mass
     contributions (decode_step_paged ``mass_defer``) are applied here
     for the accepted queries only — Eq. 9 veto state never sees a
     rejected draft. No page is ever rewound: speculative writes sit at
     positions >= lens >= the slot's shared-page floor
     (PagedKVCache.shared_floor), so refcounted prefix pages stay
     immutable.

Exactness contract: speculation changes *speed only*. Accepted target
tokens are the verify pass's own samples at the same positions, with the
same sampler, the same fold, and the same rank state plain decode would
have used — so greedy and seeded streams are token-identical with
speculation on or off, across dense/factor caches and kernel/XLA paths.
"""
from __future__ import annotations

import jax.numpy as jnp


def accept_counts(drafts: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    """Longest-matching-prefix accept count per row, (ns,) int32 in
    [1, draft_k + 1].

    drafts: (ns, k) draft tokens d_1..d_k; targets: (ns, >= k + 1) verify
    samples g_0..g_k at the same output positions. Draft d_{i+1} was
    proposed for the position g_i verifies, so j = #leading matches of
    d_{i+1} == g_i, and the step emits a = j + 1 tokens g_0..g_j — the
    first mismatching position still emits its *target* (the token plain
    decode would have produced), which is also why a >= 1: even a fully
    rejected draft run yields the one token a non-speculative step would.
    """
    k = drafts.shape[1]
    match = (drafts == targets[:, :k]).astype(jnp.int32)
    j = jnp.sum(jnp.cumprod(match, axis=1), axis=1)
    return (j + 1).astype(jnp.int32)


def clamp_to_eos(a: jnp.ndarray, targets: jnp.ndarray,
                 eos_ids: jnp.ndarray) -> jnp.ndarray:
    """Truncate accepted runs at the first EOS target, inclusive.

    Plain decode evicts the step after it emits EOS, so a speculative run
    must never emit past it. ``eos_ids`` is (ns,) with -1 for requests
    without an EOS."""
    iseos = (targets == eos_ids[:, None]) & (eos_ids >= 0)[:, None]
    first = jnp.argmax(iseos, axis=1).astype(a.dtype)
    cap = jnp.where(jnp.any(iseos, axis=1), first + 1, targets.shape[1])
    return jnp.minimum(a, cap)


def apply_deferred_mass(mass_pool: jnp.ndarray, contrib: jnp.ndarray,
                        lens: jnp.ndarray, n_q: jnp.ndarray) -> jnp.ndarray:
    """Fold the verify pass's deferred per-query mass contributions into
    the pool, accepted queries only.

    mass_pool: (L, ns, M, hkv); contrib: (L, ns, C, M, hkv) per-query
    contributions (already zero for dead lanes / padding queries via the
    forward's write_ok mask); lens: (ns,) pre-step lengths; n_q: (ns,)
    accepted query count per row (accept count for speculative rows, the
    consumed chunk length for mid-prefill rows, 0 for dead rows).

    Cells [lens, lens + n_q) are reset before the add (the same
    append-step reset the in-scan update does), then each accepted
    query's contribution is added **in query order** — bitwise the same
    accumulation sequence as n_q sequential single-token steps, so a
    later segment decision sees identical weighted-Gram input either way.
    Causality makes the content identical too: query i's softmax row only
    spans keys plain decode had at its step."""
    M = mass_pool.shape[2]
    pos = jnp.arange(M)[None, :]
    new_cell = (pos >= lens[:, None]) & (pos < (lens + n_q)[:, None])
    mass = jnp.where(new_cell[None, :, :, None], 0.0, mass_pool)
    C = contrib.shape[2]
    q_idx = jnp.arange(C)[None, :]
    q_ok = (q_idx < n_q[:, None]).astype(mass_pool.dtype)     # (ns, C)
    for q in range(C):        # static unroll: per-query adds stay ordered
        mass = mass + (contrib[:, :, q].astype(mass_pool.dtype)
                       * q_ok[None, :, q, None, None])
    return mass


def host_accept_stats(acc_h, caps, decoding, draft_k):
    """Per-step speculative accounting over the already-fetched accept
    counts — pure host arithmetic, shared by the engine's stats and the
    obs accept histogram. Returns ``(tokens, accepted, drafted)``:
    tokens emitted this step across ``decoding`` rows (accept run incl.
    the bonus token), drafts accepted, and drafts that COULD have been
    accepted (caps clamp near max_new / segment boundaries, so counting
    ``draft_k`` flat would bias the accept rate low)."""
    tokens = sum(int(acc_h[i]) for i in decoding)
    accepted = sum(int(acc_h[i]) - 1 for i in decoding)
    drafted = sum(min(draft_k, int(caps[i]) - 1) for i in decoding)
    return tokens, accepted, drafted
