"""AdamW from scratch (no optax offline): decoupled weight decay, bias
correction, global-norm clipping, schedule support. Optimizer state shares
the param tree structure, so it inherits the exact param shardings (ZeRO-
style sharded optimizer state falls out of FSDP param specs for free)."""
from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: object
    v: object


def init(params) -> AdamWState:
    zeros = lambda p: jax.tree_util.tree_map(
        lambda x: jnp.zeros_like(x, dtype=jnp.float32), p)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros(params),
                      v=zeros(params))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    g = global_norm(grads)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree_util.tree_map(lambda x: x * factor, grads), g


_WD_EXEMPT = ("ln", "norm", "bias", "b_", "bq", "bk", "bv", "A_log",
              "dt_bias", "D", "mu", "w0", "u")


def _decay_mask(path: str) -> bool:
    last = path.split("/")[-1]
    return not any(last.startswith(t) or last == t for t in _WD_EXEMPT) and \
        not last.startswith("ln")


def update(tc: TrainConfig, lr_fn: Callable, state: AdamWState, params, grads
           ) -> Tuple[object, AdamWState, dict]:
    grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
    step = state.step + 1
    lr = lr_fn(step)
    b1, b2, eps = tc.b1, tc.b2, tc.eps
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.m)
    flat_v = jax.tree_util.tree_leaves(state.v)

    new_p, new_m, new_v = [], [], []
    for (path, p), g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        pname = "/".join(str(getattr(k, "key", k)) for k in path)
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * jnp.square(gf)
        upd = (m / c1) / (jnp.sqrt(v / c2) + eps)
        if _decay_mask(pname) and tc.weight_decay > 0:
            upd = upd + tc.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_m.append(m)
        new_v.append(v)

    unflatten = jax.tree_util.tree_unflatten
    params = unflatten(treedef, [x for x in new_p])
    m_tree = unflatten(treedef, new_m)
    v_tree = unflatten(treedef, new_v)
    return params, AdamWState(step=step, m=m_tree, v=v_tree), {
        "grad_norm": gnorm, "lr": lr}
