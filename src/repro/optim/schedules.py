"""LR schedules (linear warmup + {linear, cosine, constant} decay)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import TrainConfig


def make_lr_fn(tc: TrainConfig):
    peak, warm, total = tc.lr, tc.warmup_steps, tc.total_steps

    def lr_fn(step):
        s = step.astype(jnp.float32)
        warmup = peak * s / jnp.maximum(warm, 1)
        frac = jnp.clip((s - warm) / jnp.maximum(total - warm, 1), 0.0, 1.0)
        if tc.schedule == "cosine":
            decay = peak * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        elif tc.schedule == "linear":
            decay = peak * (1.0 - frac)
        else:
            decay = jnp.asarray(peak)
        return jnp.where(s < warm, warmup, decay)

    return lr_fn
