"""Stateless synthetic LM data: batch i is a pure function of (seed, i).

Fault-tolerant by construction — resuming at step i after any failure or a
*different* device count reproduces the exact token stream with no iterator
state to checkpoint (only the integer cursor). The stream is a Zipf-ish
unigram mixture with injected local structure (repeated motifs) so that a
model can actually reduce loss on it.
"""
from __future__ import annotations

from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np


def zipf_logits(vocab: int, alpha: float = 1.2) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    return np.log(p / p.sum()).astype(np.float32)


class SyntheticLM:
    """Deterministic, seekable synthetic corpus."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, alpha: float = 1.2, motif_len: int = 8):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.motif_len = motif_len
        self._logits = jnp.asarray(zipf_logits(vocab, alpha))

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        """Pure function of (seed, step) -> {'tokens','labels','mask'}."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2, k3 = jax.random.split(key, 3)
        b, s, m = self.global_batch, self.seq_len, self.motif_len
        base = jax.random.categorical(
            k1, jnp.broadcast_to(self._logits, (b, s + 1, self.vocab)))
        # inject motif structure: every other window repeats the previous one
        n_win = (s + 1) // m
        rep = jax.random.bernoulli(k2, 0.5, (b, n_win))
        toks = base[:, :n_win * m].reshape(b, n_win, m)
        prev = jnp.concatenate([toks[:, :1], toks[:, :-1]], axis=1)
        toks = jnp.where(rep[:, :, None], prev, toks).reshape(b, n_win * m)
        full = jnp.concatenate([toks, base[:, n_win * m:]], axis=1)
        return {
            "tokens": full[:, :-1].astype(jnp.int32),
            "labels": full[:, 1:].astype(jnp.int32),
            "mask": jnp.ones((b, s), jnp.float32),
        }

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class SyntheticClassification:
    """Synthetic sentiment-like task for the Table-3 analogue: label is
    determined by which of two token populations dominates the sequence."""

    def __init__(self, vocab: int, seq_len: int, batch: int, seed: int = 0):
        self.vocab, self.seq_len, self.batch, self.seed = vocab, seq_len, batch, seed

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed + 77), step)
        k1, k2, k3 = jax.random.split(key, 3)
        b, s, v = self.batch, self.seq_len, self.vocab
        labels = jax.random.bernoulli(k1, 0.5, (b,)).astype(jnp.int32)
        lo = jax.random.randint(k2, (b, s), 0, v // 2)
        hi = jax.random.randint(jax.random.fold_in(k2, 1), (b, s), v // 2, v)
        bias = jnp.where(labels[:, None] == 1, 0.7, 0.3)
        pick_hi = jax.random.uniform(k3, (b, s)) < bias
        toks = jnp.where(pick_hi, hi, lo)
        return {"tokens": toks.astype(jnp.int32), "labels": labels}
