"""Byte-level corpus pipeline over local text files (the offline stand-in
for Wikitext/PTB/BookCorpus). Stateless: batch i is a pure function of
(corpus bytes, seed, i) via strided window sampling."""
from __future__ import annotations

import pathlib
from typing import Dict, Sequence

import numpy as np


class ByteCorpus:
    VOCAB = 256

    def __init__(self, paths: Sequence[str], seq_len: int, global_batch: int,
                 seed: int = 0, max_bytes: int = 32 * 1024 * 1024):
        buf = bytearray()
        for p in paths:
            path = pathlib.Path(p)
            if path.is_dir():
                files = sorted(path.rglob("*.py")) + sorted(path.rglob("*.md"))
            else:
                files = [path]
            for f in files:
                try:
                    buf += f.read_bytes()
                except OSError:
                    continue
                if len(buf) >= max_bytes:
                    break
        if len(buf) < (seq_len + 1) * 2:
            raise ValueError("corpus too small")
        self.data = np.frombuffer(bytes(buf), dtype=np.uint8)
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 32) ^ step)
        b, s = self.global_batch, self.seq_len
        starts = rng.integers(0, len(self.data) - s - 1, size=b)
        idx = starts[:, None] + np.arange(s + 1)[None]
        w = self.data[idx].astype(np.int32)
        return {"tokens": w[:, :-1], "labels": w[:, 1:],
                "mask": np.ones((b, s), np.float32)}
