"""Static low-rank attention baselines from the paper's comparison set:
Performer (FAVOR+ positive random features) and Nystromformer (landmark
attention). Both plug into the dense transformer as drop-in sequence mixers
for the Table-1/Table-3 reproductions.
"""
from __future__ import annotations

import math
import jax
import jax.numpy as jnp


def favor_features(x: jnp.ndarray, proj: jnp.ndarray) -> jnp.ndarray:
    """Positive softmax-kernel random features (Choromanski et al. 2020).
    x: (b, s, h, d); proj: (h, m, d) orthogonal rows. Returns (b, s, h, m)."""
    d = x.shape[-1]
    x = x / d ** 0.25
    xw = jnp.einsum("bshd,hmd->bshm", x, proj)
    sq = 0.5 * jnp.sum(jnp.square(x), axis=-1, keepdims=True)
    m = proj.shape[1]
    return jnp.exp(xw - sq - jnp.max(xw, axis=-1, keepdims=True)) / math.sqrt(m)


def orthogonal_proj(key, h: int, m: int, d: int) -> jnp.ndarray:
    """Per-head orthogonal random feature matrices (m x d)."""
    def one(k):
        blocks = []
        for i in range((m + d - 1) // d):
            q, _ = jnp.linalg.qr(jax.random.normal(
                jax.random.fold_in(k, i), (d, d)))
            blocks.append(q.T)
        w = jnp.concatenate(blocks, axis=0)[:m]
        norms = jnp.sqrt(jax.random.chisquare(
            jax.random.fold_in(k, 999), d, (m, 1)))
        return w * norms

    return jax.vmap(one)(jax.random.split(key, h))


def performer_attention(q, k, v, *, proj: jnp.ndarray,
                        causal: bool = True) -> jnp.ndarray:
    """q,k: (b, s, h, d); v: (b, s, h, dv). Linear-complexity FAVOR+."""
    qf = favor_features(q, proj)                    # (b, s, h, m)
    kf = favor_features(k, proj)
    if not causal:
        kv = jnp.einsum("bshm,bshd->bhmd", kf, v)
        z = jnp.einsum("bshm,bhm->bsh", qf, jnp.sum(kf, axis=1))
        num = jnp.einsum("bshm,bhmd->bshd", qf, kv)
        return num / jnp.maximum(z[..., None], 1e-6)
    # causal prefix sums over s
    kv_cum = jnp.cumsum(jnp.einsum("bshm,bshd->bshmd", kf, v), axis=1)
    k_cum = jnp.cumsum(kf, axis=1)
    num = jnp.einsum("bshm,bshmd->bshd", qf, kv_cum)
    den = jnp.einsum("bshm,bshm->bsh", qf, k_cum)
    return num / jnp.maximum(den[..., None], 1e-6)


def nystrom_attention(q, k, v, *, n_landmarks: int = 32,
                      causal: bool = True, pinv_iters: int = 6) -> jnp.ndarray:
    """Nystromformer (Xiong et al. 2021): landmark-based softmax
    approximation with iterative Moore-Penrose pseudo-inverse.
    q,k: (b, s, h, d); v: (b, s, h, dv)."""
    b, s, h, d = q.shape
    m = min(n_landmarks, s)
    scale = d ** -0.5
    seg = s // m
    q_l = q[:, :seg * m].reshape(b, m, seg, h, d).mean(2)     # landmarks
    k_l = k[:, :seg * m].reshape(b, m, seg, h, d).mean(2)

    def soft(a, mask=None):
        a = a * scale
        if mask is not None:
            a = jnp.where(mask, a, -1e30)
        return jax.nn.softmax(a.astype(jnp.float32), axis=-1).astype(q.dtype)

    f1 = soft(jnp.einsum("bqhd,bmhd->bhqm", q, k_l))          # (b,h,s,m)
    a_mid = soft(jnp.einsum("bqhd,bmhd->bhqm", q_l, k_l))     # (b,h,m,m)
    f3 = soft(jnp.einsum("bmhd,bkhd->bhmk", q_l, k), mask=None)  # (b,h,m,s)

    # iterative pinv of a_mid
    z = a_mid.astype(jnp.float32)
    az = z / (jnp.max(jnp.sum(jnp.abs(z), -1), -1, keepdims=True)[..., None]
              * jnp.max(jnp.sum(jnp.abs(z), -2), -1, keepdims=True)[..., None])
    zi = jnp.swapaxes(az, -1, -2)
    eye = jnp.eye(m)
    for _ in range(pinv_iters):
        zz = jnp.einsum("bhmk,bhkn->bhmn", z, zi)
        zi = jnp.einsum("bhmk,bhkn->bhmn",
                        zi, 13 * eye - jnp.einsum(
                            "bhmk,bhkn->bhmn", zz,
                            15 * eye - 7 * zz + jnp.einsum(
                                "bhmk,bhkn->bhmn", zz, zz))) / 4.0
    out = jnp.einsum("bhqm,bhmn,bhnk,bkhd->bqhd",
                     f1.astype(jnp.float32), zi, f3.astype(jnp.float32),
                     v.astype(jnp.float32))
    if causal:
        # cheap causal correction: renormalise by the causal mass fraction
        # (Nystromformer is natively bidirectional; the paper applies it to
        # GLUE-style tasks — we keep this variant for the LM comparison)
        frac = (jnp.arange(s, dtype=jnp.float32) + 1.0) / s
        out = out * frac[None, :, None, None]
    return out.astype(v.dtype)
