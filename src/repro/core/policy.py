"""Transformer-based rank-selection policy network (paper section 4.1.3/4.5.1).

The paper uses a distilled GPT-Small-style encoder over the state sequence.
We realise the state (Eq. 6) as a short sequence of feature-group tokens
  [ h_t | w_t | NER grid | dA-bound grid | prev-rank | layer-id ]
each linearly embedded into d_pol, processed by a pre-LN Transformer encoder,
mean-pooled, and decoded by an MLP into (action logits over the rank grid,
value estimate) — the value head is used by PPO.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro import nn

FEATURE_ORDER = ("h_t", "w_t", "ner", "bounds", "prev_rank", "layer_id")


def init_policy(rng, feat_dims: Dict[str, int], n_actions: int,
                d_pol: int = 64, n_layers: int = 2, n_heads: int = 4,
                d_ff: int = 128, dtype=jnp.float32) -> dict:
    ks = nn.split_keys(rng, 4 + 10 * n_layers)
    ki = iter(ks)
    p: dict = {"embed": {}, "layers": []}
    for name in FEATURE_ORDER:
        p["embed"][name] = {
            "w": nn.dense_init(next(ki), feat_dims[name], d_pol, dtype),
            "b": jnp.zeros((d_pol,), dtype),
        }
    for _ in range(n_layers):
        p["layers"].append({
            "ln1": jnp.ones((d_pol,), dtype),
            "wq": nn.dense_init(next(ki), d_pol, d_pol, dtype),
            "wk": nn.dense_init(next(ki), d_pol, d_pol, dtype),
            "wv": nn.dense_init(next(ki), d_pol, d_pol, dtype),
            "wo": nn.dense_init(next(ki), d_pol, d_pol, dtype),
            "ln2": jnp.ones((d_pol,), dtype),
            "w1": nn.dense_init(next(ki), d_pol, d_ff, dtype),
            "w2": nn.dense_init(next(ki), d_ff, d_pol, dtype),
        })
    p["ln_f"] = jnp.ones((d_pol,), dtype)
    p["head"] = {
        "w1": nn.dense_init(next(ki), d_pol, d_pol, dtype),
        "w_logits": nn.dense_init(next(ki), d_pol, n_actions, dtype, scale=0.01),
        "w_value": nn.dense_init(next(ki), d_pol, 1, dtype, scale=0.01),
    }
    return p


def _encoder_layer(lp: dict, x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """x: (B, T, d_pol) bidirectional self-attention + MLP (pre-LN)."""
    B, T, D = x.shape
    dh = D // n_heads
    h = nn.rms_norm(x, lp["ln1"])
    q = nn.linear(h, lp["wq"]).reshape(B, T, n_heads, dh)
    k = nn.linear(h, lp["wk"]).reshape(B, T, n_heads, dh)
    v = nn.linear(h, lp["wv"]).reshape(B, T, n_heads, dh)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * dh ** -0.5
    a = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(x.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(B, T, D)
    x = x + nn.linear(o, lp["wo"])
    h = nn.rms_norm(x, lp["ln2"])
    x = x + nn.linear(jax.nn.gelu(nn.linear(h, lp["w1"])), lp["w2"])
    return x


POLICY_HEADS = 4


def policy_apply(p: dict, feats: Dict[str, jnp.ndarray]
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """feats[name]: (B, feat_dims[name]). Returns (logits (B, A), value (B,))."""
    toks = []
    for name in FEATURE_ORDER:
        e = p["embed"][name]
        toks.append(nn.linear(feats[name].astype(e["w"].dtype), e["w"], e["b"]))
    x = jnp.stack(toks, axis=1)                     # (B, T=6, d_pol)
    for lp in p["layers"]:
        x = _encoder_layer(lp, x, POLICY_HEADS)
    x = nn.rms_norm(jnp.mean(x, axis=1), p["ln_f"])
    h = jax.nn.gelu(nn.linear(x, p["head"]["w1"]))
    logits = nn.linear(h, p["head"]["w_logits"])
    value = nn.linear(h, p["head"]["w_value"])[..., 0]
    return logits.astype(jnp.float32), value.astype(jnp.float32)
