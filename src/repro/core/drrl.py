"""DR-RL controller: glues spectra -> features -> policy -> guardrail -> rank.

The controller is invoked *inside* each attention layer (per layer, per
kv-head). Decisions are replicated across the mesh: every feature it consumes
is a tiny per-head summary (NER grid, Eq.9 bounds, weight stats), so no
per-token resharding is ever required (DESIGN.md section 3.6).
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RankConfig
from repro.core import lowrank as lr
from repro.core import perturbation as pert
from repro.core.policy import policy_apply

GRID_FEATS = ("ner", "bounds", "prev_rank")


def feat_dims(rank_cfg: RankConfig, h_dim: int = 8) -> Dict[str, int]:
    g = len(rank_cfg.rank_grid)
    return {"h_t": h_dim, "w_t": 9, "ner": g, "bounds": g,
            "prev_rank": g, "layer_id": 1}


def init_agent(rng, rank_cfg: RankConfig, d_model: int, *, h_dim: int = 8,
               conv_width: int = 5, d_pol: int = 64, n_layers: int = 2) -> dict:
    """Full DR-RL agent params: the 1-D conv featurizer (h_t) + the
    Transformer policy network (+ value head)."""
    from repro.core.policy import init_policy
    k_conv, k_pol = jax.random.split(rng)
    conv = (jax.random.normal(k_conv, (conv_width, d_model, h_dim), jnp.float32)
            * (conv_width * d_model) ** -0.5)
    pol = init_policy(k_pol, feat_dims(rank_cfg, h_dim),
                      n_actions=len(rank_cfg.rank_grid),
                      d_pol=d_pol, n_layers=n_layers)
    pol["conv"] = conv
    return pol


def conv_features(embeddings: jnp.ndarray, kernel: jnp.ndarray) -> jnp.ndarray:
    """Sequence-dynamics feature h_t (paper 4.1.1): depthwise 1-D conv over the
    input embeddings, mean-pooled over sequence. embeddings: (b, s, d);
    kernel: (k, d, f). Returns (b, f)."""
    y = jax.lax.conv_general_dilated(
        embeddings.astype(jnp.float32),
        kernel.astype(jnp.float32),
        window_strides=(1,), padding="SAME",
        dimension_numbers=("NWC", "WIO", "NWC"))
    return jnp.tanh(jnp.mean(y, axis=1))


def weight_stats(p_attn: Dict[str, jnp.ndarray], power_iters: int = 3) -> jnp.ndarray:
    """Layer-parameter feature w_t (paper 4.1.1): mean / var / spectral norm
    of W_Q, W_K, W_V (9 scalars). Spectral norms via power iteration Eq. 16."""
    feats = []
    for name in ("wq", "wk", "wv"):
        w = p_attn[name].astype(jnp.float32)
        w2 = w.reshape(w.shape[0], -1)
        feats += [jnp.mean(w2), jnp.var(w2),
                  lr.power_iteration_specnorm(w2, power_iters)]
    return jnp.stack(feats)


def rank_grid_index(rank_cfg: RankConfig, rank: jnp.ndarray) -> jnp.ndarray:
    grid = jnp.asarray(rank_cfg.rank_grid, jnp.int32)
    return jnp.argmin(jnp.abs(rank[..., None] - grid[None]), axis=-1)


def build_features(rank_cfg: RankConfig, ctx: Dict[str, jnp.ndarray],
                   h_t: jnp.ndarray, w_t: jnp.ndarray, layer_id,
                   prev_rank: jnp.ndarray) -> Tuple[Dict[str, jnp.ndarray], Tuple]:
    """Assemble the Eq. 6 state for every (batch, kv-head) pair.

    Returns (feats dict of (B, dim), (b, h) unflatten info)."""
    k_s2 = ctx["k_s2"]                               # (b, h, d)
    b, h, d = k_s2.shape
    grid = jnp.asarray(rank_cfg.rank_grid, jnp.int32)
    ner = lr.ner_curve(k_s2)                         # (b, h, d)
    ner_g = jnp.take(ner, jnp.clip(grid - 1, 0, d - 1), axis=-1)   # (b, h, G)
    hq = ctx["q_s2"].shape[1]
    # aggregate q-head spectra per kv group (q heads are contiguous per group)
    q_s2 = (ctx["q_s2"].reshape(b, h, hq // h, d).mean(2)
            if hq != h else ctx["q_s2"])
    bounds, norm = pert.guardrail_report(q_s2, k_s2, rank_cfg.rank_grid, d)
    bounds_rel = bounds / jnp.maximum(norm[..., None], 1e-30)       # (b, h, G)
    prev_1h = jax.nn.one_hot(rank_grid_index(rank_cfg, prev_rank), len(rank_cfg.rank_grid))
    B = b * h
    feats = {
        "h_t": jnp.broadcast_to(h_t[:, None, :], (b, h, h_t.shape[-1])).reshape(B, -1),
        "w_t": jnp.broadcast_to(w_t[None, None, :], (b, h, 9)).reshape(B, 9),
        "ner": ner_g.reshape(B, -1),
        "bounds": bounds_rel.reshape(B, -1),
        "prev_rank": prev_1h.reshape(B, -1),
        "layer_id": jnp.full((B, 1), jnp.asarray(layer_id, jnp.float32).reshape(())),
    }
    return feats, (b, h, bounds_rel, norm)


def make_action_fn(policy_params: dict, rank_cfg: RankConfig, *,
                   h_t: jnp.ndarray, greedy: bool = True) -> Callable:
    """Returns action_fn(ctx, rank_ctx) -> (rank_k (b, hkv), aux dict) for
    repro.models.attention.mhsa. Applies the Eq. 11 annealed safety mask.

    Reads from rank_ctx: 'prev_rank' (b, hkv) carry, 'layer_id' (traced ok),
    'w_t' (9,) weight stats of the current layer, 't' RL global step, 'rng'.
    """

    def action_fn(ctx, rank_ctx):
        prev = rank_ctx.get("prev_rank")
        k_s2 = ctx["k_s2"]
        b, h = k_s2.shape[0], k_s2.shape[1]
        if prev is None:
            prev = jnp.full((b, h), rank_cfg.rank_grid[-1], jnp.int32)
        w_t = rank_ctx.get("w_t")
        if w_t is None:
            w_t = jnp.zeros((9,), jnp.float32)
        layer_id = rank_ctx.get("layer_id", 0)
        feats, (b, h, bounds_rel, norm) = build_features(
            rank_cfg, ctx, h_t, w_t, layer_id, prev)
        logits, value = policy_apply(policy_params, feats)   # (B, G)
        G = logits.shape[-1]
        mask_ok = jnp.ones(logits.shape, bool)
        if rank_cfg.guardrail:
            eps_t = pert.annealed_threshold(rank_cfg.epsilon0,
                                            rank_cfg.anneal_lambda,
                                            rank_ctx.get("t", 0))
            mask_ok = pert.safety_mask(bounds_rel.reshape(-1, G), eps_t)
            logits = jnp.where(mask_ok, logits, -1e30)
        rng = rank_ctx.get("rng")
        if greedy or rng is None:
            a_idx = jnp.argmax(logits, axis=-1)
        else:
            a_idx = jax.random.categorical(rng, logits)
        logp = jax.nn.log_softmax(logits, axis=-1)
        logp_a = jnp.take_along_axis(logp, a_idx[:, None], axis=-1)[:, 0]
        grid = jnp.asarray(rank_cfg.rank_grid, jnp.int32)
        rank_k = grid[a_idx].reshape(b, h)
        chosen_bound = jnp.take_along_axis(
            bounds_rel.reshape(-1, G), a_idx[:, None], axis=-1)[:, 0].reshape(b, h)
        aux = {
            "action_idx": a_idx.reshape(b, h),
            "logits": logits.reshape(b, h, G),
            "logp": logp_a.reshape(b, h),
            "value": value.reshape(b, h),
            "delta_a_rel": chosen_bound,
            "action_mask": mask_ok.reshape(b, h, G),
            "features": feats,
        }
        return rank_k, aux

    return action_fn
