"""PPO with GAE for the rank policy (paper 4.5.3, 'Hybrid Training' stage 2).

Trajectories are collected from rollouts of the LM forward pass: each
(layer, kv-head) decision is one MDP step; the layer index is the time axis
(ranks evolve layer-to-layer through the prev-rank carry, matching the
paper's sequential-policy view).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.policy import policy_apply


class Trajectory(NamedTuple):
    feats: Dict[str, jnp.ndarray]   # each (T, B, dim)
    actions: jnp.ndarray            # (T, B) int32 grid indices
    logp_old: jnp.ndarray           # (T, B)
    values_old: jnp.ndarray         # (T, B)
    rewards: jnp.ndarray            # (T, B)
    action_mask: jnp.ndarray        # (T, B, A) bool — guardrail mask at collect time


def gae(rewards: jnp.ndarray, values: jnp.ndarray, gamma: float = 0.99,
        lam: float = 0.95) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """rewards/values: (T, B). Episode terminates after the last layer."""
    next_values = jnp.concatenate([values[1:], jnp.zeros_like(values[:1])], 0)
    deltas = rewards + gamma * next_values - values

    def body(carry, xs):
        delta = xs
        adv = delta + gamma * lam * carry
        return adv, adv

    _, advs = jax.lax.scan(body, jnp.zeros_like(deltas[0]),
                           jnp.flip(deltas, 0))
    advs = jnp.flip(advs, 0)
    returns = advs + values
    return advs, returns


def ppo_loss(policy_params: dict, traj: Trajectory, *, clip: float = 0.2,
             vf_coef: float = 0.5, ent_coef: float = 0.01) -> Tuple[jnp.ndarray, dict]:
    T, B = traj.actions.shape
    feats = {k: v.reshape(T * B, -1) for k, v in traj.feats.items()}
    logits, values = policy_apply(policy_params, feats)
    logits = jnp.where(traj.action_mask.reshape(T * B, -1), logits, -1e30)
    logp_all = jax.nn.log_softmax(logits, axis=-1)
    logp = jnp.take_along_axis(
        logp_all, traj.actions.reshape(T * B)[:, None], axis=-1)[:, 0]

    adv, returns = gae(traj.rewards, traj.values_old)
    adv = (adv - jnp.mean(adv)) / (jnp.std(adv) + 1e-8)
    adv = adv.reshape(T * B)
    returns = returns.reshape(T * B)

    ratio = jnp.exp(logp - traj.logp_old.reshape(T * B))
    pg1 = ratio * adv
    pg2 = jnp.clip(ratio, 1.0 - clip, 1.0 + clip) * adv
    pg_loss = -jnp.mean(jnp.minimum(pg1, pg2))

    vf_loss = 0.5 * jnp.mean((values - returns) ** 2)
    probs = jnp.exp(logp_all)
    entropy = -jnp.mean(jnp.sum(jnp.where(probs > 1e-12, probs * logp_all, 0.0), -1))

    loss = pg_loss + vf_coef * vf_loss - ent_coef * entropy
    metrics = {"pg_loss": pg_loss, "vf_loss": vf_loss, "entropy": entropy,
               "ratio_mean": jnp.mean(ratio)}
    return loss, metrics


def bc_loss(policy_params: dict, feats: Dict[str, jnp.ndarray],
            oracle_actions: jnp.ndarray,
            action_mask: jnp.ndarray) -> jnp.ndarray:
    """Behaviour-cloning warm start (paper 4.5.3 stage 1): cross-entropy to
    the greedy oracle's actions. feats: (N, dim) each; oracle_actions (N,)."""
    logits, _ = policy_apply(policy_params, feats)
    logits = jnp.where(action_mask, logits, -1e30)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, oracle_actions[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)
