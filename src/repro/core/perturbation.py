"""Online matrix perturbation bounds (paper section 3.3 / 4.2).

All bounds are functions of the singular-value spectra of the attention
factors, which the Gram route (lowrank.py) provides for free — so the safety
guardrail costs O(d) per head, not O(n^2).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def eckart_young_tail(sigmas_sq: jnp.ndarray, r) -> jnp.ndarray:
    """||A - A_r||_F = sqrt(sum_{i>r} sigma_i^2)   (paper Eq. 3).

    sigmas_sq: (..., d) descending. r may be a traced integer."""
    d = sigmas_sq.shape[-1]
    tail_mask = (jnp.arange(d) >= r).astype(sigmas_sq.dtype)
    return jnp.sqrt(jnp.sum(sigmas_sq * tail_mask, axis=-1))


def rank_transition_norm(sigmas_sq: jnp.ndarray, r, r_new) -> jnp.ndarray:
    """||A_{r'} - A_r||_F = sqrt(sum_{k in (r, r']} sigma_k^2)  (paper Eq. 4)."""
    d = sigmas_sq.shape[-1]
    lo, hi = jnp.minimum(r, r_new), jnp.maximum(r, r_new)
    in_band = ((jnp.arange(d) >= lo) & (jnp.arange(d) < hi)).astype(sigmas_sq.dtype)
    return jnp.sqrt(jnp.sum(sigmas_sq * in_band, axis=-1))


def output_sensitivity(sigmas_sq: jnp.ndarray, r, v_fro: jnp.ndarray) -> jnp.ndarray:
    """||Y_{r'} - Y_r||_F <= sigma_{r+1} ||V||_F   (paper Eq. 5 / 10)."""
    d = sigmas_sq.shape[-1]
    idx = jnp.clip(r, 0, d - 1)
    sigma_next = jnp.sqrt(jnp.take_along_axis(
        sigmas_sq, jnp.broadcast_to(idx, sigmas_sq.shape[:-1])[..., None], axis=-1))[..., 0]
    return sigma_next * v_fro


def delta_a_bound(q_sigmas_sq: jnp.ndarray, k_sigmas_sq: jnp.ndarray, r,
                  d_head: int) -> jnp.ndarray:
    """Paper Eq. 9:
       ||dA||_F <= (||dQ||_2 ||K||_2 + ||Q||_2 ||dK||_2) / sqrt(d)
    with ||dQ||_2 = sigma_{r+1}(Q) (best rank-r residual spectral norm)."""
    dd = q_sigmas_sq.shape[-1]
    idx = jnp.clip(r, 0, dd - 1)

    def at(s2, i):
        return jnp.sqrt(jnp.take_along_axis(
            s2, jnp.broadcast_to(i, s2.shape[:-1])[..., None], axis=-1))[..., 0]

    dq = at(q_sigmas_sq, idx)                 # sigma_{r+1}(Q)
    dk = at(k_sigmas_sq, idx)
    q_top = jnp.sqrt(q_sigmas_sq[..., 0])     # ||Q||_2
    k_top = jnp.sqrt(k_sigmas_sq[..., 0])
    return (dq * k_top + q_top * dk) / jnp.sqrt(float(d_head))


def annealed_threshold(eps0: float, lam: float, t) -> jnp.ndarray:
    """eps_t = eps0 * exp(-lam t)   (paper Eq. 11)."""
    return eps0 * jnp.exp(-lam * jnp.asarray(t, jnp.float32))


def safety_mask(bounds_per_action: jnp.ndarray, eps_t,
                normaliser: jnp.ndarray = None) -> jnp.ndarray:
    """Boolean mask over the rank grid: True = action allowed (paper 4.3.1).

    bounds_per_action: (..., n_actions) predicted ||dA||_F per candidate rank.
    Bounds are normalised by ||A||-scale (q_top*k_top/sqrt(d)) when given so
    that eps_t is a relative threshold. The *largest* rank is always allowed
    (the guardrail may never leave the agent without a legal action)."""
    b = bounds_per_action
    if normaliser is not None:
        b = b / jnp.maximum(normaliser[..., None], 1e-30)
    ok = b <= eps_t
    # always allow the most conservative (= highest-rank, lowest-bound) action
    ok = ok.at[..., -1].set(True)
    return ok


def guardrail_report(q_sigmas_sq: jnp.ndarray, k_sigmas_sq: jnp.ndarray,
                     rank_grid: Tuple[int, ...], d_head: int
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Vectorised Eq. 9 bound over a rank grid.

    Returns (bounds (..., n_actions), normaliser (...,)) where normaliser is
    the ||Q||_2 ||K||_2 / sqrt(d) scale of the full score matrix."""
    bounds = jnp.stack(
        [delta_a_bound(q_sigmas_sq, k_sigmas_sq, r, d_head) for r in rank_grid],
        axis=-1)
    norm = (jnp.sqrt(q_sigmas_sq[..., 0]) * jnp.sqrt(k_sigmas_sq[..., 0])
            / jnp.sqrt(float(d_head)))
    return bounds, norm
