"""Spectral machinery for DR-RL low-rank attention (TPU-native).

The paper computes batched partial SVDs of attention factors with cuSOLVER
(GPU). On TPU we instead work with the tiny d_h x d_h Gram matrices of Q/K/V:
their eigenvalues are the squared singular values and their top-r eigenvectors
give the optimal rank-r column-space projector (see DESIGN.md section 3).
Everything here is matmul/eigh on (..., d, d) shapes - no n x n matrix is ever
materialised.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def gram(x: jnp.ndarray) -> jnp.ndarray:
    """x: (..., n, d) -> Gram (..., d, d) in fp32."""
    xf = x.astype(jnp.float32)
    return jnp.einsum("...nd,...ne->...de", xf, xf)


def gram_spectrum(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Eigendecomposition of a PSD Gram matrix.

    Returns (sigmas_sq, eigvecs) with sigmas_sq sorted DESCENDING;
    sigmas_sq[i] == sigma_i(x)^2 for the underlying factor x.
    eigvecs[..., :, i] is the i-th right singular vector of x.
    """
    evals, evecs = jnp.linalg.eigh(g.astype(jnp.float32))   # ascending
    evals = jnp.flip(evals, axis=-1)
    evecs = jnp.flip(evecs, axis=-1)
    return jnp.maximum(evals, 0.0), evecs


def singular_values(x: jnp.ndarray) -> jnp.ndarray:
    """Descending singular values of (..., n, d) via the Gram route."""
    s2, _ = gram_spectrum(gram(x))
    return jnp.sqrt(s2)


def ner_curve(sigmas_sq: jnp.ndarray) -> jnp.ndarray:
    """Normalized Energy Ratio (paper Eq. 14) for every rank r=1..d.

    sigmas_sq: (..., d) descending. Returns (..., d) with
    NER[r-1] = sum_{i<=r} sigma_i^2 / sum_j sigma_j^2.
    """
    total = jnp.sum(sigmas_sq, axis=-1, keepdims=True)
    return jnp.cumsum(sigmas_sq, axis=-1) / jnp.maximum(total, 1e-30)


def rank_for_energy(sigmas_sq: jnp.ndarray, threshold: float,
                    r_min: int, r_max: int) -> jnp.ndarray:
    """Adaptive-SVD baseline: smallest r whose NER >= threshold (clipped)."""
    ner = ner_curve(sigmas_sq)
    r = 1 + jnp.argmax(ner >= threshold, axis=-1)   # first index meeting it
    # if never met (numerical), fall back to r_max
    met = jnp.any(ner >= threshold, axis=-1)
    r = jnp.where(met, r, r_max)
    return jnp.clip(r, r_min, r_max).astype(jnp.int32)


def rank_mask(d: int, r) -> jnp.ndarray:
    """(d,) float mask keeping the first r eigendirections. r may be traced."""
    return (jnp.arange(d) < r).astype(jnp.float32)


def project_masked(x: jnp.ndarray, evecs: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Rank-truncate x (..., n, d) with eigvecs (..., d, d) and mask (..., d).

    Returns x_r = x . E diag(mask) E^T  (same shape as x). This is the
    'masked' realisation: a single static-shape executable where dynamic rank
    is expressed through the mask (differentiable, RL-training friendly).
    """
    xe = jnp.einsum("...nd,...de->...ne", x.astype(jnp.float32), evecs)
    xe = xe * mask[..., None, :]
    out = jnp.einsum("...ne,...de->...nd", xe, evecs)
    return out.astype(x.dtype)


def project_static(x: jnp.ndarray, evecs: jnp.ndarray, r: int) -> jnp.ndarray:
    """Rank-r factor x~ = x . E[:, :r]  of shape (..., n, r) (static shapes).

    Used by the serving buckets / Pallas kernel: the score contraction runs
    over r instead of d.
    """
    return jnp.einsum("...nd,...dr->...nr", x.astype(jnp.float32),
                      evecs[..., :, :r]).astype(x.dtype)


def mixing_matrix(eq: jnp.ndarray, ek: jnp.ndarray, r: int) -> jnp.ndarray:
    """M = Eq[:, :r]^T Ek[:, :r] (..., r, r) so that
    Q_r K_r^T == (Q Eq_r) M (K Ek_r)^T with rank-r factors on both sides."""
    return jnp.einsum("...dr,...ds->...rs", eq[..., :, :r], ek[..., :, :r])


# ---------------------------------------------------------------------------
# Matmul-only spectral routines (subspace/power iteration)
# ---------------------------------------------------------------------------

def subspace_iteration(g: jnp.ndarray, r: int, iters: int = 3,
                       key: Optional[jax.Array] = None,
                       oversample: int = 4) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-r eigenpairs of PSD g (..., d, d) via subspace (block power) iteration.

    Pure matmuls + small QR: the MXU-native alternative to eigh used on the
    serving path. The block is oversampled by ``oversample`` columns so the
    convergence rate is set by the spectral gap at r+p rather than at r
    (near-degenerate clusters at the cut make the bare-r iteration stall);
    only the top r pairs are returned. Returns (evals_desc (..., r),
    basis (..., d, r))."""
    d = g.shape[-1]
    p = min(oversample, d - r)
    if key is None:
        key = jax.random.PRNGKey(0)
    q0 = jax.random.normal(key, g.shape[:-2] + (d, r + p), jnp.float32)
    q, _ = jnp.linalg.qr(q0)

    def body(q, _):
        z = jnp.einsum("...de,...er->...dr", g, q)
        q, _ = jnp.linalg.qr(z)
        return q, None

    q, _ = jax.lax.scan(body, q, None, length=iters)
    # Rayleigh-Ritz on the subspace
    h = jnp.einsum("...dr,...de,...es->...rs", q, g, q)
    evals, u = jnp.linalg.eigh(h)
    evals = jnp.flip(evals, axis=-1)[..., :r]
    u = jnp.flip(u, axis=-1)[..., :r]
    basis = jnp.einsum("...dr,...rs->...ds", q, u)
    return jnp.maximum(evals, 0.0), basis


def incremental_extend(g: jnp.ndarray, basis_r: jnp.ndarray, extra: int,
                       iters: int = 3, key: Optional[jax.Array] = None
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Incremental SVD update (paper Eq. 12), TPU form.

    Given the cached top-r eigenbasis of g, compute `extra` further
    eigenpairs by subspace iteration on the deflated operator
    (I - B B^T) g (I - B B^T). Returns (new_evals (..., extra),
    extended_basis (..., d, r+extra)). Cost ~ (r'-r)/r' of a fresh solve."""
    if key is None:
        key = jax.random.PRNGKey(1)
    d = g.shape[-1]
    b = basis_r.astype(jnp.float32)

    def deflate(v):
        return v - jnp.einsum("...dr,...er,...es->...ds", b, b, v)

    q0 = deflate(jax.random.normal(key, g.shape[:-2] + (d, extra), jnp.float32))
    q, _ = jnp.linalg.qr(q0)

    def body(q, _):
        z = deflate(jnp.einsum("...de,...er->...dr", g, q))
        q, _ = jnp.linalg.qr(z)
        return q, None

    q, _ = jax.lax.scan(body, q, None, length=iters)
    h = jnp.einsum("...dr,...de,...es->...rs", q, g, q)
    evals, u = jnp.linalg.eigh(h)
    evals = jnp.flip(evals, axis=-1)
    u = jnp.flip(u, axis=-1)
    new_basis = jnp.einsum("...dr,...rs->...ds", q, u)
    return jnp.maximum(evals, 0.0), jnp.concatenate([b, new_basis], axis=-1)


def power_iteration_specnorm(m: jnp.ndarray, iters: int = 3,
                             key: Optional[jax.Array] = None) -> jnp.ndarray:
    """Spectral norm of (..., a, b) via power iteration on M^T M (paper Eq. 16)."""
    if key is None:
        key = jax.random.PRNGKey(2)
    mf = m.astype(jnp.float32)
    v = jax.random.normal(key, m.shape[:-2] + (m.shape[-1],), jnp.float32)
    v = v / (jnp.linalg.norm(v, axis=-1, keepdims=True) + 1e-30)

    def body(v, _):
        mv = jnp.einsum("...ab,...b->...a", mf, v)
        mtmv = jnp.einsum("...ab,...a->...b", mf, mv)
        v = mtmv / (jnp.linalg.norm(mtmv, axis=-1, keepdims=True) + 1e-30)
        return v, None

    v, _ = jax.lax.scan(body, v, None, length=iters)
    mv = jnp.einsum("...ab,...b->...a", mf, v)
    return jnp.linalg.norm(mv, axis=-1)
