"""Greedy oracle for Behaviour-Cloning warm start (paper 4.5.3).

The oracle evaluates the Eq. 13 reward for *every* candidate rank in the grid
(it can afford the exhaustive sweep offline) and returns the argmax action.
Fidelity is computed exactly: cosine similarity between the full-rank
attention output and the rank-r output, per (batch, kv-head).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp

from repro.configs.base import RankConfig
from repro.core import perturbation as pert
from repro.core.rewards import reward
from repro.models.attention import attend, apply_rank_masked, spectral_ctx
from repro.models.common import repeat_kv


def oracle_actions(rank_cfg: RankConfig, q: jnp.ndarray, k: jnp.ndarray,
                   v: jnp.ndarray, *, causal: bool = True
                   ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """q: (b, s, hq, d), k/v: (b, s, hkv, d). Returns (action_idx (b, hkv),
    aux with per-candidate rewards)."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    n_rep = hq // hkv
    scale = d ** -0.5
    ctx = spectral_ctx(q, k)
    o_full = attend(q, repeat_kv(k, n_rep), repeat_kv(v, n_rep),
                    scale=scale, causal=causal)

    q_s2 = (ctx["q_s2"].reshape(b, hkv, n_rep, d).mean(2)
            if hq != hkv else ctx["q_s2"])
    bounds, norm = pert.guardrail_report(q_s2, ctx["k_s2"], rank_cfg.rank_grid, d)
    bounds_rel = bounds / jnp.maximum(norm[..., None], 1e-30)

    rewards = []
    for gi, r in enumerate(rank_cfg.rank_grid):
        rank_k = jnp.full((b, hkv), r, jnp.int32)
        rank_q = jnp.repeat(rank_k, n_rep, axis=1) if n_rep > 1 else rank_k
        q_r, k_r = apply_rank_masked(q, k, ctx, rank_q, rank_k)
        o_r = attend(q_r, repeat_kv(k_r, n_rep), repeat_kv(v, n_rep),
                     scale=scale, causal=causal)
        num = jnp.sum(o_full.astype(jnp.float32) * o_r.astype(jnp.float32),
                      axis=(1, 3))
        den = (jnp.linalg.norm(o_full.astype(jnp.float32), axis=(1, 3))
               * jnp.linalg.norm(o_r.astype(jnp.float32), axis=(1, 3)) + 1e-30)
        fid = (num / den)                                  # (b, hq)
        fid_kv = fid.reshape(b, hkv, n_rep).mean(-1) if n_rep > 1 else fid
        rw = reward(rank_cfg, fid_kv, rank_k, bounds_rel[..., gi], d, d)
        rewards.append(rw)
    rewards = jnp.stack(rewards, axis=-1)                  # (b, hkv, G)
    return jnp.argmax(rewards, axis=-1), {
        "rewards": rewards, "bounds_rel": bounds_rel}
