"""DR-RL reward function (paper Eq. 8 / Eq. 13).

R_t = alpha * sim(A_full, A_r)  -  beta * FLOPs(r_t)  -  gamma * ||dA||_F

* sim       — cosine similarity between full-rank and rank-r attention
              *outputs* (computed in the model forward when
              rank_ctx['compute_fidelity'] is set).
* FLOPs(r)  — normalised score+value FLOPs at rank r relative to full rank.
* ||dA||_F  — the Eq. 9 perturbation bound at the chosen rank, normalised by
              the full-score scale so the penalty is dimensionless.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.configs.base import RankConfig


def flops_fraction(rank: jnp.ndarray, d_head: int, d_v: int) -> jnp.ndarray:
    """Normalised attention FLOPs at rank r (score contraction r vs d_head;
    the value aggregation term is unchanged)."""
    full = d_head + d_v
    return (rank.astype(jnp.float32) + d_v) / float(full)


def reward(rank_cfg: RankConfig, fidelity: jnp.ndarray, rank: jnp.ndarray,
           delta_a_rel: jnp.ndarray, d_head: int, d_v: int) -> jnp.ndarray:
    """Element-wise Eq. 13 over whatever batch/head shape the inputs carry."""
    fl = flops_fraction(rank, d_head, d_v)
    return (rank_cfg.alpha * fidelity
            - rank_cfg.beta * fl
            - rank_cfg.gamma * delta_a_rel)


def reward_components(rank_cfg: RankConfig, fidelity, rank, delta_a_rel,
                      d_head: int, d_v: int) -> Tuple[jnp.ndarray, dict]:
    r = reward(rank_cfg, fidelity, rank, delta_a_rel, d_head, d_v)
    return r, {
        "fidelity": fidelity,
        "flops_frac": flops_fraction(rank, d_head, d_v),
        "delta_a_rel": delta_a_rel,
    }
