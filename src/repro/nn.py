"""Minimal pure-JAX functional NN substrate (no flax/optax available offline).

Params are nested dicts of jnp arrays. Every init_* function has a mirror
entry in repro.dist.sharding's path-based PartitionSpec rules.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}


def dt(name: str):
    return DTYPES[name]


def dense_init(rng, in_dim: int, out_dim: int, dtype=jnp.float32, scale: Optional[float] = None):
    """Truncated-normal fan-in init (matches common LLM practice)."""
    std = scale if scale is not None else in_dim ** -0.5
    w = jax.random.truncated_normal(rng, -3.0, 3.0, (in_dim, out_dim), jnp.float32) * std
    return w.astype(dtype)


def embed_init(rng, vocab: int, dim: int, dtype=jnp.float32):
    w = jax.random.truncated_normal(rng, -3.0, 3.0, (vocab, dim), jnp.float32)
    return w.astype(dtype)


def linear(x, w, b=None):
    y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def rms_norm(x, gamma, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32)).astype(dtype)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = linear(x, w_gate)
    u = linear(x, w_up)
    return linear(jax.nn.silu(g) * u, w_down)


def softmax_cross_entropy(logits, labels, mask=None, spec=None):
    """Mean token cross-entropy; logits (..., V) fp32-stabilised.

    The label log-prob is extracted with an iota-compare reduction rather
    than take_along_axis: a gather over a vocab dim sharded on 'model'
    forces GSPMD to all-gather the full-batch logits (33.9 GB/op on the
    deepseek-v3 train cell — see EXPERIMENTS.md §Perf), while the masked
    reduction stays local + one tiny psum. ``spec`` optionally pins the
    logits sharding, e.g. P(dp, None, 'model')."""
    if spec is not None:
        logits = jax.lax.with_sharding_constraint(logits, spec)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    ll = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0),
                 axis=-1)
    nll = logz - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


def tree_cast(params, dtype):
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), params)


def split_keys(rng, n: int):
    return list(jax.random.split(rng, n))
