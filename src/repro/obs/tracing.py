"""Span tracing for the serving loop: Chrome trace-event JSON.

Two timelines share one event buffer:

* **Per-request spans** — an async track per request id spanning
  admission -> finish/cancel, with instant events for prefill chunks,
  rank decisions, first token and speculative accept runs pinned to the
  slot's thread lane.
* **Per-step phase timeline** — each engine step is sliced into named
  phases (``schedule`` / ``admit`` / ``decide`` / ``dispatch`` /
  ``fetch`` / ``deliver``) emitted as complete ("X") events, so the gap
  between "the fused step was dispatched" and "tokens were delivered"
  is visible per step in Perfetto.

The output of :meth:`SpanTracer.chrome_trace` is the stable Chrome
trace-event format (``{"traceEvents": [...]}``): load it at
https://ui.perfetto.dev or chrome://tracing. :func:`validate_chrome_trace`
checks a document against the subset of the schema this module emits —
the bench/CI path validates every emitted trace before upload.

Everything here is host-side Python over ``time.perf_counter`` — no jax
calls, so tracing cannot introduce device syncs or recompiles (the
sanitizer's ``observability`` scenario pins that).
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

# step phases, in loop order. "schedule" covers eviction + slot harvest,
# "admit" the admission/prefill work, "decide" rank re-decisions +
# control-state sync, "dispatch" the fused step call, "fetch" the
# sanctioned host fetches, "deliver" per-slot host bookkeeping/streaming.
PHASES = ("schedule", "admit", "decide", "dispatch", "fetch", "deliver")

_VALID_PH = {"X", "B", "E", "b", "e", "n", "i", "I", "C", "M"}


class Stopwatch:
    """One wall-clock interval, optionally disabled: the shared shape of
    every timing block in the engine (compile, one-shot prefill,
    per-step token latency, run wall). ``stop()`` returns the elapsed
    seconds, or None when constructed disabled — matching the engine's
    historical ``t0 = perf_counter() if enabled else None`` idiom."""

    __slots__ = ("t0", "dt")

    def __init__(self, enabled: bool = True):
        self.t0 = time.perf_counter() if enabled else None
        self.dt: Optional[float] = None

    def stop(self) -> Optional[float]:
        if self.t0 is not None:
            self.dt = time.perf_counter() - self.t0
        return self.dt

    def __enter__(self) -> "Stopwatch":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()


class SpanTracer:
    """Bounded in-memory Chrome trace-event collector. Events beyond
    ``capacity`` are dropped (counted in ``dropped``) rather than grown
    without bound — a serving process is long-lived."""

    def __init__(self, *, pid: int = 0, capacity: int = 200_000):
        self.pid = pid
        self.capacity = capacity
        self.events: List[Dict[str, Any]] = []
        self.dropped = 0
        self._t0 = time.perf_counter()

    def now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def clear(self) -> None:
        self.events = []
        self.dropped = 0
        self._t0 = time.perf_counter()

    def _push(self, ev: Dict[str, Any]) -> None:
        if len(self.events) >= self.capacity:
            self.dropped += 1
            return
        self.events.append(ev)

    def complete(self, name: str, ts_us: float, dur_us: float, *,
                 tid: int = 0, cat: str = "step",
                 args: Optional[Dict[str, Any]] = None) -> None:
        ev = {"name": name, "ph": "X", "cat": cat, "pid": self.pid,
              "tid": tid, "ts": ts_us, "dur": max(dur_us, 0.0)}
        if args:
            ev["args"] = args
        self._push(ev)

    def instant(self, name: str, *, tid: int = 0, cat: str = "step",
                args: Optional[Dict[str, Any]] = None,
                ts_us: Optional[float] = None) -> None:
        ev = {"name": name, "ph": "i", "s": "t", "cat": cat,
              "pid": self.pid, "tid": tid,
              "ts": self.now_us() if ts_us is None else ts_us}
        if args:
            ev["args"] = args
        self._push(ev)

    def async_begin(self, name: str, aid, *, cat: str = "request",
                    args: Optional[Dict[str, Any]] = None) -> None:
        ev = {"name": name, "ph": "b", "cat": cat, "id": str(aid),
              "pid": self.pid, "tid": 0, "ts": self.now_us()}
        if args:
            ev["args"] = args
        self._push(ev)

    def async_end(self, name: str, aid, *, cat: str = "request",
                  args: Optional[Dict[str, Any]] = None) -> None:
        ev = {"name": name, "ph": "e", "cat": cat, "id": str(aid),
              "pid": self.pid, "tid": 0, "ts": self.now_us()}
        if args:
            ev["args"] = args
        self._push(ev)

    def counter(self, name: str, values: Dict[str, float],
                *, ts_us: Optional[float] = None) -> None:
        self._push({"name": name, "ph": "C", "cat": "metric",
                    "pid": self.pid, "tid": 0,
                    "ts": self.now_us() if ts_us is None else ts_us,
                    "args": dict(values)})

    def chrome_trace(self,
                     metadata: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        """The full trace document (JSON-serialisable, schema-valid)."""
        doc: Dict[str, Any] = {
            "traceEvents": list(self.events),
            "displayTimeUnit": "ms",
        }
        meta = {"dropped_events": self.dropped}
        if metadata:
            meta.update(metadata)
        doc["otherData"] = meta
        return doc


def validate_chrome_trace(doc: Any) -> List[str]:
    """Validate ``doc`` against the trace-event schema subset this module
    emits. Returns a list of problems — empty means valid. Used by the
    exporter tests and by examples/serve_observe.py before CI uploads
    the artifact."""
    errs: List[str] = []
    if not isinstance(doc, dict):
        return ["document is not a JSON object"]
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]
    open_async: Dict[tuple, int] = {}
    for n, ev in enumerate(evs):
        where = f"traceEvents[{n}]"
        if not isinstance(ev, dict):
            errs.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _VALID_PH:
            errs.append(f"{where}: bad ph {ph!r}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            errs.append(f"{where}: missing name")
        for k in ("pid", "tid"):
            if not isinstance(ev.get(k), int):
                errs.append(f"{where}: missing int {k}")
        if ph != "M" and not isinstance(ev.get("ts"), (int, float)):
            errs.append(f"{where}: missing ts")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"{where}: X event needs dur >= 0")
        if ph in ("b", "e", "n"):
            if "id" not in ev:
                errs.append(f"{where}: async event needs id")
            else:
                key = (ev.get("cat"), ev.get("name"), str(ev["id"]))
                if ph == "b":
                    open_async[key] = open_async.get(key, 0) + 1
                elif ph == "e":
                    if open_async.get(key, 0) <= 0:
                        errs.append(f"{where}: async end without begin {key}")
                    else:
                        open_async[key] -= 1
        if ph == "C" and not isinstance(ev.get("args"), dict):
            errs.append(f"{where}: counter event needs args")
    return errs


class _NullCtx:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


class _NullPhases:
    """Phase recorder used when tracing is off: ``ph("decide")`` costs
    one attribute call and returns a shared no-op context manager — the
    engine's hot loop pays nothing measurable for the instrumentation
    points."""

    __slots__ = ()
    _ctx = _NullCtx()

    def __call__(self, name: str) -> _NullCtx:
        return self._ctx


NULL_PHASES = _NullPhases()


class _PhaseCtx:
    __slots__ = ("sp", "name", "t0")

    def __init__(self, sp: "StepPhases", name: str):
        self.sp = sp
        self.name = name
        self.t0 = 0.0

    def __enter__(self):
        self.t0 = self.sp.tracer.now_us()
        return self

    def __exit__(self, *exc):
        sp, t1 = self.sp, self.sp.tracer.now_us()
        dur = t1 - self.t0
        sp.tracer.complete(self.name, self.t0, dur, tid=sp.tid,
                           cat="phase", args={"step": sp.step})
        h = sp.hists.get(self.name) if sp.hists else None
        if h is not None:
            h.observe(dur * 1e-6)
        return False


class StepPhases:
    """Live phase recorder for ONE engine step: each ``with ph(name):``
    block becomes a complete event on the step lane plus an observation
    in that phase's duration histogram."""

    __slots__ = ("tracer", "step", "hists", "tid")

    def __init__(self, tracer: SpanTracer, step: int,
                 hists: Optional[Dict[str, Any]] = None, *, tid: int = 1000):
        self.tracer = tracer
        self.step = step
        self.hists = hists
        self.tid = tid

    def __call__(self, name: str) -> _PhaseCtx:
        return _PhaseCtx(self, name)
