"""Metrics primitives for the serving stack: counters, gauges,
fixed-bucket histograms, and a per-engine registry.

Design constraints (they shape everything here):

* **Lock-free hot path.** The engine's step loop is the only writer of
  its registry shard, and every mutation is a single Python int/float
  attribute update — atomic under the GIL — so recording a metric never
  takes a lock and never calls into jax. Readers (exporters, the
  front-end stats thread, the fleet rollup) see a consistent-enough
  snapshot without stopping the writer: a counter read races at worst
  one increment behind. Only metric *creation* is locked, because two
  threads may get-or-create the same name.
* **Per-engine shards, rolled up on read.** Each ``ServeEngine`` owns
  one :class:`MetricsRegistry`. A fleet view (``Router``) does not share
  a registry across replicas — it calls :func:`aggregate` over the
  replica shards at read time, so replicas never contend.
* **Fixed buckets.** Histograms bucket at observe time (a bisect into a
  static bound table) instead of keeping raw sample lists, so memory is
  O(buckets) regardless of traffic and percentiles are O(buckets) reads.
  Percentiles are interpolated within the containing bucket and clamped
  to the observed min/max, which keeps smoke-scale estimates (a handful
  of samples) honest.

``StatsView`` is the compatibility shim: the engine's historical
``stats`` dict (``eng.stats["tokens_decoded"]`` reads, and external
``stats["decode_s"] += dt`` writes from ``repro.serve.api``) becomes a
``MutableMapping`` view over registry metrics, so every existing test,
bench key and example keeps working while the registry becomes the
single source of truth.
"""
from __future__ import annotations

import re
import threading
from bisect import bisect_left
from collections.abc import MutableMapping
from typing import Any, Dict, Iterator, List, Mapping, Optional, Sequence, Tuple, Union

Number = Union[int, float]

# geometric time buckets, 50 us .. ~104 s at factor sqrt(2): wide enough
# for compile stalls, fine enough that a p50 interpolation error is
# bounded by ~1.41x — and the regression gate compares like-for-like
# estimates against a baseline produced by this same table
DEFAULT_TIME_BUCKETS_S: Tuple[float, ...] = tuple(
    50e-6 * (2.0 ** (i / 2.0)) for i in range(43))

# small-integer buckets for discrete sizes (speculative accept runs,
# queue depths): exact counts up to 32, one overflow bucket beyond
DEFAULT_COUNT_BUCKETS: Tuple[float, ...] = tuple(float(i) for i in range(33))


class Counter:
    """Single-writer accumulator. ``value`` is a plain int or float —
    the type follows the ``init`` value, and mixed int+float arithmetic
    degrades exactly like the dict-of-numbers it replaces."""

    kind = "counter"
    __slots__ = ("name", "init", "value")

    def __init__(self, name: str, init: Number = 0):
        self.name = name
        self.init = init
        self.value = init

    def inc(self, n: Number = 1) -> None:
        self.value += n

    def set(self, v: Number) -> None:
        self.value = v

    def get(self) -> Number:
        return self.value

    def zero(self) -> None:
        self.value = self.init

    def export(self) -> Number:
        return self.value


class Gauge(Counter):
    """A value that is *set*, not accumulated (queue depth, live slots,
    effective draft window). Same storage as Counter; the distinction
    drives the Prometheus TYPE line and the fleet rollup."""

    kind = "gauge"
    __slots__ = ()


class Histogram:
    """Fixed-bucket histogram. ``bounds`` are upper bucket edges; values
    above the last bound land in one overflow bucket. Tracks count, sum,
    min and max exactly; percentiles are estimated by linear
    interpolation inside the containing bucket."""

    kind = "histogram"
    __slots__ = ("name", "bounds", "counts", "count", "total",
                 "vmin", "vmax")

    def __init__(self, name: str,
                 bounds: Optional[Sequence[float]] = None):
        self.name = name
        self.bounds: Tuple[float, ...] = tuple(
            sorted(bounds if bounds is not None else DEFAULT_TIME_BUCKETS_S))
        if not self.bounds:
            raise ValueError("histogram needs at least one bucket bound")
        self.counts: List[int] = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")

    def observe(self, v: float) -> None:
        self.counts[bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Estimated q-th percentile (q in [0, 100]); 0.0 when empty."""
        if self.count == 0:
            return 0.0
        target = (q / 100.0) * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            lo = self.bounds[i - 1] if i > 0 else min(self.vmin, self.bounds[0])
            hi = self.bounds[i] if i < len(self.bounds) else self.vmax
            lo = max(lo, self.vmin)
            hi = min(hi, self.vmax)
            if hi < lo:
                lo = hi = (self.vmin if i == 0 else self.vmax)
            if cum + c >= target:
                frac = (target - cum) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            cum += c
        return self.vmax

    def zero(self) -> None:
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")

    def export(self) -> Dict[str, Any]:
        return {
            "count": self.count,
            "sum": self.total,
            "min": self.vmin if self.count else None,
            "max": self.vmax if self.count else None,
            "mean": self.mean(),
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
        }


Metric = Union[Counter, Gauge, Histogram]

_PROM_BAD = re.compile(r"[^a-zA-Z0-9_]")


def _prom_name(namespace: str, name: str) -> str:
    return _PROM_BAD.sub("_", f"{namespace}_{name}")


class MetricsRegistry:
    """One engine's metric shard: name -> metric, get-or-create under a
    lock, every subsequent mutation lock-free (see module docstring)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[str, Metric] = {}

    def _get_or_make(self, cls, name: str, **kw) -> Metric:
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = cls(name, **kw)
                    self._metrics[name] = m
        if not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}, "
                f"requested {cls.kind}")
        return m

    def counter(self, name: str, init: Number = 0) -> Counter:
        return self._get_or_make(Counter, name, init=init)

    def gauge(self, name: str, init: Number = 0) -> Gauge:
        return self._get_or_make(Gauge, name, init=init)

    def histogram(self, name: str,
                  bounds: Optional[Sequence[float]] = None) -> Histogram:
        return self._get_or_make(Histogram, name, bounds=bounds)

    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(name)

    def metrics(self) -> List[Metric]:
        with self._lock:
            return list(self._metrics.values())

    def zero(self) -> None:
        for m in self.metrics():
            m.zero()

    def snapshot(self) -> Dict[str, Any]:
        """Point-in-time export: plain numbers for counters/gauges, a
        summary dict for histograms. Pure host Python — safe to call
        from any thread, any time, including crash paths."""
        return {m.name: m.export() for m in self.metrics()}

    def prometheus_text(self, namespace: str = "repro") -> str:
        """Prometheus text exposition (one scrape body)."""
        lines: List[str] = []
        for m in sorted(self.metrics(), key=lambda m: m.name):
            pname = _prom_name(namespace, m.name)
            if isinstance(m, Histogram):
                lines.append(f"# TYPE {pname} histogram")
                cum = 0
                for bound, c in zip(m.bounds, m.counts):
                    cum += c
                    lines.append(f'{pname}_bucket{{le="{bound:g}"}} {cum}')
                lines.append(f'{pname}_bucket{{le="+Inf"}} {m.count}')
                lines.append(f"{pname}_sum {m.total:g}")
                lines.append(f"{pname}_count {m.count}")
            else:
                lines.append(f"# TYPE {pname} {m.kind}")
                lines.append(f"{pname} {m.value:g}")
        return "\n".join(lines) + "\n"


def aggregate_registry(
        registries: Sequence[MetricsRegistry]) -> MetricsRegistry:
    """Merge per-replica shards into a fresh registry at read time:
    counters and gauges sum; histograms with identical bounds merge
    bucket-wise (count/sum/min/max exact, percentiles re-estimated over
    the merged buckets). Metrics absent from some replicas contribute
    only where present. The result is a detached copy — exporting or
    mutating it never touches the source shards."""
    out = MetricsRegistry()
    merged = out._metrics
    for reg in registries:
        for m in reg.metrics():
            have = merged.get(m.name)
            if have is None:
                if isinstance(m, Histogram):
                    h = Histogram(m.name, m.bounds)
                    h.counts = list(m.counts)
                    h.count, h.total = m.count, m.total
                    h.vmin, h.vmax = m.vmin, m.vmax
                    merged[m.name] = h
                else:
                    c = type(m)(m.name, init=m.init)
                    c.value = m.value
                    merged[m.name] = c
            elif isinstance(m, Histogram):
                if not isinstance(have, Histogram) or have.bounds != m.bounds:
                    raise TypeError(
                        f"cannot merge histogram {m.name!r}: bounds differ")
                have.counts = [a + b for a, b in zip(have.counts, m.counts)]
                have.count += m.count
                have.total += m.total
                have.vmin = min(have.vmin, m.vmin)
                have.vmax = max(have.vmax, m.vmax)
            else:
                have.value += m.value
    return out


def aggregate(registries: Sequence[MetricsRegistry]) -> Dict[str, Any]:
    """Fleet rollup snapshot (see :func:`aggregate_registry`)."""
    return aggregate_registry(registries).snapshot()


class StatsView(MutableMapping):
    """Dict-compatible view over registry metrics: the engine's legacy
    ``stats`` surface. Keys are fixed at construction (the historical
    stat names); reads and ``stats[k] = v`` / ``stats[k] += v`` writes
    go straight to the backing Counter/Gauge. Re-binding the same keys
    on an existing registry (engine reset) re-zeroes them to their init
    values — exactly the semantics of rebuilding the old dict."""

    __slots__ = ("_metrics",)

    def __init__(self, registry: MetricsRegistry, init: Mapping[str, Number],
                 *, prefix: str = "serve", gauges: Sequence[str] = ()):
        metrics: Dict[str, Counter] = {}
        for k, v in init.items():
            cls = Gauge if k in gauges else Counter
            m = registry._get_or_make(cls, f"{prefix}.{k}", init=v)
            m.init = v
            m.value = v
            metrics[k] = m
        object.__setattr__(self, "_metrics", metrics)

    def __getitem__(self, k: str) -> Number:
        return self._metrics[k].value

    def __setitem__(self, k: str, v: Number) -> None:
        self._metrics[k].value = v

    def __delitem__(self, k: str) -> None:
        raise TypeError("StatsView keys are fixed at construction")

    def __iter__(self) -> Iterator[str]:
        return iter(self._metrics)

    def __len__(self) -> int:
        return len(self._metrics)

    def __repr__(self) -> str:
        return f"StatsView({dict(self)!r})"

    def reset(self) -> None:
        for m in self._metrics.values():
            m.zero()
