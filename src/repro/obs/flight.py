"""Flight recorder: a bounded ring of recent engine events, dumped to
disk on failure so a stranded fleet is debuggable after the fact.

The ring is always on — recording is one ``deque.append`` of a small
dict (capacity-bounded, oldest events evicted), cheap enough to leave
enabled in production. Dumping only happens when a dump *directory* was
configured (``EngineConfig(flight_dir=...)``) or an explicit path is
passed, and is triggered from three places:

* ``FrontEnd``'s stepping thread catching a step exception (the moment
  every outstanding handle is about to be aborted with ``EngineStopped``),
* ``FrontEnd.shutdown()`` (normal teardown — the last-breath state),
* ``repro.serve.api.Engine.reset()`` when it strands unfinished handles.

A dump is pure host Python over already-host data: the event ring plus a
registry snapshot. It never touches jax, so it is safe to call from an
exception handler in any thread.
"""
from __future__ import annotations

import json
import os
import time
from collections import deque
from typing import Any, Deque, Dict, Optional

FLIGHT_SCHEMA_VERSION = 1


class FlightRecorder:
    """Bounded event ring + crash-dump writer for one engine."""

    def __init__(self, capacity: int = 256,
                 directory: Optional[str] = None, name: str = "engine"):
        if capacity < 1:
            raise ValueError(f"flight capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.directory = directory
        self.name = name
        self.events: Deque[Dict[str, Any]] = deque(maxlen=capacity)
        self.n_recorded = 0
        self.n_dumps = 0
        self._t0 = time.perf_counter()

    def record(self, kind: str, **fields: Any) -> None:
        """Append one event. ``fields`` must be JSON-serialisable (the
        engine passes ints/floats/strings only)."""
        ev = {"t_s": round(time.perf_counter() - self._t0, 6), "kind": kind}
        ev.update(fields)
        self.events.append(ev)
        self.n_recorded += 1

    def dump(self, reason: str, *, metrics: Optional[Dict[str, Any]] = None,
             error: Optional[BaseException] = None,
             path: Optional[str] = None) -> Optional[str]:
        """Write the ring (plus a metrics snapshot) to disk. Returns the
        path written, or None when no directory/path is configured.
        Never raises: a crash dump failing must not mask the crash."""
        if path is None:
            if not self.directory:
                return None
            fname = (f"flight_{self.name}_{self.n_dumps:03d}"
                     f"_pid{os.getpid()}.json")
            path = os.path.join(self.directory, fname)
        doc = {
            "schema": FLIGHT_SCHEMA_VERSION,
            "reason": reason,
            "error": repr(error) if error is not None else None,
            "wall_time": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "engine": self.name,
            "events_recorded": self.n_recorded,
            "events": list(self.events),
            "metrics": metrics or {},
        }
        try:
            d = os.path.dirname(path)
            if d:
                os.makedirs(d, exist_ok=True)
            with open(path, "w") as f:
                json.dump(doc, f, indent=1, default=str)
        except OSError:
            return None
        self.n_dumps += 1
        return path
