"""Observability facade: one object per engine bundling the metrics
registry shard, the optional span tracer, and the flight recorder.

The engine calls the ``on_*`` hooks from its step loop with values it
**already holds on host** (slot ids, host token counts, wall-clock
deltas): every hook is pure host Python — dict lookups, int/float
adds, deque appends — with zero jax calls, so observability can stay ON
in steady state without adding device syncs or executables (the
sanitizer's ``observability`` scenario runs the steady loop with
tracing enabled under ``jax.transfer_guard("disallow")`` and a compile
counter to pin exactly that).

Rank telemetry is the one place device values are involved, and it is
**export-time only**: :meth:`Observability.rank_telemetry` derives the
kept-rank series, switch counts and factor-read bytes/token from the
engine's ``rank_history`` (device arrays the loop already keeps,
appended without synchronisation) and fetches the per-decision Eq. 9
veto flags — device booleans the jitted ``decide`` call returns and the
engine banks unfetched — in one batched ``device_get`` when a report is
actually requested. The fused loop never gains a host sync (invariant
R1) no matter which observability features are enabled.
"""
from __future__ import annotations

import itertools
from typing import Any, Dict, List, Optional

from repro.obs.flight import FlightRecorder
from repro.obs.metrics import (DEFAULT_COUNT_BUCKETS, MetricsRegistry,
                               StatsView)
from repro.obs.tracing import (NULL_PHASES, PHASES, SpanTracer, StepPhases,
                               Stopwatch)

__all__ = ["Observability", "Stopwatch"]

_ENGINE_SEQ = itertools.count()


class Observability:
    """Per-engine observability bundle. Always constructed (the registry
    and flight ring are cheap and always on); span/phase tracing is
    opt-in via ``trace=True`` because it allocates an event per step
    phase and per request milestone."""

    def __init__(self, *, trace: bool = False, trace_capacity: int = 200_000,
                 flight_dir: Optional[str] = None,
                 flight_capacity: int = 256,
                 engine_id: Optional[int] = None):
        self.engine_id = (next(_ENGINE_SEQ) if engine_id is None
                          else engine_id)
        self.registry = MetricsRegistry()
        self.tracer = (SpanTracer(pid=self.engine_id,
                                  capacity=trace_capacity)
                       if trace else None)
        self.flight = FlightRecorder(flight_capacity, flight_dir,
                                     name=f"engine{self.engine_id}")
        r = self.registry
        # histograms: TTFT and per-token decode latency feed the bench
        # percentiles; accept-run lengths are small discrete counts
        self.ttft_hist = r.histogram("serve.ttft_s")
        self.latency_hist = r.histogram("serve.token_latency_s")
        self.accept_hist = r.histogram("serve.accept_len",
                                       bounds=DEFAULT_COUNT_BUCKETS)
        self._phase_hists = ({p: r.histogram(f"serve.phase.{p}_s")
                              for p in PHASES} if trace else None)
        # request + rank control-plane counters (the per-step token/stat
        # counters live behind the engine's StatsView — same registry)
        self._c_admitted = r.counter("requests.admitted")
        self._c_finished = r.counter("requests.finished")
        self._c_cancelled = r.counter("requests.cancelled")
        self._c_decisions = r.counter("rank.decisions")
        self._c_refreshes = r.counter("rank.basis_refreshes")
        self._c_forced = r.counter("rank.forced_decides")
        self._c_drift = r.counter("rank.drift_triggers")
        self._c_veto = r.counter("rank.veto_fires")
        self._g_queue = r.gauge("queue.depth")
        self._g_live = r.gauge("slots.live")
        self._g_prefix_nodes = r.gauge("prefix.nodes")
        self._g_prefix_pages = r.gauge("prefix.pages")

    # -- engine wiring ----------------------------------------------------

    def stats_view(self, init: Dict[str, Any],
                   gauges=("eff_draft_k",)) -> StatsView:
        """The engine's legacy ``stats`` surface as a registry view (and
        the reset path: re-binding zeroes the backing metrics)."""
        return StatsView(self.registry, init, prefix="serve", gauges=gauges)

    def reset_run(self) -> None:
        """Engine reset: clear the per-run trace buffer (the flight ring
        deliberately survives — it exists for post-mortems)."""
        if self.tracer is not None:
            self.tracer.clear()

    def step_phases(self, step: int):
        """Phase recorder for one step; a shared no-op when tracing is
        off so the loop pays one attribute check per step."""
        if self.tracer is None:
            return NULL_PHASES
        return StepPhases(self.tracer, step, self._phase_hists)

    # -- hooks (host values only; called from the step loop) --------------

    def on_admit(self, rid: int, slot: int, prompt_len: int, *,
                 reused: int = 0, queued: int = 0, live: int = 0) -> None:
        self._c_admitted.inc()
        self._g_queue.set(queued)
        self._g_live.set(live)
        self.flight.record("admit", rid=rid, slot=slot,
                           prompt_len=prompt_len, reused=reused)
        if self.tracer is not None:
            self.tracer.async_begin("request", rid,
                                    args={"rid": rid, "slot": slot,
                                          "prompt_len": prompt_len,
                                          "prefix_reused": reused})

    def on_first_token(self, rid: int, slot: int, ttft_s: float) -> None:
        self.ttft_hist.observe(ttft_s)
        if self.tracer is not None:
            self.tracer.instant("first_token", tid=slot, cat="request",
                                args={"rid": rid,
                                      "ttft_ms": ttft_s * 1e3})

    def on_finish(self, rid: int, slot: int, n_out: int,
                  reason: str) -> None:
        (self._c_cancelled if reason == "cancel"
         else self._c_finished).inc()
        self.flight.record("finish", rid=rid, slot=slot, n_out=n_out,
                           reason=reason)
        if self.tracer is not None:
            self.tracer.async_end("request", rid,
                                  args={"rid": rid, "n_out": n_out,
                                        "reason": reason})

    def on_decide(self, slot: int, seg_t: int, *,
                  forced: bool = False) -> None:
        self._c_decisions.inc()
        self._c_refreshes.inc()   # every decision refreshes the basis
        if forced:
            self._c_forced.inc()
        self.flight.record("decide", slot=slot, seg_t=seg_t,
                           forced=forced)
        if self.tracer is not None:
            self.tracer.instant("rank_decide", tid=slot, cat="rank",
                                args={"slot": slot, "seg_t": seg_t,
                                      "forced": forced})

    def on_drift(self, slot: int, drift: float) -> None:
        self._c_drift.inc()
        self.flight.record("drift", slot=slot, drift=drift)
        if self.tracer is not None:
            self.tracer.instant("basis_drift", tid=slot, cat="rank",
                                args={"slot": slot, "drift": drift})

    def on_prefill_chunk(self, slot: int, rid: int, q: int,
                         prefilled: int) -> None:
        if self.tracer is not None:
            self.tracer.instant("prefill_chunk", tid=slot, cat="request",
                                args={"rid": rid, "q": q,
                                      "prefilled": prefilled})

    def on_spec_accept(self, slot: int, accepted: int,
                       drafted: int) -> None:
        self.accept_hist.observe(float(accepted))
        if self.tracer is not None:
            self.tracer.instant("spec_accept", tid=slot, cat="spec",
                                args={"slot": slot, "accepted": accepted,
                                      "drafted": drafted})

    def on_token_latency(self, dt_s: float) -> None:
        self.latency_hist.observe(dt_s)

    def set_prefix_size(self, nodes: int, pages: int) -> None:
        self._g_prefix_nodes.set(nodes)
        self._g_prefix_pages.set(pages)

    def record_event(self, kind: str, **fields: Any) -> None:
        """Free-form flight-ring event (cancellations, evictions,
        exceptions)."""
        self.flight.record(kind, **fields)

    # -- exporters (read side; any thread) --------------------------------

    def snapshot(self) -> Dict[str, Any]:
        """JSON-ready point-in-time export of this engine's shard."""
        return {
            "engine_id": self.engine_id,
            "metrics": self.registry.snapshot(),
            "trace": {
                "enabled": self.tracer is not None,
                "events": len(self.tracer.events) if self.tracer else 0,
                "dropped": self.tracer.dropped if self.tracer else 0,
            },
            "flight": {
                "events": len(self.flight.events),
                "recorded": self.flight.n_recorded,
                "dumps": self.flight.n_dumps,
            },
        }

    def prometheus(self, namespace: str = "repro") -> str:
        return self.registry.prometheus_text(namespace)

    def chrome_trace(self) -> Dict[str, Any]:
        """Chrome trace-event document (empty when tracing is off)."""
        if self.tracer is None:
            return {"traceEvents": [], "displayTimeUnit": "ms",
                    "otherData": {"dropped_events": 0}}
        return self.tracer.chrome_trace(
            metadata={"engine_id": self.engine_id})

    def flight_dump(self, reason: str, *,
                    error: Optional[BaseException] = None,
                    path: Optional[str] = None) -> Optional[str]:
        """Dump the flight ring + a registry snapshot. Host-only — safe
        from exception handlers on any thread."""
        return self.flight.dump(reason, metrics=self.registry.snapshot(),
                                error=error, path=path)

    def rank_telemetry(self, engine) -> Dict[str, Any]:
        """Export-time rank report for ``engine`` (a ServeEngine): the
        kept-rank time series, switch counts, Eq. 9 veto fires and
        factor-read bytes/token, derived from device state the loop
        already banked (``rank_history`` and the unfetched per-decision
        veto flags). The only host transfers happen HERE, at read time —
        never inside the fused loop."""
        import jax
        import numpy as np

        series = engine.ranks_per_step()          # host: -1 = off/dead
        switches = 0
        mean_rank = 0.0
        if series:
            mat = np.stack(series)                # (steps, n_slots)
            live = mat >= 0
            mean_rank = float(mat[live].mean()) if live.any() else 0.0
            for j in range(mat.shape[1]):
                col = mat[live[:, j], j]
                if col.size > 1:
                    switches += int((np.diff(col) != 0).sum())
        pend = getattr(engine, "_veto_pending", ())
        veto = 0
        if len(pend):
            flags = jax.device_get(list(pend))
            veto = int(sum(bool(f) for f in flags))
        self._c_veto.set(veto)
        # analytic factor-read bytes/token for currently-live slots
        # (same formula as repro.serve.traces: L * kv_len * hkv * r * 4)
        cfg = engine.cfg
        hkv = cfg.num_kv_heads
        read_bpt = []
        if series:
            last = series[-1]
            for j, r in enumerate(last):
                if r >= 0:
                    read_bpt.append(float(cfg.num_layers)
                                    * float(engine.cache.lens[j])
                                    * hkv * float(r) * 4.0)
        return {
            "steps_recorded": len(series),
            # rank is uniform across layers in this engine (the decision
            # is driven by layer-0 spectra and applied to every layer),
            # so one series per slot IS the per-layer series
            "per_layer_uniform": True,
            "kept_rank": [[int(v) for v in row] for row in series],
            "mean_kept_rank": mean_rank,
            "rank_switches": switches,
            "veto_fires": veto,
            "basis_refreshes": self._c_refreshes.value,
            "drift_triggers": self._c_drift.value,
            "decisions": self._c_decisions.value,
            "read_bytes_per_token": (float(np.mean(read_bpt))
                                     if read_bpt else 0.0),
        }
