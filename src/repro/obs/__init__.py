"""repro.obs — low-overhead observability for the serving stack.

Four pieces, one facade:

* :mod:`repro.obs.metrics` — counters / gauges / fixed-bucket
  histograms in a lock-free per-engine :class:`MetricsRegistry` shard,
  rolled up across replicas on read (:func:`aggregate`), with
  Prometheus-text and JSON-snapshot exporters. ``StatsView`` keeps the
  engine's historical ``stats`` dict surface alive as a view over the
  registry.
* :mod:`repro.obs.tracing` — per-request span traces and the per-step
  phase timeline as Chrome trace-event JSON (Perfetto-loadable), plus
  the schema validator and the shared ``Stopwatch`` timing helper.
* :mod:`repro.obs.flight` — a bounded ring of recent events dumped to
  disk on step exceptions / ``EngineStopped`` / front-end shutdown.
* :mod:`repro.obs.core` — :class:`Observability`, the per-engine bundle
  the serving loop talks to. All hooks are host-side Python over values
  the loop already fetched: metrics/tracing ON adds zero device syncs
  and zero executables (pinned by the sanitizer ``observability``
  scenario).
"""
from repro.obs.core import Observability
from repro.obs.flight import FLIGHT_SCHEMA_VERSION, FlightRecorder
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               StatsView, aggregate, aggregate_registry)
from repro.obs.tracing import (NULL_PHASES, PHASES, SpanTracer, StepPhases,
                               Stopwatch, validate_chrome_trace)

__all__ = [
    "Observability",
    "FlightRecorder",
    "FLIGHT_SCHEMA_VERSION",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "StatsView",
    "aggregate",
    "aggregate_registry",
    "NULL_PHASES",
    "PHASES",
    "SpanTracer",
    "StepPhases",
    "Stopwatch",
    "validate_chrome_trace",
]
