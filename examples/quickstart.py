"""Quickstart: build a small DR-RL LM, train the rank agent (BC + PPO),
run a forward pass with dynamic ranks, and inspect the decisions.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.drrl import init_agent
from repro.data.synthetic import SyntheticLM
from repro.models import transformer as tr
from repro.models.api import get_model
from repro.train.rl import train_agent


def main():
    # 1. model + agent
    cfg = get_config("drrl-paper", reduced=True)
    fns = get_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    agent = init_agent(jax.random.PRNGKey(7), cfg.rank, cfg.d_model)
    data = SyntheticLM(cfg.vocab_size, 64, 4, seed=0)

    # 2. hybrid training: behaviour cloning from the greedy oracle, then PPO
    print("training rank agent (BC warm start + PPO)...")
    agent, hist = train_agent(cfg, params, agent, data, bc_steps=5,
                              ppo_steps=8, ppo_epochs=1)
    print(f"  BC loss: {hist['bc_loss'][0]:.3f} -> {hist['bc_loss'][-1]:.3f}")
    print(f"  PPO reward: {hist['ppo'][0]['reward']:.3f} -> "
          f"{hist['ppo'][-1]['reward']:.3f}")

    # 3. forward pass with dynamic ranks + the perturbation guardrail
    batch = data.batch_at(123)
    logits, aux = tr.forward_dense(
        cfg, params, batch["tokens"], policy_params=agent,
        rank_rng=jax.random.PRNGKey(1), collect_aux="ranks",
        compute_fidelity=True)
    ranks = np.asarray(aux["layers"]["rank"])            # (L, b, heads)
    fid = np.asarray(aux["layers"]["fidelity"])
    print(f"logits: {logits.shape}")
    print(f"per-layer mean rank: {ranks.mean(axis=(1, 2)).round(1)} "
          f"(grid {cfg.rank.rank_grid})")
    print(f"attention fidelity vs full rank: {fid.mean():.4f}")


if __name__ == "__main__":
    main()
