"""Batched adaptive serving: the DR-RL policy re-picks each stream's rank
bucket every segment (paper section 4.5.2), the perturbation guardrail
vetoes unsafe switches per slot, and heterogeneous ranks share ONE fused
decode executable (factor padding + rank masking — see repro.serve).

    PYTHONPATH=src python examples/serve_adaptive.py --tokens 96
"""
import argparse

import jax

from repro.configs import get_config
from repro.configs.base import RankConfig
from repro.core.drrl import init_agent
from repro.data.synthetic import SyntheticLM
from repro.launch.serve import AdaptiveServer
from repro.models.api import get_model
from repro.train.rl import train_agent


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=96)
    ap.add_argument("--segment", type=int, default=16)
    ap.add_argument("--mode", default="drrl",
                    choices=["drrl", "adaptive", "fixed", "off"])
    args = ap.parse_args()

    cfg = get_config("drrl-paper", reduced=True)
    cfg = cfg.with_(rank=RankConfig(mode=args.mode, rank_grid=(4, 8, 12, 16),
                                    fixed_rank=8, segment_len=args.segment))
    fns = get_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))

    policy = None
    if args.mode == "drrl":
        policy = init_agent(jax.random.PRNGKey(7), cfg.rank, cfg.d_model)
        data = SyntheticLM(cfg.vocab_size, 48, 2, seed=3)
        print("warm-starting policy (BC + PPO)...")
        policy, _ = train_agent(cfg, params, policy, data, bc_steps=4,
                                ppo_steps=4, ppo_epochs=1)

    server = AdaptiveServer(cfg, params, policy,
                            max_len=args.prompt_len + args.tokens + 8)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    res = server.generate(prompts, args.tokens, segment_len=args.segment)
    print(f"decoded {res['tokens'].shape[1]} tokens x {args.batch} streams "
          f"at {res['tok_per_s']:.1f} tok/s "
          f"(compile {res['compile_s']:.2f}s, prefill {res['prefill_s']:.2f}s)")
    print(f"rank schedule (per step, per stream): {res['ranks']}")
    buckets = sorted({r for step in res['ranks'] for r in step if r >= 0})
    print(f"rank buckets exercised: {buckets} (one fused executable)")


if __name__ == "__main__":
    main()
