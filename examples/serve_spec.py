"""Low-rank self-speculative decoding (repro.serve.spec): the factor
cache as a free draft model.

With ``EngineConfig(speculative=True)`` each fused step drafts
``draft_k`` tokens ahead reading only the factor cache at roughly
``draft_rank_frac`` of each stream's live rank, then verifies all of
them in ONE chunked step at the full current rank and accepts the
longest matching prefix. Speculation is exact — greedy and seeded
streams are token-identical to plain decode, which this example asserts
— so the accept rate is pure speedup: every accepted draft is a decode
step the engine never had to dispatch.

    PYTHONPATH=src python examples/serve_spec.py --tokens 24
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import RankConfig
from repro.models.api import get_model
from repro.serve import Engine, EngineConfig, SamplingParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--draft-k", type=int, default=4)
    ap.add_argument("--draft-rank-frac", type=float, default=0.25)
    ap.add_argument("--mode", default="adaptive",
                    choices=["adaptive", "fixed", "off"])
    args = ap.parse_args()

    cfg = get_config("drrl-paper", reduced=True)
    cfg = cfg.with_(rank=RankConfig(mode=args.mode, rank_grid=(4, 8, 12, 16),
                                    fixed_rank=8, segment_len=8))
    params = get_model(cfg).init(jax.random.PRNGKey(0))

    rnd = np.random.default_rng(1)
    prompts = [rnd.integers(0, cfg.vocab_size,
                            args.prompt_len).astype(np.int32)
               for _ in range(args.streams)]
    max_len = args.prompt_len + args.tokens + 8

    def serve(speculative):
        # greedy-only executable: this demo quotes wall clocks, and at toy
        # scale the sampling machinery (drafted + verified positions all
        # draw) would dominate the step; seeded sampling works identically
        # (see tests/test_serve_spec.py for the parity proof)
        eng = Engine(cfg, params, config=EngineConfig(
            n_slots=args.streams, max_len=max_len, segment_len=8,
            max_new_cap=args.tokens, prefill_chunk=8, page_size=8,
            speculative=speculative, draft_k=args.draft_k,
            draft_rank_frac=args.draft_rank_frac, sampling=False))
        # two passes: the first also absorbs the control-plane ops that
        # warmup() cannot reach; the quoted wall clock is the warm pass
        for rep in range(2):
            if rep:
                eng.reset()
            handles = [eng.submit(p, SamplingParams(max_new=args.tokens))
                       for p in prompts]
            eng.warmup()
            t0 = time.perf_counter()
            eng.run()
            wall = time.perf_counter() - t0
        return eng, handles, wall

    eng, handles, wall_spec = serve(True)
    eng_plain, handles_plain, wall_plain = serve(False)

    for h, hp in zip(handles, handles_plain):
        assert np.array_equal(h.result(), hp.result()), \
            f"rid {h.rid}: speculative decode diverged from plain decode"

    s = eng.stats
    accept_rate = s["spec_accepted"] / max(s["spec_drafted"], 1)
    mean_run = (s["spec_tokens"]
                / max(s["spec_tokens"] - s["spec_accepted"], 1))
    print(f"{args.streams} streams x {args.tokens} tokens, "
          f"draft_k={args.draft_k}, "
          f"draft_rank_frac={args.draft_rank_frac} ({args.mode} mode)")
    print(f"  exact: all streams token-identical to plain decode")
    print(f"  accept rate      : {accept_rate:.2f} "
          f"({s['spec_accepted']}/{s['spec_drafted']} drafts)")
    print(f"  mean accepted run: {mean_run:.2f} tokens per fused step "
          f"(max {args.draft_k + 1})")
    print(f"  fused steps      : {s['steps']} speculative vs "
          f"{eng_plain.stats['steps']} plain")
    # wall clock is informational at this scale: the draft's rank cut
    # saves attention/KV reads, which a toy model on CPU barely has, so
    # the win here is the fused-dispatch reduction above
    print(f"  wall clock       : {wall_spec:.2f}s speculative vs "
          f"{wall_plain:.2f}s plain "
          f"({wall_plain / max(wall_spec, 1e-9):.2f}x)")
    first = handles[0]
    print(f"  accept runs rid 0: {eng.accept_lens()[first.rid]}")


if __name__ == "__main__":
    main()
