"""Async front door + multi-replica router (repro.serve.frontend).

A 2-replica fleet behind one ``Router.submit()``: each replica runs a
background stepping thread (FrontEnd), so callers just iterate their
handles — sync (``for tok in h.tokens()``) or async
(``async for tok in h``) — while the fleet decodes continuously.

The workload is shared-system-prompt traffic in groups: one leader per
group warms a replica's radix tree, then a shuffled burst of follow-ups
arrives. Prefix-affinity dispatch probes every replica's tree and lands
each follow-up where its prefix is already cached; the same burst under
round-robin sprays groups across the fleet and re-prefills. The example
prints both dispatch policies' hit-rates and asserts affinity wins.

    PYTHONPATH=src python examples/serve_router.py --groups 2 --per-group 4
"""
import argparse
import asyncio
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import RankConfig
from repro.models.api import get_model
from repro.serve import FleetConfig, EngineConfig, Router, SamplingParams


def build_workload(args, vocab):
    rnd = np.random.default_rng(7)
    groups = [rnd.integers(0, vocab, args.system_len)
              for _ in range(args.groups)]
    tails = [[rnd.integers(0, vocab, args.user_len)
              for _ in range(args.per_group)] for _ in groups]
    prompts = [[np.concatenate([g, t]).astype(np.int32) for t in ts]
               for g, ts in zip(groups, tails)]
    order = [(g, j) for j in range(1, args.per_group)
             for g in range(args.groups)]
    return prompts, [order[k] for k in rnd.permutation(len(order))]


def drive(router, prompts, order, max_new):
    sp = SamplingParams(max_new=max_new)
    t0 = time.perf_counter()
    leaders = [router.submit(ps[0], sp) for ps in prompts]
    for h in leaders:
        h.result()                       # one warm replica per group
    burst = [router.submit(prompts[g][j], sp) for g, j in order]

    async def consume():                 # async consumption, all at once
        return await asyncio.gather(
            *[asyncio.to_thread(lambda h=h: [t for t in h.tokens()])
              for h in burst])

    outs = asyncio.run(consume())
    router.drain(60.0)
    wall = time.perf_counter() - t0
    st = router.stats()
    return outs, wall, st


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--groups", type=int, default=2)
    ap.add_argument("--per-group", type=int, default=4)
    ap.add_argument("--system-len", type=int, default=32)
    ap.add_argument("--user-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config("drrl-paper", reduced=True)
    cfg = cfg.with_(rank=RankConfig(mode="adaptive", rank_grid=(4, 8, 12, 16),
                                    segment_len=8))
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    prompts, order = build_workload(args, cfg.vocab_size)

    ecfg = EngineConfig(
        n_slots=2, max_len=args.system_len + args.user_len + args.tokens + 8,
        page_size=16, segment_len=8, max_new_cap=args.tokens,
        prefill_chunk=16, prefix_cache=True)

    results = {}
    for routing in ("affinity", "round_robin"):
        fleet = FleetConfig(engine=ecfg, n_replicas=args.replicas,
                            routing=routing, affinity_min_tokens=16,
                            idle_poll_s=0.005)
        with Router(cfg, params, fleet=fleet) as router:
            outs, wall, st = drive(router, prompts, order, args.tokens)
            agg = st["aggregate"]
            results[routing] = (outs, agg)
            print(f"{routing:>12}: hit_rate {agg['hit_rate']:.2f}  "
                  f"tokens {agg['tokens_decoded']}  wall {wall * 1e3:.0f} ms  "
                  f"routed {st['routed']}  kinds {st['route_kinds']}")

    # routing must never change the decode: token parity across policies
    for a, b in zip(results["affinity"][0], results["round_robin"][0]):
        assert a == b, "routing changed decoded tokens"
    aff, rr = results["affinity"][1], results["round_robin"][1]
    assert aff["hit_rate"] > rr["hit_rate"], \
        f"affinity {aff['hit_rate']:.2f} <= round-robin {rr['hit_rate']:.2f}"
    print(f"affinity reused {aff['hit_rate']:.0%} of prompts from a warm "
          f"replica (round-robin: {rr['hit_rate']:.0%}); tokens identical")


if __name__ == "__main__":
    main()
