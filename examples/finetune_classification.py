"""Table-3-style downstream fine-tune: classification head on a DR-RL LM,
comparing full-rank vs DR-RL vs Performer on the synthetic sentiment task.

    PYTHONPATH=src python examples/finetune_classification.py
"""
from benchmarks.table3_downstream import run

if __name__ == "__main__":
    run(ft_steps=40, quick=True)
