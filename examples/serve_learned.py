"""Close the loop on the paper's RL agent against serving traffic:
record traces -> train the rank policy offline -> serve with it.

Three acts, one script:

1. **Record** — the deterministic workload suite (repro.serve.workloads)
   is served under the adaptive spectral heuristic with
   ``EngineConfig(record_traces=...)``: every per-segment rank decision
   lands in a versioned npz trace (features + outcomes).
2. **Train**  — repro.train.serve_policy rebuilds the Eq. 6 policy
   features from the trace bit-compatibly with serving-time inference
   and trains the Transformer policy net: BC warm start, BC to the
   constrained reward oracle, then PPO. The offline replay evaluation
   prints learned vs adaptive vs oracle on the Eq. 13 reward.
3. **Serve**  — the trained checkpoint loads straight into
   ``EngineConfig(... )`` with ``mode="learned"``: the policy net runs
   device-resident inside the jitted decide executable (same zero
   steady-state recompile discipline as every other mode — the
   sanitizer's ``learned_policy`` scenario gates exactly that).

    PYTHONPATH=src python examples/serve_learned.py --tokens 12
"""
import argparse
import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import RankConfig
from repro.models.api import get_model
from repro.serve import Request, ServeEngine
from repro.serve.traces import TraceReader, TraceRecorder
from repro.serve.workloads import build, make_workload, workload_names
from repro.train.serve_policy import load_policy, train_serve_policy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=5,
                    help="requests per workload scenario")
    ap.add_argument("--tokens", type=int, default=12,
                    help="decode budget per request")
    ap.add_argument("--bc-steps", type=int, default=60)
    ap.add_argument("--ppo-steps", type=int, default=4)
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--work-dir", default=None,
                    help="keep traces + checkpoint here (default: temp)")
    args = ap.parse_args()

    grid = (4, 8, 12, 16)
    cfg = get_config("drrl-paper", reduced=True)
    acfg = cfg.with_(rank=RankConfig(mode="adaptive", rank_grid=grid,
                                     segment_len=8))
    lcfg = cfg.with_(rank=RankConfig(mode="learned", rank_grid=grid,
                                     segment_len=8))
    params = get_model(acfg).init(jax.random.PRNGKey(0))
    specs = [make_workload(n, seed=args.seed, n_requests=args.requests,
                           max_new=args.tokens, vocab=cfg.vocab_size,
                           max_prompt=40) for n in workload_names()]

    def serve_suite(run_cfg, policy_params, recorder):
        total = 0
        for spec in specs:
            eng = ServeEngine(run_cfg, params, policy_params, n_slots=4,
                              max_len=96, page_size=16, segment_len=8,
                              max_new_cap=args.tokens, prefill_chunk=8,
                              record_traces=recorder,
                              **spec.engine_overrides)
            for r in build(spec):
                eng.submit(r)
            outs = eng.run()
            assert all(0 < len(v) <= args.tokens for v in outs.values()), \
                f"{spec.name}: invalid streams"
            total += len(outs)
        recorder.flush()
        return total

    with tempfile.TemporaryDirectory() as tmp:
        base = args.work_dir or tmp
        adir, ldir, pdir = (f"{base}/trace_adaptive",
                            f"{base}/trace_learned", f"{base}/policy")

        n = serve_suite(acfg, None,
                        TraceRecorder(adir, acfg, scenario="suite"))
        print(f"recorded   : {n} requests over {workload_names()} -> "
              f"{len(TraceReader(adir))} decision records")

        _, history = train_serve_policy(
            adir, acfg.rank, out_dir=pdir,
            bc_steps=args.bc_steps, ppo_steps=args.ppo_steps)
        ev = history["eval"]
        print(f"trained    : picked {ev['picked']} "
              f"(bc {args.bc_steps} steps, ppo {args.ppo_steps} steps)")
        for name in ("adaptive", "learned", "oracle"):
            e = ev[name]
            print(f"  {name:9s}: reward {e['reward']:+.4f}  "
                  f"mean rank {e['mean_rank']:.2f}  "
                  f"agreement {e['agreement']:.3f}  "
                  f"read frac {e['read_frac']:.3f}")

        pol = load_policy(pdir)
        n = serve_suite(lcfg, pol,
                        TraceRecorder(ldir, lcfg, scenario="suite"))
        kept = TraceReader(ldir).records["chosen_rank"]
        print(f"served     : {n} requests with mode='learned' "
              f"(mean kept rank {float(np.mean(kept)):.2f}) — valid "
              f"streams, policy net device-resident in the decide step")


if __name__ == "__main__":
    main()
