"""Observability tour (repro.obs): metrics, span traces, flight dumps.

Drives one speculative, prefix-cached engine through a mixed workload —
chunked prefills, speculative accept runs, and a mid-flight
cancellation — with span/phase tracing ON, then renders every export
surface:

* a Chrome trace-event JSON (open in Perfetto / chrome://tracing):
  per-request async spans (admission -> first token -> finish), instant
  events for prefill chunks, rank decisions and speculative accepts,
  and the per-step phase timeline (schedule/admit/decide/dispatch/
  fetch/deliver);
* the Prometheus text exposition and the JSON metrics snapshot;
* the rank-telemetry report (per-layer kept-rank series, Eq. 9 veto
  fires, basis refreshes, factor-read bytes/token);
* a flight-recorder dump, forced here so the artifact shape is on show.

The trace document is validated against the trace-event schema and
round-tripped through JSON before anything is written, and the obs run
is asserted token-identical to a plain run of the same workload.

    PYTHONPATH=src python examples/serve_observe.py --out-dir obs_out
"""
import argparse
import json
import os

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import RankConfig
from repro.models.api import get_model
from repro.obs import validate_chrome_trace
from repro.serve import Engine, EngineConfig, SamplingParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=20)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--out-dir", default="obs_out")
    args = ap.parse_args()

    cfg = get_config("drrl-paper", reduced=True)
    cfg = cfg.with_(rank=RankConfig(mode="adaptive", rank_grid=(4, 8, 12, 16),
                                    fixed_rank=8, segment_len=8))
    params = get_model(cfg).init(jax.random.PRNGKey(0))

    rnd = np.random.default_rng(1)
    prompts = [rnd.integers(0, cfg.vocab_size, args.prompt_len)
               .astype(np.int32) for _ in range(args.streams)]
    max_len = args.prompt_len + args.tokens + 8
    os.makedirs(args.out_dir, exist_ok=True)

    def serve(obs_trace, flight_dir=None):
        eng = Engine(cfg, params, config=EngineConfig(
            n_slots=args.streams, max_len=max_len, segment_len=8,
            max_new_cap=args.tokens, prefill_chunk=8, page_size=8,
            speculative=True, draft_k=3,
            sampling=False, obs_trace=obs_trace, flight_dir=flight_dir))
        eng.warmup()
        hs = [eng.submit(p, SamplingParams(max_new=args.tokens))
              for p in prompts]
        # cancel the last stream a few steps in: the span trace shows an
        # admitted request ending with reason "cancel"
        for _ in range(4):
            eng.step()
        cancelled = hs[-1].cancel()
        outs = {h.rid: h.result() for h in hs[:-1]}
        return eng, outs, cancelled

    # parity: the traced run must decode the exact same tokens
    _, plain_outs, _ = serve(False)
    eng, outs, cancelled = serve(True, flight_dir=args.out_dir)
    assert cancelled, "cancellation did not land"
    assert all(np.array_equal(plain_outs[r], outs[r]) for r in outs), \
        "token streams diverged with observability enabled"

    # -- Chrome trace: validate, round-trip, write ----------------------
    doc = eng.obs.chrome_trace()
    errs = validate_chrome_trace(doc)
    assert not errs, f"trace schema violations: {errs[:5]}"
    doc = json.loads(json.dumps(doc))          # round-trip before writing
    trace_path = os.path.join(args.out_dir, "serve_trace.json")
    with open(trace_path, "w") as f:
        json.dump(doc, f)
    phases = sorted({e["name"] for e in doc["traceEvents"]
                     if e.get("cat") == "phase"})
    spans = sum(e["ph"] == "b" for e in doc["traceEvents"])
    print(f"chrome trace : {len(doc['traceEvents'])} events "
          f"({spans} request spans; phases: {', '.join(phases)}) "
          f"-> {trace_path}")

    # -- metrics: snapshot + Prometheus ---------------------------------
    snap = eng.obs.snapshot()
    snap_path = os.path.join(args.out_dir, "metrics.json")
    with open(snap_path, "w") as f:
        json.dump(snap, f, indent=2, default=str)
    prom_path = os.path.join(args.out_dir, "metrics.prom")
    with open(prom_path, "w") as f:
        f.write(eng.obs.prometheus())
    m = snap["metrics"]
    print(f"metrics      : {len(m)} series -> {snap_path}, {prom_path}")
    print(f"  admitted {m['requests.admitted']} finished "
          f"{m['requests.finished']} cancelled {m['requests.cancelled']}; "
          f"ttft samples {m['serve.ttft_s']['count']}, accept runs "
          f"{m['serve.accept_len']['count']} "
          f"(mean {m['serve.accept_len']['mean']:.2f} tok/step)")

    # -- rank telemetry -------------------------------------------------
    tel = eng.obs.rank_telemetry(eng.core)
    tel_path = os.path.join(args.out_dir, "rank_telemetry.json")
    with open(tel_path, "w") as f:
        json.dump(tel, f, indent=2)
    print(f"rank         : {tel['decisions']} decisions over "
          f"{tel['steps_recorded']} steps; mean kept rank "
          f"{tel['mean_kept_rank']:.2f}, {tel['rank_switches']} switches, "
          f"{tel['veto_fires']} veto fires -> {tel_path}")

    # -- flight recorder: force a dump so the artifact shape is visible -
    dump_path = eng.obs.flight_dump("example_dump")
    with open(dump_path) as f:
        dump = json.load(f)
    kinds = sorted({e["kind"] for e in dump["events"]})
    print(f"flight       : {dump['events_recorded']} events recorded "
          f"(kinds: {', '.join(kinds)}) -> {dump_path}")


if __name__ == "__main__":
    main()
