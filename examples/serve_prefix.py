"""Shared-prefix KV reuse (repro.serve.prefix): many users behind one
system prompt.

Every request carries the same system prefix plus a short unique user
tail. With ``EngineConfig(prefix_cache=True)`` the first stream prefills
the full prompt and caches it in the radix tree; every later stream
shares those pages (refcounted, zero attention re-run over the prefix),
rehydrates its attention-mass row from the tree's snapshot, and chunk-
prefills only its own tail — token-for-token identical to cold
admission, which this example asserts against a cache-off engine.

    PYTHONPATH=src python examples/serve_prefix.py --tokens 24
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import RankConfig
from repro.models.api import get_model
from repro.serve import Engine, EngineConfig, SamplingParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=4)
    ap.add_argument("--system-len", type=int, default=32)
    ap.add_argument("--user-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--mode", default="adaptive",
                    choices=["adaptive", "fixed", "off"])
    args = ap.parse_args()

    cfg = get_config("drrl-paper", reduced=True)
    cfg = cfg.with_(rank=RankConfig(mode=args.mode, rank_grid=(4, 8, 12, 16),
                                    fixed_rank=8, segment_len=16))
    params = get_model(cfg).init(jax.random.PRNGKey(0))

    rnd = np.random.default_rng(1)
    system = rnd.integers(0, cfg.vocab_size, args.system_len)
    prompts = [np.concatenate([system,
                               rnd.integers(0, cfg.vocab_size,
                                            args.user_len)]).astype(np.int32)
               for _ in range(args.streams)]
    max_len = args.system_len + args.user_len + args.tokens + 8
    # arrivals spaced past the first prefill so the tree is populated
    # before the followers arrive (page_size-multiple chunks give a reuse
    # point at every page)
    gap = -(-(args.system_len + args.user_len) // 16) + 2

    def serve(prefix_cache):
        eng = Engine(cfg, params, config=EngineConfig(
            n_slots=args.streams, max_len=max_len, segment_len=16,
            max_new_cap=args.tokens, prefill_chunk=16, page_size=16,
            prefix_cache=prefix_cache))
        # two passes: the first also compiles the admission-time control
        # ops (snapshot slices, rehydration, CoW) that warmup() cannot
        # reach; the quoted TTFTs come from the warm second pass, whose
        # hit pattern is identical (reset clears the tree)
        for rep in range(2):
            if rep:
                eng.reset()
            handles = [eng.submit(p, SamplingParams(max_new=args.tokens),
                                  arrival=gap * i)
                       for i, p in enumerate(prompts)]
            eng.warmup()
            eng.run()
        return eng, handles

    eng, handles = serve(True)
    eng_cold, handles_cold = serve(False)

    s = eng.stats
    for h, hc in zip(handles, handles_cold):
        assert np.array_equal(h.result(), hc.result()), \
            f"rid {h.rid}: prefix-hit decode diverged from cold admission"
    eng.core.cache.check_refs(eng.core.prefix.all_pages())

    n = args.streams
    print(f"{n} streams sharing a {args.system_len}-token system prompt; "
          f"token parity with the cache-off engine verified")
    print(f"  hits/misses      : {s['prefix_hits']}/{s['prefix_misses']}  "
          f"(reused {s['prefix_reused_tokens']} tokens, "
          f"{s['prefix_cow']} CoW pages)")
    print(f"  prefill tok/req  : {s['prefill_tokens'] / n:.1f} cached vs "
          f"{eng_cold.stats['prefill_tokens'] / n:.1f} cold "
          f"({eng_cold.stats['prefill_tokens'] / max(s['prefill_tokens'], 1):.1f}x cut)")
    for h, hc in zip(handles, handles_cold):
        tag = "hit " if eng.core.request_prefix_hit.get(h.rid) else "cold"
        print(f"  rid {h.rid} [{tag}]: TTFT {h.ttft_s * 1e3:6.1f} ms cached "
              f"vs {hc.ttft_s * 1e3:6.1f} ms cache-off; first tokens "
              f"{h.result()[:5].tolist()}")


if __name__ == "__main__":
    main()
