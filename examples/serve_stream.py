"""Streaming serving via the unified request/response API
(repro.serve.api): submit returns a RequestHandle, tokens arrive
incrementally while chunked prefill interleaves new prompts into the
fused decode step — admission never stalls the streams already decoding.

    PYTHONPATH=src python examples/serve_stream.py --tokens 48
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import RankConfig
from repro.models.api import get_model
from repro.serve import Engine, EngineConfig, SamplingParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=48)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--mode", default="adaptive",
                    choices=["adaptive", "fixed", "off"])
    args = ap.parse_args()

    cfg = get_config("drrl-paper", reduced=True)
    cfg = cfg.with_(rank=RankConfig(mode=args.mode, rank_grid=(4, 8, 12, 16),
                                    fixed_rank=8, segment_len=16))
    params = get_model(cfg).init(jax.random.PRNGKey(0))

    eng = Engine(cfg, params, config=EngineConfig(
        n_slots=args.streams,
        max_len=args.prompt_len + args.tokens + 8,
        segment_len=16, max_new_cap=args.tokens,
        prefill_chunk=args.chunk))
    rnd = np.random.default_rng(1)
    prompts = [rnd.integers(0, cfg.vocab_size, args.prompt_len)
               for _ in range(args.streams)]

    # stream 0: greedy, consumed incrementally via the handle iterator;
    # the rest: seeded temperature sampling, staggered arrivals, finished
    # in the background by the same step loop
    h0 = eng.submit(prompts[0], SamplingParams(max_new=args.tokens))
    rest = [eng.submit(p, SamplingParams(max_new=args.tokens,
                                         temperature=0.8, top_k=16,
                                         seed=100 + i),
                       arrival=2 * (i + 1))
            for i, p in enumerate(prompts[1:])]
    eng.warmup()

    got = []
    for tok in h0.tokens():          # drives eng.step() under the hood
        got.append(tok)
        if len(got) <= 5 or len(got) % 16 == 0:
            print(f"stream 0 token[{len(got) - 1:3d}] = {tok}")
    eng.run()                        # drain the sampled streams

    s = eng.stats
    tps = s["tokens_decoded"] / max(s["decode_s"], 1e-9)
    print(f"\n{args.streams} streams x {args.tokens} tokens at "
          f"{tps:.1f} tok/s (compile {s['compile_s']:.2f}s excluded); "
          f"chunked prefill: {s['mixed_steps']} mixed steps, "
          f"stall {s['stall_s'] * 1e3:.1f} ms")
    for h in [h0] + rest:
        assert h.done and len(h.result()) == args.tokens
        print(f"  rid {h.rid}: TTFT {h.ttft_s * 1e3:7.1f} ms  "
              f"temp {h.params.temperature}  first tokens "
              f"{h.result()[:6].tolist()}")


if __name__ == "__main__":
    main()
