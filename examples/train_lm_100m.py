"""End-to-end driver: train a ~100M-parameter GPT-small-class LM with DR-RL
dynamic-rank attention for a few hundred steps, with checkpointing.

Defaults are sized for this CPU container (--steps 300 takes a while; use
--steps 30 for a smoke run). On real hardware the same script scales via
the mesh flags (see repro/launch/train.py for the production path).

    PYTHONPATH=src python examples/train_lm_100m.py --steps 300
"""
import argparse

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.configs.base import RankConfig, TrainConfig
from repro.core.drrl import init_agent
from repro.data.synthetic import SyntheticLM
from repro.dist import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models.api import get_model
from repro.train.loop import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--drrl", action="store_true", default=True)
    ap.add_argument("--ckpt", default="/tmp/repro_lm100m")
    args = ap.parse_args()

    # ~100M params: GPT-small geometry (12L x 768d, 50k vocab)
    cfg = get_config("drrl-paper")         # full paper config = GPT-small
    if not args.drrl:
        cfg = cfg.with_(rank=RankConfig(mode="off"))
    fns = get_model(cfg)
    n = cfg.n_params()
    print(f"model: {cfg.name} {cfg.num_layers}L x {cfg.d_model}d "
          f"~{n / 1e6:.0f}M params, rank mode = {cfg.rank.mode}")

    agent = None
    if cfg.rank.mode == "drrl":
        agent = init_agent(jax.random.PRNGKey(7), cfg.rank, cfg.d_model)

    tc = TrainConfig(global_batch=args.batch, seq_len=args.seq, lr=3e-4,
                     total_steps=args.steps,
                     warmup_steps=max(args.steps // 20, 1),
                     checkpoint_every=max(args.steps // 3, 1),
                     checkpoint_dir=args.ckpt)
    data = SyntheticLM(cfg.vocab_size, tc.seq_len, tc.global_batch, seed=0)
    ckpt = CheckpointManager(tc.checkpoint_dir)
    mesh = make_host_mesh()

    def loss_fn(p, b, rng):
        extra = ({"policy_params": agent, "rank_rng": rng}
                 if cfg.rank.mode == "drrl" else {})
        return fns.loss(p, b, **extra)

    import numpy as np
    with mesh:
        pshape = jax.eval_shape(fns.init, jax.random.PRNGKey(0))
        pspecs = shd.param_pspecs(pshape, cfg, mesh)
        n_exact = sum(int(np.prod(s.shape))
                      for s in jax.tree_util.tree_leaves(pshape))
        print(f"param count (exact): {n_exact / 1e6:.1f}M")
        out = run_training(cfg, tc, init_fn=fns.init, loss_fn=loss_fn,
                           data=data, ckpt_manager=ckpt, param_specs=pspecs)
    h = out["history"]
    print(f"loss: {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} over "
          f"{args.steps} steps")


if __name__ == "__main__":
    main()
