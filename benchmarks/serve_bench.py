"""Serving benchmark: continuous batching vs sequential lock-step decode.

Synthetic multi-user workload — mixed prompt lengths, staggered arrivals —
decoded twice:

  * **engine**: one ServeEngine with n_slots concurrent lanes (the
    continuous-batching path: slot-paged cache, per-slot dynamic ranks,
    one fused executable);
  * **sequential**: the same requests served one at a time through a
    1-slot ``repro.serve.api.Engine`` (per-request lock-step decode), the
    way a single-stream server would drain the queue.

Both sides are warmed first; compilation is reported separately and
excluded from throughput. Emits aggregate tok/s and p50/p95 per-token
decode latency as JSON to BENCH_serve.json.

A third section compares the **factor-form paged K cache** (kt = K . B_r)
against the dense paged path: token parity is asserted at full rank, and
the score-contraction read bytes per decoded token are recorded for a
low-rank serving grid (r_max/d of the dense K bytes; the wall-clock gap
only opens on accelerators where decode is KV-bandwidth bound — CPU toy
scale is dispatch-bound).

A fourth section compares **interleaved (chunked) vs blocking (one-shot)
prefill admission** on the same staggered workload: token parity between
the two admission modes is asserted, and per-request TTFT p50/p95 plus
the decode-stall seconds (wall time spent in monolithic prefills while
other streams had decode work pending — identically zero for chunked
admission) land in BENCH_serve.json.

A fifth section drives a **shared-system-prompt workload** through the
prefix cache (repro.serve.prefix) and its cache-off twin: prefix-hit vs
cold token parity and the refcount invariant are asserted, and the
section records hit rate, prefill tokens computed per request (>= 2x
reduction asserted) and TTFT p50/p95 split hot vs cold.

A ``learned_policy`` section closes the loop on the paper's RL agent
against serving traffic: the deterministic workload suite
(repro.serve.workloads) is served under the adaptive heuristic with the
trace recorder on, repro.train.serve_policy trains the policy net
offline on that trace, and the suite is replayed with ``mode="learned"``
— the Eq. 13 reward gain over the heuristic (at equal-or-lower mean kept
rank) and the replay validity land in the JSON for check_bench to gate.

    PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np


def compile_guard() -> dict:
    """Runtime sanitizer lane (repro.analysis.sanitizer) in a fresh
    subprocess: transfer-guarded fused steps plus warm/steady compile
    counts.  A subprocess because compile counting must start from an
    empty executable cache — the bench process has already compiled
    dozens of step variants by the time this section runs.

    The counts are deterministic (same engines, same shape layout every
    run), so check_bench gates them exactly: steady_new_executables
    must be 0 and warm_executables must not grow past the committed
    baseline."""
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.sanitizer", "--json"],
        capture_output=True, text=True, env=env)
    try:
        doc = json.loads(proc.stdout)
    except json.JSONDecodeError:
        return {"ok": False,
                "error": (proc.stderr or proc.stdout)[-2000:]}
    out = {"ok": doc["ok"]}
    for res in doc["scenarios"]:
        out[res["scenario"]] = {
            k: res.get(k) for k in ("warm_executables",
                                    "steady_new_executables",
                                    "transfer_guard", "ok", "error")
            if k in res}
    return out


def obs_overhead(cfg, params, workload, n_slots: int, max_len: int):
    """Observability overhead, report-only: the identical workload with
    span/phase tracing OFF (metrics registry still on — it always is)
    vs ON. Token parity is asserted; tok/s both ways and the ratio are
    recorded as ``info`` rows so drift is visible in review without
    gating CI on sub-millisecond host timing noise."""
    from repro.serve import Request, ServeEngine

    def drive(obs_trace):
        eng = ServeEngine(cfg, params, n_slots=n_slots, max_len=max_len,
                          page_size=16, segment_len=8,
                          max_new_cap=max(w["max_new"] for w in workload),
                          prefill_chunk=8, obs_trace=obs_trace)
        for w in workload:
            eng.submit(Request(**w))
        eng.warmup()
        outs = eng.run()
        tok_s = eng.stats["tokens_decoded"] / max(eng.stats["decode_s"], 1e-9)
        return outs, tok_s, eng

    outs_off, tok_off, _ = drive(False)
    outs_on, tok_on, eng_on = drive(True)
    parity = all(np.array_equal(outs_off[w["rid"]], outs_on[w["rid"]])
                 for w in workload)
    assert parity, "token streams diverged with obs tracing enabled"
    doc = eng_on.obs.chrome_trace()
    snap = eng_on.obs.snapshot()
    return {
        "parity": parity,
        "tok_per_s_off": tok_off,
        "tok_per_s_on": tok_on,
        "on_off_ratio": tok_on / max(tok_off, 1e-9),
        "trace_events": len(doc["traceEvents"]),
        "trace_dropped": doc["otherData"]["dropped_events"],
        "ttft_count": snap["metrics"]["serve.ttft_s"]["count"],
    }


def build_workload(n_requests: int, max_new: int, seed: int = 0):
    """Mixed prompt lengths (8..32), arrivals staggered every 2 steps."""
    rnd = np.random.default_rng(seed)
    lens = rnd.choice([8, 12, 16, 24, 32], size=n_requests)
    return [dict(rid=i, tokens=rnd.integers(0, 256, int(s)).astype(np.int32),
                 max_new=max_new, arrival=2 * i)
            for i, s in enumerate(lens)]


def factor_compare(cfg, params, workload, n_slots: int, max_len: int):
    """Factored vs dense paged decode.

    Runs the same workload through four engines: a full-rank pair whose
    token outputs must be IDENTICAL (the factor path changes the memory
    layout, not the math), and a low-rank pair (grid top = dh/2) whose
    score-contraction read-bytes-per-token quantify the r/d bandwidth cut.
    """
    from repro.configs.base import RankConfig
    from repro.serve import Request, ServeEngine

    dh = cfg.resolved_head_dim()
    hkv = cfg.num_kv_heads

    def drive(rank_cfg, factor):
        eng = ServeEngine(cfg.with_(rank=rank_cfg), params, n_slots=n_slots,
                          max_len=max_len, page_size=16, segment_len=8,
                          max_new_cap=max(w["max_new"] for w in workload),
                          factor_cache=factor)
        for w in workload:
            eng.submit(Request(**w))
        eng.warmup()
        outs = eng.run()
        c = eng.cache
        width = c.r_keep if factor else dh
        itemsize = np.dtype(np.asarray(c.k_pool).dtype).itemsize
        # score-contraction K-side read per decoded token: one gather of
        # the slot's logical view (pages * page_size positions) per layer
        read = cfg.num_layers * c.max_len * hkv * width * itemsize
        return outs, {
            "tok_per_s": eng.stats["tokens_decoded"]
                         / max(eng.stats["decode_s"], 1e-9),
            "k_read_bytes_per_token": read,
        }

    full = RankConfig(mode="fixed", rank_grid=(dh // 2, dh), fixed_rank=dh,
                      segment_len=8)
    outs_f, stats_f = drive(full, True)
    outs_d, stats_d = drive(full, False)
    parity = all(np.array_equal(outs_f[w["rid"]], outs_d[w["rid"]])
                 for w in workload)
    assert parity, "factored decode diverged from dense paged decode " \
                   "at full rank"

    low = RankConfig(mode="adaptive", rank_grid=(dh // 4, dh // 2),
                     segment_len=8)
    _, lo_f = drive(low, True)
    _, lo_d = drive(low, False)
    return {
        "parity_full_rank": parity,
        "full_rank": {"factored": stats_f, "dense": stats_d},
        "low_rank": {"factored": lo_f, "dense": lo_d,
                     "r_keep": dh // 2, "dh": dh,
                     "read_ratio": lo_f["k_read_bytes_per_token"]
                                   / lo_d["k_read_bytes_per_token"]},
    }


def chunked_compare(cfg, params, workload, n_slots: int, max_len: int,
                    chunk: int = 8):
    """Interleaved (chunked) vs blocking (one-shot) prefill admission.

    Both engines run the identical staggered workload with per-step
    blocking (honest walls). Token parity between the admission modes is
    asserted; per-request TTFT (admission -> token 0) p50/p95 and the
    blocking path's decode-stall seconds are reported.
    """
    from repro.serve import Request, ServeEngine

    def drive(prefill_chunk):
        eng = ServeEngine(cfg, params, n_slots=n_slots, max_len=max_len,
                          page_size=16, segment_len=8,
                          max_new_cap=max(w["max_new"] for w in workload),
                          prefill_chunk=prefill_chunk, time_per_token=True)
        for w in workload:
            eng.submit(Request(**w))
        eng.warmup()
        outs = eng.run()
        ttft = np.asarray(eng.first_token_s) * 1e3          # ms
        return outs, {
            "ttft_p50_ms": float(np.percentile(ttft, 50)),
            "ttft_p95_ms": float(np.percentile(ttft, 95)),
            # same TTFTs through the obs histogram (fixed-bucket,
            # interpolated): the serving-path estimate an exporter
            # scrape would see, reported beside the exact percentile
            "ttft_hist_p50_ms": eng.obs.ttft_hist.percentile(50) * 1e3,
            "ttft_hist_p95_ms": eng.obs.ttft_hist.percentile(95) * 1e3,
            "decode_stall_s": eng.stats["stall_s"],
            "mixed_steps": eng.stats["mixed_steps"],
            "steps": eng.stats["steps"],
            "tok_per_s": eng.stats["tokens_decoded"]
                         / max(eng.stats["decode_s"], 1e-9),
        }

    outs_b, blocking = drive(None)
    outs_i, interleaved = drive(chunk)
    parity = all(np.array_equal(outs_b[w["rid"]], outs_i[w["rid"]])
                 for w in workload)
    assert parity, "chunked-prefill decode diverged from one-shot prefill"
    return {
        "parity": parity,
        "chunk": chunk,
        "interleaved": interleaved,
        "blocking": blocking,
    }


def prefix_compare(cfg, params, n_slots: int, max_len: int,
                   smoke: bool = False):
    """Shared-system-prompt traffic with the prefix cache on vs off.

    Every request = one shared 32-token prefix + a unique 8-token tail,
    arrivals spaced so the first prefill finishes (and inserts into the
    radix tree) before the rest arrive. Token parity between the two
    engines is asserted (prefix-hit admission must equal cold admission),
    the refcount invariant is checked after the run, and the JSON section
    records hit rate, prefill tokens computed per request (the >= 2x
    reduction headline) and TTFT p50/p95 split hot (prefix hit) vs cold.
    """
    from repro.serve import Request, ServeEngine

    rnd = np.random.default_rng(7)
    n_req, shared_len, tail, max_new = (4 if smoke else 8), 32, 8, 8
    shared = rnd.integers(0, 256, shared_len).astype(np.int32)
    reqs = [dict(rid=i,
                 tokens=np.concatenate(
                     [shared, rnd.integers(0, 256, tail).astype(np.int32)]),
                 max_new=max_new, arrival=6 * i)
            for i in range(n_req)]

    def drive(prefix):
        # time_per_token blocks every fused step, so TTFT is a true wall
        # (free-running dispatch would timestamp the enqueue, not the
        # token)
        eng = ServeEngine(cfg, params, n_slots=n_slots, max_len=max_len,
                          page_size=16, segment_len=8, max_new_cap=max_new,
                          prefill_chunk=16, prefix_cache=prefix,
                          time_per_token=True)
        # two passes: the first also compiles the admission-time control
        # ops (snapshot slices, mass rehydration, CoW copies) that
        # warmup() cannot reach; the second pass is the measurement —
        # reset() clears the tree, so its hit pattern is identical
        for rep in range(2):
            if rep:
                eng.reset()
            for w in reqs:
                eng.submit(Request(**w))
            eng.warmup()
            outs = eng.run()
        s = eng.stats
        # first_token_s and sched.finished append in the same eviction
        # loop, so they zip rid-aligned
        pairs = [(req.rid, t * 1e3) for (req, _), t
                 in zip(eng.sched.finished, eng.first_token_s)]
        hot = [t for rid, t in pairs if eng.request_prefix_hit.get(rid)]
        cold = [t for rid, t in pairs if not eng.request_prefix_hit.get(rid)]
        if prefix:
            eng.cache.check_refs(eng.prefix.all_pages())
        def pct(xs):
            return (None if not xs else
                    {"p50_ms": float(np.percentile(xs, 50)),
                     "p95_ms": float(np.percentile(xs, 95))})
        return outs, {
            "hit_rate": s["prefix_hits"] / n_req if prefix else 0.0,
            "reused_tokens": s["prefix_reused_tokens"],
            "prefill_tokens_per_request": s["prefill_tokens"] / n_req,
            "cow_pages": s["prefix_cow"],
            "evicted_pages": s["prefix_evictions"],
            "ttft_hot": pct(hot),
            "ttft_cold": pct(cold),
            "tok_per_s": s["tokens_decoded"] / max(s["decode_s"], 1e-9),
        }

    outs_on, on = drive(True)
    outs_off, off = drive(False)
    parity = all(np.array_equal(outs_on[w["rid"]], outs_off[w["rid"]])
                 for w in reqs)
    assert parity, "prefix-hit admission diverged from cold admission"
    reduction = (off["prefill_tokens_per_request"]
                 / max(on["prefill_tokens_per_request"], 1e-9))
    assert reduction >= 2.0, \
        f"prefill-token reduction {reduction:.2f}x below the 2x bar"
    return {
        "parity": parity,
        "workload": {"n_requests": n_req, "shared_len": shared_len,
                     "tail_len": tail},
        "cached": on,
        "baseline": off,
        "prefill_token_reduction": reduction,
    }


def spec_compare(cfg, params, workload, n_slots: int, max_len: int,
                 repeats: int = 2, draft_k: int = 7,
                 draft_rank_frac: float = 0.25):
    """Low-rank self-speculative decode vs plain chunked decode.

    Both engines run the identical workload; token parity is asserted
    (speculation is exact — it may only change speed). Records the draft
    accept rate (accepted / draftable), the mean accepted run length per
    fused step (1 = all drafts rejected .. draft_k + 1 = all survived),
    and the tok/s ratio. Both engines are built first, then measurement
    reps ALTERNATE plain/spec (best-of-``repeats`` each): host timing
    drifts across a process's lifetime, and back-to-back blocks would
    hand one engine a systematically warmer machine than the other.

    ``draft_k`` defaults to segment_len - 1: accepts are clamped at
    segment boundaries anyway (rank decisions must fire at identical
    token counts), so a segment-aligned draft window is the largest one
    that can fully accept — a perfect run covers a whole segment in one
    fused dispatch. ``draft_rank_frac`` defaults to r/4 — the policy
    floor clamps the draft rank from below, so quarter-rank drafts
    accept just as often as half-rank ones while reading less.

    The workload's decode budget is raised to 32 tokens per request:
    speculation targets the decode phase, and the smoke workload's
    8-token windows are over in a handful of steps — all prefill,
    admission and decision overhead, which both engines pay identically,
    drowning the signal in dispatch noise.

    What to gate: accept rate and tokens-per-dispatch (mean accepted run
    length) are deterministic given the model and workload. The
    wall-clock tok/s ratio is NOT a meaningful gate at this scale — the
    draft's rank cut saves attention/KV reads, which are negligible for
    a toy model at seq <= 80 on CPU, so a quarter-rank draft forward
    costs about the same as the full fused step it replaces and the
    measured ratio sits near or below 1.0. The speedup this subsystem
    buys is per-dispatch: ~6x fewer fused steps (and host syncs) per
    decoded token, which converts to wall-clock exactly where decode is
    dispatch- or KV-read-bound."""
    from repro.serve import Request, ServeEngine

    workload = [dict(w, max_new=32) for w in workload]
    max_len = max(max_len, 32 + 32 + 16)  # longest prompt + budget + slack

    def build(speculative):
        return ServeEngine(cfg, params, n_slots=n_slots, max_len=max_len,
                           page_size=16, segment_len=8,
                           max_new_cap=max(w["max_new"] for w in workload),
                           prefill_chunk=8, speculative=speculative,
                           draft_k=draft_k, draft_rank_frac=draft_rank_frac)

    def pass_(eng, warmed):
        if warmed:
            eng.reset()
        for w in workload:
            eng.submit(Request(**w))
        if not warmed:
            eng.warmup()
        outs = eng.run()
        return outs, dict(eng.stats)

    engines = {False: build(False), True: build(True)}
    best = {False: None, True: None}
    for rep in range(max(repeats, 2) + 1):
        for speculative in (False, True):
            outs, st = pass_(engines[speculative], warmed=rep > 0)
            if rep == 0:
                continue  # warm pass: compiles + control-plane one-offs
            if (best[speculative] is None
                    or st["decode_s"] < best[speculative][1]["decode_s"]):
                best[speculative] = (outs, st)

    outs_p, sp = best[False]
    outs_s, ss = best[True]
    parity = all(np.array_equal(outs_p[w["rid"]], outs_s[w["rid"]])
                 for w in workload)
    assert parity, "speculative decode diverged from plain decode"
    tok_plain = sp["tokens_decoded"] / max(sp["decode_s"], 1e-9)
    tok_spec = ss["tokens_decoded"] / max(ss["decode_s"], 1e-9)
    return {
        "parity": parity,
        "draft_k": draft_k,
        "draft_rank_frac": draft_rank_frac,
        "accept_rate": ss["spec_accepted"] / max(ss["spec_drafted"], 1),
        # accepted run per stream-step: each decoding row contributes one
        # bonus token per step, so row-steps == spec_tokens - spec_accepted
        "mean_accept_len": ss["spec_tokens"]
                           / max(ss["spec_tokens"] - ss["spec_accepted"], 1),
        "spec_steps": ss["spec_steps"],
        "steps_plain": sp["steps"],
        "tok_per_s": tok_spec,
        "tok_per_s_plain": tok_plain,
        "tok_per_s_ratio": tok_spec / max(tok_plain, 1e-9),
    }


def learned_policy_compare(cfg, params, smoke: bool = False,
                           work_dir: str | None = None):
    """Close the loop on the paper's RL agent against serving traffic:
    record traces -> train offline -> replay with ``mode="learned"``.

    1. The deterministic workload suite (repro.serve.workloads) is served
       under the adaptive heuristic with the trace recorder attached —
       one shared recorder across all scenarios, one dataset out.
    2. repro.train.serve_policy trains the policy net on that trace
       (BC warm start -> constrained-oracle BC -> PPO) and the offline
       replay evaluation scores learned vs adaptive (the recorded
       actions) vs the constrained oracle on the same Eq. 13 reward.
    3. The suite is served again with ``mode="learned"`` — stream
       validity is asserted, and a second trace records the ranks the
       learned policy actually kept.

    What to gate: ``reward_gain`` (learned minus adaptive Eq. 13 reward,
    must not be negative — the constrained oracle dominates the
    heuristic by construction, so a trained policy that loses reward
    has failed to fit) and ``rank_ratio`` (learned/adaptive mean kept
    rank, must stay <= 1: the policy may not buy reward with extra
    factor-read bytes). Both are deterministic given model + workloads.
    ``replay.serve_rank_ratio`` is informational — at serve time the
    policy feeds back into its own prev-rank state, so its trajectory
    legitimately drifts from the offline replay."""
    import tempfile

    from repro.configs.base import RankConfig
    from repro.serve import Request, ServeEngine
    from repro.serve.traces import TraceReader, TraceRecorder
    from repro.serve.workloads import build, make_workload, workload_names
    from repro.train.serve_policy import load_policy, train_serve_policy

    n_requests, max_new = (4, 10) if smoke else (8, 24)
    grid = (4, 8, 12, 16)
    acfg = cfg.with_(rank=RankConfig(mode="adaptive", rank_grid=grid,
                                     segment_len=8))
    lcfg = cfg.with_(rank=RankConfig(mode="learned", rank_grid=grid,
                                     segment_len=8))
    specs = [make_workload(n, seed=3, n_requests=n_requests,
                           max_new=max_new, vocab=cfg.vocab_size,
                           max_prompt=40) for n in workload_names()]

    def serve_suite(run_cfg, policy_params, recorder):
        served = 0
        valid = True
        for spec in specs:
            eng = ServeEngine(run_cfg, params, policy_params, n_slots=4,
                              max_len=96, page_size=16, segment_len=8,
                              max_new_cap=max_new, prefill_chunk=8,
                              record_traces=recorder,
                              **spec.engine_overrides)
            for r in build(spec):
                eng.submit(r)
            outs = eng.run()
            served += len(outs)
            valid = valid and all(
                0 < len(v) <= max_new for v in outs.values())
        recorder.flush()
        return served, valid

    with tempfile.TemporaryDirectory() as tmp:
        base = work_dir or tmp
        adir, ldir, pdir = (f"{base}/trace_adaptive", f"{base}/trace_learned",
                            f"{base}/policy")
        _, a_valid = serve_suite(
            acfg, None, TraceRecorder(adir, acfg, scenario="suite"))
        _, history = train_serve_policy(
            adir, acfg.rank, out_dir=pdir,
            bc_steps=40 if smoke else 160,
            ppo_steps=2 if smoke else 8)
        pol = load_policy(pdir)
        served, l_valid = serve_suite(
            lcfg, pol, TraceRecorder(ldir, lcfg, scenario="suite"))
        rank_adaptive = float(
            np.mean(TraceReader(adir).records["chosen_rank"]))
        rank_learned = float(
            np.mean(TraceReader(ldir).records["chosen_rank"]))

    ev = history["eval"]
    return {
        "workloads": workload_names(),
        "n_requests": n_requests, "max_new": max_new,
        "n_records": ev["n_records"],
        "offline": {k: ev[k] for k in ("learned", "adaptive", "oracle")},
        "picked": ev["picked"],
        "reward_gain": ev["learned"]["reward"] - ev["adaptive"]["reward"],
        "rank_ratio": ev["learned"]["mean_rank"]
                      / max(ev["adaptive"]["mean_rank"], 1e-9),
        "agreement_gain": ev["learned"]["agreement"]
                          - ev["adaptive"]["agreement"],
        "replay": {
            "served_requests": served,
            "valid": bool(a_valid and l_valid),
            "mean_rank_adaptive": rank_adaptive,
            "mean_rank_learned": rank_learned,
            "serve_rank_ratio": rank_learned / max(rank_adaptive, 1e-9),
        },
    }


def router_compare(cfg, params, smoke: bool = False):
    """Multi-replica front door: prefix-affinity routing vs round-robin
    vs a single replica.

    Workload: ``n_groups`` distinct shared prefixes (system prompts),
    each fanned out to ``per_group`` requests with unique tails. Group
    leaders go first and finish (warming exactly one replica's radix
    tree per group), then the remaining traffic arrives as one burst.
    The full-mode group count is sized so all chains together OVERFLOW
    one replica's page pool but two groups per replica fit: affinity
    routing partitions groups across the fleet (aggregate cache
    capacity), while a single replica — and round-robin, which sprays
    every group onto every replica — LRU-evicts shared chains and
    re-prefills. That cut prefill work is what makes the 2-replica
    fleet beat one replica wall-clock even on a single-core host.
    """
    from repro.serve import EngineConfig, FleetConfig, Router, SamplingParams

    rnd = np.random.default_rng(11)
    n_groups, per_group = (2 if smoke else 4), 3
    shared_len, tail, max_new = 48, 8, (6 if smoke else 10)
    groups = [rnd.integers(0, 256, shared_len).astype(np.int32)
              for _ in range(n_groups)]
    tails = [[rnd.integers(0, 256, tail).astype(np.int32)
              for _ in range(per_group)] for _ in groups]
    # burst arrival order — smoke: each group's follow-ups back to back,
    # which provably misaligns a 2-replica round-robin rotation (a
    # consecutive pair always straddles both replicas, so every group
    # cold-misses somewhere); full: a fixed-seed shuffle of the
    # 4-group burst, so round-robin sprays groups across replicas
    # while affinity re-partitions them
    order = [(g, j) for g in range(n_groups) for j in range(1, per_group)]
    if not smoke:
        order = [order[k] for k in rnd.permutation(len(order))]
    # 48-token prefixes = 3-page chains; prefix_pages=2 -> 12 usable
    # pages per replica: 2 chains stay resident, 4 can't (the overflow
    # described above), and every miss re-pays 3 prefill chunks
    ecfg = EngineConfig(n_slots=2, max_len=80, page_size=16, segment_len=8,
                        max_new_cap=max_new, prefill_chunk=16,
                        prefix_cache=True, prefix_pages=2, sampling=False)

    def drive(routing, n_replicas, repeats=2 if smoke else 5):
        # best-of-N per side: the decode window is ~0.1 s at this scale
        # and stepping threads add scheduler jitter
        best = None
        fleet = FleetConfig(engine=ecfg, n_replicas=n_replicas,
                            routing=routing, affinity_min_tokens=16,
                            idle_poll_s=0.002)
        router = Router(cfg, params, fleet=fleet)
        sp = SamplingParams(max_new=max_new)
        for _ in range(repeats):
            router.reset()
            t0 = time.perf_counter()
            leaders = [router.submit(np.concatenate([g, t[0]]), sp)
                       for g, t in zip(groups, tails)]
            for h in leaders:
                h.result()        # warm one replica per group
            burst = [router.submit(np.concatenate([groups[g], tails[g][j]]),
                                   sp) for g, j in order]
            router.drain()
            wall = time.perf_counter() - t0
            st = router.stats()
            res = {
                "hit_rate": st["aggregate"]["hit_rate"],
                "tokens": st["aggregate"]["tokens_decoded"],
                "tok_per_s": st["aggregate"]["tokens_decoded"] / wall,
                "wall_s": wall,
                "prefill_tokens": sum(p["engine"]["prefill_tokens"]
                                      for p in st["replicas"]),
                "routed": st["routed"],
                "route_kinds": st["route_kinds"],
                "burst_replicas": sorted({h.replica for h in burst}),
            }
            if best is None or res["tok_per_s"] > best["tok_per_s"]:
                best = res
        router.shutdown()
        return best

    aff = drive("affinity", 2)
    rr = drive("round_robin", 2)
    single = drive("affinity", 1)
    assert aff["hit_rate"] > rr["hit_rate"], \
        f"affinity hit-rate {aff['hit_rate']:.2f} not above round-robin " \
        f"{rr['hit_rate']:.2f}"
    ratio = aff["tok_per_s"] / max(single["tok_per_s"], 1e-9)
    if not smoke:
        # one replica = same total compute on this host; the fleet must
        # at least hold parity while doubling the lanes in flight
        assert ratio >= 1.0, \
            f"2-replica aggregate {aff['tok_per_s']:.0f} tok/s below " \
            f"single replica {single['tok_per_s']:.0f} tok/s"
    return {
        "workload": {"n_groups": n_groups, "per_group": per_group,
                     "shared_len": shared_len, "tail_len": tail,
                     "max_new": max_new},
        "n_replicas": 2,
        "affinity": aff,
        "round_robin": rr,
        "single": single,
        "hit_rate_gain": aff["hit_rate"] - rr["hit_rate"],
        "tok_per_s_ratio_vs_single": ratio,
    }


def run(quick: bool = False, smoke: bool = False, n_slots: int = 8,
        out_path: str = "BENCH_serve.json"):
    import jax

    from repro.configs import get_config
    from repro.configs.base import RankConfig
    from repro.models.api import get_model
    from repro.serve import Request, ServeEngine
    from repro.serve.api import Engine, EngineConfig, SamplingParams

    n_requests, max_new = (4, 8) if smoke else (8, 16) if quick else (16, 24)
    if smoke:
        n_slots = min(n_slots, 4)
    cfg = get_config("drrl-paper", reduced=True).with_(
        rank=RankConfig(mode="adaptive", rank_grid=(4, 8, 12, 16),
                        segment_len=8))
    fns = get_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    workload = build_workload(n_requests, max_new)
    max_len = 64

    repeats = 1 if smoke else 2

    # -- multi-replica router: affinity vs round-robin vs 1 replica -----
    # first, while the process is clean: the fleet-vs-single wall-clock
    # comparison is sensitive to heap size and stray live engines from
    # the other sections (its bands were calibrated in a fresh process)
    router_res = router_compare(cfg, params, smoke=smoke)

    # -- continuous batching --------------------------------------------
    # throughput runs: free-running dispatch (no per-step blocking);
    # best-of-N because the decode window is sub-second at this scale
    eng = ServeEngine(cfg, params, n_slots=n_slots, max_len=max_len,
                      page_size=16, segment_len=8, max_new_cap=max_new)
    es = None
    compile_s = 0.0
    for rep in range(repeats):
        if rep:
            eng.reset()
        for w in workload:
            eng.submit(Request(**w))
        eng.warmup()
        eng.run()
        compile_s += eng.stats["compile_s"]
        if es is None or eng.stats["decode_s"] < es["decode_s"]:
            es = dict(eng.stats)
    es["compile_s"] = compile_s
    # latency run: same workload, blocking each fused step for honest
    # per-token wall times (the blocking itself costs throughput, so the
    # two metrics come from separate runs over identical requests)
    eng.reset()
    eng.time_per_token = True
    for w in workload:
        eng.submit(Request(**w))
    eng.run()
    lat = np.asarray(eng.token_latencies) * 1e3        # ms per decoded token
    engine_res = {
        "tok_per_s": es["tokens_decoded"] / max(es["decode_s"], 1e-9),
        "p50_ms": float(np.percentile(lat, 50)) if lat.size else None,
        "p95_ms": float(np.percentile(lat, 95)) if lat.size else None,
        "first_token_s_mean": float(np.mean(eng.first_token_s))
                              if eng.first_token_s else None,
        "decode_s": es["decode_s"], "prefill_s": es["prefill_s"],
        "compile_s": es["compile_s"], "steps": es["steps"],
        "tokens_decoded": es["tokens_decoded"], "n_slots": n_slots,
    }

    # -- sequential per-request lock-step (1-slot api.Engine) -----------
    def seq_engine(timed: bool) -> Engine:
        return Engine(cfg, params, config=EngineConfig(
            n_slots=1, max_len=max_len, page_size=16, segment_len=8,
            max_new_cap=max_new, prefill_chunk=None, sampling=False,
            time_per_token=timed))

    seq_server = seq_engine(False)
    best = None
    for _ in range(repeats):
        seq_decode_s = seq_prefill_s = seq_compile_s = 0.0
        seq_tokens = 0
        for w in workload:
            seq_server.reset()
            seq_server.submit(w["tokens"],
                              SamplingParams(max_new=w["max_new"]))
            seq_compile_s += seq_server.warmup()
            seq_server.run()
            s = seq_server.stats
            seq_decode_s += s["decode_s"]
            seq_prefill_s += s["prefill_s"]
            seq_tokens += s["tokens_decoded"]
        if best is None or seq_decode_s < best[0]:
            best = (seq_decode_s, seq_prefill_s, seq_compile_s, seq_tokens)
    seq_decode_s, seq_prefill_s, seq_compile_s, seq_tokens = best
    # sequential latency pass: same per-step blocking the engine's latency
    # run uses, so both p50/p95 are true per-token walls
    seq_lat = []
    server_lat = seq_engine(True)
    for w in workload:
        server_lat.reset()
        server_lat.submit(w["tokens"], SamplingParams(max_new=w["max_new"]))
        server_lat.warmup()
        server_lat.run()
        seq_lat.extend(t * 1e3 for t in server_lat.core.token_latencies)
    seq_lat = np.asarray(seq_lat)
    seq_res = {
        "tok_per_s": seq_tokens / max(seq_decode_s, 1e-9),
        "p50_ms": float(np.percentile(seq_lat, 50)) if seq_lat.size else None,
        "p95_ms": float(np.percentile(seq_lat, 95)) if seq_lat.size else None,
        "decode_s": seq_decode_s, "prefill_s": seq_prefill_s,
        "compile_s": seq_compile_s, "tokens_decoded": seq_tokens,
    }

    # -- factor-form cache: parity + read bandwidth ---------------------
    fc_workload = workload[:4] if not smoke else workload
    factor_res = factor_compare(cfg, params, fc_workload,
                                n_slots=min(n_slots, 4), max_len=max_len)

    # -- chunked (interleaved) vs one-shot (blocking) admission ---------
    chunk_res = chunked_compare(cfg, params, workload,
                                n_slots=min(n_slots, 4), max_len=max_len)

    # -- shared-prefix KV reuse: hit rate, prefill cut, hot/cold TTFT ---
    prefix_res = prefix_compare(cfg, params, n_slots=min(n_slots, 4),
                                max_len=max_len, smoke=smoke)

    # -- self-speculative decode: accept rate + tok/s vs plain ----------
    # spec_compare runs its own warm pass (the plain engine pays a
    # one-off mid-run compile there) and alternates plain/spec
    # measurement reps so host-timing drift cancels out of the ratio
    spec_res = spec_compare(cfg, params, workload,
                            n_slots=min(n_slots, 4), max_len=max_len,
                            repeats=max(repeats, 2))

    # -- observability overhead: tracing on vs off, parity asserted -----
    obs_res = obs_overhead(cfg, params, workload, n_slots=min(n_slots, 4),
                           max_len=max_len)

    # -- learned rank policy: trace -> offline train -> replay ----------
    learned_res = learned_policy_compare(cfg, params, smoke=smoke)

    # -- runtime sanitizer: transfer guard + steady-state compile count -
    guard_res = compile_guard()

    out = {
        "workload": {"n_requests": n_requests, "max_new": max_new,
                     "prompt_lens": [len(w["tokens"]) for w in workload],
                     "arrivals": [w["arrival"] for w in workload]},
        "engine": engine_res,
        "sequential": seq_res,
        "speedup": engine_res["tok_per_s"] / max(seq_res["tok_per_s"], 1e-9),
        "factor_cache": factor_res,
        "chunked_prefill": chunk_res,
        "prefix_cache": prefix_res,
        "speculative": spec_res,
        "router": router_res,
        "obs": obs_res,
        "learned_policy": learned_res,
        "compile_guard": guard_res,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload — CI canary")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    res = run(quick=args.quick, smoke=args.smoke, n_slots=args.slots,
              out_path=args.out)
    e, s = res["engine"], res["sequential"]
    print(f"engine     : {e['tok_per_s']:8.1f} tok/s  "
          f"p50 {e['p50_ms']:.1f} ms  p95 {e['p95_ms']:.1f} ms  "
          f"(compile {e['compile_s']:.2f}s excluded)")
    print(f"sequential : {s['tok_per_s']:8.1f} tok/s  "
          f"p50 {s['p50_ms']:.1f} ms  p95 {s['p95_ms']:.1f} ms")
    print(f"speedup    : {res['speedup']:.2f}x  -> {args.out}")
    fc = res["factor_cache"]
    lo = fc["low_rank"]
    print(f"factor     : parity@full-rank {fc['parity_full_rank']}  "
          f"K-read/token {lo['factored']['k_read_bytes_per_token']}B vs "
          f"{lo['dense']['k_read_bytes_per_token']}B dense "
          f"(ratio {lo['read_ratio']:.2f} = r{lo['r_keep']}/d{lo['dh']})")
    cp = res["chunked_prefill"]
    ci, cb = cp["interleaved"], cp["blocking"]
    print(f"chunked    : parity {cp['parity']}  TTFT p50/p95 "
          f"{ci['ttft_p50_ms']:.1f}/{ci['ttft_p95_ms']:.1f} ms interleaved "
          f"vs {cb['ttft_p50_ms']:.1f}/{cb['ttft_p95_ms']:.1f} ms blocking; "
          f"decode stall {ci['decode_stall_s']:.2f}s vs "
          f"{cb['decode_stall_s']:.2f}s")
    px = res["prefix_cache"]
    hot = px["cached"]["ttft_hot"] or {"p50_ms": float("nan")}
    cold = px["baseline"]["ttft_cold"]
    print(f"prefix     : parity {px['parity']}  hit rate "
          f"{px['cached']['hit_rate']:.2f}  prefill tok/req "
          f"{px['cached']['prefill_tokens_per_request']:.1f} vs "
          f"{px['baseline']['prefill_tokens_per_request']:.1f} "
          f"({px['prefill_token_reduction']:.1f}x cut); TTFT p50 "
          f"{hot['p50_ms']:.1f} ms hot vs {cold['p50_ms']:.1f} ms cold")
    sd = res["speculative"]
    print(f"speculative: parity {sd['parity']}  accept rate "
          f"{sd['accept_rate']:.2f}  mean run {sd['mean_accept_len']:.2f} "
          f"tok/step (draft_k {sd['draft_k']}); "
          f"{sd['tok_per_s']:.0f} tok/s vs {sd['tok_per_s_plain']:.0f} "
          f"plain (ratio {sd['tok_per_s_ratio']:.2f})")
    rt = res["router"]
    print(f"router     : hit rate {rt['affinity']['hit_rate']:.2f} affinity "
          f"vs {rt['round_robin']['hit_rate']:.2f} round-robin; "
          f"2-replica {rt['affinity']['tok_per_s']:.0f} tok/s vs "
          f"1-replica {rt['single']['tok_per_s']:.0f} tok/s "
          f"(ratio {rt['tok_per_s_ratio_vs_single']:.2f})")
    ob = res["obs"]
    print(f"obs        : parity {ob['parity']}  tok/s on/off ratio "
          f"{ob['on_off_ratio']:.2f} ({ob['tok_per_s_on']:.0f} traced vs "
          f"{ob['tok_per_s_off']:.0f} plain); {ob['trace_events']} trace "
          f"events, {ob['trace_dropped']} dropped")
    lp = res["learned_policy"]
    print(f"learned    : replay valid {lp['replay']['valid']}  reward "
          f"{lp['offline']['learned']['reward']:.4f} vs "
          f"{lp['offline']['adaptive']['reward']:.4f} adaptive "
          f"(gain {lp['reward_gain']:+.4f}); mean rank "
          f"{lp['offline']['learned']['mean_rank']:.2f} vs "
          f"{lp['offline']['adaptive']['mean_rank']:.2f} "
          f"(ratio {lp['rank_ratio']:.3f}, {lp['n_records']} records)")
    cg = res["compile_guard"]
    if cg.get("error"):
        print(f"sanitizer  : FAILED — {cg['error'][:200]}")
    else:
        ms, sp = cg["mixed_sampling"], cg["speculative"]
        lg = cg.get("learned_policy", {})
        og = cg.get("observability", {})
        print(f"sanitizer  : {'ok' if cg['ok'] else 'FAIL'}  "
              f"transfer guard disallow; executables warm/steady "
              f"{ms['warm_executables']}/+{ms['steady_new_executables']} "
              f"mixed, {sp['warm_executables']}/+"
              f"{sp['steady_new_executables']} speculative, "
              f"{lg.get('warm_executables', '?')}/+"
              f"{lg.get('steady_new_executables', '?')} learned, "
              f"{og.get('warm_executables', '?')}/+"
              f"{og.get('steady_new_executables', '?')} obs")
    if res["speedup"] <= 1.0 and not args.smoke:
        # --smoke is a does-it-run canary: 4 under-saturated requests,
        # single repeat — not a throughput measurement
        print("WARNING: continuous batching did not beat sequential decode")


if __name__ == "__main__":
    main()
