"""Fig. 4 reproduction: attention FLOPs vs sequence length for Full-Rank vs
DR-RL (and fixed low-rank). Validates the paper's headline claim:
  > 40% FLOPs reduction in long-sequence regimes (L > 4096).

Protocol: train the bench LM (spectra concentrate with training, mirroring
the paper's Fig. 3 layer-wise structure), roll out the rank policy on real
spectra, then evaluate the exact per-head cost model
  score term: 2 L^2 r   +  value term: 2 L^2 r_v
with r from the policy. Both the paper-faithful score-side truncation and
the score+value truncation (RankConfig.truncate_values, Eq. 5/10) are
reported.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import bench_cfg, save_json, train_lm
from repro.data.synthetic import SyntheticLM
from repro.models import transformer as tr
from repro.models.attention import attention_flops

LENGTHS = (512, 1024, 2048, 4096, 8192, 16384, 32768)


def mean_rank(cfg, params, L_run: int = 1024) -> float:
    data = SyntheticLM(cfg.vocab_size, L_run, 2, seed=9)
    _, aux = tr.forward_dense(cfg, params, data.batch_at(0)["tokens"],
                              collect_aux="ranks",
                              rank_rng=jax.random.PRNGKey(0))
    return float(np.mean(np.asarray(aux["layers"]["rank"])))


def run(quick: bool = False) -> dict:
    cfg = bench_cfg("adaptive")
    trained = train_lm(bench_cfg("off"), steps=15 if quick else 60)
    dh = cfg.resolved_head_dim()
    h = cfg.num_heads
    r_mean = mean_rank(cfg, trained["params"], L_run=256 if quick else 1024)
    r_fixed = cfg.rank.fixed_rank

    rows = []
    for L in LENGTHS:
        full = attention_flops(L, L, h, dh, dh) * cfg.num_layers
        # paper-faithful: scores contracted at r, values at full d_v
        drrl_score = attention_flops(L, L, h, dh, dh, rank=r_mean) \
            * cfg.num_layers
        # +value truncation (truncate_values=True)
        drrl_qkv = 2.0 * h * (L * L * r_mean + L * L * r_mean) \
            * cfg.num_layers
        fixed = attention_flops(L, L, h, dh, dh, rank=r_fixed) * cfg.num_layers
        rows.append({
            "L": L, "full": full, "drrl_score": drrl_score,
            "drrl_qkv": drrl_qkv, "fixed": fixed,
            "reduction_score_pct": round(100 * (1 - drrl_score / full), 1),
            "reduction_qkv_pct": round(100 * (1 - drrl_qkv / full), 1),
        })
        print(f"  L={L:6d} full={full:.3e} "
              f"score-only −{rows[-1]['reduction_score_pct']:.1f}% "
              f"score+value −{rows[-1]['reduction_qkv_pct']:.1f}%")
    out = {"rows": rows, "mean_rank": r_mean, "head_dim": dh,
           "claim_L4096_reduction_pct": rows[3]["reduction_qkv_pct"],
           "claim_paper": 41.5}
    print(f"  mean policy rank {r_mean:.1f}/{dh}; reduction at L=4096: "
          f"{out['claim_L4096_reduction_pct']}% (paper: 41.5%)")
    save_json("fig4", out)
    return out


if __name__ == "__main__":
    run()
