"""Perf-regression gate over BENCH_serve.json.

Compares a freshly generated serving benchmark (normally
``python -m benchmarks.serve_bench --smoke --out fresh.json`` in CI)
against the committed full-run baseline, with a per-metric tolerance
band. Bands are deliberately scale-free or structural: the smoke
workload is far smaller than the committed run and CI runners are
slower/noisier than the box that produced the baseline, so each band
is wide enough to absorb that — while still failing on order-of-kind
regressions (batching broken, prefix cache not reusing, factor path
reading dense bytes, affinity routing not beating round-robin).

    python -m benchmarks.check_bench fresh.json            # gate
    python -m benchmarks.check_bench fresh.json --baseline BENCH_serve.json

Exit status is non-zero iff any gated metric is out of band. To
re-baseline after an intentional perf change, run the full bench on a
quiet machine and commit the result:

    python -m benchmarks.serve_bench --out BENCH_serve.json
"""
import argparse
import json
import sys


def _get(d, path):
    for k in path.split("."):
        if d is None:
            return None
        d = d.get(k)
    return d


# (path, kind, band) — kind:
#   "flag"      value must be truthy in the fresh run
#   "min_ratio" fresh >= band * baseline
#   "max_ratio" fresh <= band * baseline
#   "min_abs"   fresh >= band (baseline shown for context only)
#   "max_abs"   fresh <= band (baseline shown for context only)
#   "eq_abs"    fresh == band exactly (deterministic counters only)
#   "info"      reported, never gated (wall-clock on shared runners)
CHECKS = [
    ("chunked_prefill.parity", "flag", None,
     "chunked admission is token-identical to one-shot"),
    ("prefix_cache.parity", "flag", None,
     "prefix-cache hit is token-identical to cold admission"),
    ("factor_cache.parity_full_rank", "flag", None,
     "factored decode matches dense at full rank"),
    ("speedup", "min_ratio", 0.20,
     "continuous batching vs sequential (smoke under-saturates the slots)"),
    ("chunked_prefill.interleaved.ttft_p50_ms", "max_ratio", 4.0,
     "chunked-prefill time-to-first-token, p50"),
    ("prefix_cache.prefill_token_reduction", "min_ratio", 0.5,
     "prefill tokens cut by shared-prefix reuse"),
    ("prefix_cache.cached.hit_rate", "min_ratio", 0.7,
     "radix-tree hit rate on the shared-prefix workload"),
    ("factor_cache.low_rank.read_ratio", "max_ratio", 1.05,
     "K-cache bytes/token, factored vs dense (r_keep/dh, deterministic)"),
    ("router.hit_rate_gain", "min_abs", 0.10,
     "affinity hit-rate minus round-robin (must stay decisively positive)"),
    ("speculative.parity", "flag", None,
     "speculative decode is token-identical to plain decode"),
    ("speculative.accept_rate", "min_abs", 0.6,
     "quarter-rank draft accept rate (deterministic given model/workload)"),
    ("speculative.mean_accept_len", "min_abs", 1.3,
     "tokens per fused dispatch (plain decode is exactly 1.0; this is "
     "the speedup factor wherever per-step cost dominates)"),
    ("speculative.tok_per_s_ratio", "info", None,
     "speculative vs plain tok/s (toy-scale CPU wall-clock: the drafts' "
     "rank cut saves attention reads, which are negligible here — "
     "report, don't gate)"),
    ("router.tok_per_s_ratio_vs_single", "info", None,
     "2-replica aggregate vs 1 replica (wall-clock: report, don't gate)"),
    ("engine.tok_per_s", "info", None,
     "absolute throughput (runner-speed dependent)"),
    # learned rank policy: trace -> offline train -> replay. Reward and
    # kept rank are deterministic given model + workload suite; the
    # constrained oracle dominates the adaptive heuristic by
    # construction, so a trained policy that loses reward or inflates
    # rank has failed to fit — that's a regression, not noise
    ("learned_policy.replay.valid", "flag", None,
     "mode='learned' serves the full replay suite with valid streams"),
    ("learned_policy.reward_gain", "min_abs", -0.002,
     "learned Eq. 13 reward must match/beat the adaptive heuristic "
     "(small band = BC fit tolerance)"),
    ("learned_policy.rank_ratio", "max_abs", 1.0005,
     "learned/adaptive mean kept rank — the policy may not buy reward "
     "with extra factor-read bytes (trainer's constrained snapshot "
     "selection guarantees <= 1 whenever any snapshot achieves it)"),
    ("learned_policy.agreement_gain", "info", None,
     "retained-energy agreement, learned minus adaptive"),
    ("learned_policy.replay.serve_rank_ratio", "info", None,
     "kept-rank ratio during live replay (policy feeds back into its "
     "own prev-rank state: report, don't gate)"),
    # runtime sanitizer lane: deterministic counters, gated EXACTLY —
    # one extra executable in steady state is a latency cliff, not noise
    ("compile_guard.ok", "flag", None,
     "transfer-guarded fused steps ran clean (no implicit host sync)"),
    ("compile_guard.mixed_sampling.steady_new_executables", "eq_abs", 0,
     "zero new executables across the steady mixed greedy/top-k/top-p run"),
    ("compile_guard.speculative.steady_new_executables", "eq_abs", 0,
     "zero new executables across the steady draft/verify + rank-switch run"),
    ("compile_guard.learned_policy.steady_new_executables", "eq_abs", 0,
     "zero new executables across the steady mode='learned' run (the "
     "policy net rides the jitted decide executable)"),
    ("compile_guard.observability.steady_new_executables", "eq_abs", 0,
     "metrics + span tracing ON adds zero executables to the steady "
     "serving loop (repro.obs hooks are pure host Python)"),
    ("compile_guard.mixed_sampling.warm_executables", "max_ratio", 1.0,
     "warmup executable count must not grow past the committed baseline"),
    ("compile_guard.speculative.warm_executables", "max_ratio", 1.0,
     "warmup executable count must not grow past the committed baseline"),
    ("compile_guard.learned_policy.warm_executables", "max_ratio", 1.0,
     "warmup executable count must not grow past the committed baseline"),
    ("compile_guard.observability.warm_executables", "max_ratio", 1.0,
     "warmup executable count must not grow past the committed baseline"),
    # observability overhead lane: parity is a hard gate, the timing
    # ratio is report-only (host-timer noise at smoke scale)
    ("obs.parity", "flag", None,
     "token streams identical with obs tracing enabled vs disabled"),
    ("obs.on_off_ratio", "info", None,
     "decode tok/s with tracing on over tracing off (report-only)"),
    ("obs.trace_events", "info", None,
     "Chrome trace events recorded for the bench workload"),
    ("obs.trace_dropped", "eq_abs", 0,
     "the bench workload must fit the trace ring (no dropped events)"),
]


def check(fresh: dict, baseline: dict):
    rows, failures = [], []
    for path, kind, band, why in CHECKS:
        f, b = _get(fresh, path), _get(baseline, path)
        ok, detail = True, ""
        if f is None:
            ok, detail = False, "missing from fresh run"
        elif kind == "flag":
            ok, detail = bool(f), "must be true"
        elif kind == "info":
            detail = "informational"
        elif kind == "min_abs":
            ok = f >= band
            detail = f">= {band:.3g}"
        elif kind == "max_abs":
            ok = f <= band
            detail = f"<= {band:.3g}"
        elif kind == "eq_abs":
            ok = f == band
            detail = f"== {band}"
        elif b is None:
            ok, detail = False, "missing from baseline"
        elif kind == "min_ratio":
            ok = f >= band * b
            detail = f">= {band:.2f}x baseline ({band * b:.3g})"
        elif kind == "max_ratio":
            ok = f <= band * b
            detail = f"<= {band:.2f}x baseline ({band * b:.3g})"
        rows.append((path, b, f, detail, ok, why))
        if not ok and kind != "info":
            failures.append(path)
    return rows, failures


def _fmt(v):
    if v is None:
        return "-"
    if isinstance(v, bool):
        return str(v)
    if isinstance(v, float):
        return f"{v:.3f}"
    return str(v)


def main(argv=None):
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("fresh", help="freshly generated serve-bench JSON")
    ap.add_argument("--baseline", default="BENCH_serve.json",
                    help="committed baseline (default: BENCH_serve.json)")
    args = ap.parse_args(argv)
    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    rows, failures = check(fresh, baseline)
    w = max(len(r[0]) for r in rows)
    print(f"{'metric':<{w}}  {'baseline':>10}  {'fresh':>10}  "
          f"{'band':<34} status")
    for path, b, f, detail, ok, why in rows:
        status = "ok" if ok else "FAIL"
        if detail == "informational":
            status = "info"
        print(f"{path:<{w}}  {_fmt(b):>10}  {_fmt(f):>10}  "
              f"{detail:<34} {status}")
        if not ok:
            print(f"{'':<{w}}  -> {why}")

    if failures:
        print(f"\nREGRESSION: {len(failures)} metric(s) out of band: "
              f"{', '.join(failures)}")
        print("If intentional, re-baseline: "
              "python -m benchmarks.serve_bench --out BENCH_serve.json")
        return 1
    print(f"\nall gated metrics within tolerance of {args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
