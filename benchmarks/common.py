"""Shared benchmark harness utilities (small-scale paper reproductions).

All benchmarks run the paper's protocol at reduced scale on CPU (see
DESIGN.md section 2): the paper's datasets are unavailable offline, so the
seeded synthetic corpora stand in and results are compared *relatively*
(method orderings and reduction percentages, not absolute PPL)."""
from __future__ import annotations

import json
import pathlib
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig, RankConfig, TrainConfig
from repro.core.rewards import flops_fraction
from repro.data.synthetic import SyntheticLM
from repro.models import transformer as tr
from repro.models.api import get_model
from repro.optim import adamw
from repro.train.loop import make_train_step

ART = pathlib.Path(__file__).resolve().parent / "artifacts"
ART.mkdir(parents=True, exist_ok=True)

BENCH_SEQ = 128
BENCH_BATCH = 8
BENCH_STEPS = 80
BENCH_VOCAB_SEED = 11


def bench_cfg(mode: str, **rank_kw) -> ModelConfig:
    base = get_config("drrl-paper", reduced=True)
    # slightly larger than the smoke config so spectra are non-trivial
    base = base.with_(num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
                      head_dim=32, d_ff=256, vocab_size=512)
    grid = (8, 12, 16, 20, 24, 28, 32)
    return base.with_(rank=RankConfig(mode=mode, rank_grid=grid,
                                      fixed_rank=16, **rank_kw))


def train_lm(cfg: ModelConfig, *, steps: int = BENCH_STEPS, seed: int = 0,
             agent=None, drrl_refresh: int = 20) -> Dict:
    """Train the bench LM with the given rank mode active during forward
    (the paper's protocol: identical hyperparameters across methods)."""
    fns = get_model(cfg)
    tc = TrainConfig(global_batch=BENCH_BATCH, seq_len=BENCH_SEQ, lr=1e-3,
                     total_steps=steps, warmup_steps=steps // 10,
                     weight_decay=0.01, seed=seed)
    data = SyntheticLM(cfg.vocab_size, BENCH_SEQ, BENCH_BATCH,
                       seed=BENCH_VOCAB_SEED)

    if cfg.rank.mode == "drrl":
        assert agent is not None

    def loss_fn(p, b, rng):
        extra = {}
        if cfg.rank.mode in ("drrl",):
            extra = {"policy_params": agent, "rank_rng": rng}
        elif cfg.rank.mode in ("random",):
            extra = {"rank_rng": rng}
        return fns.loss(p, b, **extra)

    step_fn = jax.jit(make_train_step(cfg, tc, loss_fn))
    params = fns.init(jax.random.PRNGKey(seed))
    opt = adamw.init(params)
    losses = []
    t0 = time.monotonic()
    for i in range(steps):
        params, opt, m = step_fn(params, opt, data.batch_at(i),
                                 jax.random.fold_in(jax.random.PRNGKey(7), i))
        losses.append(float(m["loss"]))
    wall = time.monotonic() - t0
    return {"params": params, "losses": losses, "wall_s": wall, "fns": fns,
            "tc": tc}


def eval_ppl(cfg: ModelConfig, params, fns, *, agent=None, n_batches: int = 8,
             seed: int = 999) -> float:
    data = SyntheticLM(cfg.vocab_size, BENCH_SEQ, BENCH_BATCH, seed=seed)
    tot = 0.0
    extra = {}
    if cfg.rank.mode == "drrl":
        extra = {"policy_params": agent,
                 "rank_rng": jax.random.PRNGKey(0)}
    elif cfg.rank.mode == "random":
        extra = {"rank_rng": jax.random.PRNGKey(0)}
    lf = jax.jit(lambda p, b, i: fns.loss(p, b, **extra)[0])
    for i in range(n_batches):
        tot += float(lf(params, data.batch_at(10_000 + i), i))
    return float(np.exp(tot / n_batches))


def attn_flops_fraction(cfg: ModelConfig, params, *, agent=None,
                        seed: int = 3) -> float:
    """Measured mean attention-FLOPs fraction vs full rank (score+value
    terms, Eq. 8 normalisation) over eval batches."""
    if cfg.rank.mode == "off":
        return 1.0
    if cfg.rank.mode in ("performer", "nystrom"):
        # linear methods: features/landmarks m vs seq: (m + dv) / (s + dv)
        dh = cfg.resolved_head_dim()
        m = max(2 * dh, 4 * cfg.rank.fixed_rank) if cfg.rank.mode == "performer" \
            else cfg.rank.fixed_rank
        return float((m + dh) / (BENCH_SEQ + dh))
    data = SyntheticLM(cfg.vocab_size, BENCH_SEQ, BENCH_BATCH, seed=seed)
    extra = {"collect_aux": "ranks", "rank_rng": jax.random.PRNGKey(1)}
    if cfg.rank.mode == "drrl":
        extra["policy_params"] = agent
    _, aux = tr.forward_dense(cfg, params, data.batch_at(0)["tokens"], **extra)
    ranks = np.asarray(aux["layers"]["rank"], np.float32)
    dh = cfg.resolved_head_dim()
    return float(np.mean(np.asarray(flops_fraction(jnp.asarray(ranks), dh, dh))))


def save_json(name: str, obj) -> pathlib.Path:
    p = ART / f"{name}.json"
    p.write_text(json.dumps(obj, indent=2))
    return p
