"""Fig. 2 reproduction: LM loss curve + RL reward curve during DR-RL
training (loss descends; reward stabilises)."""
from __future__ import annotations

import jax

from benchmarks.common import bench_cfg, save_json, train_lm, BENCH_BATCH, BENCH_SEQ
from repro.core.drrl import init_agent
from repro.data.synthetic import SyntheticLM
from repro.train.rl import train_agent


def run(quick: bool = False) -> dict:
    cfg = bench_cfg("drrl")
    warm = train_lm(bench_cfg("off"), steps=5 if quick else 15)
    agent = init_agent(jax.random.PRNGKey(7), cfg.rank, cfg.d_model)
    data = SyntheticLM(cfg.vocab_size, BENCH_SEQ, BENCH_BATCH, seed=21)
    agent, hist = train_agent(cfg, warm["params"], agent, data,
                              bc_steps=3 if quick else 10,
                              ppo_steps=5 if quick else 15, ppo_epochs=1)
    lm = train_lm(cfg, steps=10 if quick else 40, agent=agent)
    out = {
        "lm_loss_curve": [round(x, 4) for x in lm["losses"]],
        "bc_loss_curve": [round(x, 4) for x in hist["bc_loss"]],
        "reward_curve": [round(h["reward"], 4) for h in hist["ppo"]],
        "rank_curve": [round(h["rank_mean"], 2) for h in hist["ppo"]],
        "fidelity_curve": [round(h["fidelity"], 4) for h in hist["ppo"]],
    }
    print(f"  loss {out['lm_loss_curve'][0]:.3f} -> {out['lm_loss_curve'][-1]:.3f}; "
          f"reward {out['reward_curve'][0]:.3f} -> {out['reward_curve'][-1]:.3f}")
    save_json("fig2", out)
    return out


if __name__ == "__main__":
    run()
