"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us_per_call = wall time of the
benchmark; derived = the headline number it reproduces).

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--only table1]
"""
from __future__ import annotations

import argparse
import time


def _timed(fn, *a, **kw):
    t0 = time.monotonic()
    out = fn(*a, **kw)
    return out, (time.monotonic() - t0) * 1e6


def bench_table1(quick=False):
    from benchmarks.table1_lm import run
    res, us = _timed(run, quick=quick)
    drrl, full = res["drrl"], res["off"]
    derived = (f"drrl_ppl={drrl['ppl']};full_ppl={full['ppl']};"
               f"drrl_flops_frac={drrl['attn_flops_frac']}")
    return us, derived


def bench_table2(quick=False):
    from benchmarks.table2_ablation import run
    res, us = _timed(run, quick=quick)
    derived = ";".join(f"{k}={v['ppl']}" for k, v in res.items())
    return us, derived


def bench_table3(quick=False):
    from benchmarks.table3_downstream import run
    res, us = _timed(run, quick=quick)
    derived = ";".join(f"{k}={v['accuracy']}" for k, v in res.items())
    return us, derived


def bench_fig2(quick=False):
    from benchmarks.fig2_training import run
    res, us = _timed(run, quick=quick)
    derived = (f"final_loss={res['lm_loss_curve'][-1]};"
               f"final_reward={res['reward_curve'][-1]}")
    return us, derived


def bench_fig3(quick=False):
    from benchmarks.fig3_rank_evolution import run
    res, us = _timed(run, quick=quick)
    derived = (f"adaptive_layers={res['adaptive']['per_layer_mean_rank']};"
               f"drrl_mean={res['drrl']['overall']}")
    return us, derived


def bench_fig4(quick=False):
    from benchmarks.fig4_flops_scaling import run
    res, us = _timed(run, quick=quick)
    derived = f"reduction_at_L4096={res['claim_L4096_reduction_pct']}%"
    return us, derived


def bench_fig5(quick=False):
    from benchmarks.fig5_perturbation import run
    res, us = _timed(run, quick=quick)
    import numpy as np
    tr_frac = float(np.mean(np.asarray(res["trust_region"], dtype=float)))
    derived = f"trust_region_frac={tr_frac:.3f}"
    return us, derived


def bench_serve(quick=False):
    from benchmarks.serve_bench import run
    res, us = _timed(run, quick=quick)
    derived = (f"speedup={res['speedup']:.2f}x;"
               f"engine_tok_s={res['engine']['tok_per_s']:.0f};"
               f"p95_ms={res['engine']['p95_ms']:.1f}")
    return us, derived


def bench_roofline(quick=False):
    from benchmarks.roofline import load_all
    t0 = time.monotonic()
    rows = load_all("single")
    us = (time.monotonic() - t0) * 1e6
    if not rows:
        return us, "no_dryrun_artifacts"
    best = max(rows, key=lambda r: r["roofline_frac"])
    derived = (f"cells={len(rows)};best={best['arch']}/{best['cell']}"
               f"@{100 * best['roofline_frac']:.1f}%")
    return us, derived


def bench_smoke(quick=False):
    """CI canary: one train step + one eval batch + the FLOPs probe at tiny
    scale, through the same shared-harness code paths every table/figure
    uses — so import or API rot in benchmarks/ fails CI in seconds."""
    del quick  # always minimal
    from benchmarks.common import attn_flops_fraction, bench_cfg, eval_ppl, train_lm
    t0 = time.monotonic()
    cfg = bench_cfg("fixed")
    out = train_lm(cfg, steps=1)
    ppl = eval_ppl(cfg, out["params"], out["fns"], n_batches=1)
    frac = attn_flops_fraction(cfg, out["params"])
    us = (time.monotonic() - t0) * 1e6
    import numpy as np
    assert np.isfinite(ppl) and 0.0 < frac <= 1.0
    return us, f"ppl={ppl:.2f};attn_flops_frac={frac:.3f};steps=1"


BENCHES = {
    "smoke": bench_smoke,
    "table1": bench_table1,
    "table2": bench_table2,
    "table3": bench_table3,
    "fig2": bench_fig2,
    "fig3": bench_fig3,
    "fig4": bench_fig4,
    "fig5": bench_fig5,
    "serve": bench_serve,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, one step — CI canary for the harness")
    args = ap.parse_args()
    if args.smoke:
        print("name,us_per_call,derived")
        us, derived = bench_smoke()
        print(f"smoke,{us:.0f},{derived}", flush=True)
        return
    names = [args.only] if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    for name in names:
        print(f"# running {name} ...", flush=True)
        us, derived = BENCHES[name](quick=args.quick)
        print(f"{name},{us:.0f},{derived}", flush=True)


if __name__ == "__main__":
    main()
