"""Roofline analysis (deliverable g): derive the three roofline terms per
(arch x shape x mesh) from the compiled dry-run artifacts.

  compute    = HLO_FLOPs_per_device / 197e12          [bf16 peak / chip]
  memory     = HLO_bytes_per_device / 819e9           [HBM bw / chip]
  collective = collective_bytes_per_device / 50e9     [ICI bw / link]

Calibration note (verified in-repo): compiled.cost_analysis() reports the
PER-DEVICE partitioned program, so no further division by chip count.
MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE) for train cells
(3x forward for fwd+bwd), 2 N D for single forward (prefill), 2 N_active
per generated token for decode.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--mesh single] [--csv]
"""
from __future__ import annotations

import argparse
import json
import pathlib

ART = pathlib.Path(__file__).resolve().parent / "artifacts" / "dryrun"

PEAK = 197e12
HBM = 819e9
ICI = 50e9

# analytic params (from ModelConfig.n_params / n_active_params, precomputed
# lazily below to avoid importing jax here)
_CACHE = {}


def _counts(arch: str):
    if arch in _CACHE:
        return _CACHE[arch]
    from repro.configs import get_config
    cfg = get_config(arch)
    n = cfg.n_params()
    na = cfg.n_active_params()
    _CACHE[arch] = (n, na, cfg)
    return _CACHE[arch]


def model_flops(arch: str, cell: str, devices: int) -> float:
    """Global useful model FLOPs for this cell (forward+backward for train)."""
    n, na, cfg = _counts(arch)
    non_emb = na - cfg.vocab_size * cfg.d_model * (
        1 if cfg.tie_embeddings else 2)
    seq, batch = {"train_4k": (4096, 256), "prefill_32k": (32768, 32),
                  "decode_32k": (32768, 128), "long_500k": (524288, 1)}[cell]
    if cell == "train_4k":
        return 6.0 * non_emb * seq * batch
    if cell == "prefill_32k":
        return 2.0 * non_emb * seq * batch
    # decode: one token per sequence
    return 2.0 * non_emb * 1 * batch


def analyse(rec: dict) -> dict:
    dev = rec["devices"]
    comp = rec["flops"] / PEAK
    mem = rec["bytes_accessed"] / HBM
    coll = rec["collectives"]["total"] / ICI
    dominant = max((comp, "compute"), (mem, "memory"), (coll, "collective"))[1]
    mf = model_flops(rec["arch"], rec["cell"], dev)
    hlo_global = rec["flops"] * dev
    useful = mf / hlo_global if hlo_global else 0.0
    bound = max(comp, mem, coll)
    # roofline fraction: useful model FLOP/s achievable vs peak, assuming the
    # dominant term sets the step time
    mfu_bound = (mf / dev / PEAK) / bound if bound else 0.0
    return {
        "arch": rec["arch"], "cell": rec["cell"], "mesh": rec["mesh"],
        "compute_s": comp, "memory_s": mem, "collective_s": coll,
        "dominant": dominant, "model_flops": mf,
        "useful_ratio": useful, "roofline_frac": mfu_bound,
        "flops_dev": rec["flops"], "bytes_dev": rec["bytes_accessed"],
        "coll_dev": rec["collectives"]["total"],
    }


def load_all(mesh: str = "single", tag: str = "", prefer_calib: bool = True):
    """Load artifacts; when a '__calib' (scan-corrected) artifact exists for a
    cell it replaces the raw scanned one (see dryrun.run_cell_calibrated)."""
    recs = {}
    for f in sorted(ART.glob(f"*__{mesh}{tag}.json")):
        parts = f.stem.split("__")
        if not tag and len(parts) != 3:
            continue
        rec = json.loads(f.read_text())
        if rec.get("ok"):
            recs[(rec["arch"], rec["cell"])] = rec
    if prefer_calib and not tag:
        for f in sorted(ART.glob(f"*__{mesh}__calib.json")):
            rec = json.loads(f.read_text())
            if rec.get("ok"):
                recs[(rec["arch"], rec["cell"])] = rec
    return [analyse(r) for _, r in sorted(recs.items())]


def fmt_table(rows) -> str:
    hdr = (f"{'arch':22s} {'cell':12s} {'compute':>10s} {'memory':>10s} "
           f"{'collect':>10s} {'dominant':>10s} {'useful':>7s} {'roofl%':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:22s} {r['cell']:12s} {r['compute_s']:.3e} "
            f"{r['memory_s']:.3e} {r['collective_s']:.3e} "
            f"{r['dominant']:>10s} {r['useful_ratio']:6.2f} "
            f"{100*r['roofline_frac']:6.1f}%")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args(argv)
    rows = load_all(args.mesh, args.tag)
    if args.csv:
        print("arch,cell,mesh,compute_s,memory_s,collective_s,dominant,"
              "useful_ratio,roofline_frac")
        for r in rows:
            print(f"{r['arch']},{r['cell']},{r['mesh']},{r['compute_s']:.6e},"
                  f"{r['memory_s']:.6e},{r['collective_s']:.6e},"
                  f"{r['dominant']},{r['useful_ratio']:.4f},"
                  f"{r['roofline_frac']:.4f}")
    else:
        print(fmt_table(rows))


if __name__ == "__main__":
    main()
