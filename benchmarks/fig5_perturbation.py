"""Fig. 5 reproduction: perturbation-bound heatmap over rank transitions
(r -> r') computed from real attention spectra (Eq. 4 / Eq. 9), plus the
trust-region mask at the annealed threshold."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import bench_cfg, save_json
from repro.core import perturbation as pert
from repro.data.synthetic import SyntheticLM
from repro.models import transformer as tr
from repro.models.api import get_model


def run(quick: bool = False) -> dict:
    cfg = bench_cfg("adaptive")
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    data = SyntheticLM(cfg.vocab_size, 256, 2, seed=13)
    _, aux = tr.forward_dense(cfg, params, data.batch_at(0)["tokens"],
                              collect_aux="rl",
                              rank_rng=jax.random.PRNGKey(0))
    k_s2 = np.asarray(aux["layers"]["k_s2"])          # (L, b, h, d)
    s2 = k_s2.mean(axis=(0, 1, 2))                    # average spectrum
    grid = list(cfg.rank.rank_grid)
    heat = np.zeros((len(grid), len(grid)))
    for i, r in enumerate(grid):
        for j, r2 in enumerate(grid):
            heat[i, j] = float(pert.rank_transition_norm(
                jax.numpy.asarray(s2), r, r2))
    norm = float(np.sqrt(s2.sum()))          # ||K||_F scale
    rel = heat / norm
    # late-training annealed threshold (Eq. 11, t=1000): transitions whose
    # relative perturbation exceeds it are vetoed — the paper's Fig. 5
    # "high-cost top-left region"
    eps_rel = float(pert.annealed_threshold(1.0, 1e-3, 1000))
    out = {
        "grid": grid,
        "heatmap": heat.round(4).tolist(),
        "heatmap_rel": rel.round(4).tolist(),
        "trust_region": (rel <= eps_rel).tolist(),
        "threshold_rel": eps_rel,
    }
    print("  ||dA||_F heatmap (rows r -> cols r'):")
    for i, r in enumerate(grid):
        print(f"   r={r:3d}: " + " ".join(f"{v:7.2f}" for v in heat[i]))
    save_json("fig5", out)
    return out


if __name__ == "__main__":
    run()
