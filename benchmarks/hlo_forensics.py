"""HLO forensics for the perf hillclimb: lower one cell and report the
largest collectives and the largest tensor-producing ops, so every
hypothesis in EXPERIMENTS.md section Perf is grounded in the compiled IR.

Usage:
  PYTHONPATH=src:. python -m benchmarks.hlo_forensics --arch qwen2.5-14b \
      --cell train_4k [--layers 2] [--remat dots] [--topk 15]
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse      # noqa: E402
import re            # noqa: E402

import jax           # noqa: E402

from repro.configs.base import SHAPE_CELLS  # noqa: E402

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_DTB = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
        "s32": 4, "u32": 4, "s8": 1, "u8": 1, "pred": 1}


def shape_bytes(tok: str) -> int:
    m = _SHAPE_RE.match(tok)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTB.get(dt, 0)


def forensics(hlo: str, topk: int = 15):
    colls, ops = [], []
    for line in hlo.splitlines():
        line = line.strip()
        if not ("=" in line and "[" in line):
            continue
        rhs = line.split("=", 1)
        shapes = re.findall(r"\w+\[[0-9,]*\]", rhs[1].split("(")[0])
        nbytes = sum(shape_bytes(s) for s in shapes)
        m = re.search(r"\]\**\)?\s*(\w[\w-]*)\(", rhs[1])
        head = rhs[1].split("(")[0].split()
        opname = m.group(1) if m else (head[-1] if head else "?")
        if any(c in line for c in ("all-reduce", "all-gather", "reduce-scatter",
                                   "all-to-all", "collective-permute")):
            colls.append((nbytes, opname, line[:180]))
        elif nbytes > 0:
            ops.append((nbytes, opname, line[:150]))
    colls.sort(reverse=True)
    ops.sort(reverse=True)
    return colls[:topk], ops[:topk]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--cell", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--layers", type=int, default=None,
                    help="override layer count (unrolled when set)")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--sharding", default=None)
    ap.add_argument("--topk", type=int, default=15)
    ap.add_argument("--static-rank", type=int, default=None)
    args = ap.parse_args()

    from repro.launch.dryrun import build_cell
    from repro.launch.mesh import make_production_mesh

    overrides = {}
    if args.layers:
        overrides.update(num_layers=args.layers, scan_layers=False)
        if args.arch == "seamless-m4t-medium":
            overrides["num_encoder_layers"] = args.layers
    if args.remat:
        overrides["remat"] = args.remat
    if args.sharding:
        overrides["sharding"] = args.sharding

    cell = next(c for c in SHAPE_CELLS if c.name == args.cell)
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    fn, fargs, outs = build_cell(args.arch, cell, mesh, overrides=overrides,
                                 static_rank=args.static_rank)
    with mesh:
        jitted = jax.jit(fn, out_shardings=outs) if outs else jax.jit(fn)
        compiled = jitted.lower(*fargs).compile()
    ca = compiled.cost_analysis() or {}
    print(f"flops={ca.get('flops', 0):.4e}  bytes={ca.get('bytes accessed', 0):.4e}")
    colls, ops = forensics(compiled.as_text(), args.topk)
    print(f"\n== top {args.topk} collectives (per-device result bytes) ==")
    for b, op, line in colls:
        print(f"  {b / 1e9:9.3f} GB  {line}")
    print(f"\n== top {args.topk} ops by result bytes ==")
    for b, op, line in ops:
        print(f"  {b / 1e9:9.3f} GB  {op:28s} {line[:100]}")


if __name__ == "__main__":
    main()
