"""Table 1 reproduction (small scale): PPL + FLOPs for Full-Rank, Fixed
Low-Rank, Adaptive SVD, Random Rank, and DR-RL on the synthetic LM corpus.

Paper claims to validate (relative, at reduced scale):
  * DR-RL PPL ~ Full-Rank PPL, better than Fixed/Random/Adaptive
  * DR-RL attention FLOPs fraction < 0.6 of full rank
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import (attn_flops_fraction, bench_cfg, eval_ppl,
                               save_json, train_lm, BENCH_SEQ, BENCH_BATCH)
from repro.core.drrl import init_agent
from repro.data.synthetic import SyntheticLM
from repro.train.rl import train_agent

METHODS = ("off", "fixed", "adaptive", "random", "drrl")
LABELS = {"off": "Full-Rank", "fixed": "Fixed Low-Rank (r=16)",
          "adaptive": "Adaptive SVD (90%)", "random": "Random Rank",
          "drrl": "DR-RL (ours)"}


def run(steps: int = 60, quick: bool = False) -> dict:
    if quick:
        steps = 20
    results = {}
    for mode in METHODS:
        cfg = bench_cfg(mode)
        agent = None
        t0 = time.monotonic()
        if mode == "drrl":
            # hybrid training (paper 4.5.3): BC warm start + PPO on a
            # briefly pretrained LM, then the LM continues training with the
            # greedy policy active (inference-time adaptation protocol)
            warm = train_lm(bench_cfg("off"), steps=max(steps // 3, 5))
            agent = init_agent(jax.random.PRNGKey(7), cfg.rank, cfg.d_model)
            data = SyntheticLM(cfg.vocab_size, BENCH_SEQ, BENCH_BATCH, seed=21)
            agent, _ = train_agent(cfg, warm["params"], agent, data,
                                   bc_steps=3 if quick else 8,
                                   ppo_steps=3 if quick else 10,
                                   ppo_epochs=1)
        out = train_lm(cfg, steps=steps, agent=agent)
        ppl = eval_ppl(cfg, out["params"], out["fns"], agent=agent)
        frac = attn_flops_fraction(cfg, out["params"], agent=agent)
        results[mode] = {
            "label": LABELS[mode], "ppl": round(ppl, 3),
            "attn_flops_frac": round(frac, 4),
            "train_wall_s": round(out["wall_s"], 1),
            "final_train_loss": round(out["losses"][-1], 4),
            "setup_s": round(time.monotonic() - t0 - out["wall_s"], 1),
        }
        print(f"  {LABELS[mode]:24s} ppl={ppl:8.3f} "
              f"attn_flops={frac:.3f} ({out['wall_s']:.0f}s)")
    save_json("table1", results)
    return results


if __name__ == "__main__":
    run()
