"""Table 2 reproduction: DR-RL ablations on the synthetic corpus.

  Full DR-RL | w/o RL (fixed policy) | w/o perturbation guardrail |
  w/o reward shaping (beta = 0)
"""
from __future__ import annotations

import dataclasses

import jax

from benchmarks.common import (attn_flops_fraction, bench_cfg, eval_ppl,
                               save_json, train_lm, BENCH_SEQ, BENCH_BATCH)
from repro.core.drrl import init_agent
from repro.data.synthetic import SyntheticLM
from repro.train.rl import train_agent

VARIANTS = {
    "full": {},
    "wo_rl": {"mode": "fixed"},                       # fixed policy
    "wo_perturbation": {"guardrail": False},
    "wo_reward_shaping": {"beta": 0.0},
}
LABELS = {"full": "Full DR-RL", "wo_rl": "w/o RL (Fixed Policy)",
          "wo_perturbation": "w/o Perturbation",
          "wo_reward_shaping": "w/o Reward Shaping"}


def run(steps: int = 50, quick: bool = False) -> dict:
    if quick:
        steps = 20
    results = {}
    warm = train_lm(bench_cfg("off"), steps=max(steps // 3, 5))
    for name, delta in VARIANTS.items():
        cfg = bench_cfg("drrl")
        rank = dataclasses.replace(cfg.rank, **delta)
        cfg = cfg.with_(rank=rank)
        agent = None
        if rank.mode == "drrl":
            agent = init_agent(jax.random.PRNGKey(7), rank, cfg.d_model)
            data = SyntheticLM(cfg.vocab_size, BENCH_SEQ, BENCH_BATCH, seed=21)
            agent, _ = train_agent(cfg, warm["params"], agent, data,
                                   bc_steps=3 if quick else 6,
                                   ppo_steps=3 if quick else 8, ppo_epochs=1)
        out = train_lm(cfg, steps=steps, agent=agent)
        ppl = eval_ppl(cfg, out["params"], out["fns"], agent=agent)
        frac = attn_flops_fraction(cfg, out["params"], agent=agent)
        results[name] = {"label": LABELS[name], "ppl": round(ppl, 3),
                         "attn_flops_frac": round(frac, 4)}
        print(f"  {LABELS[name]:28s} ppl={ppl:8.3f} attn_flops={frac:.3f}")
    save_json("table2", results)
    return results


if __name__ == "__main__":
    run()
