"""Table 3 reproduction: downstream classification (SST-2 analogue).

Pretrain a small LM once, attach a classification head on the mean-pooled
final hidden state, fine-tune with each attention method active, report
accuracy. Adds the static baselines the paper compares against: Performer
(FAVOR+) and Nystromformer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_cfg, save_json, train_lm, BENCH_BATCH
from repro import nn
from repro.configs.base import TrainConfig
from repro.core.drrl import init_agent
from repro.data.synthetic import SyntheticClassification, SyntheticLM
from repro.models import transformer as tr
from repro.optim import adamw
from repro.optim.schedules import make_lr_fn
from repro.train.rl import train_agent

METHODS = ("off", "performer", "nystrom", "fixed", "adaptive", "drrl")
LABELS = {"off": "Full-Rank", "performer": "Performer",
          "nystrom": "Nystromformer", "fixed": "Fixed Rank (r=16)",
          "adaptive": "Adaptive SVD", "drrl": "DR-RL (ours)"}
CLS_SEQ = 64


def run(ft_steps: int = 60, quick: bool = False) -> dict:
    if quick:
        ft_steps = 25
    base = train_lm(bench_cfg("off"), steps=10 if quick else 40)
    results = {}
    for mode in METHODS:
        cfg = bench_cfg(mode)
        agent = None
        if mode == "drrl":
            agent = init_agent(jax.random.PRNGKey(7), cfg.rank, cfg.d_model)
            lm_data = SyntheticLM(cfg.vocab_size, CLS_SEQ, BENCH_BATCH,
                                  seed=21)
            agent, _ = train_agent(cfg, base["params"], agent, lm_data,
                                   bc_steps=3 if quick else 6,
                                   ppo_steps=3 if quick else 8, ppo_epochs=1)

        params = {"trunk": base["params"],
                  "head": nn.dense_init(jax.random.PRNGKey(5), cfg.d_model, 2)}
        data = SyntheticClassification(cfg.vocab_size, CLS_SEQ, BENCH_BATCH,
                                       seed=4)

        def loss_fn(p, batch, rng=None):
            extra = {}
            if cfg.rank.mode == "drrl":
                extra = {"policy_params": agent,
                         "rank_rng": jax.random.PRNGKey(0)}
            elif cfg.rank.mode == "random":
                extra = {"rank_rng": jax.random.PRNGKey(0)}
            _, aux = tr.forward_dense(cfg, p["trunk"], batch["tokens"],
                                      return_hidden=True, **extra)
            pooled = jnp.mean(aux["hidden"].astype(jnp.float32), axis=1)
            cls = nn.linear(pooled, p["head"].astype(pooled.dtype))
            labels = batch["labels"]
            logp = jax.nn.log_softmax(cls, -1)
            nll = -jnp.take_along_axis(logp, labels[:, None], -1)[:, 0]
            acc = jnp.mean((jnp.argmax(cls, -1) == labels).astype(jnp.float32))
            return jnp.mean(nll), acc

        tc = TrainConfig(lr=2e-3, total_steps=ft_steps,
                         warmup_steps=max(ft_steps // 10, 1),
                         weight_decay=0.0)
        lr_fn = make_lr_fn(tc)
        opt = adamw.init(params)
        grad = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))
        for i in range(ft_steps):
            (loss, _), g = grad(params, data.batch_at(i))
            params, opt, _ = adamw.update(tc, lr_fn, opt, params, g)
        ev = jax.jit(lambda p, b: loss_fn(p, b)[1])
        accs = [float(ev(params, data.batch_at(5000 + i))) for i in range(6)]
        acc = float(np.mean(accs))
        results[mode] = {"label": LABELS[mode], "accuracy": round(acc, 4)}
        print(f"  {LABELS[mode]:20s} acc={acc:.4f}")
    save_json("table3", results)
    return results


if __name__ == "__main__":
    run()
