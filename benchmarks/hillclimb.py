"""Perf hillclimb driver (§Perf): run named config variants of a cell
through the calibrated dry-run, record the three roofline terms per
variant, and print the hypothesis -> before -> after log.

Variants compose config overrides; every run writes an artifact tagged
with the variant name so EXPERIMENTS.md can cite exact numbers.

Usage:
  PYTHONPATH=src:. python -m benchmarks.hillclimb --cell qwen-train [--only v2_bf16]
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse      # noqa: E402
import json          # noqa: E402

from repro.configs.base import SHAPE_CELLS  # noqa: E402

PEAK, HBM, ICI = 197e12, 819e9, 50e9

# hypothesis text lives here so the EXPERIMENTS log and the code can't drift
CELLS = {
    "qwen-train": {
        "arch": "qwen2.5-14b", "cell": "train_4k",
        "variants": [
            ("v0_baseline", {},
             "paper-faithful baseline (full-rank attention, f32 softmax, "
             "Megatron TP + FSDP, remat=dots)"),
            ("v1_bf16_scores", {"softmax_dtype": "bfloat16"},
             "H1: the dominant HLO tensors are f32[b,h,s,s] softmax chains; "
             "storing scores/probs in bf16 (f32 denominator) halves s^2 "
             "traffic => memory term ~-45%"),
            ("v2_seqshard", {"softmax_dtype": "bfloat16",
                             "seq_shard_attn": True},
             "H2: 40 heads % 16 != 0 forced GSPMD to gather the batch for "
             "score tensors (85.9GB/dev each); sharding scores over "
             "(data, query-seq x model) divides them 16x further and kills "
             "the gather all-reduces => memory -10x, collective down"),
            ("v3_remat_none", {"softmax_dtype": "bfloat16",
                               "seq_shard_attn": True, "remat": "none"},
             "H3: remat=dots recomputes the s^2 chains in bwd; storing "
             "activations instead trades HBM capacity for ~1.3x less "
             "traffic and ~1.25x fewer flops"),
            ("v4_rank64", {"softmax_dtype": "bfloat16",
                           "seq_shard_attn": True},
             "H4 (beyond-paper, uses the paper's own technique at serving "
             "rank): DR-RL static bucket r=64 halves the score-contraction "
             "FLOPs (128->64) => compute term of scores -2x",
             64),
            ("v5_seqshard_f32", {"seq_shard_attn": True},
             "H5 (isolation): seq-sharding with the stock f32 softmax — "
             "is bf16 score storage adding or removing bytes once sharding "
             "is fixed? (H1 said remove; v1 measured +9%)"),
            ("v7_best", {"seq_shard_attn": True, "remat": "none"},
             "combine the confirmed wins: seq-sharded scores + sharded CE "
             "+ remat none (store activations)"),
            ("v6_sharded_ce", {"seq_shard_attn": True},
             "H6: iota-compare sharded cross-entropy (see deepseek H4) on "
             "qwen's 152k vocab => memory down, collective down"),
        ],
    },
    "qwen-prefill": {
        "arch": "qwen2.5-14b", "cell": "prefill_32k",
        "variants": [
            ("v0_full", {"seq_shard_attn": True},
             "paper-faithful full-rank prefill at L=32k (seq-sharded "
             "scores); attention is ~100x the FFN FLOPs here — the paper's "
             "'long-sequence regime'"),
            ("v1_rank64", {"seq_shard_attn": True},
             "H-paper: DR-RL serving bucket r=64 — score contraction "
             "128->64 should cut ~25% of total prefill FLOPs (scores are "
             "~half the attention work)", 64),
            ("v2_rank32", {"seq_shard_attn": True},
             "H-paper: aggressive bucket r=32 (the paper's fixed-rank "
             "baseline value) => ~37% score FLOPs cut", 32),
        ],
    },
    "qwen-decode": {
        "arch": "qwen2.5-14b", "cell": "decode_32k",
        "variants": [
            ("v0_baseline", {},
             "baseline: GQA kv=8 cannot shard heads over model=16; the "
             "824GB KV cache replicates across 'model' and 116GB/dev of "
             "all-gather moves it"),
            ("v1_splitkv", {"cache_seq_shard": True},
             "H1: shard the cache sequence dim M over 'model' (split-KV "
             "decode); partial-softmax combine is tiny => collective -10x"),
            ("v2_splitkv_bf16", {"cache_seq_shard": True,
                                 "softmax_dtype": "bfloat16"},
             "H2: + bf16 scores on the 32k decode score row"),
            ("v3_splitkv_attn", {"cache_seq_shard": True,
                                 "softmax_dtype": "bfloat16"},
             "H3: v1 left the cache resharded (f32 all-gather over kv "
             "heads!) between update and use; constraining attention to "
             "consume the M-sharded layout makes the partial-softmax "
             "combine the only cross-shard op => collective -big"),
        ],
    },
    "deepseek-train": {
        "arch": "deepseek-v3-671b", "cell": "train_4k",
        "variants": [
            ("v0_baseline", {},
             "paper-faithful baseline (MLA + 256-expert MoE, remat=full)"),
            ("v1_bf16_scores", {"softmax_dtype": "bfloat16"},
             "H1: bf16 score chains (MLA heads=128 shard cleanly, but "
             "s^2 f32 chains still dominate bytes) => memory -30-45%"),
            ("v2_remat_dots", {"remat": "dots"},
             "H2: remat=full recomputes every MoE expert matmul in bwd; "
             "dots policy saves matmul outputs => compute -25%, bytes down"),
            ("v3_seqshard", {"remat": "dots", "seq_shard_attn": True},
             "H3: + sequence-sharded scores (seq 4096 % 16 == 0 always; "
             "also splits the softmax bwd chains 16x further)"),
            ("v5_moe_bf16", {"remat": "dots", "seq_shard_attn": True},
             "H5: the MoE combine multiplied the (T*K, d) gather chain by "
             "f32 gates, promoting 240 GB/op fusions to f32; casting the "
             "gate to bf16 keeps dispatch+combine in bf16 => memory -25%+"),
            ("v4_sharded_ce", {"remat": "dots", "seq_shard_attn": True},
             "H4: the loss all-gathers full-batch f32[256,4096,8080] logits "
             "(33.9GB x several, incl. MTP) because take_along_axis gathers "
             "over the model-sharded vocab; iota-compare CE + logits "
             "constraint keeps it local => memory -2x, collective down"),
        ],
    },
}


def run_variant(arch, cell_name, overrides, static_rank=None, tag=""):
    """run_cell_calibrated with this variant's config overrides merged in
    (wraps dryrun.build_cell for the duration of the run)."""
    import repro.launch.dryrun as dr
    cell = next(c for c in SHAPE_CELLS if c.name == cell_name)
    orig = dr.build_cell

    def patched(a, c, m, static_rank=None, overrides=None):
        merged = dict(globals_ov)
        merged.update(overrides or {})
        return orig(a, c, m, static_rank=static_rank, overrides=merged)

    globals_ov = dict(overrides)
    dr.build_cell = patched
    try:
        rec = dr.run_cell_calibrated(arch, cell, "single",
                                     static_rank=static_rank,
                                     tag=tag, force=False)
    finally:
        dr.build_cell = orig
    return rec


def terms(rec):
    return (rec["flops"] / PEAK, rec["bytes_accessed"] / HBM,
            rec["collectives"]["total"] / ICI)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=list(CELLS))
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    spec = CELLS[args.cell]
    print(f"=== hillclimb {args.cell}: {spec['arch']} x {spec['cell']} ===")
    base = None
    for entry in spec["variants"]:
        name, ov, hyp = entry[0], entry[1], entry[2]
        static_rank = entry[3] if len(entry) > 3 else None
        if args.only and name != args.only:
            continue
        rec = run_variant(spec["arch"], spec["cell"], ov,
                          static_rank=static_rank, tag=f"__{name}")
        if not rec.get("ok"):
            print(f"  {name}: FAILED {rec.get('error')}")
            continue
        c, m, x = terms(rec)
        line = f"  {name:16s} compute={c:9.3e} memory={m:9.3e} coll={x:9.3e}"
        if base:
            bc, bm, bx = base
            line += (f"   Δ vs base: comp {c / bc:5.2f}x mem {m / bm:5.2f}x "
                     f"coll {x / bx:5.2f}x")
        else:
            base = (c, m, x)
        print(line)
        print(f"      {hyp}")


if __name__ == "__main__":
    main()
