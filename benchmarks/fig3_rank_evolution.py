"""Fig. 3 reproduction: layer-wise rank allocation. The paper's Fig. 3 shows
the agent allocating different computational budgets across layers/time.
We report the per-layer mean rank selected on trained-model spectra (energy
policy and DR-RL agent)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import (bench_cfg, save_json, train_lm, BENCH_BATCH,
                               BENCH_SEQ)
from repro.core.drrl import init_agent
from repro.data.synthetic import SyntheticLM
from repro.models import transformer as tr
from repro.train.rl import train_agent


def run(quick: bool = False) -> dict:
    trained = train_lm(bench_cfg("off"), steps=15 if quick else 60)
    out = {}
    for mode in ("adaptive", "drrl"):
        cfg = bench_cfg(mode)
        agent = None
        if mode == "drrl":
            agent = init_agent(jax.random.PRNGKey(7), cfg.rank, cfg.d_model)
            data = SyntheticLM(cfg.vocab_size, BENCH_SEQ, BENCH_BATCH, seed=21)
            agent, _ = train_agent(cfg, trained["params"], agent, data,
                                   bc_steps=3 if quick else 8,
                                   ppo_steps=3 if quick else 8, ppo_epochs=1)
        data = SyntheticLM(cfg.vocab_size, BENCH_SEQ, 4, seed=9)
        extra = {"rank_rng": jax.random.PRNGKey(0)}
        if agent is not None:
            extra["policy_params"] = agent
        _, aux = tr.forward_dense(cfg, trained["params"],
                                  data.batch_at(0)["tokens"],
                                  collect_aux="ranks", **extra)
        ranks = np.asarray(aux["layers"]["rank"], np.float32)
        per_layer = ranks.mean(axis=(1, 2)).round(2).tolist()
        out[mode] = {"per_layer_mean_rank": per_layer,
                     "overall": round(float(ranks.mean()), 2)}
        print(f"  {mode:9s} per-layer mean rank: {per_layer} "
              f"(grid {cfg.rank.rank_grid})")
    save_json("fig3", out)
    return out


if __name__ == "__main__":
    run()
