"""End-to-end behaviour tests for the DR-RL system: training converges,
checkpoint/restart resumes bit-exact, adaptive serving dispatches rank
buckets, and the DR-RL modes trade fidelity for FLOPs as the paper claims."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.configs.base import RankConfig, TrainConfig
from repro.core.rewards import flops_fraction
from repro.data.synthetic import SyntheticLM
from repro.models import transformer as tr
from repro.models.api import get_model
from repro.optim import adamw
from repro.train.loop import make_train_step, run_training

RNG = jax.random.PRNGKey(0)


def test_training_reduces_loss():
    cfg = get_config("drrl-paper", reduced=True).with_(
        rank=RankConfig(mode="off"))
    fns = get_model(cfg)
    tc = TrainConfig(global_batch=4, seq_len=64, lr=1e-3, total_steps=30,
                     warmup_steps=3, checkpoint_every=0, log_every=100)
    data = SyntheticLM(cfg.vocab_size, tc.seq_len, tc.global_batch, seed=0)
    out = run_training(cfg, tc, init_fn=fns.init,
                       loss_fn=lambda p, b, r: fns.loss(p, b), data=data)
    h = out["history"]
    assert h[-1]["loss"] < h[0]["loss"] - 0.3, h


def test_checkpoint_restart_is_bit_exact(tmp_path):
    cfg = get_config("drrl-paper", reduced=True).with_(
        rank=RankConfig(mode="off"))
    fns = get_model(cfg)
    data = SyntheticLM(cfg.vocab_size, 32, 2, seed=0)
    tc = TrainConfig(global_batch=2, seq_len=32, lr=1e-3, total_steps=6,
                     warmup_steps=1, checkpoint_every=3, log_every=100,
                     async_checkpoint=False, schedule="constant")

    # run A: 6 steps straight through
    outA = run_training(cfg, tc, init_fn=fns.init,
                        loss_fn=lambda p, b, r: fns.loss(p, b), data=data)
    # run B: 3 steps with checkpoint, then "crash" and resume
    cmB = CheckpointManager(str(tmp_path), async_save=False)
    tcB = dataclasses.replace(tc, total_steps=3)
    run_training(cfg, tcB, init_fn=fns.init,
                 loss_fn=lambda p, b, r: fns.loss(p, b), data=data,
                 ckpt_manager=cmB)
    outB = run_training(cfg, tc, init_fn=fns.init,
                        loss_fn=lambda p, b, r: fns.loss(p, b), data=data,
                        ckpt_manager=cmB)
    for a, b in zip(jax.tree_util.tree_leaves(outA["params"]),
                    jax.tree_util.tree_leaves(outB["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=1e-6)


def test_drrl_flops_reduction_vs_fidelity():
    """Paper core claim at unit scale: rank truncation cuts score FLOPs while
    keeping attention-output fidelity high."""
    base = get_config("drrl-paper", reduced=True)
    cfg = base.with_(rank=RankConfig(mode="adaptive", rank_grid=(4, 8, 12, 16),
                                     energy_threshold=0.90))
    params = tr.init_dense(cfg, RNG)
    toks = jax.random.randint(RNG, (2, 64), 0, cfg.vocab_size)
    _, aux = tr.forward_dense(cfg, params, toks, compute_fidelity=True,
                              collect_aux="ranks", rank_rng=RNG)
    la = aux["layers"]
    fid = float(np.mean(np.asarray(la["fidelity"])))
    ranks = np.asarray(la["rank"]).astype(np.float32)
    frac = float(np.mean(np.asarray(
        flops_fraction(jnp.asarray(ranks), 16, 16))))
    assert fid > 0.9, fid
    assert frac < 0.95, frac


def test_adaptive_server_rank_dispatch():
    from repro.launch.serve import AdaptiveServer
    cfg = get_config("drrl-paper", reduced=True).with_(
        rank=RankConfig(mode="adaptive", rank_grid=(4, 8, 12, 16),
                        segment_len=8))
    fns = get_model(cfg)
    params = fns.init(RNG)
    server = AdaptiveServer(cfg, params, max_len=96)
    prompts = jax.random.randint(RNG, (2, 16), 0, cfg.vocab_size)
    res = server.generate(prompts, 24, segment_len=8)
    assert res["tokens"].shape == (2, 24)
    # per-step per-stream rank record: 23 fused steps, both streams live
    assert len(res["ranks"]) == 23
    used = {r for step in res["ranks"] for r in step}
    assert used <= set(cfg.rank.rank_grid) | {-1}
    assert res["compile_s"] > 0.0 and res["tok_per_s"] > 0.0


def test_grad_accumulation_matches_single_batch():
    cfg = get_config("drrl-paper", reduced=True).with_(
        rank=RankConfig(mode="off"))
    fns = get_model(cfg)
    data = SyntheticLM(cfg.vocab_size, 32, 4, seed=0)
    batch = data.batch_at(0)
    params = fns.init(RNG)
    opt = adamw.init(params)
    tc1 = TrainConfig(global_batch=4, seq_len=32, microbatches=1,
                      lr=1e-3, warmup_steps=1)
    tc2 = TrainConfig(global_batch=4, seq_len=32, microbatches=2,
                      lr=1e-3, warmup_steps=1)
    s1 = jax.jit(make_train_step(cfg, tc1, lambda p, b, r: fns.loss(p, b)))
    s2 = jax.jit(make_train_step(cfg, tc2, lambda p, b, r: fns.loss(p, b)))
    p1, _, m1 = s1(params, opt, batch, RNG)
    p2, _, m2 = s2(params, opt, batch, RNG)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-4)


def test_bf16_grad_compression_close_to_fp32():
    cfg = get_config("drrl-paper", reduced=True).with_(
        rank=RankConfig(mode="off"))
    fns = get_model(cfg)
    data = SyntheticLM(cfg.vocab_size, 32, 4, seed=0)
    batch = data.batch_at(0)
    params = fns.init(RNG)
    opt = adamw.init(params)
    tc = TrainConfig(global_batch=4, seq_len=32, microbatches=2, lr=1e-3,
                     warmup_steps=1)
    s_fp = jax.jit(make_train_step(cfg, tc, lambda p, b, r: fns.loss(p, b)))
    s_bf = jax.jit(make_train_step(cfg, tc, lambda p, b, r: fns.loss(p, b),
                                   grad_compression="bf16"))
    p1, _, _ = s_fp(params, opt, batch, RNG)
    p2, _, _ = s_bf(params, opt, batch, RNG)
    deltas = [float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree_util.tree_leaves(p1),
                              jax.tree_util.tree_leaves(p2))]
    assert max(deltas) < 5e-3, max(deltas)
