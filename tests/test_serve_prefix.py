"""Shared-prefix KV reuse (repro.serve.prefix).

Covers the subsystem bottom-up:
  * radix-tree property tests (vendored-hypothesis fallback compatible):
    insert/match/evict round-trips against a real refcounted page pool —
    matches are true prefixes snapped to exact reuse points, refcounts
    never go negative, and zero live references <=> page reclaimable,
  * refcount/COW unit behaviour: page-aligned vs prompt-end reuse
    points, duplicate prompts snapping down a page, split invalidation,
  * the acceptance property: prefix-hit admission is token-for-token
    identical to cold admission across dense/factor x kernel/XLA, with
    recycled slots, copy-on-write tail pages and LRU eviction pressure
    on the line, and no page leaks under refcounting,
  * Engine.reset() clears the tree; EngineConfig validation.
"""
import threading

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.configs.base import RankConfig
from repro.models.api import get_model
from repro.serve import PagedKVCache, PrefixCache, Request, ServeEngine
from repro.serve.api import Engine, EngineConfig, SamplingParams


pytestmark = pytest.mark.serve

RNG = jax.random.PRNGKey(0)


def _cfg(mode="adaptive", **kw):
    cfg = get_config("drrl-paper", reduced=True)
    return cfg.with_(rank=RankConfig(mode=mode, rank_grid=(4, 8, 12, 16),
                                     fixed_rank=16, segment_len=8, **kw))


# ---------------------------------------------------------------------------
# radix tree + refcount property tests (host control plane only)
# ---------------------------------------------------------------------------

def _aligned_snaps(p_len, ps):
    """The snapshot positions the engine would capture with chunk == ps:
    every page boundary inside the prompt, plus the prompt end."""
    pts = {pos: None for pos in range(ps, p_len, ps)}
    pts[p_len] = None
    return pts


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 16 - 1), st.integers(1, 3), st.integers(12, 40))
def test_prefix_tree_roundtrip_properties(seed, n_slots, n_ops):
    """Random insert/match/release/evict workload over a tiny pool drawn
    from a 2-token alphabet (prefix collisions everywhere). Invariants
    after every op: refcount == slot references + tree references (never
    negative), zero refs <=> free-listed, match returns a snapped true
    prefix of an inserted prompt shorter than the query."""
    rnd = np.random.default_rng(seed)
    cfg = _cfg("off")
    ps = 8
    cache = PagedKVCache(cfg, n_slots, max_len=32, page_size=ps, n_pages=20)
    pc = PrefixCache(cache)
    inserted = []          # prompts the tree has seen
    live = {}              # slot -> pages owed to release

    def invariants():
        cache.check_refs(pc.all_pages())
        assert (cache.ref >= 0).all()

    for _ in range(n_ops):
        op = rnd.integers(0, 4)
        if op <= 1:                                   # admit + insert
            free = [s for s in range(n_slots) if s not in live]
            if not free:
                continue
            slot = free[0]
            p_len = int(rnd.integers(4, 25))
            toks = rnd.integers(0, 2, p_len).astype(np.int32)
            hit = pc.match(toks)
            assert hit.reuse_len <= p_len - 1
            if hit.reuse_len:
                # a true prefix of something inserted earlier
                assert any(len(q) >= hit.reuse_len
                           and np.array_equal(q[:hit.reuse_len],
                                              toks[:hit.reuse_len])
                           for q in inserted)
                assert (hit.reuse_len % ps == 0
                        or any(len(q) == hit.reuse_len for q in inserted))
            shared = hit.pages[:-1] if hit.cow_src is not None else hit.pages
            if not cache.allocate(slot, p_len + 2, prefix_pages=shared):
                continue
            invariants()
            n_pg = cache.pages_needed(p_len)
            pc.insert(toks, [int(p) for p in cache.page_table[slot, :n_pg]],
                      _aligned_snaps(p_len, ps))
            inserted.append(toks)
            live[slot] = True
        elif op == 2 and live:                        # release a slot
            slot = list(live)[int(rnd.integers(0, len(live)))]
            cache.release(slot)
            del live[slot]
        elif op == 3:                                 # evict some leaves
            pc.evict_lru(int(rnd.integers(1, 5)))
        invariants()
    # drain: zero live refs => every page reclaimable
    for slot in list(live):
        cache.release(slot)
    pc.evict_lru(cache.n_pages + 1)
    cache.check_refs(pc.all_pages())
    assert pc.all_pages() == []
    assert cache.free_pages == cache.n_pages - 1


def test_refcount_underflow_raises():
    cfg = _cfg("off")
    cache = PagedKVCache(cfg, 1, max_len=16, page_size=8)
    assert cache.allocate(0, 10)
    pages = [int(p) for p in cache.page_table[0] if p]
    cache.release(0)
    with pytest.raises(AssertionError, match="underflow"):
        cache.unref(pages)


def test_match_snaps_to_reuse_points_and_cow():
    """A 20-token prompt (ps=8) caches reuse points at 8, 16 and 20.
    Extending prompts reuse 20 tokens through a COW tail page; an exact
    duplicate must snap down to 16 (at least one token recomputed); a
    prompt diverging mid-page snaps to the last aligned point."""
    cfg = _cfg("off")
    ps = 8
    cache = PagedKVCache(cfg, 1, max_len=32, page_size=ps, n_pages=16)
    pc = PrefixCache(cache)
    rnd = np.random.default_rng(0)
    toks = rnd.integers(0, 50, 20).astype(np.int32)
    assert cache.allocate(0, 24)
    pc.insert(toks, [int(p) for p in cache.page_table[0, :3]],
              _aligned_snaps(20, ps))

    ext = np.concatenate([toks, [7, 8, 9]])
    hit = pc.match(ext)
    assert hit.reuse_len == 20 and len(hit.pages) == 3
    assert hit.cow_src == hit.pages[-1]        # partial tail page: COW
    assert pc.match(toks).reuse_len == 16      # duplicate: snap a page down
    assert pc.match(toks).cow_src is None
    div = toks.copy()
    div[18] += 1                               # diverge mid-tail-page
    assert pc.match(div).reuse_len == 16
    assert pc.match(toks[:9]).reuse_len == 8   # short query caps at P-1


def test_split_invalidates_cut_and_insert_heals():
    """Diverging inside a cached node splits it: the cut point is not an
    exact reuse point (the aggregate mass cannot be decomposed there)
    until a later insertion ending exactly there heals it."""
    cfg = _cfg("off")
    ps = 8
    cache = PagedKVCache(cfg, 2, max_len=32, page_size=ps, n_pages=24)
    pc = PrefixCache(cache)
    rnd = np.random.default_rng(1)
    base = rnd.integers(0, 50, 16).astype(np.int32)
    assert cache.allocate(0, 20)
    # snapshot only at the prompt end: one 16-token node, no interior cut
    pc.insert(base, [int(p) for p in cache.page_table[0, :2]], {16: None})
    fork = base.copy()
    fork[12] += 1                              # splits the node at 12
    assert cache.allocate(1, 20)
    pc.insert(fork, [int(p) for p in cache.page_table[1, :2]], {16: None})
    probe = np.concatenate([base[:12], [99] * 8]).astype(np.int32)
    assert pc.match(probe).reuse_len == 0      # cut at 12 not reusable
    # both originals still fully reusable through the split
    assert pc.match(np.concatenate([base, [1]])).reuse_len == 16
    assert pc.match(np.concatenate([fork, [1]])).reuse_len == 16
    cache.check_refs(pc.all_pages())


# ---------------------------------------------------------------------------
# acceptance: prefix-hit admission == cold admission, token for token
# ---------------------------------------------------------------------------

def _run(cfg, params, prompts, *, prefix, n_slots=2, max_new=8, gap=8,
         **ekw):
    eng = ServeEngine(cfg, params, n_slots=n_slots, max_len=64, page_size=8,
                      segment_len=8, max_new_cap=max_new, prefill_chunk=8,
                      prefix_cache=prefix, **ekw)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, tokens=p, max_new=max_new,
                           arrival=gap * i))
    outs = eng.run()
    return outs, eng


def _shared_prefix_prompts(cfg, n=3, shared_len=24, tail=8, seed=0):
    rnd = np.random.default_rng(seed)
    shared = rnd.integers(0, cfg.vocab_size, shared_len).astype(np.int32)
    return [np.concatenate([shared, rnd.integers(0, cfg.vocab_size,
                                                 tail).astype(np.int32)])
            for _ in range(n)]


@pytest.mark.parametrize("mode,factor,kernel", [
    ("adaptive", None, False),          # dense paged read, live ranks
    ("fixed", True, False),             # factor-form cache, XLA
    ("fixed", True, True),              # factor-form cache, Pallas kernel
    ("off", None, False),               # no rank path, pages only
])
def test_prefix_hit_parity_with_cold(mode, factor, kernel):
    """Shared-system-prompt traffic: later requests hit the cached prefix
    (arrivals spaced past the first prefill) and must decode exactly the
    tokens the cache-off engine produces — the rehydrated mass row seeds
    the same weighted-Gram first decision a cold prefill would take."""
    cfg = _cfg(mode)
    params = get_model(cfg).init(RNG)
    prompts = _shared_prefix_prompts(cfg, n=3)
    kw = dict(factor_cache=factor, use_kernel=kernel)
    outs_on, eng_on = _run(cfg, params, prompts, prefix=True, **kw)
    outs_off, _ = _run(cfg, params, prompts, prefix=False, **kw)
    for i in range(len(prompts)):
        np.testing.assert_array_equal(
            outs_on[i], outs_off[i],
            err_msg=f"stream {i}: prefix-hit decode diverged from cold")
    s = eng_on.stats
    assert s["prefix_hits"] == 2 and s["prefix_misses"] == 1
    assert s["prefix_reused_tokens"] == 2 * 24
    # ISSUE metric: prefill tokens computed shrink by the reused amount
    assert s["prefill_tokens"] == sum(len(p) for p in prompts) - 2 * 24
    # page accounting: every non-tree page back in the pool, refcounts ==
    # references (the generalized leak invariant)
    eng_on.cache.check_refs(eng_on.prefix.all_pages())
    tree = len(eng_on.prefix.all_pages())
    assert eng_on.cache.free_pages == eng_on.cache.n_pages - 1 - tree


def test_prefix_cow_and_duplicate_parity():
    """Reuse at a prompt-end point (mid-page): the extending request COWs
    the shared tail page; the exact duplicate snaps down to the page
    boundary. Both must match the cache-off engine token for token."""
    cfg = _cfg("adaptive")
    params = get_model(cfg).init(RNG)
    rnd = np.random.default_rng(3)
    p1 = rnd.integers(0, cfg.vocab_size, 20).astype(np.int32)
    prompts = [p1,
               np.concatenate([p1, rnd.integers(0, cfg.vocab_size,
                                                8).astype(np.int32)]),
               p1.copy()]
    outs_on, eng_on = _run(cfg, params, prompts, prefix=True)
    outs_off, _ = _run(cfg, params, prompts, prefix=False)
    for i in range(3):
        np.testing.assert_array_equal(outs_on[i], outs_off[i])
    s = eng_on.stats
    assert s["prefix_cow"] == 1                 # the extension COWed
    assert s["prefix_reused_tokens"] == 20 + 16  # end point + snapped dup
    eng_on.cache.check_refs(eng_on.prefix.all_pages())


def test_prefix_hit_on_recycled_slot():
    """More requests than slots: a hit rides a slot whose previous
    occupant left stale mass/kt/prompt state behind."""
    cfg = _cfg("adaptive")
    params = get_model(cfg).init(RNG)
    prompts = _shared_prefix_prompts(cfg, n=3, seed=4)
    kw = dict(n_slots=1, factor_cache=True)
    outs_on, eng_on = _run(cfg, params, prompts, prefix=True, **kw)
    outs_off, _ = _run(cfg, params, prompts, prefix=False, **kw)
    for i in range(3):
        np.testing.assert_array_equal(outs_on[i], outs_off[i])
    assert eng_on.stats["prefix_hits"] >= 1


def test_prefix_parity_under_eviction_pressure():
    """A pool with zero prefix headroom forces LRU eviction while serving
    two alternating prefix families through one slot; hits that survive
    must stay token-exact and the refcount invariant must hold through
    evict/release interleavings."""
    cfg = _cfg("adaptive")
    params = get_model(cfg).init(RNG)
    rnd = np.random.default_rng(5)
    fam_a = rnd.integers(0, cfg.vocab_size, 16).astype(np.int32)
    fam_b = rnd.integers(0, cfg.vocab_size, 16).astype(np.int32)
    prompts = [np.concatenate([base, rnd.integers(0, cfg.vocab_size,
                                                  6).astype(np.int32)])
               for base in (fam_a, fam_a, fam_b, fam_b, fam_a)]

    def run(prefix):
        eng = ServeEngine(cfg, params, n_slots=1, max_len=32, page_size=8,
                          segment_len=8, max_new_cap=4, prefill_chunk=8,
                          prefix_cache=prefix,
                          prefix_pages=0 if prefix else None)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, tokens=p, max_new=4, arrival=0))
        return eng.run(), eng

    outs_on, eng_on = run(True)
    outs_off, _ = run(False)
    for i in range(len(prompts)):
        np.testing.assert_array_equal(outs_on[i], outs_off[i])
    assert eng_on.stats["prefix_evictions"] > 0
    assert eng_on.stats["prefix_hits"] >= 1
    eng_on.cache.check_refs(eng_on.prefix.all_pages())


def test_prefix_sampled_stream_parity():
    """Sampling PRNG folds (seed, output index): a sampled stream draws
    identically whether its prompt came from a prefix hit or a cold
    prefill."""
    cfg = _cfg("adaptive")
    params = get_model(cfg).init(RNG)
    prompts = _shared_prefix_prompts(cfg, n=2, seed=6)

    def run(prefix):
        eng = ServeEngine(cfg, params, n_slots=2, max_len=64, page_size=8,
                          segment_len=8, max_new_cap=8, prefill_chunk=8,
                          prefix_cache=prefix, sampling=True)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, tokens=p, max_new=8, arrival=8 * i,
                               temperature=0.7, top_k=12, seed=11 + i))
        return eng.run()

    on, off = run(True), run(False)
    for i in range(2):
        np.testing.assert_array_equal(on[i], off[i])


def test_engine_reset_clears_tree_and_validation():
    cfg = _cfg("adaptive")
    params = get_model(cfg).init(RNG)
    with pytest.raises(ValueError, match="prefix_cache"):
        ServeEngine(cfg, params, prefill_chunk=None, prefix_cache=True)
    with pytest.raises(ValueError, match="prefix_cache"):
        EngineConfig(prefill_chunk=None, prefix_cache=True)
    eng = Engine(cfg, params, config=EngineConfig(
        n_slots=2, max_len=64, page_size=8, prefill_chunk=8,
        max_new_cap=8, prefix_cache=True))
    prompts = _shared_prefix_prompts(cfg, n=2, seed=7)
    for p in prompts:
        eng.submit(p, SamplingParams(max_new=4))
    eng.run()
    assert eng.core.prefix.n_nodes > 0
    eng.reset()
    assert eng.core.prefix.n_nodes == 0
    assert eng.core.prefix.all_pages() == []
    assert eng.stats["prefix_hits"] == 0
    assert eng.core.cache.free_pages == eng.core.cache.n_pages - 1


# ---------------------------------------------------------------------------
# satellite: thread-safe submit
# ---------------------------------------------------------------------------

def test_submit_from_background_thread():
    """Requests submitted from a non-loop thread while the step loop runs
    must all complete with the same tokens an upfront submission yields
    (per-stream decode is batching/admission-invariant)."""
    cfg = _cfg("off")
    params = get_model(cfg).init(RNG)
    rnd = np.random.default_rng(8)
    prompts = [rnd.integers(0, cfg.vocab_size, s).astype(np.int32)
               for s in (9, 13, 11, 7)]

    ref_eng = Engine(cfg, params, config=EngineConfig(
        n_slots=2, max_len=64, page_size=8, prefill_chunk=8, max_new_cap=6,
        sampling=False))
    ref_handles = [ref_eng.submit(p, SamplingParams(max_new=6))
                   for p in prompts]
    ref_eng.run()

    eng = Engine(cfg, params, config=EngineConfig(
        n_slots=2, max_len=64, page_size=8, prefill_chunk=8, max_new_cap=6,
        sampling=False))
    first = eng.submit(prompts[0], SamplingParams(max_new=6))
    rest = []

    def feeder():
        for p in prompts[1:]:
            rest.append(eng.submit(p, SamplingParams(max_new=6)))

    t = threading.Thread(target=feeder)
    t.start()
    # drive the loop until the feeder finished AND everything drained
    # (check liveness BEFORE stepping: a submit landing after a False
    # step() is then seen by the next iteration, never dropped)
    while True:
        alive = t.is_alive()
        more = eng.step()
        if not alive and not more:
            break
    t.join()
    handles = [first] + rest
    assert all(h.done for h in handles)
    for h, r in zip(handles, ref_handles):
        np.testing.assert_array_equal(h.result(), r.result())
    assert eng.core.cache.free_pages == eng.core.cache.n_pages - 1
