"""Static invariant checker (repro.analysis): per-rule fixtures,
pragma life-cycle, and the whole-repo cleanliness smoke.

Each rule gets a positive (seeded violation fires), a negative (the
clean twin in the same fixture stays silent), a pragma'd variant (the
same violation with a justified ``inv-ok`` comment moves to the
suppressed list), and the hygiene cases (stale and malformed pragmas
are themselves findings).  The final smoke asserts the real tree under
``src/`` is clean — the same gate CI runs via tools/check_invariants.py.
"""
import os

import pytest

from repro.analysis.fixtures import (
    FIXTURE_REGISTRY,
    FIXTURES,
    SEED_RE,
    run_selftest,
    seeded_expectations,
)
from repro.analysis.pragmas import scan_pragmas
from repro.analysis.report import format_report, run_static

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_fixture(tmp_path, name, source):
    path = tmp_path / name
    path.write_text(source)
    un, sup = run_static([str(path)], reg=FIXTURE_REGISTRY)
    return str(path), un, sup


def _seeded_lines(source, rule):
    return {i for i, line in enumerate(source.splitlines(), start=1)
            if any(m.group(1) == rule for m in SEED_RE.finditer(line))}


# ---------------------------------------------------------------------------
# per-rule positive + negative
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rule,fixture", [
    ("R1", "fix_r1.py"),
    ("R2", "fix_r2.py"),
    ("R3", "fix_r3.py"),
    ("R4", "fix_r4.py"),
    ("R5", "fix_r5.py"),
])
def test_rule_fires_exactly_on_seeded_lines(tmp_path, rule, fixture):
    src = FIXTURES[fixture]
    _, un, _ = _run_fixture(tmp_path, fixture, src)
    found = {f.line for f in un if f.rule == rule}
    assert found == _seeded_lines(src, rule), \
        f"{rule} fired on {sorted(found)}, seeded " \
        f"{sorted(_seeded_lines(src, rule))}"
    # negative: nothing outside the seeded set, for ANY rule
    all_seeded = {(r, ln) for (r, _, ln)
                  in seeded_expectations({fixture: src}, str(tmp_path))}
    assert {(f.rule, f.line) for f in un} == all_seeded


def test_selftest_roundtrip():
    ok, lines = run_selftest()
    assert ok, "\n".join(lines)


# ---------------------------------------------------------------------------
# pragma life-cycle
# ---------------------------------------------------------------------------

def test_justified_pragma_suppresses(tmp_path):
    src = FIXTURES["fix_r1.py"].replace(
        "jax.block_until_ready(x)  # seeded[R1]",
        "jax.block_until_ready(x)  # inv-ok[R1]: test suppression")
    _, un, sup = _run_fixture(tmp_path, "fix_r1.py", src)
    assert not any(f.rule == "R1" and "block_until_ready" in f.message
                   for f in un)
    assert any(f.rule == "R1" and "block_until_ready" in f.message
               for f in sup)
    # the pragma is live, so no R5 stale finding appears for its line
    assert not any(f.rule == "R5" for f in un)


def test_pragma_only_covers_listed_rule(tmp_path):
    # an R4 pragma on an R1 violation suppresses nothing — and is
    # itself stale
    src = FIXTURES["fix_r1.py"].replace(
        "jax.block_until_ready(x)  # seeded[R1]",
        "jax.block_until_ready(x)  # inv-ok[R4]: wrong rule on purpose")
    path, un, sup = _run_fixture(tmp_path, "fix_r1.py", src)
    assert any(f.rule == "R1" and "block_until_ready" in f.message
               for f in un)
    assert any(f.rule == "R5" and "stale" in f.message for f in un)


def test_stale_pragma_is_a_finding(tmp_path):
    _, un, _ = _run_fixture(tmp_path, "clean.py",
                            "X = 1  # inv-ok[R1]: nothing ever fired here\n")
    assert [f.rule for f in un] == ["R5"]
    assert "stale" in un[0].message


@pytest.mark.parametrize("line,complaint", [
    ("X = 1  # inv-ok[R1]", "justification"),
    ("X = 1  # inv-ok[]: no rules listed", "no rules"),
    ("X = 1  # inv-ok[R7]: not a rule", "unknown rule"),
])
def test_malformed_pragmas_are_findings(tmp_path, line, complaint):
    _, un, _ = _run_fixture(tmp_path, "bad.py", line + "\n")
    assert [f.rule for f in un] == ["R5"]
    assert complaint in un[0].message


def test_pragma_scanner_parses_multi_rule():
    pragmas = scan_pragmas(
        "x.py", "y = 1  # inv-ok[R1,R4]: one reason for both\n")
    assert len(pragmas) == 1
    assert pragmas[0].rules == ("R1", "R4")
    assert pragmas[0].malformed is None
    assert pragmas[0].covers("R4", 1) and not pragmas[0].covers("R2", 1)


# ---------------------------------------------------------------------------
# report formatting
# ---------------------------------------------------------------------------

def test_json_report_shape(tmp_path):
    import json
    _, un, sup = _run_fixture(tmp_path, "fix_r3.py", FIXTURES["fix_r3.py"])
    doc = json.loads(format_report(un, sup, fmt="json"))
    assert doc["ok"] is False
    assert doc["counts"]["R3"] == len(_seeded_lines(FIXTURES["fix_r3.py"],
                                                    "R3"))
    assert all({"rule", "path", "line", "col", "message",
                "rule_name"} <= set(f) for f in doc["findings"])


def test_clean_tree_reports_ok(tmp_path):
    _, un, sup = _run_fixture(tmp_path, "fine.py", "X = 1\n")
    assert not un and not sup
    assert "invariants clean" in format_report(un, sup)


# ---------------------------------------------------------------------------
# whole-repo smoke: the real tree must be clean under the real registry
# ---------------------------------------------------------------------------

def test_repo_tree_is_clean():
    un, sup = run_static([REPO_SRC])
    assert not un, format_report(un, sup)
    # the sanctioned syncs exist and stay visible as suppressions
    assert any(f.rule == "R1" and f.path.endswith("serve/engine.py")
               for f in sup), \
        "expected the engine's sanctioned per-step sync among suppressions"
