"""Sharding rules: spec trees are structurally valid, divisibility is
enforced, and an 8-device pjit end-to-end run works (subprocess so the
forced device count doesn't leak into other tests)."""
import json
import os
import subprocess
import sys

import pytest

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.dist import sharding as shd
from repro.models.api import get_model

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _host_mesh():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def test_param_pspecs_cover_all_leaves():
    for arch in ["qwen2.5-14b", "granite-moe-3b-a800m", "deepseek-v3-671b",
                 "zamba2-7b", "rwkv6-1.6b", "seamless-m4t-medium"]:
        cfg = get_config(arch, reduced=True)
        fns = get_model(cfg)
        shapes = jax.eval_shape(fns.init, jax.random.PRNGKey(0))
        specs = shd.param_pspecs(shapes, cfg, _host_mesh())
        n_leaves = len(jax.tree_util.tree_leaves(shapes))
        n_specs = len(jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P)))
        assert n_leaves == n_specs, arch


def test_divisibility_dropping():
    """A 'model' axis that doesn't divide the dim must be dropped."""
    cfg = get_config("qwen2.5-14b", reduced=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    import jax.numpy as jnp
    fake = {"layers": {"attn": {"wq": jnp.zeros((7, 13))}}}  # primes
    specs = shd.param_pspecs(fake, cfg, mesh)
    # with mesh sizes 1 everything divides (big-mesh dropping is covered by
    # test_dist_units on a duck-typed mesh)
    assert isinstance(jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))[0], P)


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
sys.path.insert(0, "__SRC__")
from repro.configs import get_config
from repro.dist import sharding as shd
from repro.models.api import get_model
from repro.optim import adamw
from repro.configs.base import TrainConfig
from repro.train.loop import make_train_step

cfg = get_config("granite-moe-3b-a800m", reduced=True)
fns = get_model(cfg)
mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
with mesh:
    params = fns.init(jax.random.PRNGKey(0))
    pspecs = shd.param_pspecs(params, cfg, mesh)
    params = jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params,
        pspecs, is_leaf=lambda x: hasattr(x, "shape"))
    opt = adamw.init(params)
    batch = {
        "tokens": jnp.zeros((8, 32), jnp.int32),
        "labels": jnp.zeros((8, 32), jnp.int32),
    }
    bspec = shd.batch_pspecs(batch, mesh)
    batch = jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), batch, bspec)
    tc = TrainConfig(global_batch=8, seq_len=32, total_steps=2, warmup_steps=1)
    step = jax.jit(make_train_step(cfg, tc, lambda p, b, r: fns.loss(p, b)))
    p2, o2, m = step(params, opt, batch, jax.random.PRNGKey(1))
    p3, o3, m2 = step(p2, o2, batch, jax.random.PRNGKey(2))
    print(json.dumps({"loss": float(m["loss"]), "loss2": float(m2["loss"]),
                      "n_dev": len(jax.devices())}))
"""


@pytest.mark.dist
@pytest.mark.slow
def test_pjit_8dev_end_to_end():
    code = _SUBPROC.replace("__SRC__", os.path.abspath(SRC))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["n_dev"] == 8
    assert np.isfinite(res["loss"]) and np.isfinite(res["loss2"])
    assert res["loss2"] <= res["loss"] + 1.0
