"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and finiteness (assignment requirement f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import TrainConfig
from repro.models.api import get_model
from repro.optim import adamw
from repro.train.loop import make_train_step

RNG = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    batch = {
        "tokens": jax.random.randint(RNG, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(RNG, (b, s), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        npch = cfg.frontend_positions
        batch["patch_embeds"] = jax.random.normal(RNG, (b, npch, cfg.d_model))
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s + npch)[None, None], (b, 3, s + npch)).astype(jnp.int32)
        batch["labels"] = jax.random.randint(RNG, (b, s + npch), 0,
                                             cfg.vocab_size)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            RNG, (b, cfg.frontend_positions, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_loss_finite(arch):
    cfg = get_config(arch, reduced=True)
    fns = get_model(cfg)
    params = fns.init(RNG)
    loss, _ = fns.loss(params, _batch(cfg))
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "granite-moe-3b-a800m",
                                  "zamba2-7b", "rwkv6-1.6b",
                                  "deepseek-v3-671b"])
def test_one_train_step(arch):
    cfg = get_config(arch, reduced=True)
    fns = get_model(cfg)
    params = fns.init(RNG)
    tc = TrainConfig(global_batch=2, seq_len=32, total_steps=2,
                     warmup_steps=1, lr=1e-3)
    step = jax.jit(make_train_step(cfg, tc, lambda p, b, r: fns.loss(p, b)))
    opt = adamw.init(params)
    batch = _batch(cfg)
    params2, opt2, metrics = step(params, opt, batch, RNG)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_shapes(arch):
    cfg = get_config(arch, reduced=True)
    fns = get_model(cfg)
    params = fns.init(RNG)
    b = 2
    cache = fns.init_cache(b, 16)
    tok = jax.random.randint(RNG, (b, 1), 0, cfg.vocab_size)
    logits, new_cache = fns.decode_step(params, cache, tok)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
