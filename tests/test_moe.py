"""MoE dispatch: grouped == dense fallback (no drops), capacity drops are
bounded, aux loss behaves, shared experts add in."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import moe as moe_mod

RNG = jax.random.PRNGKey(0)


def _cfg(cf=8.0, shared=0):
    cfg = get_config("granite-moe-3b-a800m", reduced=True)
    moe = dataclasses.replace(cfg.moe, capacity_factor=cf,
                              num_shared_experts=shared,
                              d_shared=32 if shared else 0)
    return cfg.with_(moe=moe)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 16 - 1), st.sampled_from([8, 17, 64]))
def test_grouped_matches_dense_when_no_drops(seed, s):
    cfg = _cfg(cf=8.0)
    mp = moe_mod.init_moe(cfg, jax.random.PRNGKey(seed), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, s, cfg.d_model)) * 0.5
    y1, _ = moe_mod.moe_ffn(cfg, mp, x)
    y2 = moe_mod.moe_ffn_dense_fallback(cfg, mp, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)


def test_shared_expert_contributes():
    cfg = _cfg(cf=8.0, shared=1)
    mp = moe_mod.init_moe(cfg, RNG, jnp.float32)
    x = jax.random.normal(RNG, (2, 16, cfg.d_model)) * 0.5
    y1, _ = moe_mod.moe_ffn(cfg, mp, x)
    mp2 = dict(mp)
    mp2.pop("shared")
    y2, _ = moe_mod.moe_ffn(cfg, mp2, x)
    assert float(jnp.max(jnp.abs(y1 - y2))) > 1e-4


def test_capacity_drop_is_graceful():
    """With capacity 0.1 the layer must still produce finite output of the
    right shape (dropped tokens pass through the residual path upstream)."""
    cfg = _cfg(cf=0.1)
    mp = moe_mod.init_moe(cfg, RNG, jnp.float32)
    x = jax.random.normal(RNG, (2, 64, cfg.d_model))
    y, aux = moe_mod.moe_ffn(cfg, mp, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux["aux_loss"]) >= 0


def test_aux_loss_penalises_imbalance():
    cfg = _cfg(cf=8.0)
    mp = moe_mod.init_moe(cfg, RNG, jnp.float32)
    x = jax.random.normal(RNG, (2, 64, cfg.d_model))
    # collapse: every token identical => all tokens route to the same top-k
    x_bad = jnp.broadcast_to(x[:1, :1], x.shape)
    _, a1 = moe_mod.moe_ffn(cfg, mp, x)
    _, a2 = moe_mod.moe_ffn(cfg, mp, x_bad)
    assert float(a2["aux_loss"]) > float(a1["aux_loss"])
