"""Unified streaming serving API (repro.serve.api).

Covers the request/response surface on top of the continuous-batching
core: EngineConfig / SamplingParams validation (fail fast at submit, not
silent forever-queueing), RequestHandle streaming (iterator + callback)
vs batch results, per-request TTFT, seeded sampling reproducibility, and
the deprecated AdaptiveServer compatibility shim.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import RankConfig
from repro.models.api import get_model
from repro.serve import Engine, EngineConfig, SamplingParams
from repro.serve.scheduler import Request


pytestmark = pytest.mark.serve

RNG = jax.random.PRNGKey(0)


def _cfg(mode="adaptive"):
    cfg = get_config("drrl-paper", reduced=True)
    return cfg.with_(rank=RankConfig(mode=mode, rank_grid=(4, 8, 12, 16),
                                     fixed_rank=8, segment_len=8))


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = get_model(cfg).init(RNG)
    return cfg, params


def _engine(cfg, params, **over):
    kw = dict(n_slots=2, max_len=48, page_size=8, segment_len=8,
              max_new_cap=8, prefill_chunk=4)
    kw.update(over)
    return Engine(cfg, params, config=EngineConfig(**kw))


# ---------------------------------------------------------------------------
# validation: fail fast at submit / construction
# ---------------------------------------------------------------------------

def test_submit_validation_fail_fast(setup):
    cfg, params = setup
    eng = _engine(cfg, params)
    prompt = np.arange(8, dtype=np.int32)
    with pytest.raises(ValueError, match="negative arrival"):
        eng.submit(prompt, SamplingParams(max_new=4), arrival=-1)
    with pytest.raises(ValueError, match="max_new"):
        eng.submit(prompt, SamplingParams(max_new=9))   # > max_new_cap
    with pytest.raises(ValueError, match="cache positions"):
        # prompt + max_new exceeds a slot's page capacity: would queue
        # forever under the old surface, must raise at submit
        eng.submit(np.arange(44, dtype=np.int32), SamplingParams(max_new=8))
    with pytest.raises(ValueError, match="top_k"):
        eng.submit(prompt, SamplingParams(max_new=4, top_k=1000))
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(np.zeros((0,), np.int32), SamplingParams(max_new=4))
    # nothing above leaked into the queue
    assert not eng.core.sched.pending
    greedy_only = _engine(cfg, params, sampling=False)
    with pytest.raises(ValueError, match="sampling=False"):
        greedy_only.submit(prompt, SamplingParams(max_new=4,
                                                  temperature=0.5))


def test_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(max_new=0)
    with pytest.raises(ValueError):
        EngineConfig(prefill_chunk=0)
    with pytest.raises(ValueError):
        Request(rid=0, tokens=np.arange(3), max_new=1, temperature=-1.0)


# ---------------------------------------------------------------------------
# streaming handles
# ---------------------------------------------------------------------------

def test_handle_streaming_matches_result(setup):
    cfg, params = setup
    eng = _engine(cfg, params)
    rnd = np.random.default_rng(0)
    p0, p1 = (rnd.integers(0, cfg.vocab_size, s).astype(np.int32)
              for s in (10, 13))
    seen = []
    h0 = eng.submit(p0, SamplingParams(max_new=8),
                    on_token=lambda i, t: seen.append((i, t)))
    h1 = eng.submit(p1, SamplingParams(max_new=8), arrival=2)
    streamed = list(h0.tokens())          # drives the engine until h0 done
    assert h0.done and len(streamed) == 8
    out = eng.run()                       # drain h1
    assert h1.done
    np.testing.assert_array_equal(streamed, h0.result())
    np.testing.assert_array_equal(out[h0.rid], h0.result())
    assert seen == list(enumerate(streamed))       # callback saw every token
    assert h0.ttft_s is not None and h0.ttft_s > 0
    assert h1.ttft_s is not None
    assert len(h1.result()) == 8
    assert set(eng.ttft()) == {h0.rid, h1.rid}


def test_streaming_matches_nonstreaming_run(setup):
    """A handle consumed incrementally and a handle read only at the end
    must hold identical tokens (per-step sync changes delivery, not
    content)."""
    cfg, params = setup
    prompt = np.random.default_rng(1).integers(
        0, cfg.vocab_size, 11).astype(np.int32)
    eng_a = _engine(cfg, params)
    toks_stream = list(eng_a.submit(prompt,
                                    SamplingParams(max_new=8)).tokens())
    eng_b = _engine(cfg, params)
    h = eng_b.submit(prompt, SamplingParams(max_new=8))
    eng_b.run()
    np.testing.assert_array_equal(toks_stream, h.result())


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

def test_seeded_sampling_reproducible_and_varied(setup):
    cfg, params = setup
    prompt = np.random.default_rng(2).integers(
        0, cfg.vocab_size, 9).astype(np.int32)

    def draw(seed):
        eng = _engine(cfg, params)
        h = eng.submit(prompt, SamplingParams(max_new=8, temperature=1.0,
                                              seed=seed))
        eng.run()
        return h.result()

    a, b, c = draw(7), draw(7), draw(8)
    np.testing.assert_array_equal(a, b)     # same seed -> same stream
    assert not np.array_equal(a, c)         # different seed -> different draw


def test_greedy_on_sampling_engine_matches_greedy_only(setup):
    """temperature == 0 rows take the plain argmax: a sampling-enabled
    engine serves greedy requests bitwise like the greedy-only build."""
    cfg, params = setup
    prompt = np.random.default_rng(3).integers(
        0, cfg.vocab_size, 12).astype(np.int32)
    outs = []
    for sampling in (True, False):
        eng = _engine(cfg, params, sampling=sampling)
        h = eng.submit(prompt, SamplingParams(max_new=8))
        eng.run()
        outs.append(h.result())
    np.testing.assert_array_equal(outs[0], outs[1])


def test_topk_masks_tail(setup):
    """top_k=1 sampling is argmax regardless of temperature."""
    cfg, params = setup
    prompt = np.random.default_rng(4).integers(
        0, cfg.vocab_size, 9).astype(np.int32)
    eng = _engine(cfg, params)
    h_greedy = eng.submit(prompt, SamplingParams(max_new=6))
    h_k1 = eng.submit(prompt, SamplingParams(max_new=6, temperature=2.0,
                                             top_k=1, seed=3))
    eng.run()
    np.testing.assert_array_equal(h_k1.result(), h_greedy.result())


def test_top_p_validation(setup):
    cfg, params = setup
    for bad in (0.0, -0.5, 1.2):
        with pytest.raises(ValueError, match="top_p"):
            SamplingParams(top_p=bad)
        with pytest.raises(ValueError, match="top_p"):
            Request(rid=0, tokens=np.arange(3), max_new=1, top_p=bad)
    greedy_only = _engine(cfg, params, sampling=False)
    with pytest.raises(ValueError, match="sampling=False"):
        greedy_only.submit(np.arange(8, dtype=np.int32),
                           SamplingParams(max_new=4, temperature=1.0,
                                          top_p=0.5))
    # the nucleus cut is a compiled-in full-vocab sort: engines that did
    # not opt in reject top_p requests instead of silently paying for it
    no_nucleus = _engine(cfg, params)
    with pytest.raises(ValueError, match="nucleus"):
        no_nucleus.submit(np.arange(8, dtype=np.int32),
                          SamplingParams(max_new=4, temperature=1.0,
                                         top_p=0.5))
    with pytest.raises(ValueError, match="nucleus"):
        Engine(cfg, params,
               config=EngineConfig(nucleus=True, sampling=False))


def test_top_p_tiny_nucleus_is_greedy(setup):
    """top_p -> 0 shrinks the nucleus to the single most likely token, so
    hot sampling collapses to argmax."""
    cfg, params = setup
    prompt = np.random.default_rng(11).integers(
        0, cfg.vocab_size, 9).astype(np.int32)
    eng = _engine(cfg, params, nucleus=True)
    h_greedy = eng.submit(prompt, SamplingParams(max_new=6))
    h_p = eng.submit(prompt, SamplingParams(max_new=6, temperature=2.0,
                                            top_p=1e-9, seed=5))
    eng.run()
    np.testing.assert_array_equal(h_p.result(), h_greedy.result())


def test_top_p_one_bypasses_nucleus_bitwise(setup):
    """top_p == 1 rows take the exact pre-top-p sampling path: the same
    stream draws bitwise identically on a nucleus-enabled engine and on
    one compiled without the cut (same temperature/top_k/seed)."""
    cfg, params = setup
    prompt = np.random.default_rng(12).integers(
        0, cfg.vocab_size, 10).astype(np.int32)

    def draw(**over):
        eng = _engine(cfg, params, **over)
        h = eng.submit(prompt, SamplingParams(max_new=8, temperature=0.9,
                                              top_k=12, seed=21))
        eng.run()
        return h.result()

    np.testing.assert_array_equal(draw(), draw(nucleus=True))


def test_top_p_draws_stay_inside_nucleus(setup):
    """In-graph nucleus math vs a NumPy oracle: every sampled token must
    lie in the smallest probability-sorted set reaching top_p mass, for a
    mixed batch (greedy / top-k / top-p / combined) in ONE call."""
    import jax.numpy as jnp
    cfg, params = setup
    eng = _engine(cfg, params, nucleus=True)
    core = eng.core
    rnd = np.random.default_rng(13)
    ns, V = 4, cfg.vocab_size
    logits = rnd.normal(scale=3.0, size=(ns, V)).astype(np.float32)
    temps = np.asarray([0.0, 1.0, 0.8, 1.2], np.float32)
    topks = np.asarray([0, 16, 0, 8], np.int32)
    topps = np.asarray([1.0, 1.0, 0.7, 0.5], np.float32)
    seeds = np.asarray([1, 2, 3, 4], np.uint32)

    def nucleus(row):
        lg = logits[row].copy()
        if topks[row] > 0:
            thr = np.sort(lg)[::-1][topks[row] - 1]
            lg[lg < thr] = -np.inf
        pr = np.exp(lg / max(temps[row], 1e-6)
                    - np.max(lg / max(temps[row], 1e-6)))
        pr /= pr.sum()
        order = np.argsort(-pr)
        cum = np.cumsum(pr[order])
        n_keep = int(np.searchsorted(cum, topps[row]) + 1)
        return set(order[:n_keep].tolist())

    for pos in range(6):
        tok = np.asarray(core._select_token(
            jnp.asarray(logits), jnp.full((ns,), pos, jnp.int32),
            jnp.asarray(temps), jnp.asarray(topks), jnp.asarray(topps),
            jnp.asarray(seeds)))
        assert tok[0] == np.argmax(logits[0])          # greedy row
        for row in range(1, ns):
            assert int(tok[row]) in nucleus(row), (pos, row)


def test_top_p_parity_chunked_vs_oneshot(setup):
    """The nucleus cut runs through the same (seed, output index) PRNG
    fold: a top-p stream draws identically under chunked and one-shot
    admission."""
    cfg, params = setup
    prompt = np.random.default_rng(14).integers(
        0, cfg.vocab_size, 11).astype(np.int32)

    def run(chunk):
        eng = _engine(cfg, params, prefill_chunk=chunk, nucleus=True)
        h = eng.submit(prompt, SamplingParams(max_new=8, temperature=0.8,
                                              top_k=20, top_p=0.8, seed=9))
        eng.run()
        return h.result()

    np.testing.assert_array_equal(run(4), run(None))


# ---------------------------------------------------------------------------
# deprecated AdaptiveServer shim
# ---------------------------------------------------------------------------

def test_adaptive_server_shim(setup):
    cfg, params = setup
    with pytest.warns(DeprecationWarning, match="AdaptiveServer"):
        from repro.launch.serve import AdaptiveServer
        server = AdaptiveServer(cfg, params, max_len=48, page_size=8)
    prompts = np.random.default_rng(5).integers(
        0, cfg.vocab_size, (2, 10)).astype(np.int32)
    res = server.generate(prompts, 6, segment_len=8)
    assert res["tokens"].shape == (2, 6)
    assert res["compile_s"] > 0.0 and res["stats"]["prefills"] == 2
    # the shim serves through the same engine: parity with direct api use
    eng = _engine(cfg, params, prefill_chunk=None, sampling=False,
                  max_new_cap=6)
    hs = [eng.submit(prompts[i], SamplingParams(max_new=6))
          for i in range(2)]
    eng.run()
    for i, h in enumerate(hs):
        np.testing.assert_array_equal(res["tokens"][i], h.result())


def test_streaming_oneshot_admission_ordered(setup):
    """One-shot admission emits token 0 outside the fused step: a
    streaming consumer must still receive the full, in-order sequence
    (review fix: tok0 used to never reach the streaming plane)."""
    cfg, params = setup
    prompt = np.random.default_rng(6).integers(
        0, cfg.vocab_size, 10).astype(np.int32)
    eng = _engine(cfg, params, prefill_chunk=None)
    seen = []
    h = eng.submit(prompt, SamplingParams(max_new=8),
                   on_token=lambda i, t: seen.append((i, t)))
    eng.run()
    assert seen == list(enumerate(h.result().tolist()))


def test_late_consumer_backfills_gap(setup):
    """A consumer attaching after tokens were already emitted (another
    handle's streaming flipped the sync on mid-run) gets a contiguous
    stream via device-buffer backfill, never a garbled one."""
    cfg, params = setup
    rnd = np.random.default_rng(7)
    pa = rnd.integers(0, cfg.vocab_size, 10).astype(np.int32)
    pb = rnd.integers(0, cfg.vocab_size, 7).astype(np.int32)
    eng = _engine(cfg, params)
    eng.submit(pa, SamplingParams(max_new=8))
    hb = eng.submit(pb, SamplingParams(max_new=8), arrival=1)
    for _ in range(6):
        eng.step()                       # hb mid-flight, no consumer yet
    got = list(hb.tokens())              # late attach
    np.testing.assert_array_equal(got, hb.result())
    eng.run()


def test_step_loop_accrues_decode_time_and_releases_sync(setup):
    """Iterator/step-driven loops must accrue stats['decode_s'] (review
    fix: only run() used to account wall time, inflating tok/s), and the
    per-step token sync must switch off with the last streaming
    consumer."""
    cfg, params = setup
    prompt = np.random.default_rng(8).integers(
        0, cfg.vocab_size, 9).astype(np.int32)
    eng = _engine(cfg, params)
    h = eng.submit(prompt, SamplingParams(max_new=8))
    list(h.tokens())
    assert eng.stats["decode_s"] > 0.0
    assert eng.core._stream_sync is False     # consumer finished
    eng.reset()
    assert eng.core._stream_sync is False


def test_ttft_is_first_token_not_completion(setup):
    """A non-streaming handle's ttft_s must come from the engine's
    token-0 timestamp, not from result delivery at completion (review
    fix: the finish-time backfill used to stamp token 0 with the full
    generation wall)."""
    import time
    cfg, params = setup
    prompt = np.random.default_rng(9).integers(
        0, cfg.vocab_size, 10).astype(np.int32)
    eng = _engine(cfg, params)
    h = eng.submit(prompt, SamplingParams(max_new=8))
    eng.warmup()
    t0 = time.perf_counter()
    eng.run()
    wall = time.perf_counter() - t0
    # token 0 lands after ~3 chunk steps out of ~11 total steps: TTFT must
    # be well below the full generation wall
    assert h.ttft_s is not None and h.ttft_s < 0.8 * wall, (h.ttft_s, wall)


def test_on_token_callback_may_reenter_engine(setup):
    """An on_token callback runs under the step lock; it must be able to
    drive the engine itself (submit a follow-up and block on its result)
    — the lock is reentrant, recursing instead of deadlocking."""
    cfg, params = setup
    rnd = np.random.default_rng(15)
    pa = rnd.integers(0, cfg.vocab_size, 9).astype(np.int32)
    pb = rnd.integers(0, cfg.vocab_size, 7).astype(np.int32)
    eng = _engine(cfg, params)
    follow = {}

    def cb(idx, tok):
        if idx == 2 and "h" not in follow:
            follow["h"] = eng.submit(pb, SamplingParams(max_new=4))
            follow["out"] = follow["h"].result()     # re-enters step()

    ha = eng.submit(pa, SamplingParams(max_new=6), on_token=cb)
    eng.run()
    assert ha.done and follow["h"].done
    solo = _engine(cfg, params)
    hb = solo.submit(pb, SamplingParams(max_new=4))
    solo.run()
    np.testing.assert_array_equal(follow["out"], hb.result())


def test_tokens_on_finished_handle_keeps_sync_free_loop(setup):
    """Iterating tokens() on an already-finished request must not flip
    the engine into permanent per-step host syncing (review fix)."""
    cfg, params = setup
    prompt = np.random.default_rng(10).integers(
        0, cfg.vocab_size, 9).astype(np.int32)
    eng = _engine(cfg, params)
    h = eng.submit(prompt, SamplingParams(max_new=6))
    eng.run()
    got = list(h.tokens())                  # post-hoc read
    np.testing.assert_array_equal(got, h.result())
    assert eng.core._stream_sync is False
    assert not eng._streaming
