"""Property-based tests (hypothesis) on the paper's perturbation bounds —
the system invariants that make the guardrail sound."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import lowrank as lr
from repro.core import perturbation as pert

SEEDS = st.integers(0, 2 ** 16 - 1)
DIMS = st.sampled_from([4, 8, 16])
NS = st.sampled_from([16, 32, 48])


def _mat(seed, n, d):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, d))


@settings(max_examples=25, deadline=None)
@given(SEEDS, NS, DIMS, st.integers(0, 15))
def test_eckart_young_tail_exact(seed, n, d, r_raw):
    """||A - A_r||_F equals the sigma tail exactly (paper Eq. 3)."""
    r = min(r_raw, d - 1)
    x = _mat(seed, n, d)
    s2, e = lr.gram_spectrum(lr.gram(x))
    mask = (jnp.arange(d) < r).astype(jnp.float32)
    xr = lr.project_masked(x, e, mask)
    err = float(jnp.linalg.norm(x - xr))
    tail = float(pert.eckart_young_tail(s2, r))
    np.testing.assert_allclose(err, tail, rtol=1e-3, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(SEEDS, NS, DIMS, st.integers(0, 15), st.integers(0, 15))
def test_rank_transition_norm_exact(seed, n, d, r1_raw, r2_raw):
    """||A_{r'} - A_r||_F == sqrt(sum_{(r,r']} sigma^2) (paper Eq. 4)."""
    r1, r2 = sorted((min(r1_raw, d), min(r2_raw, d)))
    x = _mat(seed, n, d)
    s2, e = lr.gram_spectrum(lr.gram(x))
    m1 = (jnp.arange(d) < r1).astype(jnp.float32)
    m2 = (jnp.arange(d) < r2).astype(jnp.float32)
    x1 = lr.project_masked(x, e, m1)
    x2 = lr.project_masked(x, e, m2)
    err = float(jnp.linalg.norm(x2 - x1))
    band = float(pert.rank_transition_norm(s2, r1, r2))
    np.testing.assert_allclose(err, band, rtol=1e-3, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(SEEDS, st.sampled_from([16, 32]), st.sampled_from([8, 16]),
       st.integers(1, 7))
def test_eq9_is_upper_bound(seed, n, d, r):
    """The Eq. 9 guardrail bound must dominate the true ||Q_r K_r^T - QK^T||_F
    / sqrt(d) perturbation (sufficient condition, possibly loose)."""
    r = min(r, d - 1)
    q = _mat(seed, n, d)
    k = _mat(seed + 1, n, d)
    qs2, qe = lr.gram_spectrum(lr.gram(q))
    ks2, ke = lr.gram_spectrum(lr.gram(k))
    mask = (jnp.arange(d) < r).astype(jnp.float32)
    qr = lr.project_masked(q, qe, mask)
    kr = lr.project_masked(k, ke, mask)
    true = float(jnp.linalg.norm(qr @ kr.T - q @ k.T) / np.sqrt(d))
    bound = float(pert.delta_a_bound(qs2, ks2, r, d))
    # ||dQ K_r^T + Q dK^T|| <= ||dQ||_2 ||K||_F + ... — the paper states the
    # spectral/Frobenius mixed form; verify with a modest slack factor for
    # the F-norm of the n x n product (rank <= 2d):
    slack = np.sqrt(2 * d)
    assert true <= bound * slack + 1e-4


@settings(max_examples=25, deadline=None)
@given(SEEDS, st.floats(0.1, 5.0), st.floats(1e-4, 1e-1),
       st.integers(0, 1000))
def test_annealed_threshold_decreasing(seed, eps0, lam, t):
    e1 = float(pert.annealed_threshold(eps0, lam, t))
    e2 = float(pert.annealed_threshold(eps0, lam, t + 1))
    assert e2 <= e1 <= eps0 + 1e-6


@settings(max_examples=25, deadline=None)
@given(SEEDS, st.integers(2, 8))
def test_safety_mask_always_has_legal_action(seed, g):
    bounds = jax.random.uniform(jax.random.PRNGKey(seed), (5, g)) * 10
    ok = pert.safety_mask(bounds, eps_t=1e-6)
    assert bool(jnp.all(jnp.any(ok, axis=-1)))


@settings(max_examples=15, deadline=None)
@given(SEEDS, st.sampled_from([16, 32]), st.sampled_from([8, 16]))
def test_output_sensitivity_bound(seed, n, d):
    """Eq. 5/10: ||Y_{r+1} - Y_r||_F <= sigma_{r+1}(A-side) * ||V||_F applied
    to the K-side truncation of the score matrix."""
    r = d // 2
    q = _mat(seed, n, d)
    k = _mat(seed + 1, n, d)
    v = _mat(seed + 2, n, d)
    ks2, ke = lr.gram_spectrum(lr.gram(k))
    m1 = (jnp.arange(d) < r).astype(jnp.float32)
    m2 = (jnp.arange(d) < r + 1).astype(jnp.float32)
    k1 = lr.project_masked(k, ke, m1)
    k2 = lr.project_masked(k, ke, m2)
    # linear attention surrogate (pre-softmax) where the bound is exact math
    y1 = (q @ k1.T) @ v
    y2 = (q @ k2.T) @ v
    lhs = float(jnp.linalg.norm(y1 - y2))
    # ||Q (K_2-K_1)^T V|| <= ||Q||_2 ||K_2-K_1||_2 ||V||_F
    q_top = float(jnp.sqrt(lr.gram_spectrum(lr.gram(q))[0][0]))
    sigma = float(jnp.sqrt(ks2[r]))
    rhs = q_top * sigma * float(jnp.linalg.norm(v))
    assert lhs <= rhs * (1 + 1e-4)
