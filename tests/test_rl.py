"""RL machinery: GAE correctness, PPO improves a known-best-action setup,
BC clones the oracle, the full hybrid pipeline runs and respects the
guardrail."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import RankConfig
from repro.core import ppo as ppo_mod
from repro.core.drrl import init_agent
from repro.core.oracle import oracle_actions
from repro.core.policy import policy_apply
from repro.data.synthetic import SyntheticLM
from repro.models import transformer as tr
from repro.optim import adamw
from repro.optim.schedules import make_lr_fn
from repro.configs.base import TrainConfig
from repro.train.rl import collect_rollout, train_agent

RNG = jax.random.PRNGKey(0)


def test_gae_hand_example():
    rewards = jnp.array([[1.0], [1.0], [1.0]])
    values = jnp.array([[0.0], [0.0], [0.0]])
    adv, ret = ppo_mod.gae(rewards, values, gamma=1.0, lam=1.0)
    np.testing.assert_allclose(np.asarray(ret[:, 0]), [3.0, 2.0, 1.0],
                               atol=1e-6)


def _toy_traj(agent, key, G=4, T=4, B=16, best=2):
    """Bandit-ish: reward 1 for action `best`, 0 otherwise."""
    feats = {
        "h_t": jax.random.normal(key, (T, B, 8)),
        "w_t": jnp.zeros((T, B, 9)),
        "ner": jnp.linspace(0, 1, G)[None, None].repeat(T, 0).repeat(B, 1),
        "bounds": jnp.zeros((T, B, G)),
        "prev_rank": jnp.zeros((T, B, G)),
        "layer_id": jnp.zeros((T, B, 1)),
    }
    logits, values = policy_apply(agent, {k: v.reshape(T * B, -1)
                                          for k, v in feats.items()})
    a = jax.random.categorical(key, logits).reshape(T, B)
    logp = jax.nn.log_softmax(logits, -1)
    logp_a = jnp.take_along_axis(logp, a.reshape(-1, 1), -1)[:, 0].reshape(T, B)
    rew = (a == best).astype(jnp.float32)
    return ppo_mod.Trajectory(
        feats=feats, actions=a, logp_old=logp_a,
        values_old=values.reshape(T, B), rewards=rew,
        action_mask=jnp.ones((T, B, G), bool)), rew


def test_ppo_learns_best_action():
    cfg = get_config("drrl-paper", reduced=True)
    agent = init_agent(RNG, cfg.rank, cfg.d_model)
    tc = TrainConfig(lr=3e-3, total_steps=60, warmup_steps=1,
                     weight_decay=0.0)
    lr_fn = make_lr_fn(tc)
    opt = adamw.init(agent)
    grad = jax.jit(jax.value_and_grad(
        lambda a, t: ppo_mod.ppo_loss(a, t, ent_coef=0.001)[0]))
    key = RNG
    first = None
    for i in range(50):
        key, k = jax.random.split(key)
        traj, rew = _toy_traj(agent, k)
        if first is None:
            first = float(jnp.mean(rew))
        loss, g = grad(agent, traj)
        agent, opt, _ = adamw.update(tc, lr_fn, opt, agent, g)
    _, rew = _toy_traj(agent, jax.random.PRNGKey(999))
    final = float(jnp.mean(rew))
    assert final > first + 0.2, (first, final)


def test_oracle_prefers_low_rank_on_lowrank_data():
    """If K is exactly rank-4, the oracle should not pay for rank 16."""
    rc = RankConfig(mode="drrl", rank_grid=(4, 8, 12, 16), beta=0.5,
                    gamma=0.05)
    b, s, h, d = 2, 32, 2, 16
    ks = jax.random.split(RNG, 4)
    basis = jax.random.normal(ks[0], (4, d))
    q = jax.random.normal(ks[1], (b, s, h, 4)) @ basis
    k = jax.random.normal(ks[2], (b, s, h, 4)) @ basis
    v = jax.random.normal(ks[3], (b, s, h, d))
    acts, aux = oracle_actions(rc, q, k, v)
    assert int(jnp.max(acts)) == 0, "oracle should pick rank 4 (index 0)"


def test_guardrail_masks_respected_in_rollout():
    cfg = get_config("drrl-paper", reduced=True).with_(
        rank=RankConfig(mode="drrl", rank_grid=(4, 8, 12, 16),
                        guardrail=True, epsilon0=1e-9))
    params = tr.init_dense(cfg, RNG)
    agent = init_agent(jax.random.PRNGKey(7), cfg.rank, cfg.d_model)
    data = SyntheticLM(cfg.vocab_size, 32, 2, seed=1)
    traj, _ = collect_rollout(cfg, params, agent, data.batch_at(0), RNG)
    # with an impossibly tight threshold only the max-rank action is legal
    chosen = np.asarray(traj.actions)
    assert (chosen == len(cfg.rank.rank_grid) - 1).all()


def test_hybrid_pipeline_runs_and_improves_reward():
    cfg = get_config("drrl-paper", reduced=True)
    params = tr.init_dense(cfg, RNG)
    agent = init_agent(jax.random.PRNGKey(7), cfg.rank, cfg.d_model)
    data = SyntheticLM(cfg.vocab_size, 32, 2, seed=5)
    agent, hist = train_agent(cfg, params, agent, data, bc_steps=3,
                              ppo_steps=3, ppo_epochs=1)
    assert len(hist["bc_loss"]) == 3
    assert all(np.isfinite(h["reward"]) for h in hist["ppo"])
