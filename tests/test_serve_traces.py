"""Serving-trace subsystem: recorder round-trip, schema versioning,
workload determinism, and the offline-trained policy closing the loop.

* recorder round-trip — records written through the engine hook land on
  disk exactly (shard + manifest), and two recording runs over the same
  seeded workload produce identical traces (column-for-column);
* schema versioning — TraceReader rejects unknown versions loudly and a
  missing manifest raises FileNotFoundError;
* sharding — records spill across shards at shard_size and concatenate
  back in order;
* workload suite — every named generator is a pure function of its seed
  (same seed = identical requests, different seed = different tokens),
  and arrivals are ticks, not wall clock;
* trainer — features rebuilt from the trace are bit-compatible with the
  serving decide() path: the constrained oracle never loses reward or
  raises rank vs the recorded actions, training is deterministic, and a
  trained checkpoint loads into ``mode="learned"`` and serves valid
  streams;
* fail-fast — drrl/learned engines without policy params refuse to
  construct.
"""
import json

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.configs.base import RankConfig
from repro.models.api import get_model
from repro.serve import Request, ServeEngine
from repro.serve.traces import TRACE_SCHEMA_VERSION, TraceReader, TraceRecorder
from repro.serve.workloads import build, make_workload, workload_names

pytestmark = pytest.mark.serve

RNG = jax.random.PRNGKey(0)
GRID = (4, 8, 12, 16)


def _cfg(mode="adaptive"):
    cfg = get_config("drrl-paper", reduced=True)
    return cfg.with_(rank=RankConfig(mode=mode, rank_grid=GRID,
                                     fixed_rank=16, segment_len=8))


@pytest.fixture(scope="module")
def model():
    cfg = _cfg()
    return cfg, get_model(cfg).init(RNG)


def _record_suite(cfg, params, directory, *, seed=3, n_requests=4,
                  max_new=10, shard_size=512):
    rec = TraceRecorder(directory, cfg, shard_size=shard_size,
                        scenario="suite")
    for name in workload_names():
        spec = make_workload(name, seed=seed, n_requests=n_requests,
                             max_new=max_new, vocab=cfg.vocab_size,
                             max_prompt=40)
        eng = ServeEngine(cfg, params, n_slots=4, max_len=96, page_size=16,
                          segment_len=8, max_new_cap=max_new,
                          prefill_chunk=8, record_traces=rec,
                          **spec.engine_overrides)
        for r in build(spec):
            eng.submit(r)
        outs = eng.run()
        assert all(0 < len(v) <= max_new for v in outs.values())
    return rec.flush()


# ---------------------------------------------------------------------------
# recorder round-trip + determinism
# ---------------------------------------------------------------------------

def test_trace_roundtrip_and_determinism(model, tmp_path):
    cfg, params = model
    m1 = _record_suite(cfg, params, tmp_path / "a")
    m2 = _record_suite(cfg, params, tmp_path / "b")
    assert m1["version"] == TRACE_SCHEMA_VERSION
    assert m1["n_records"] == m2["n_records"] > 0
    assert m1["rank_grid"] == list(GRID)

    r1, r2 = TraceReader(tmp_path / "a"), TraceReader(tmp_path / "b")
    assert len(r1) == m1["n_records"]
    assert sorted(r1.records) == sorted(r2.records)
    for col in r1.records:
        assert np.array_equal(r1.records[col], r2.records[col]), \
            f"column {col} differs between identical recording runs"
    # spectra columns carry the model geometry
    n, hkv, dh = r1.records["s2"].shape
    assert n == m1["n_records"]
    assert (hkv, dh) == (cfg.num_kv_heads, cfg.resolved_head_dim())
    # outcome windows accumulated real decode work
    assert r1.records["n_tokens"].sum() > 0
    assert (r1.records["chosen_rank"][:, None]
            == np.asarray(GRID)[None, :]).any(axis=1).all()
    # a slot's first decision has no previous segment
    assert (~r1.records["has_prev"]).any()


def test_trace_sharding_preserves_order(model, tmp_path):
    cfg, params = model
    whole = _record_suite(cfg, params, tmp_path / "one", shard_size=512)
    tiny = _record_suite(cfg, params, tmp_path / "many", shard_size=3)
    assert whole["n_records"] == tiny["n_records"]
    assert len(whole["shards"]) == 1 and len(tiny["shards"]) > 1
    a, b = TraceReader(tmp_path / "one"), TraceReader(tmp_path / "many")
    for col in a.records:
        assert np.array_equal(a.records[col], b.records[col])


def test_trace_schema_version_rejected(model, tmp_path):
    cfg, params = model
    _record_suite(cfg, params, tmp_path)
    mpath = tmp_path / "manifest.json"
    doc = json.loads(mpath.read_text())
    doc["version"] = TRACE_SCHEMA_VERSION + 1
    mpath.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="schema version"):
        TraceReader(tmp_path)
    with pytest.raises(FileNotFoundError):
        TraceReader(tmp_path / "nowhere")


def test_recorder_validates_shard_size(model, tmp_path):
    cfg, _ = model
    with pytest.raises(ValueError, match="shard_size"):
        TraceRecorder(tmp_path, cfg, shard_size=0)


# ---------------------------------------------------------------------------
# workload suite determinism
# ---------------------------------------------------------------------------

def test_workloads_seed_reproducible():
    for name in workload_names():
        a = make_workload(name, seed=5, n_requests=6)
        b = make_workload(name, seed=5, n_requests=6)
        c = make_workload(name, seed=6, n_requests=6)
        assert a.engine_overrides == b.engine_overrides
        assert len(a.requests) == 6
        for ra, rb in zip(a.requests, b.requests):
            assert ra.keys() == rb.keys()
            assert np.array_equal(ra["tokens"], rb["tokens"])
            assert ra["arrival"] == rb["arrival"]
        assert any(not np.array_equal(ra["tokens"], rc["tokens"])
                   for ra, rc in zip(a.requests, c.requests)), \
            f"{name}: different seeds produced identical token streams"
        for req in build(a):
            assert isinstance(req.arrival, int)  # ticks, never wall clock


def test_workload_unknown_name():
    with pytest.raises(ValueError, match="unknown workload"):
        make_workload("nope")


def test_workload_shapes():
    spec = make_workload("shared_prefix", seed=1, n_requests=5)
    assert spec.engine_overrides == {"prefix_cache": True}
    toks = [r["tokens"] for r in spec.requests]
    # chat turns share one of the few system prefixes
    assert any(np.array_equal(toks[i][:8], toks[j][:8])
               for i in range(5) for j in range(i + 1, 5))
    mixed = make_workload("mixed_sampling", seed=1, n_requests=6)
    assert mixed.engine_overrides == {"sampling": True, "nucleus": True}
    kinds = [("top_k" in r, "top_p" in r) for r in mixed.requests]
    assert (True, False) in kinds and (False, True) in kinds


# ---------------------------------------------------------------------------
# offline trainer + mode="learned" round trip
# ---------------------------------------------------------------------------

def test_train_and_serve_learned(model, tmp_path):
    from repro.train.serve_policy import (build_dataset, evaluate_policy,
                                          load_policy, train_serve_policy)
    cfg, params = model
    _record_suite(cfg, params, tmp_path / "trace")
    ds = build_dataset(tmp_path / "trace", cfg.rank)
    assert ds["feats"]["ner"].shape == (ds["n"] * ds["h"], len(GRID))

    # the constrained oracle dominates the recorded heuristic: per
    # record, reward can only go up and kept rank can only go down
    idx = np.arange(ds["n"])
    rew = np.asarray(ds["reward_matrix"])
    assert (rew[idx, np.asarray(ds["oracle"])]
            >= rew[idx, np.asarray(ds["actions"])] - 1e-6).all()
    assert (np.asarray(ds["grid"])[np.asarray(ds["oracle"])]
            <= np.asarray(ds["grid"])[np.asarray(ds["actions"])]).all()

    pol, hist = train_serve_policy(
        tmp_path / "trace", cfg.rank, out_dir=tmp_path / "pol",
        bc_steps=30, ppo_steps=2, ppo_epochs=1)
    ev = hist["eval"]
    assert ev["learned"]["reward"] >= ev["adaptive"]["reward"] - 2e-3
    assert (ev["learned"]["mean_rank"]
            <= ev["adaptive"]["mean_rank"] * 1.005)

    # checkpoint round trip: loaded tree serves in mode="learned"
    pol2 = load_policy(tmp_path / "pol")
    for a, b in zip(jax.tree_util.tree_leaves(pol),
                    jax.tree_util.tree_leaves(pol2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    lcfg = _cfg("learned")
    eng = ServeEngine(lcfg, params, pol2, n_slots=2, max_len=64,
                      page_size=16, segment_len=8, max_new_cap=8,
                      prefill_chunk=8)
    rnd = np.random.default_rng(0)
    for i in range(2):
        eng.submit(Request(rid=i, tokens=rnd.integers(
            1, cfg.vocab_size, 12).astype(np.int32), max_new=8))
    outs = eng.run()
    assert all(len(v) == 8 for v in outs.values())
    # offline greedy mirror agrees with itself across calls (pure fn)
    e1 = evaluate_policy(ds, cfg.rank, policy_params=pol2)
    e2 = evaluate_policy(ds, cfg.rank, policy_params=pol2)
    assert e1 == e2


def test_train_rejects_empty_trace(model, tmp_path):
    from repro.train.serve_policy import build_dataset
    cfg, _ = model
    TraceRecorder(tmp_path, cfg).flush()        # no records
    with pytest.raises(ValueError, match="empty"):
        build_dataset(tmp_path, cfg.rank)


def test_load_policy_missing_meta(tmp_path):
    from repro.train.serve_policy import load_policy
    with pytest.raises(FileNotFoundError, match="policy_meta"):
        load_policy(tmp_path)


# ---------------------------------------------------------------------------
# fail-fast: policy modes refuse to serve without params
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["drrl", "learned"])
def test_policy_mode_requires_params(model, mode):
    _, params = model
    with pytest.raises(ValueError, match="needs policy params"):
        ServeEngine(_cfg(mode), params, n_slots=2, max_len=64,
                    page_size=16, segment_len=8)
