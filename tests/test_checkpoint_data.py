"""Checkpoint manager (atomic, async, resume, GC) and stateless data."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.data.synthetic import SyntheticClassification, SyntheticLM
from repro.data.text import ByteCorpus


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": jnp.float32(3.5)}}


def test_roundtrip_sync(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_save=False)
    t = _tree()
    cm.save(7, t, specs=jax.tree_util.tree_map(lambda _: P(), t))
    loaded, step, _ = cm.load(t)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_latest(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_save=True, keep=2)
    for s in (1, 2, 3):
        cm.save(s, _tree(s))
    cm.wait()
    assert cm.latest_step() == 3
    assert cm.all_steps() == [2, 3]          # GC keeps 2
    loaded, step, _ = cm.load(_tree())
    assert step == 3
    np.testing.assert_array_equal(np.asarray(loaded["a"]),
                                  np.asarray(_tree(3)["a"]))


def test_atomicity_no_partial_dirs(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_save=False)
    cm.save(1, _tree())
    for p in pathlib.Path(tmp_path).iterdir():
        assert not p.name.startswith(".tmp")


def test_elastic_load_with_mesh(tmp_path):
    """Specs referencing absent axes must degrade to replication."""
    cm = CheckpointManager(str(tmp_path), async_save=False)
    t = {"w": jnp.ones((8, 4))}
    cm.save(1, t, specs={"w": P(("pod", "data"), "model")})
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    loaded, _, _ = cm.load(t, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(loaded["w"]), np.ones((8, 4)))


def test_synthetic_deterministic_and_seekable():
    d1 = SyntheticLM(vocab=100, seq_len=16, global_batch=2, seed=3)
    d2 = SyntheticLM(vocab=100, seq_len=16, global_batch=2, seed=3)
    b5a, b5b = d1.batch_at(5), d2.batch_at(5)
    np.testing.assert_array_equal(np.asarray(b5a["tokens"]),
                                  np.asarray(b5b["tokens"]))
    assert not np.array_equal(np.asarray(d1.batch_at(6)["tokens"]),
                              np.asarray(b5a["tokens"]))
    # labels are next-token shifted
    full_a = np.asarray(b5a["tokens"])[:, 1:]
    np.testing.assert_array_equal(full_a, np.asarray(b5a["labels"])[:, :-1])


def test_classification_data_learnable_signal():
    d = SyntheticClassification(vocab=64, seq_len=32, batch=256, seed=0)
    b = d.batch_at(0)
    hi_frac = (np.asarray(b["tokens"]) >= 32).mean(axis=1)
    lab = np.asarray(b["labels"])
    assert hi_frac[lab == 1].mean() > hi_frac[lab == 0].mean() + 0.2


def test_byte_corpus(tmp_path):
    f = tmp_path / "x.py"
    f.write_bytes(b"hello world, this is a tiny corpus for testing. " * 50)
    c = ByteCorpus([str(f)], seq_len=16, global_batch=4, seed=0)
    b0, b0b = c.batch_at(0), c.batch_at(0)
    np.testing.assert_array_equal(b0["tokens"], b0b["tokens"])
    assert b0["tokens"].shape == (4, 16)
    assert (b0["tokens"] < 256).all()
