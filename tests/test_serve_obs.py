"""Observability layer (repro.obs).

Covers the layer from primitives up through the serving stack:
  * metrics primitives: counter/gauge/histogram semantics, percentile
    interpolation + clamping, kind-mismatch rejection, the StatsView
    dict shim (reads, ``+=`` writes, reset-by-rebind), and the fleet
    rollup (counters sum, histograms merge bucket-wise, bound mismatch
    rejected),
  * exporter formats: Prometheus text exposition shape, Chrome trace
    documents validate against the schema subset and survive a JSON
    round-trip (the validator itself is exercised on broken docs),
  * flight recorder: bounded ring, dump files parse, no-directory and
    crash paths never raise,
  * engine integration: metrics + tracing ON is token-identical to OFF
    (greedy/factored and seeded-sampled/dense), request counters and
    TTFT samples line up with the workload, rank telemetry is sane,
  * FrontEnd integration: a raising step dumps the flight ring with
    reason "step_exception" before handles are stopped; concurrent
    exporter reads during background stepping never trip the writer.
"""
import json
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import RankConfig
from repro.models.api import get_model
from repro.obs import (FlightRecorder, Gauge, Histogram, MetricsRegistry,
                       SpanTracer, StatsView, Stopwatch, aggregate,
                       aggregate_registry, validate_chrome_trace)
from repro.serve import (Engine, EngineConfig, EngineStopped, FrontEnd,
                         SamplingParams)

pytestmark = pytest.mark.serve

RNG = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# metrics primitives
# ---------------------------------------------------------------------------

def test_counter_gauge_basics():
    r = MetricsRegistry()
    c = r.counter("toks")
    c.inc()
    c.inc(5)
    assert c.get() == 6 and r.get("toks") is c
    g = r.gauge("depth")
    g.set(3)
    g.set(1)
    assert g.get() == 1
    # get-or-create returns the same object; kind mismatch is an error
    assert r.counter("toks") is c
    with pytest.raises(TypeError):
        r.gauge("toks")
    with pytest.raises(TypeError):
        r.histogram("depth")
    c.zero()
    assert c.get() == 0
    snap = r.snapshot()
    assert snap == {"toks": 0, "depth": 1}


def test_histogram_percentiles_and_clamp():
    h = Histogram("lat", bounds=[0.001, 0.01, 0.1, 1.0])
    for v in [0.002, 0.003, 0.004, 0.005, 0.05, 0.5]:
        h.observe(v)
    assert h.count == 6
    assert h.mean() == pytest.approx(sum([0.002, 0.003, 0.004, 0.005,
                                          0.05, 0.5]) / 6)
    # percentiles are interpolated but always clamped to [vmin, vmax]
    for q in (0, 25, 50, 90, 99, 100):
        assert h.vmin <= h.percentile(q) <= h.vmax
    assert h.percentile(50) <= 0.01    # 4 of 6 samples in (0.001, 0.01]
    # overflow bucket: above the top bound still counted, clamped to vmax
    h.observe(50.0)
    assert h.count == 7 and h.percentile(100) == 50.0
    empty = Histogram("e", bounds=[1.0])
    assert empty.percentile(50) == 0.0 and empty.export()["min"] is None


def test_statsview_dict_shim():
    r = MetricsRegistry()
    sv = StatsView(r, {"steps": 0, "decode_s": 0.0, "eff_draft_k": 4},
                   gauges=("eff_draft_k",))
    sv["steps"] += 3
    sv["decode_s"] += 0.25
    sv["eff_draft_k"] = 2
    assert sv["steps"] == 3 and dict(sv) == {"steps": 3, "decode_s": 0.25,
                                             "eff_draft_k": 2}
    assert len(sv) == 3 and set(sv) == {"steps", "decode_s", "eff_draft_k"}
    # the view writes through to the registry (and respects gauge kinds)
    assert r.get("serve.steps").value == 3
    assert isinstance(r.get("serve.eff_draft_k"), Gauge)
    assert not isinstance(r.get("serve.steps"), Gauge)
    with pytest.raises(TypeError):
        del sv["steps"]
    # re-binding the same keys (engine reset) re-zeroes to init values
    sv2 = StatsView(r, {"steps": 0, "decode_s": 0.0, "eff_draft_k": 4},
                    gauges=("eff_draft_k",))
    assert dict(sv2) == {"steps": 0, "decode_s": 0.0, "eff_draft_k": 4}
    assert r.get("serve.steps").value == 0


def test_aggregate_fleet_rollup():
    regs = []
    for n in (2, 5):
        r = MetricsRegistry()
        r.counter("toks").inc(n)
        r.gauge("depth").set(n)
        h = r.histogram("lat", bounds=[1.0, 10.0])
        h.observe(float(n))
        regs.append(r)
    regs[0].counter("only_a").inc(7)           # absent from replica 1
    merged = aggregate(regs)
    assert merged["toks"] == 7 and merged["depth"] == 7
    assert merged["only_a"] == 7
    assert merged["lat"]["count"] == 2 and merged["lat"]["sum"] == 7.0
    assert merged["lat"]["min"] == 2.0 and merged["lat"]["max"] == 5.0
    # the rollup is a detached copy: mutating it leaves shards alone
    out = aggregate_registry(regs)
    out.counter("toks").inc(100)
    assert regs[0].counter("toks").value == 2
    # histogram bound mismatch is a structural error, not a silent merge
    bad = MetricsRegistry()
    bad.histogram("lat", bounds=[1.0, 99.0]).observe(1.0)
    with pytest.raises(TypeError):
        aggregate_registry([regs[0], bad])


def test_prometheus_text_format():
    r = MetricsRegistry()
    r.counter("serve.tokens_decoded").inc(12)
    h = r.histogram("serve.ttft_s", bounds=[0.1, 1.0])
    h.observe(0.05)
    h.observe(0.5)
    text = r.prometheus_text("repro")
    lines = text.strip().split("\n")
    assert "# TYPE repro_serve_tokens_decoded counter" in lines
    assert "repro_serve_tokens_decoded 12" in lines
    assert "# TYPE repro_serve_ttft_s histogram" in lines
    # cumulative buckets + +Inf + sum/count
    assert 'repro_serve_ttft_s_bucket{le="0.1"} 1' in lines
    assert 'repro_serve_ttft_s_bucket{le="1"} 2' in lines
    assert 'repro_serve_ttft_s_bucket{le="+Inf"} 2' in lines
    assert "repro_serve_ttft_s_count 2" in lines


def test_stopwatch_disabled_is_none():
    assert Stopwatch(False).stop() is None
    sw = Stopwatch()
    assert sw.stop() >= 0.0


# ---------------------------------------------------------------------------
# tracer + validator
# ---------------------------------------------------------------------------

def test_tracer_emits_valid_round_trippable_trace():
    tr = SpanTracer(pid=3, capacity=100)
    tr.async_begin("request", 7, args={"rid": 7})
    tr.instant("first_token", tid=1, cat="request")
    tr.complete("dispatch", tr.now_us(), 12.5, tid=1000, cat="phase")
    tr.counter("queue", {"depth": 2.0})
    tr.async_end("request", 7, args={"reason": "eos"})
    doc = tr.chrome_trace(metadata={"engine_id": 3})
    assert validate_chrome_trace(doc) == []
    rt = json.loads(json.dumps(doc))
    assert rt == doc and rt["otherData"]["engine_id"] == 3
    # capacity bound: overflow drops (counted), never grows the buffer
    small = SpanTracer(capacity=2)
    for _ in range(5):
        small.instant("x")
    assert len(small.events) == 2 and small.dropped == 3
    small.clear()
    assert small.events == [] and small.dropped == 0


def test_trace_validator_rejects_malformed():
    assert validate_chrome_trace([]) == ["document is not a JSON object"]
    assert validate_chrome_trace({}) == ["traceEvents missing or not a list"]
    bad_ph = {"traceEvents": [{"name": "x", "ph": "Z", "pid": 0, "tid": 0,
                               "ts": 0.0}]}
    assert any("bad ph" in e for e in validate_chrome_trace(bad_ph))
    no_dur = {"traceEvents": [{"name": "x", "ph": "X", "pid": 0, "tid": 0,
                               "ts": 0.0}]}
    assert any("dur" in e for e in validate_chrome_trace(no_dur))
    orphan_end = {"traceEvents": [{"name": "r", "ph": "e", "id": "1",
                                   "cat": "request", "pid": 0, "tid": 0,
                                   "ts": 0.0}]}
    assert any("end without begin" in e
               for e in validate_chrome_trace(orphan_end))


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

def test_flight_recorder_ring_and_dump(tmp_path):
    fr = FlightRecorder(4, str(tmp_path), name="t")
    for i in range(10):
        fr.record("tick", i=i)
    assert len(fr.events) == 4 and fr.n_recorded == 10
    assert [e["i"] for e in fr.events] == [6, 7, 8, 9]   # newest survive
    path = fr.dump("unit_test", metrics={"toks": 3},
                   error=RuntimeError("boom"))
    with open(path) as f:
        doc = json.load(f)
    assert doc["reason"] == "unit_test" and doc["events_recorded"] == 10
    assert [e["i"] for e in doc["events"]] == [6, 7, 8, 9]
    assert doc["metrics"] == {"toks": 3}
    assert "boom" in doc["error"]
    # no directory configured: recording works, dump is a silent no-op
    off = FlightRecorder(4, None)
    off.record("tick")
    assert off.dump("nowhere") is None


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = get_config("drrl-paper", reduced=True).with_(
        rank=RankConfig(mode="adaptive", rank_grid=(4, 8, 12, 16),
                        fixed_rank=8, segment_len=8))
    return cfg, get_model(cfg).init(RNG)


def _prompts(n, seed=0, lo=8, hi=14):
    rnd = np.random.default_rng(seed)
    return [rnd.integers(0, 256, int(rnd.integers(lo, hi))).astype(np.int32)
            for _ in range(n)]


def _run(cfg, params, sps, prompts, *, obs_trace, sampling, factor,
         flight_dir=None):
    eng = Engine(cfg, params, config=EngineConfig(
        n_slots=2, max_len=48, page_size=8, segment_len=8, max_new_cap=8,
        prefill_chunk=8, factor_cache=factor, sampling=sampling,
        obs_trace=obs_trace, flight_dir=flight_dir))
    hs = [eng.submit(p, sp) for p, sp in zip(prompts, sps)]
    eng.run()
    return eng, {h.rid: h.result().tolist() for h in hs}


@pytest.mark.parametrize("factor,sampling", [(True, False), (False, True)],
                         ids=["factor-greedy", "dense-sampled"])
def test_obs_on_off_token_parity(setup, factor, sampling, tmp_path):
    """Tracing + metrics ON must not change a single emitted token, and
    the exports must describe the workload exactly."""
    cfg, params = setup
    prompts = _prompts(3, seed=1)
    if sampling:
        sps = [SamplingParams(max_new=6, temperature=0.8, top_k=8, seed=i)
               for i in range(3)]
    else:
        sps = [SamplingParams(max_new=6) for _ in range(3)]
    _, ref = _run(cfg, params, sps, prompts, obs_trace=False,
                  sampling=sampling, factor=factor)
    eng, out = _run(cfg, params, sps, prompts, obs_trace=True,
                    sampling=sampling, factor=factor,
                    flight_dir=str(tmp_path))
    assert out == ref

    snap = eng.obs.snapshot()
    m = snap["metrics"]
    assert m["requests.admitted"] == 3 and m["requests.finished"] == 3
    assert m["requests.cancelled"] == 0
    assert m["serve.ttft_s"]["count"] == 3
    assert m["serve.tokens_decoded"] == eng.stats["tokens_decoded"]
    assert snap["trace"]["enabled"] and snap["trace"]["dropped"] == 0

    doc = eng.obs.chrome_trace()
    assert validate_chrome_trace(doc) == []
    assert json.loads(json.dumps(doc)) == doc
    evs = doc["traceEvents"]
    assert sum(e["ph"] == "b" for e in evs) == 3    # one span per request
    assert sum(e["ph"] == "e" for e in evs) == 3
    phases = {e["name"] for e in evs if e.get("cat") == "phase"}
    assert phases == {"schedule", "admit", "decide", "dispatch", "fetch",
                      "deliver"}

    prom = eng.obs.prometheus()
    assert "# TYPE repro_requests_admitted counter" in prom
    assert "repro_requests_admitted 3" in prom
    assert 'repro_serve_ttft_s_bucket{le="+Inf"} 3' in prom

    tel = eng.obs.rank_telemetry(eng.core)
    assert 0 < tel["steps_recorded"] <= eng.stats["steps"]
    assert tel["decisions"] == eng.stats["decides"] > 0
    assert tel["veto_fires"] >= 0 and tel["per_layer_uniform"]
    grid = set(cfg.rank.rank_grid) | {-1}
    assert all(v in grid for row in tel["kept_rank"] for v in row)


def test_frontend_step_exception_dumps_flight_ring(setup, tmp_path):
    cfg, params = setup
    eng = Engine(cfg, params, config=EngineConfig(
        n_slots=2, max_len=48, page_size=8, segment_len=8, max_new_cap=8,
        prefill_chunk=8, flight_dir=str(tmp_path)))

    def boom():
        raise RuntimeError("injected step failure")

    eng.core.step = boom
    fe = FrontEnd(eng, idle_poll_s=0.01, warmup=False)
    try:
        # the thread may die before or after submit returns — the raise
        # surfaces at whichever call touches the dead front end first
        with pytest.raises(EngineStopped):
            h = fe.submit(_prompts(1, seed=2)[0], SamplingParams(max_new=4))
            h.result()
    finally:
        fe.shutdown(drain=False)
    dumps = sorted(tmp_path.glob("flight_*.json"))
    assert dumps, "no flight dump written on step exception"
    with open(dumps[0]) as f:
        doc = json.load(f)
    assert doc["reason"] == "step_exception"
    assert "injected step failure" in doc["error"]
    assert "metrics" in doc


def test_registry_reads_safe_under_background_stepping(setup):
    """Exporters are documented as any-thread-safe: hammer them from a
    reader thread while the FrontEnd's stepping thread is writing."""
    cfg, params = setup
    eng = Engine(cfg, params, config=EngineConfig(
        n_slots=2, max_len=48, page_size=8, segment_len=8, max_new_cap=8,
        prefill_chunk=8, obs_trace=True))
    stop = threading.Event()
    errors, reads = [], [0]

    def reader():
        try:
            while not stop.is_set():
                snap = eng.obs.snapshot()
                assert snap["metrics"]["requests.admitted"] >= 0
                eng.obs.prometheus()
                json.dumps(eng.obs.chrome_trace())
                reads[0] += 1
        except Exception as e:   # surfaced after join — threads can't fail a test
            errors.append(e)

    t = threading.Thread(target=reader, daemon=True)
    with FrontEnd(eng, idle_poll_s=0.01) as fe:
        t.start()
        hs = [fe.submit(p, SamplingParams(max_new=6))
              for p in _prompts(4, seed=3)]
        outs = [h.result() for h in hs]
    stop.set()
    t.join(timeout=5)
    assert not errors and reads[0] > 0
    assert all(len(o) == 6 for o in outs)
    assert eng.obs.snapshot()["metrics"]["requests.finished"] == 4
