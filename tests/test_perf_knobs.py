"""Perf knobs must be semantics-preserving: sharded CE == gather CE exactly,
bf16 softmax close to f32, seq-shard/cache knobs are no-ops off-mesh."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro import nn
from repro.configs import get_config
from repro.models import transformer as tr
from repro.models.attention import attend

K0 = jax.random.PRNGKey(0)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 16 - 1), st.sampled_from([7, 32, 100]))
def test_iota_ce_equals_gather_ce(seed, vocab):
    """The sharded-friendly iota-compare CE must equal the take_along_axis
    form bit-for-bit (it replaced it globally after §Perf H4/H6)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    logits = jax.random.normal(k1, (3, 5, vocab))
    labels = jax.random.randint(k2, (3, 5), 0, vocab)
    ours = nn.softmax_cross_entropy(logits, labels)
    lz = jax.nn.logsumexp(logits, -1)
    ll = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    ref = jnp.mean(lz - ll)
    np.testing.assert_allclose(float(ours), float(ref), rtol=1e-6)


def test_bf16_score_softmax_close_to_f32():
    ks = jax.random.split(K0, 3)
    q = jax.random.normal(ks[0], (2, 32, 4, 16))
    k = jax.random.normal(ks[1], (2, 32, 4, 16))
    v = jax.random.normal(ks[2], (2, 32, 4, 16))
    o32 = attend(q, k, v, scale=0.25, causal=True, score_dtype=jnp.float32)
    o16 = attend(q, k, v, scale=0.25, causal=True, score_dtype=jnp.bfloat16)
    np.testing.assert_allclose(np.asarray(o16, np.float32),
                               np.asarray(o32, np.float32), atol=3e-2)


def test_knobs_are_noops_off_mesh():
    """With mesh_axes=() the seq-shard / split-KV knobs must not change the
    computation at all (CPU tests and the paper-faithful path rely on it)."""
    cfg = get_config("qwen2.5-14b", reduced=True)
    fns_params = tr.init_dense(cfg, K0)
    toks = jax.random.randint(K0, (2, 16), 0, cfg.vocab_size)
    base, _ = tr.forward_dense(cfg, fns_params, toks)
    cfg2 = cfg.with_(seq_shard_attn=True, cache_seq_shard=True)
    out, _ = tr.forward_dense(cfg2, fns_params, toks)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(out))
