"""Chunked sequence-mixer kernels vs naive recurrence oracles (hypothesis
sweeps over shapes), plus single-step decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models.mamba2 import ssd_chunked, ssd_naive
from repro.models.rwkv6 import wkv6_chunked, wkv6_naive

SEEDS = st.integers(0, 2 ** 16 - 1)


@settings(max_examples=10, deadline=None)
@given(SEEDS, st.sampled_from([17, 32, 100]), st.sampled_from([8, 16]),
       st.sampled_from([1, 2]))
def test_ssd_chunked_matches_naive(seed, l, chunk, g):
    b, h, p, n = 2, 4, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, l, g, n))
    C = jax.random.normal(ks[4], (b, l, g, n))
    yc, _ = ssd_chunked(x, dt, A, B, C, chunk=chunk)
    yn = ssd_naive(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yn),
                               atol=1e-3, rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(SEEDS, st.sampled_from([16, 33, 64]), st.sampled_from([8, 16]))
def test_wkv6_chunked_matches_naive(seed, l, chunk):
    b, d, hd = 2, 32, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r = jax.random.normal(ks[0], (b, l, d))
    k = jax.random.normal(ks[1], (b, l, d))
    v = jax.random.normal(ks[2], (b, l, d))
    w_log = jnp.clip(-jnp.exp(jax.random.normal(ks[3], (b, l, d)) * 0.5),
                     -8.0, -1e-4)
    u = jax.random.normal(ks[4], (d,)) * 0.1
    yc, sc = wkv6_chunked(r, k, v, w_log, u, hd, chunk=chunk)
    yn, sn = wkv6_naive(r, k, v, w_log, u, hd)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yn),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(sc), np.asarray(sn),
                               atol=2e-3, rtol=2e-3)


def test_wkv6_state_carries_across_calls():
    """Running two halves with carried state == one full pass."""
    b, l, d, hd = 1, 32, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    r = jax.random.normal(ks[0], (b, l, d))
    k = jax.random.normal(ks[1], (b, l, d))
    v = jax.random.normal(ks[2], (b, l, d))
    w_log = jnp.clip(-jnp.exp(jax.random.normal(ks[3], (b, l, d)) * 0.5),
                     -8.0, -1e-4)
    u = jax.random.normal(ks[4], (d,)) * 0.1
    y_full, s_full = wkv6_naive(r, k, v, w_log, u, hd)
    y1, s1 = wkv6_chunked(r[:, :16], k[:, :16], v[:, :16], w_log[:, :16],
                          u, hd, chunk=8)
    y2, s2 = wkv6_chunked(r[:, 16:], k[:, 16:], v[:, 16:], w_log[:, 16:],
                          u, hd, chunk=8, state0=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               atol=2e-3, rtol=2e-3)
