"""Pipeline parallelism: the 4-stage streamed schedule must equal applying
the stages sequentially (real 4-device ring, subprocess)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json, sys
import jax, jax.numpy as jnp
sys.path.insert(0, "__SRC__")
from repro.dist.pipeline import make_pipeline

P_STAGES, D = 4, 8
mesh = jax.make_mesh((P_STAGES,), ("pod",),
                     axis_types=(jax.sharding.AxisType.Auto,))
ks = jax.random.split(jax.random.PRNGKey(0), 2)
# stage i: x -> tanh(x @ W_i + b_i)
params = {
    "w": jax.random.normal(ks[0], (P_STAGES, D, D)) * 0.5,
    "b": jax.random.normal(ks[1], (P_STAGES, D)) * 0.1,
}

def stage_fn(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])

n_micro, mb = 6, 3
x = jax.random.normal(jax.random.PRNGKey(2), (n_micro, mb, D))

with mesh:
    pipe = make_pipeline(mesh, stage_fn, axis_name="pod")
    out = jax.jit(pipe)(params, x)

# sequential reference
ref = x
for i in range(P_STAGES):
    pi = {"w": params["w"][i], "b": params["b"][i]}
    ref = jax.vmap(lambda xb: stage_fn(pi, xb))(ref)
err = float(jnp.max(jnp.abs(out - ref)))
print(json.dumps({"err": err}))
"""


@pytest.mark.dist
@pytest.mark.slow
def test_pipeline_4stage_matches_sequential():
    code = _SUBPROC.replace("__SRC__", os.path.abspath(SRC))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["err"] < 1e-5, res
