"""Serve-time weighted-Gram basis + factor-form paged K cache.

Covers the fixes of the serve data-plane rework:
  * serve-time half-rank top-1 agreement clears the 0.8 bar with the
    softmax-weighted basis (the plain-Gram basis sat at ~0.75 — the bug
    the prefill-path weighted Gram had already fixed),
  * the factored decode path (kt_pool = K . B_r) is token-for-token
    identical to the dense paged path at full rank,
  * recycled-slot isolation: a new occupant of freed pages never reads the
    previous occupant's stale factors / attention mass,
  * page-leak invariant after run(),
  * prefill bucket clamping, random-mode slot fold-in, and the Eq. 9 veto
    actually measuring the previous-segment -> current transition.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import RankConfig
from repro.models import transformer as tr
from repro.models.api import get_model
from repro.models.lowrank_cache import attention_mass
from repro.serve import PagedKVCache, Request, ServeEngine
from repro.serve.policy import make_decide_fn
from repro.serve.scheduler import bucket_for, prefill_buckets


pytestmark = pytest.mark.serve

RNG = jax.random.PRNGKey(0)


def _drrl_cfg(mode="fixed", **kw):
    cfg = get_config("drrl-paper", reduced=True)
    return cfg.with_(rank=RankConfig(mode=mode, rank_grid=(4, 8, 12, 16),
                                     segment_len=8, **kw))


# ---------------------------------------------------------------------------
# serve-time basis quality: weighted Gram clears the bar the plain one missed
# ---------------------------------------------------------------------------

def test_serve_halfrank_agreement_weighted_basis():
    """Teacher-forced decode against the paged cache at half rank: the
    decide-time weighted basis must reach >= 0.8 top-1 agreement with the
    full-rank reference AND beat the plain-Gram basis (zero mass falls
    back to plain — the pre-fix serve behaviour, ~0.75 here)."""
    cfg0 = get_config("qwen2.5-14b", reduced=True)
    dh = cfg0.resolved_head_dim()
    half = dh // 2
    cfg = cfg0.with_(rank=RankConfig(mode="fixed", rank_grid=(half, dh),
                                     fixed_rank=half, segment_len=32))
    params = tr.init_dense(cfg0, RNG)
    fns = get_model(cfg)
    pf_cfg = cfg.with_(rank=cfg.rank.__class__(mode="off"))
    fns_off = get_model(pf_cfg)
    b, s, n = 2, 24, 6
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    nxt = jax.random.randint(jax.random.PRNGKey(2), (b, n), 0,
                             cfg.vocab_size)

    cf = fns_off.init_cache(b, 40)
    _, cf = fns_off.decode_step(params, cf, toks)
    refs = []
    for t in range(n):
        lg, cf = fns_off.decode_step(params, cf, nxt[:, t:t + 1])
        refs.append(np.asarray(lg[:, 0]))
    ref = np.stack(refs, 1)                              # (b, n, V)

    _, aux = tr.forward_dense(pf_cfg, params, toks, collect_aux="rl",
                              collect_qkv=True)
    qkv = aux["layers"]["qkv"]
    mass = attention_mass(qkv["q"], qkv["k"])            # (L, b, hkv, s)

    def run(weighted):
        cache = PagedKVCache(cfg, n_slots=b, max_len=40, page_size=8,
                             factored=True)
        decide = make_decide_fn(cfg)
        for slot in range(b):
            cache.allocate(slot, s + n)
            m = jnp.swapaxes(mass[:, slot], 1, 2) if weighted else None
            cache.write_prefill(slot, qkv["k"][:, slot], qkv["v"][:, slot],
                                mass_layers=m)
            (cache.ranks, cache.basis, cache.spectra, cache.kt_pool,
             _veto) = decide(
                cache.k_pool, cache.mass_pool, cache.kt_pool,
                jnp.asarray(cache.page_table),
                jnp.asarray(cache.lens, jnp.int32), cache.ranks,
                cache.basis, cache.spectra, np.int32(slot),
                np.bool_(False), np.int32(0))
        lens = jnp.asarray(cache.lens, jnp.int32)
        pt = jnp.asarray(cache.page_table)
        outs = []
        for t in range(n):
            logits, pools = fns.decode_step_paged(
                params, cache.k_pool, cache.v_pool, pt, nxt[:, t:t + 1],
                slot_lens=lens, slot_ranks=cache.ranks, basis=cache.basis,
                kt_pool=cache.kt_pool, mass_pool=cache.mass_pool)
            cache.k_pool, cache.v_pool = pools["k"], pools["v"]
            cache.kt_pool, cache.mass_pool = pools["kt"], pools["mass"]
            lens = lens + 1
            outs.append(np.asarray(logits[:, 0]))
        got = np.stack(outs, 1)
        return float(np.mean(np.argmax(got, -1) == np.argmax(ref, -1)))

    agree_plain = run(weighted=False)
    agree_weighted = run(weighted=True)
    assert agree_weighted >= 0.8, (agree_weighted, agree_plain)
    assert agree_weighted > agree_plain, (agree_weighted, agree_plain)


# ---------------------------------------------------------------------------
# factor path == dense paged path at full rank; no page leaks
# ---------------------------------------------------------------------------

def _run_engine(cfg, params, prompts, *, factor, n_slots=2, max_new=12,
                use_kernel=False):
    eng = ServeEngine(cfg, params, n_slots=n_slots, max_len=64, page_size=8,
                      segment_len=8, max_new_cap=max_new,
                      factor_cache=factor, use_kernel=use_kernel)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, tokens=p, max_new=max_new, arrival=2 * i))
    eng.run()
    return eng


def test_factor_parity_and_page_leak():
    cfg = _drrl_cfg("fixed", fixed_rank=16)        # top of grid == dh: full
    fns = get_model(cfg)
    params = fns.init(RNG)
    rnd = np.random.default_rng(0)
    prompts = [rnd.integers(0, cfg.vocab_size, s).astype(np.int32)
               for s in (12, 20, 9)]
    eng_f = _run_engine(cfg, params, prompts, factor=True)
    eng_d = _run_engine(cfg, params, prompts, factor=False)
    assert eng_f.cache.kt_pool is not None and eng_d.cache.kt_pool is None
    outs_f, outs_d = eng_f.results(), eng_d.results()
    for i in range(len(prompts)):
        np.testing.assert_array_equal(
            outs_f[i], outs_d[i],
            err_msg=f"stream {i}: factored decode diverged at full rank")
    # page-leak invariant: every page back in the pool, tables on scratch
    for eng in (eng_f, eng_d):
        assert eng.cache.free_pages == eng.cache.n_pages - 1
        assert (eng.cache.page_table == 0).all()


def test_factor_parity_kernel_path():
    """The per-row flash-decode kernel consumes the same paged factors (and
    emits the mass row itself): tokens must match the XLA factor path."""
    cfg = _drrl_cfg("fixed", fixed_rank=16)
    fns = get_model(cfg)
    params = fns.init(RNG)
    rnd = np.random.default_rng(1)
    prompts = [rnd.integers(0, cfg.vocab_size, s).astype(np.int32)
               for s in (10, 17)]
    outs_x = _run_engine(cfg, params, prompts, factor=True,
                         max_new=6).results()
    outs_k = _run_engine(cfg, params, prompts, factor=True, max_new=6,
                         use_kernel=True).results()
    for i in range(len(prompts)):
        np.testing.assert_array_equal(outs_k[i], outs_x[i])


def test_recycled_slot_isolation():
    """A stream admitted into a recycled slot (same pages, same factor /
    mass cells) must decode exactly as if it had the engine to itself."""
    cfg = _drrl_cfg("adaptive", energy_threshold=0.90)
    fns = get_model(cfg)
    params = fns.init(RNG)
    rnd = np.random.default_rng(2)
    p1 = rnd.integers(0, cfg.vocab_size, 14).astype(np.int32)
    p2 = rnd.integers(0, cfg.vocab_size, 11).astype(np.int32)
    eng = ServeEngine(cfg, params, n_slots=1, max_len=48, page_size=8,
                      segment_len=8, max_new_cap=10, factor_cache=True)
    eng.submit(Request(rid=0, tokens=p1, max_new=10))
    eng.submit(Request(rid=1, tokens=p2, max_new=10))   # rides recycled slot
    outs = eng.run()
    solo = ServeEngine(cfg, params, n_slots=1, max_len=48, page_size=8,
                       segment_len=8, max_new_cap=10, factor_cache=True)
    solo.submit(Request(rid=1, tokens=p2, max_new=10))
    outs_solo = solo.run()
    np.testing.assert_array_equal(
        outs[1], outs_solo[1],
        err_msg="recycled slot leaked previous occupant's state")
    assert eng.cache.free_pages == eng.cache.n_pages - 1


def test_recycled_slot_isolation_drrl():
    """Same isolation property under the drrl policy: the recycled slot's
    first decision must not feed the previous occupant's rank into the
    policy features."""
    from repro.core.drrl import init_agent
    cfg = _drrl_cfg("drrl")
    fns = get_model(cfg)
    params = fns.init(RNG)
    policy = init_agent(jax.random.PRNGKey(7), cfg.rank, cfg.d_model)
    rnd = np.random.default_rng(4)
    p1 = rnd.integers(0, cfg.vocab_size, 13).astype(np.int32)
    p2 = rnd.integers(0, cfg.vocab_size, 10).astype(np.int32)

    def serve(reqs):
        eng = ServeEngine(cfg, params, policy, n_slots=1, max_len=48,
                          page_size=8, segment_len=8, max_new_cap=10,
                          factor_cache=True)
        for r in reqs:
            eng.submit(r)
        return eng.run()

    outs = serve([Request(rid=0, tokens=p1, max_new=10),
                  Request(rid=1, tokens=p2, max_new=10)])
    outs_solo = serve([Request(rid=1, tokens=p2, max_new=10)])
    np.testing.assert_array_equal(outs[1], outs_solo[1])


# ---------------------------------------------------------------------------
# satellite fixes
# ---------------------------------------------------------------------------

def test_prefill_buckets_clamped_to_max_len():
    bks = prefill_buckets(100)
    assert bks[-1] == 100 and bucket_for(100, bks) == 100
    assert prefill_buckets(64)[-1] == 64          # powers of two unchanged
    assert prefill_buckets(5)[-1] == 5
    # an engine at a non-power-of-two max_len never compiles a prefill
    # bucket (and cache) wider than a slot can hold
    cfg = _drrl_cfg("off")
    fns = get_model(cfg)
    params = fns.init(RNG)
    eng = ServeEngine(cfg, params, n_slots=1, max_len=20, page_size=8,
                      max_new_cap=4)
    assert max(eng._buckets) <= 20
    eng.submit(Request(rid=0, tokens=np.arange(16, dtype=np.int32),
                       max_new=4))
    outs = eng.run()
    assert outs[0].shape == (4,)


def test_random_mode_folds_slot_into_key():
    """Two slots with identical K content at the same segment clock must
    not draw identical bucket sequences."""
    cfg = _drrl_cfg("random")
    decide = make_decide_fn(cfg)
    cache = PagedKVCache(cfg, 2, max_len=16, page_size=8)
    L, hkv, dh = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim()
    k = np.random.default_rng(0).normal(
        size=(L, 12, hkv, dh)).astype(np.float32)
    for slot in (0, 1):
        cache.allocate(slot, 12)
        cache.write_prefill(slot, jnp.asarray(k), jnp.asarray(k))
    draws = {0: [], 1: []}
    for slot in (0, 1):
        for t in range(8):
            (cache.ranks, cache.basis, cache.spectra, cache.kt_pool,
             _veto) = decide(
                cache.k_pool, cache.mass_pool, cache.kt_pool,
                jnp.asarray(cache.page_table),
                jnp.asarray(cache.lens, jnp.int32), cache.ranks,
                cache.basis, cache.spectra, np.int32(slot),
                np.bool_(False), np.int32(t))
            draws[slot].append(int(cache.ranks[slot]))
    assert draws[0] != draws[1], draws


def test_veto_uses_previous_segment_spectra():
    """The Eq. 9 transition veto must read the slot's persisted
    previous-decision spectra: fabricating a huge flat 'before' spectrum
    blows up the relative bound and freezes the slot at its previous rank,
    which comparing the current spectra against themselves never would."""
    cfg = _drrl_cfg("adaptive", energy_threshold=0.90, epsilon0=1.0)
    decide = make_decide_fn(cfg)
    L, hkv, dh = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim()
    cache = PagedKVCache(cfg, 1, max_len=16, page_size=8)
    k = np.random.default_rng(3).normal(
        size=(L, 12, hkv, dh)).astype(np.float32)
    cache.allocate(0, 12)
    cache.write_prefill(0, jnp.asarray(k), jnp.asarray(k))

    def run_decide(has_rank):
        return decide(cache.k_pool, cache.mass_pool, cache.kt_pool,
                      jnp.asarray(cache.page_table),
                      jnp.asarray(cache.lens, jnp.int32), cache.ranks,
                      cache.basis, cache.spectra, np.int32(0),
                      np.bool_(has_rank), np.int32(0))

    ranks, basis, spectra, kt, veto = run_decide(False)
    natural = int(ranks[0])
    # a first decision has no previous rank to veto against
    assert not bool(veto)
    # first decision persisted its layer-0 spectra
    assert float(jnp.abs(spectra[0]).max()) > 0.0
    # normal transition: same K, stored spectra == current -> no veto, the
    # slot re-chooses its natural rank even from a different prev rank
    cache.spectra = spectra
    cache.ranks = jnp.asarray([4 if natural != 4 else 16], jnp.int32)
    ranks2, _, _, _, veto2 = run_decide(True)
    assert int(ranks2[0]) == natural
    assert not bool(veto2)
    # fabricated huge flat previous spectrum -> relative bound >> eps_t ->
    # the veto keeps the previous rank, and reports the fire
    cache.spectra = jnp.full_like(cache.spectra, 1e8)
    ranks3, _, _, _, veto3 = run_decide(True)
    assert int(ranks3[0]) == int(cache.ranks[0]) != natural
    assert bool(veto3)
