"""Chunked prefill interleaved into the fused decode step.

Covers the serve.api tentpole's data-plane half:
  * token-for-token parity: staggered heterogeneous streams admitted via
    chunked prefill decode identically to one-shot bucketed prefill —
    dense + factor cache, kernel + XLA paths, remainder chunks included,
  * the chunk-accumulated attention-mass seed equals the one-shot seed
    (bitwise when the prompt fits one chunk; up to summation association
    when the query-sum is split across chunks),
  * admission/eviction safety for prompts still in flight: a mid-prefill
    slot is never double-admitted, never evicted early (stale EOS /
    max_new cannot fire before token 0 exists), and the page-leak
    invariant holds through an immediate post-prefill EOS eviction,
  * decode never stalls on admission: chunked engines accrue zero
    blocking-prefill stall while the one-shot engine accrues it whenever
    it prefills with live decode streams waiting.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import RankConfig
from repro.models.api import get_model
from repro.serve import Request, ServeEngine


pytestmark = pytest.mark.serve

RNG = jax.random.PRNGKey(0)


def _cfg(mode="adaptive", **kw):
    cfg = get_config("drrl-paper", reduced=True)
    return cfg.with_(rank=RankConfig(mode=mode, rank_grid=(4, 8, 12, 16),
                                     segment_len=8, **kw))


def _run(cfg, params, prompts, *, chunk, n_slots=3, max_new=12,
         max_len=64, arrivals=None, eos=None, **ekw):
    eng = ServeEngine(cfg, params, n_slots=n_slots, max_len=max_len,
                      page_size=8, segment_len=8, max_new_cap=max_new,
                      prefill_chunk=chunk, **ekw)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, tokens=p, max_new=max_new,
                           arrival=(arrivals[i] if arrivals else 2 * i),
                           eos_id=eos))
    outs = eng.run()
    return outs, eng


# ---------------------------------------------------------------------------
# token parity: chunked == one-shot on the staggered heterogeneous workload
# ---------------------------------------------------------------------------

def test_chunked_parity_staggered_streams():
    """4 mixed-length staggered requests through 3 slots (one recycled),
    remainder chunks included (13, 20, 9, 15 with C=5): tokens must match
    one-shot admission exactly while two rank buckets are live, chunked
    admission must interleave (mixed steps > 0) and never stall decode."""
    cfg = _cfg("adaptive")
    params = get_model(cfg).init(RNG)
    rnd = np.random.default_rng(0)
    prompts = [np.full((13,), 7, np.int32)] + [
        rnd.integers(0, cfg.vocab_size, s).astype(np.int32)
        for s in (20, 9, 15)]
    outs_1, eng_1 = _run(cfg, params, prompts, chunk=None)
    outs_c, eng_c = _run(cfg, params, prompts, chunk=5)
    for i in range(len(prompts)):
        np.testing.assert_array_equal(
            outs_c[i], outs_1[i],
            err_msg=f"stream {i}: chunked prefill diverged from one-shot")
    assert eng_c.stats["mixed_steps"] > 0
    assert eng_c.stats["stall_s"] == 0.0         # admission never blocks
    assert eng_1.stats["stall_s"] > 0.0          # one-shot blocks the loop
    # heterogeneous ranks in one fused step, same as the one-shot engine
    distinct = max(len({r for r in step.tolist() if r >= 0})
                   for step in eng_c.ranks_per_step())
    assert distinct >= 2
    # page-leak invariant after the full run
    for eng in (eng_1, eng_c):
        assert eng.cache.free_pages == eng.cache.n_pages - 1
        assert (eng.cache.page_table == 0).all()


@pytest.mark.parametrize("use_kernel,factor", [(True, None), (False, True),
                                               (True, True)])
def test_chunked_parity_kernel_and_factor(use_kernel, factor):
    """The mixed step's per-row q_len path through the Pallas kernel and
    the factor-form cache must keep chunked == one-shot token parity."""
    cfg = _cfg("fixed", fixed_rank=16)
    params = get_model(cfg).init(RNG)
    rnd = np.random.default_rng(1)
    prompts = [rnd.integers(0, cfg.vocab_size, s).astype(np.int32)
               for s in (13, 21)]
    kw = dict(n_slots=2, max_new=8, use_kernel=use_kernel,
              factor_cache=factor)
    outs_1, _ = _run(cfg, params, prompts, chunk=None, **kw)
    outs_c, _ = _run(cfg, params, prompts, chunk=8, **kw)
    for i in range(len(prompts)):
        np.testing.assert_array_equal(outs_c[i], outs_1[i])


def test_chunked_parity_rank_off():
    cfg = _cfg("off")
    params = get_model(cfg).init(RNG)
    rnd = np.random.default_rng(2)
    prompts = [rnd.integers(0, cfg.vocab_size, s).astype(np.int32)
               for s in (11, 17)]
    outs_1, _ = _run(cfg, params, prompts, chunk=None, n_slots=2, max_new=8)
    outs_c, _ = _run(cfg, params, prompts, chunk=4, n_slots=2, max_new=8)
    for i in range(len(prompts)):
        np.testing.assert_array_equal(outs_c[i], outs_1[i])


# ---------------------------------------------------------------------------
# chunk-aware attention-mass seeding
# ---------------------------------------------------------------------------

def _seed_mass(cfg, params, prompt, chunk):
    """Mass-pool contents of slot 0's pages at the exact prefill boundary
    (one-shot: right after admission; chunked: right after the finishing
    mixed step, before any decode step adds its own row)."""
    eng = ServeEngine(cfg, params, n_slots=1, max_len=32, page_size=8,
                      segment_len=64, max_new_cap=4, prefill_chunk=chunk)
    eng.submit(Request(rid=0, tokens=prompt, max_new=4))
    if chunk is None:
        eng._admit()
    else:
        st = eng.sched.slots[0]
        while not st.active or st.mid_prefill:
            eng.step()
    return np.asarray(eng.cache.mass_pool)[:, 0, :len(prompt)]


def test_chunked_mass_seed_matches_oneshot():
    """The weighted-Gram basis must see the full prompt mass under chunked
    admission: a single covering chunk reproduces the one-shot prefill
    seed BITWISE (same math, same per-query softmax rows, same query-sum),
    and splitting the prompt across chunks changes only the association
    of the query-sum — equality to a couple of f32 ulps."""
    cfg = _cfg("adaptive")
    params = get_model(cfg).init(RNG)
    prompt = np.random.default_rng(3).integers(
        0, cfg.vocab_size, 13).astype(np.int32)
    ref = _seed_mass(cfg, params, prompt, None)
    assert np.abs(ref).max() > 0.0
    # chunk covers the prompt -> identical accumulation order -> bitwise
    for C in (13, 32):
        np.testing.assert_array_equal(_seed_mass(cfg, params, prompt, C), ref)
    # split chunks: same mass, summed in a different association
    for C in (4, 5):
        np.testing.assert_allclose(_seed_mass(cfg, params, prompt, C), ref,
                                   rtol=0.0, atol=8e-7)


# ---------------------------------------------------------------------------
# mid-prefill admission/eviction safety
# ---------------------------------------------------------------------------

def test_mid_prefill_never_evicted_or_double_admitted():
    from repro.serve import PagedKVCache, Scheduler
    from repro.serve.scheduler import prefill_buckets
    cfg = _cfg("off")
    cache = PagedKVCache(cfg, 1, max_len=32, page_size=8)
    sched = Scheduler(1, prefill_buckets(32))
    sched.submit(Request(rid=0, tokens=np.arange(16), max_new=1, eos_id=5))
    [(slot, req, _)] = sched.admit(0, cache.allocate)
    st = sched.slots[slot]
    st.prefilled = 8                      # chunked prompt half consumed
    assert st.mid_prefill
    # stale state from a previous occupant must not evict the new stream:
    # n_out >= max_new and last_tok == eos are both meaningless pre-token-0
    st.n_out, st.last_tok = 1, 5
    assert not sched.should_evict(slot)
    # the busy slot is not offered to the next request
    sched.submit(Request(rid=1, tokens=np.arange(4), max_new=1))
    assert sched.admit(1, cache.allocate) == []
    # once the prompt is fully consumed, the normal rules apply again
    st.prefilled = st.prompt_len
    assert sched.should_evict(slot)


def test_page_leak_mid_prefill_eos_eviction():
    """EOS as the very first generated token right after a chunked
    prefill: the slot must evict cleanly and return every page."""
    cfg = _cfg("off")
    params = get_model(cfg).init(RNG)
    prompt = np.arange(10, dtype=np.int32)
    outs, _ = _run(cfg, params, [prompt], chunk=4, n_slots=1, max_new=6,
                   arrivals=[0])
    eos = int(outs[0][0])                 # token 0 of the unconstrained run
    outs2, eng2 = _run(cfg, params, [prompt], chunk=4, n_slots=1, max_new=6,
                       arrivals=[0], eos=eos)
    assert outs2[0].tolist() == [eos]     # stopped immediately after prefill
    assert eng2.cache.free_pages == eng2.cache.n_pages - 1
    assert (eng2.cache.page_table == 0).all()


def test_chunked_recycled_slot_isolation():
    """A stream riding a recycled slot under chunked admission decodes as
    if it had the engine to itself (stale kt/mass/prompt_buf state from
    the previous occupant must not leak through the mixed step)."""
    cfg = _cfg("adaptive")
    params = get_model(cfg).init(RNG)
    rnd = np.random.default_rng(4)
    p1 = rnd.integers(0, cfg.vocab_size, 14).astype(np.int32)
    p2 = rnd.integers(0, cfg.vocab_size, 11).astype(np.int32)
    outs, eng = _run(cfg, params, [p1, p2], chunk=4, n_slots=1, max_new=8,
                     arrivals=[0, 0], factor_cache=True)
    solo, _ = _run(cfg, params, [p2], chunk=4, n_slots=1, max_new=8,
                   arrivals=[0], factor_cache=True)
    np.testing.assert_array_equal(outs[1], solo[0])
    assert eng.cache.free_pages == eng.cache.n_pages - 1


# ---------------------------------------------------------------------------
# sampling under chunked admission
# ---------------------------------------------------------------------------

def test_sampled_stream_parity_chunked_vs_oneshot():
    """The sampling PRNG folds (seed, output index), so a sampled stream's
    draws are independent of the admission mode: chunked and one-shot
    engines must produce identical sampled tokens."""
    cfg = _cfg("adaptive")
    params = get_model(cfg).init(RNG)
    rnd = np.random.default_rng(5)
    prompts = [rnd.integers(0, cfg.vocab_size, s).astype(np.int32)
               for s in (13, 9)]

    def run(chunk):
        eng = ServeEngine(cfg, params, n_slots=2, max_len=64, page_size=8,
                          segment_len=8, max_new_cap=8, prefill_chunk=chunk,
                          sampling=True)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, tokens=p, max_new=8, arrival=2 * i,
                               temperature=0.7, top_k=12, seed=41 + i))
        return eng.run()

    outs_1, outs_c = run(None), run(6)
    for i in range(len(prompts)):
        np.testing.assert_array_equal(outs_c[i], outs_1[i])
