"""Deterministic fallback for the ``hypothesis`` API surface this repo uses.

The container has no hypothesis wheel and nothing may be pip-installed, so
conftest.py puts this shim on sys.path only when the real package is
missing. It keeps the property-test modules collectible and meaningful:
``@given`` draws ``max_examples`` pseudo-random samples from each strategy
with a fixed seed, so runs are reproducible (no shrinking, no database —
install real hypothesis to get those back).
"""
from __future__ import annotations

import functools
import inspect
import random
from typing import Any, Callable, List

__version__ = "0.0-repro-fallback"


class _Strategy:
    def __init__(self, draw: Callable[[random.Random], Any], boundary=()):
        self._draw = draw
        self._boundary = list(boundary)   # always tried first

    def draw(self, rnd: random.Random, i: int):
        if i < len(self._boundary):
            return self._boundary[i]
        return self._draw(rnd)


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda r: r.randint(min_value, max_value),
                         boundary=(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda r: r.uniform(min_value, max_value),
                         boundary=(min_value, max_value))

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        elements = list(elements)
        return _Strategy(lambda r: r.choice(elements),
                         boundary=elements[:1])

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda r: r.random() < 0.5, boundary=(False, True))


def settings(max_examples: int = 10, deadline=None, **_kw):
    def deco(fn):
        fn._he_max_examples = max_examples
        return fn
    return deco


def given(*strats: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def runner(*args, **kwargs):
            n = getattr(runner, "_he_max_examples", 10)
            rnd = random.Random(0xC0FFEE)
            for i in range(n):
                vals: List[Any] = [s.draw(rnd, i) for s in strats]
                try:
                    fn(*args, *vals, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (fallback hypothesis, "
                        f"draw {i}): {vals!r}") from e
        # hide the strategy-filled params from pytest's fixture resolution
        runner.__signature__ = inspect.Signature()
        del runner.__dict__["__wrapped__"]
        return runner
    return deco
