"""Rank-r KV cache (beyond-paper serving extension): exact at full rank,
high-fidelity at r = d/2, and the cache factor really is r-dimensional."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as tr
from repro.models.api import get_model
from repro.models.lowrank_cache import (decode_step_lowrank,
                                        init_lowrank_cache, prefill_lowrank)

RNG = jax.random.PRNGKey(0)


def _run(cfg, params, toks, nxt, rank):
    cache = init_lowrank_cache(cfg, toks.shape[0], 40, rank)
    _, cache = prefill_lowrank(cfg, params, toks, cache, rank)
    outs = []
    for t in range(nxt.shape[1]):
        lg, cache = decode_step_lowrank(cfg, params, cache, nxt[:, t:t + 1])
        outs.append(lg[:, 0])
    return jnp.stack(outs, 1), cache


def test_lowrank_cache_decode():
    # the softmax-weighted Gram basis (attention-mass-weighted prompt-K
    # Gram) lifts half-rank top-1 agreement 0.75 -> 0.83 at this toy scale,
    # clearing the 0.8 bar that the plain prompt-K basis missed
    cfg = get_config("qwen2.5-14b", reduced=True)
    params = tr.init_dense(cfg, RNG)
    fns = get_model(cfg)
    b, s, n = 2, 24, 6
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    nxt = jax.random.randint(jax.random.PRNGKey(2), (b, n), 0, cfg.vocab_size)

    cache_full = fns.init_cache(b, 40)
    _, cache_full = fns.decode_step(params, cache_full, toks)
    outs = []
    for t in range(n):
        lg, cache_full = fns.decode_step(params, cache_full, nxt[:, t:t + 1])
        outs.append(lg[:, 0])
    ref = jnp.stack(outs, 1)

    dh = cfg.resolved_head_dim()
    # full rank: exact
    got, cache = _run(cfg, params, toks, nxt, dh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-4, rtol=1e-3)
    assert cache["kt"].shape[-1] == dh

    # half rank: high fidelity, top-1 preserved, cache actually smaller
    got2, cache2 = _run(cfg, params, toks, nxt, dh // 2)
    assert cache2["kt"].shape[-1] == dh // 2
    cos = float(jnp.mean(
        jnp.sum(got2 * ref, -1)
        / (jnp.linalg.norm(got2, axis=-1) * jnp.linalg.norm(ref, axis=-1))))
    agree = float(jnp.mean(
        (jnp.argmax(got2, -1) == jnp.argmax(ref, -1)).astype(jnp.float32)))
    assert cos > 0.98, cos
    assert agree >= 0.8, agree
