"""Spectral machinery: Gram route vs jnp SVD, projections, subspace and
power iteration, incremental extension, masked == static equivalence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lowrank as lr
from repro.models.attention import (apply_rank_masked, apply_rank_static,
                                    attend, spectral_ctx)

K0 = jax.random.PRNGKey(0)


def test_gram_spectrum_matches_svd():
    x = jax.random.normal(K0, (3, 40, 16))
    s2, e = lr.gram_spectrum(lr.gram(x))
    sv = jnp.linalg.svd(x, compute_uv=False)
    np.testing.assert_allclose(np.sqrt(np.asarray(s2)), np.asarray(sv),
                               atol=1e-3, rtol=1e-3)


def test_projection_is_best_rank_r():
    """x E_r E_r^T must hit the Eckart-Young optimum (vs SVD truncation)."""
    x = jax.random.normal(K0, (30, 8))
    s2, e = lr.gram_spectrum(lr.gram(x))
    r = 3
    mask = (jnp.arange(8) < r).astype(jnp.float32)
    xr = lr.project_masked(x, e, mask)
    u, s, vt = jnp.linalg.svd(x, full_matrices=False)
    x_opt = (u[:, :r] * s[:r]) @ vt[:r]
    err_g = float(jnp.linalg.norm(x - xr))
    err_opt = float(jnp.linalg.norm(x - x_opt))
    assert abs(err_g - err_opt) < 1e-4


def test_ner_monotone_and_bounded():
    x = jax.random.normal(K0, (2, 4, 64, 16))
    s2, _ = lr.gram_spectrum(lr.gram(x))
    ner = lr.ner_curve(s2)
    d = np.diff(np.asarray(ner), axis=-1)
    assert (d >= -1e-6).all(), "NER must be nondecreasing in r"
    np.testing.assert_allclose(np.asarray(ner[..., -1]), 1.0, atol=1e-5)


def test_rank_for_energy_hits_threshold():
    x = jax.random.normal(K0, (1, 1, 128, 16))
    s2, _ = lr.gram_spectrum(lr.gram(x))
    r = lr.rank_for_energy(s2, 0.9, 1, 16)
    ner = lr.ner_curve(s2)
    r_i = int(r[0, 0])
    assert float(ner[0, 0, r_i - 1]) >= 0.9
    if r_i > 1:
        assert float(ner[0, 0, r_i - 2]) < 0.9


def test_subspace_iteration_approximates_eigh():
    g = lr.gram(jax.random.normal(K0, (64, 16)))
    s2, e = lr.gram_spectrum(g)
    evals, basis = lr.subspace_iteration(g, r=4, iters=30)
    np.testing.assert_allclose(np.asarray(evals), np.asarray(s2[:4]),
                               rtol=5e-3)
    # reconstruction through the subspace is near-optimal (the serving-path
    # criterion; individual eigvectors may rotate within near-degenerate
    # eigenvalue clusters)
    err_sub = float(jnp.linalg.norm(g - basis @ (basis.T @ g)))
    err_opt = float(jnp.linalg.norm(g - e[:, :4] @ (e[:, :4].T @ g)))
    assert err_sub <= err_opt * 1.05 + 1e-3


def test_incremental_extend_matches_full():
    g = lr.gram(jax.random.normal(K0, (64, 16)))
    s2, e = lr.gram_spectrum(g)
    _, basis4 = lr.subspace_iteration(g, r=4, iters=30)
    evals_new, basis8 = lr.incremental_extend(g, basis4, extra=4, iters=30)
    np.testing.assert_allclose(np.asarray(evals_new), np.asarray(s2[4:8]),
                               rtol=5e-2, atol=1e-3)
    err_sub = float(jnp.linalg.norm(g - basis8 @ (basis8.T @ g)))
    err_opt = float(jnp.linalg.norm(g - e[:, :8] @ (e[:, :8].T @ g)))
    assert err_sub <= err_opt * 1.10 + 1e-3


def test_power_iteration_specnorm():
    w = jax.random.normal(K0, (48, 32))
    est = lr.power_iteration_specnorm(w, iters=20)
    true = jnp.linalg.norm(w, ord=2)
    np.testing.assert_allclose(float(est), float(true), rtol=1e-2)


def test_masked_equals_static_realisation():
    """The serving bucket (rank-r factors + mixing matrix) must produce the
    same attention output as the masked realisation at the same rank."""
    b, s, hq, hkv, d = 2, 24, 4, 2, 16
    ks = jax.random.split(K0, 3)
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    ctx = spectral_ctx(q, k)
    r = 6
    rank_q = jnp.full((b, hq), r, jnp.int32)
    rank_k = jnp.full((b, hkv), r, jnp.int32)
    qm, km = apply_rank_masked(q, k, ctx, rank_q, rank_k)
    qs, ks_ = apply_rank_static(q, k, ctx, r)
    from repro.models.common import repeat_kv
    scale = d ** -0.5
    om = attend(qm, repeat_kv(km, 2), repeat_kv(v, 2), scale=scale, causal=True)
    ost = attend(qs, repeat_kv(ks_, 2), repeat_kv(v, 2), scale=scale, causal=True)
    np.testing.assert_allclose(np.asarray(om), np.asarray(ost),
                               atol=1e-4, rtol=1e-3)


def test_fidelity_increases_with_rank():
    b, s, h, d = 2, 48, 2, 16
    ks = jax.random.split(K0, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    ctx = spectral_ctx(q, k)
    o_full = attend(q, k, v, scale=d ** -0.5, causal=True)
    errs = []
    for r in (2, 4, 8, 16):
        rr = jnp.full((b, h), r, jnp.int32)
        qm, km = apply_rank_masked(q, k, ctx, rr, rr)
        o_r = attend(qm, km, v, scale=d ** -0.5, causal=True)
        errs.append(float(jnp.linalg.norm(o_r - o_full)))
    assert errs[0] >= errs[1] >= errs[2]
    assert errs[3] < 1e-3           # full rank recovers exactly
