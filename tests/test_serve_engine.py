"""Continuous-batching serving engine (repro.serve).

Covers the three layers separately and end-to-end:
  * scheduler admission/eviction invariants (property-based),
  * slot-paged KV cache write/gather round-trips and page accounting,
  * fused paged decode == monolithic decode (mode 'off'),
  * per-row rank masking == whole-batch static rank factors,
  * the acceptance parity run: >= 3 staggered heterogeneous streams decode
    token-identically to per-stream lock-step generate while two distinct
    rank buckets are live in one fused step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.configs.base import RankConfig
from repro.models.api import get_model
from repro.serve import PagedKVCache, Request, Scheduler, ServeEngine
from repro.serve.kv_cache import gather_views
from repro.serve.scheduler import bucket_for, prefill_buckets


pytestmark = pytest.mark.serve

RNG = jax.random.PRNGKey(0)


def _cfg(mode="off", seg=8):
    cfg = get_config("drrl-paper", reduced=True)
    return cfg.with_(rank=RankConfig(mode=mode, rank_grid=(4, 8, 12, 16),
                                     fixed_rank=8, segment_len=seg))


# ---------------------------------------------------------------------------
# scheduler invariants
# ---------------------------------------------------------------------------

def test_prefill_buckets_cover_and_validate():
    bks = prefill_buckets(100)
    assert bks[-1] >= 100 and all(a < b for a, b in zip(bks, bks[1:]))
    assert bucket_for(9, bks) == 16 and bucket_for(8, bks) == 8


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 16 - 1), st.integers(1, 4), st.integers(1, 12))
def test_scheduler_invariants(seed, n_slots, n_reqs):
    """Random workload through admit/evict: slots never double-booked, pages
    of live slots stay disjoint, FIFO admission order, everything finishes."""
    rnd = np.random.default_rng(seed)
    cfg = _cfg()
    cache = PagedKVCache(cfg, n_slots, max_len=32, page_size=8)
    sched = Scheduler(n_slots, prefill_buckets(16))
    reqs = [Request(rid=i, tokens=rnd.integers(0, 99, rnd.integers(1, 13)),
                    max_new=int(rnd.integers(1, 8)),
                    arrival=int(rnd.integers(0, 6)))
            for i in range(n_reqs)]
    for r in reqs:
        sched.submit(r)
    admitted_order = []
    for now in range(200):
        placed = sched.admit(now, cache.allocate)
        for slot, req, bucket in placed:
            assert bucket >= len(req.tokens)
            assert req.arrival <= now
            admitted_order.append(req.rid)
            cache.lens[slot] = len(req.tokens)
            sched.slots[slot].prefilled = len(req.tokens)  # one-shot prefill
            sched.slots[slot].n_out = 1
        # invariant: one live request per slot, disjoint live pages
        live = [s.req.rid for s in sched.slots if s.active]
        assert len(live) == len(set(live))
        pages = [p for row in cache.live_pages().values() for p in row]
        assert len(pages) == len(set(pages)) and 0 not in pages
        # decode tick: every live slot emits one token, then evict
        for i, stt in enumerate(sched.slots):
            if stt.active:
                stt.decode_i += 1
                stt.n_out += 1
                cache.lens[i] += 1
            if stt.active and sched.should_evict(i):
                sched.evict(i, cache.release, list(range(stt.n_out)))
        if sched.done():
            break
    assert sched.done()
    assert sorted(r for r, _ in
                  [(rq.rid, o) for rq, o in sched.finished]) == sorted(
                      r.rid for r in reqs)
    # FIFO: requests with earlier arrival among the same admission window
    # never overtake — admitted order is sorted by (arrival, rid) per wave
    assert len(admitted_order) == n_reqs
    # all pages returned to the pool at the end
    assert cache.free_pages == cache.n_pages - 1


def test_scheduler_rejects_oversized_and_blocks_fifo():
    cfg = _cfg()
    cache = PagedKVCache(cfg, 2, max_len=16, page_size=8)
    sched = Scheduler(2, prefill_buckets(16))
    big = Request(rid=0, tokens=np.arange(10), max_new=10)   # needs 20 > 16
    sched.submit(big)
    sched.submit(Request(rid=1, tokens=np.arange(4), max_new=2))
    placed = sched.admit(0, cache.allocate)
    # head-of-queue can't be placed -> FIFO blocks the whole queue
    assert placed == [] and len(sched.pending) == 2


# ---------------------------------------------------------------------------
# paged cache round-trip
# ---------------------------------------------------------------------------

def test_paged_cache_roundtrip_and_release():
    cfg = _cfg()
    cache = PagedKVCache(cfg, n_slots=3, max_len=24, page_size=8)
    L, hkv, dh = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim()
    rnd = np.random.default_rng(0)
    written = {}
    for slot, s in ((0, 5), (1, 24), (2, 9)):
        assert cache.allocate(slot, s)
        k = rnd.normal(size=(L, s, hkv, dh)).astype(np.float32)
        v = rnd.normal(size=(L, s, hkv, dh)).astype(np.float32)
        cache.write_prefill(slot, jnp.asarray(k), jnp.asarray(v))
        written[slot] = (k, v, s)
    kv_all, vv_all = gather_views(cache.k_pool, cache.v_pool,
                                  jnp.asarray(cache.page_table))
    for slot, (k, v, s) in written.items():
        kg, vg = cache.gather_slot(slot)
        np.testing.assert_array_equal(np.asarray(kg[:, :s]), k)
        np.testing.assert_array_equal(np.asarray(vg[:, :s]), v)
        np.testing.assert_array_equal(np.asarray(kv_all[:, slot, :s]), k)
        np.testing.assert_array_equal(np.asarray(vv_all[:, slot, :s]), v)
        assert int(cache.lens[slot]) == s
    # release returns pages; a fresh allocation can reuse them
    free0 = cache.free_pages
    cache.release(1)
    assert cache.free_pages == free0 + cache.pages_needed(24)
    assert cache.allocate(1, 16)


# ---------------------------------------------------------------------------
# fused paged decode == monolithic decode
# ---------------------------------------------------------------------------

def test_paged_step_matches_monolithic_decode():
    """Mode 'off': the fused per-row step must reproduce decode_step_dense
    for each slot independently, including slots at different lengths."""
    cfg = _cfg("off")
    fns = get_model(cfg)
    params = fns.init(RNG)
    lens = [6, 11]
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 12), 0,
                              cfg.vocab_size)
    cache = PagedKVCache(cfg, n_slots=2, max_len=16, page_size=8)
    refs = []
    for slot, s in enumerate(lens):
        mono = fns.init_cache(1, 16)
        _, mono = fns.decode_step(params, mono, toks[slot:slot + 1, :s])
        cache.allocate(slot, s + 1)
        cache.write_prefill(slot, mono["k"][:, 0, :s], mono["v"][:, 0, :s])
        lg, _ = fns.decode_step(params, mono, toks[slot:slot + 1, -1:])
        refs.append(np.asarray(lg[0]))
    logits, _ = fns.decode_step_paged(
        params, cache.k_pool, cache.v_pool, jnp.asarray(cache.page_table),
        jnp.stack([toks[0, -1:], toks[1, -1:]]),
        slot_lens=jnp.asarray(cache.lens, jnp.int32))
    for slot in range(2):
        np.testing.assert_allclose(np.asarray(logits[slot]), refs[slot],
                                   atol=2e-4, rtol=2e-3)


def test_per_row_rank_masking_equals_truncated_basis():
    """Zeroing basis columns beyond each row's rank must give the same
    scores as actually slicing the basis to r columns (factor padding +
    rank masking only ever adds exact 0.0 terms to the contraction)."""
    from repro.core import lowrank as lr
    b, m, h, d, r_max = 3, 12, 2, 16, 12
    ks = jax.random.split(RNG, 2)
    q = jax.random.normal(ks[0], (b, 1, h, d))
    k = jax.random.normal(ks[1], (b, m, h, d))
    _, evecs = lr.gram_spectrum(lr.gram(jnp.swapaxes(k, 1, 2)))
    basis = evecs[..., :r_max]                       # (b, h, d, r_max)
    ranks = jnp.asarray([4, 8, 12], jnp.int32)
    col_ok = (jnp.arange(r_max)[None, :] < ranks[:, None]).astype(jnp.float32)
    bm = basis * col_ok[:, None, None, :]
    q_m = jnp.einsum("bshd,bhdr->bshr", q, bm)
    k_m = jnp.einsum("bmhd,bhdr->bmhr", k, bm)
    sc_m = jnp.einsum("bshr,bmhr->bshm", q_m, k_m)
    for i, r in enumerate([4, 8, 12]):
        bs = basis[i:i + 1, ..., :r]
        q_s = jnp.einsum("bshd,bhdr->bshr", q[i:i + 1], bs)
        k_s = jnp.einsum("bmhd,bhdr->bmhr", k[i:i + 1], bs)
        sc_s = jnp.einsum("bshr,bmhr->bshm", q_s, k_s)
        np.testing.assert_allclose(np.asarray(sc_m[i:i + 1]),
                                   np.asarray(sc_s), atol=1e-4, rtol=1e-4)


def test_decide_matches_numpy_oracle():
    """Independent oracle for the slot-indexed rank decision: the adaptive
    rule (NER threshold per head -> median -> grid snap) recomputed in
    plain NumPy must agree, and the refreshed basis must be orthonormal
    while the other slot's state stays untouched."""
    from repro.serve.policy import make_decide_fn
    cfg = _cfg("adaptive")
    decide = make_decide_fn(cfg)
    cache = PagedKVCache(cfg, 2, max_len=16, page_size=8)
    L, hkv, dh = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim()
    rnd = np.random.default_rng(3)
    s = 12
    k = rnd.normal(size=(L, s, hkv, dh)).astype(np.float32)
    cache.allocate(0, s)
    cache.write_prefill(0, jnp.asarray(k), jnp.asarray(np.zeros_like(k)))
    ranks, basis, spectra, _, _veto = decide(
        cache.k_pool, cache.mass_pool, cache.kt_pool,
        jnp.asarray(cache.page_table),
        jnp.asarray(cache.lens, jnp.int32), cache.ranks,
        cache.basis, cache.spectra, np.int32(0), np.bool_(False),
        np.int32(0))
    grid = np.asarray(cfg.rank.rank_grid)
    g = np.einsum("shd,she->hde", k[0], k[0])   # (hkv, dh, dh) layer-0 Gram
    evals = np.linalg.eigvalsh(g)[..., ::-1]
    ner = np.cumsum(evals, -1) / evals.sum(-1, keepdims=True)
    met = (ner >= cfg.rank.energy_threshold).any(-1)
    r = np.where(met, 1 + np.argmax(ner >= cfg.rank.energy_threshold, -1),
                 grid[-1])
    r = np.clip(r, grid[0], grid[-1])
    expect = grid[np.argmin(np.abs(grid - np.median(r)))]
    assert int(ranks[0]) == int(expect)
    # refreshed basis: orthonormal columns per (layer, head)
    b = np.asarray(basis[:, 0])              # (L, hkv, dh, r_keep)
    btb = np.einsum("lhdr,lhds->lhrs", b, b)
    eye = np.broadcast_to(np.eye(b.shape[-1]), btb.shape)
    np.testing.assert_allclose(btb, eye, atol=1e-4)
    # slot 1 untouched by the dynamic-index update
    assert int(ranks[1]) == int(cache.ranks[1])
    assert float(jnp.abs(basis[:, 1]).max()) == 0.0
    # the decision persisted its layer-0 spectra (veto "before" side);
    # zero mass falls back to the plain Gram, so they match the oracle
    np.testing.assert_allclose(np.asarray(spectra[0]),
                               np.maximum(evals, 0.0), rtol=1e-4,
                               atol=1e-3 * float(evals.max()))
    assert float(jnp.abs(spectra[1]).max()) == 0.0


def test_fullrank_basis_projection_matches_off():
    """Independent check of the rank path: projecting onto a full-rank
    (r = dh) eigenbasis must reproduce the unprojected mode-'off' logits —
    the projection plumbing cannot change the math at full rank."""
    from repro.core import lowrank as lr
    cfg = _cfg("adaptive")                   # grid top 16 == dh
    fns = get_model(cfg)
    params = fns.init(RNG)
    dh = cfg.resolved_head_dim()
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 12), 0,
                              cfg.vocab_size)
    cache = PagedKVCache(cfg, n_slots=2, max_len=16, page_size=8)
    pf = get_model(cfg.with_(rank=cfg.rank.__class__(mode="off")))
    for slot, s in enumerate((6, 11)):
        mono = pf.init_cache(1, 16)
        _, mono = pf.decode_step(params, mono, toks[slot:slot + 1, :s])
        cache.allocate(slot, s + 1)
        cache.write_prefill(slot, mono["k"][:, 0, :s], mono["v"][:, 0, :s])
    kv_all, _ = gather_views(cache.k_pool, cache.v_pool,
                             jnp.asarray(cache.page_table))
    lens = jnp.asarray(cache.lens, jnp.int32)
    valid = jnp.arange(kv_all.shape[2])[None, :] < lens[:, None]
    kk = (jnp.swapaxes(kv_all, 2, 3)
          * valid[None, :, None, :, None])
    _, evecs = lr.gram_spectrum(lr.gram(kk))
    args = (params, cache.k_pool, cache.v_pool,
            jnp.asarray(cache.page_table),
            jnp.stack([toks[0, -1:], toks[1, -1:]]))
    lg_off, _ = fns.decode_step_paged(*args, slot_lens=lens)
    lg_proj, _ = fns.decode_step_paged(
        *args, slot_lens=lens, slot_ranks=jnp.full((2,), dh, jnp.int32),
        basis=evecs[..., :dh])
    np.testing.assert_allclose(np.asarray(lg_proj), np.asarray(lg_off),
                               atol=2e-4, rtol=2e-3)


# ---------------------------------------------------------------------------
# the acceptance run: staggered heterogeneous streams, token parity
# ---------------------------------------------------------------------------

def test_engine_parity_staggered_streams():
    from repro.launch.serve import AdaptiveServer
    cfg = _cfg("adaptive", seg=8)
    fns = get_model(cfg)
    params = fns.init(RNG)
    rnd = np.random.default_rng(0)
    prompts = [
        np.full((12,), 7, np.int32),                   # low-spectral prompt
        rnd.integers(0, cfg.vocab_size, 20).astype(np.int32),
        rnd.integers(0, cfg.vocab_size, 9).astype(np.int32),
        rnd.integers(0, cfg.vocab_size, 15).astype(np.int32),
    ]
    N = 16
    # 4 requests through 3 slots: the 4th stream rides a recycled slot,
    # so stale-page masking is on the line too
    eng = ServeEngine(cfg, params, n_slots=3, max_len=64, page_size=8,
                      segment_len=8, max_new_cap=N)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, tokens=p, max_new=N, arrival=2 * i))
    eng.warmup()
    outs = eng.run()
    assert eng.stats["compile_s"] > 0.0
    assert eng.stats["prefills"] == len(prompts)

    # at least two distinct rank buckets live in one fused step
    per_step = eng.ranks_per_step()
    distinct = max(len({r for r in step.tolist() if r >= 0})
                   for step in per_step)
    assert distinct >= 2, per_step

    # token-for-token parity with per-stream lock-step generate
    server = AdaptiveServer(cfg, params, max_len=64, page_size=8)
    for i, p in enumerate(prompts):
        ref = server.generate(jnp.asarray(p[None]), N, segment_len=8)
        np.testing.assert_array_equal(
            outs[i], np.asarray(ref["tokens"])[0],
            err_msg=f"stream {i} diverged from lock-step decode")


def test_engine_drift_trigger_forces_redecisions():
    """drift_threshold=0 makes every post-decision step re-decide (any
    nonzero residual trips it), so the decide count must exceed the
    segment-schedule count of an identical run without the trigger."""
    cfg = _cfg("adaptive", seg=8)
    fns = get_model(cfg)
    params = fns.init(RNG)
    prompt = np.arange(10, dtype=np.int32)

    def go(drift):
        eng = ServeEngine(cfg, params, n_slots=1, max_len=48, page_size=8,
                          segment_len=8, max_new_cap=12,
                          drift_threshold=drift)
        eng.submit(Request(rid=0, tokens=prompt, max_new=12))
        outs = eng.run()
        return outs[0], eng.stats["decides"]

    out_base, n_base = go(None)
    out_drift, n_drift = go(0.0)
    assert n_drift > n_base
    assert out_drift.shape == out_base.shape


def test_engine_eos_eviction():
    """A stream whose request carries eos_id stops early and frees its slot."""
    cfg = _cfg("off")
    fns = get_model(cfg)
    params = fns.init(RNG)
    prompt = np.arange(8, dtype=np.int32)
    eng = ServeEngine(cfg, params, n_slots=1, max_len=48, page_size=8,
                      max_new_cap=12)
    eng.submit(Request(rid=0, tokens=prompt, max_new=12))
    outs = eng.run()
    full = outs[0]
    assert full.shape == (12,)
    # re-run with eos at whatever the 4th token was: must stop at its
    # first occurrence (which may be earlier)
    eos = int(full[3])
    stop = int(np.argmax(full == eos)) + 1
    eng2 = ServeEngine(cfg, params, n_slots=1, max_len=48, page_size=8,
                       max_new_cap=12)
    eng2.submit(Request(rid=0, tokens=prompt, max_new=12, eos_id=eos))
    outs2 = eng2.run()
    assert outs2[0].tolist() == full[:stop].tolist()
