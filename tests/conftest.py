import os
import sys

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device (the dry-run sets 512 in its own process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import hypothesis  # noqa: F401
except ImportError:  # container has no hypothesis wheel — use the shim
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_vendor"))

import jax

import repro  # noqa: F401  (applies the jax forward-compat shim)

jax.config.update("jax_enable_x64", False)

# dist/slow markers are registered in pyproject.toml [tool.pytest.ini_options]
