"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import decode_attention, flash_attention
from repro.kernels.ref import decode_ref, flash_ref

K0 = jax.random.PRNGKey(0)


def _rand(shape, key, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


FLASH_CASES = [
    # (b, hq, hkv, sq, skv, r, dv, causal)
    (2, 4, 2, 64, 64, 16, 32, True),      # GQA, low rank
    (1, 4, 4, 128, 128, 64, 64, True),    # MHA, r=dv
    (2, 2, 1, 48, 96, 8, 16, False),      # cross-ish, non-causal
    (1, 8, 2, 37, 37, 24, 16, True),      # ragged seq vs block
    (1, 2, 2, 16, 16, 128, 128, True),    # full-rank head_dim 128
    (2, 6, 3, 33, 65, 40, 48, True),      # odd everything
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=[str(c) for c in FLASH_CASES])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_vs_ref(case, dtype):
    b, hq, hkv, sq, skv, r, dv, causal = case
    ks = jax.random.split(K0, 3)
    q = _rand((b, hq, sq, r), ks[0], dtype)
    k = _rand((b, hkv, skv, r), ks[1], dtype)
    v = _rand((b, hkv, skv, dv), ks[2], dtype)
    out = flash_attention(q, k, v, scale=r ** -0.5, causal=causal,
                          block_q=16, block_k=16, interpret=True)
    ref = flash_ref(q, k, v, scale=r ** -0.5, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


DECODE_CASES = [
    (2, 4, 2, 128, 16, 32, 100),
    (1, 8, 8, 256, 64, 64, 256),
    (2, 2, 1, 64, 8, 16, 1),
    (1, 4, 1, 96, 128, 128, 50),
]


@pytest.mark.parametrize("case", DECODE_CASES, ids=[str(c) for c in DECODE_CASES])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_vs_ref(case, dtype):
    b, hq, hkv, M, r, dv, klen = case
    ks = jax.random.split(K0, 3)
    q = _rand((b, hq, r), ks[0], dtype)
    k = _rand((b, hkv, M, r), ks[1], dtype)
    v = _rand((b, hkv, M, dv), ks[2], dtype)
    out = decode_attention(q, k, v, jnp.int32(klen), scale=r ** -0.5,
                           block_k=32, interpret=True)
    ref = decode_ref(q, k, v, jnp.int32(klen), scale=r ** -0.5)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_per_row_kv_len(dtype):
    """Continuous-batching form: every batch row carries its own valid
    prefix length (and, via zeroed factor columns, its own rank)."""
    b, hq, hkv, M, r, dv = 4, 4, 2, 96, 16, 32
    ks = jax.random.split(K0, 3)
    q = _rand((b, hq, r), ks[0], dtype)
    k = _rand((b, hkv, M, r), ks[1], dtype)
    v = _rand((b, hkv, M, dv), ks[2], dtype)
    lens = jnp.asarray([1, 17, 96, 40], jnp.int32)
    # per-row rank masking: rows truncate their factors differently
    ranks = jnp.asarray([4, 8, 16, 12], jnp.int32)
    col_ok = jnp.arange(r)[None, :] < ranks[:, None]
    q = q * col_ok[:, None, :]
    k = k * col_ok[:, None, None, :]
    out = decode_attention(q, k, v, lens, scale=r ** -0.5, block_k=32,
                           interpret=True)
    ref = decode_ref(q, k, v, lens, scale=r ** -0.5)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)
    # row i must equal a solo decode at its own length
    for i in (0, 1, 3):
        solo = decode_ref(q[i:i + 1], k[i:i + 1], v[i:i + 1],
                          jnp.int32(int(lens[i])), scale=r ** -0.5)
        np.testing.assert_allclose(np.asarray(out[i:i + 1], np.float32),
                                   np.asarray(solo, np.float32),
                                   atol=tol, rtol=tol)


def test_decode_return_probs():
    """The probability-row output (serving's attention-mass feed) must be
    the normalised softmax row: rescaled correctly across kv blocks,
    exactly zero beyond each row's kv_len, and consistent with the
    no-probs output."""
    b, hq, hkv, M, r, dv = 3, 4, 2, 96, 16, 8
    ks = jax.random.split(K0, 3)
    q = _rand((b, hq, r), ks[0], jnp.float32)
    k = _rand((b, hkv, M, r), ks[1], jnp.float32)
    v = _rand((b, hkv, M, dv), ks[2], jnp.float32)
    lens = jnp.asarray([5, 96, 41], jnp.int32)
    out, probs = decode_attention(q, k, v, lens, scale=r ** -0.5,
                                  block_k=32, interpret=True,
                                  return_probs=True)
    out0 = decode_attention(q, k, v, lens, scale=r ** -0.5, block_k=32,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out0), atol=1e-6)
    kr = jnp.repeat(k, hq // hkv, axis=1)
    sc = jnp.einsum("bhr,bhmr->bhm", q, kr) * r ** -0.5
    sc = jnp.where(jnp.arange(M)[None, None, :] < lens[:, None, None],
                   sc, -1e30)
    ref = jax.nn.softmax(sc, axis=-1)
    np.testing.assert_allclose(np.asarray(probs), np.asarray(ref), atol=1e-5)
    for i, n in enumerate([5, 41]):
        assert float(np.abs(np.asarray(probs)[(0, 2)[i], :, n:]).max()) == 0.0


def test_flash_q_offset_matches_decode_semantics():
    """flash with q_offset == suffix rows of the full causal result."""
    b, h, s, d = 1, 2, 32, 16
    ks = jax.random.split(K0, 3)
    q = _rand((b, h, s, d), ks[0], jnp.float32)
    k = _rand((b, h, s, d), ks[1], jnp.float32)
    v = _rand((b, h, s, d), ks[2], jnp.float32)
    full = flash_ref(q, k, v, scale=d ** -0.5, causal=True)
    tail = flash_attention(q[:, :, -4:], k, v, scale=d ** -0.5, causal=True,
                           q_offset=s - 4, block_q=8, block_k=8,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(tail), np.asarray(full[:, :, -4:]),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_chunked_queries(dtype):
    """Chunked-prefill form: each row carries a block of C query tokens at
    its own cache offset (q_start), causal within the chunk — decode rows
    (C effective 1) and mid-prefill rows share the executable. Rows whose
    causal window hasn't reached a kv block must contribute exact zeros,
    and the probs output must stay normalised per valid query."""
    from repro.kernels.ref import decode_chunk_ref
    b, hq, hkv, C, M, r, dv = 4, 4, 2, 6, 96, 16, 32
    ks = jax.random.split(K0, 3)
    q = _rand((b, hq, C, r), ks[0], dtype)
    k = _rand((b, hkv, M, r), ks[1], dtype)
    v = _rand((b, hkv, M, dv), ks[2], dtype)
    # fresh prompt start / mid-prompt chunk / chunk crossing kv blocks /
    # decode-style row (1 valid query + padding)
    q_start = jnp.asarray([0, 17, 29, 64], jnp.int32)
    kv_len = q_start + jnp.asarray([6, 6, 6, 1], jnp.int32)
    out, probs = decode_attention(q, k, v, kv_len, scale=r ** -0.5,
                                  block_k=32, interpret=True,
                                  return_probs=True, q_start=q_start)
    ref, ref_p = decode_chunk_ref(q, k, v, kv_len, q_start, scale=r ** -0.5)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    # only the valid queries are comparable (padding rows see whatever
    # the kv_len clamp leaves; the engine discards them)
    for i in range(b):
        n_q = int(kv_len[i] - q_start[i])
        np.testing.assert_allclose(
            np.asarray(out[i, :, :n_q], np.float32),
            np.asarray(ref[i, :, :n_q], np.float32), atol=tol, rtol=tol)
        p = np.asarray(probs[i, :, :n_q], np.float32)
        np.testing.assert_allclose(
            p, np.asarray(ref_p[i, :, :n_q], np.float32),
            atol=tol, rtol=tol)
        np.testing.assert_allclose(p.sum(-1), 1.0, atol=1e-5)
        # nothing visible beyond each query's causal position
        for j in range(n_q):
            assert np.all(p[:, j, int(q_start[i]) + j + 1:] == 0.0)
    # the single-token (3-d q) decode form is the C=1 slice of the same
    # kernel: row 3 must match a classic decode call at its length
    o1 = decode_attention(q[3:4, :, 0], k[3:4], v[3:4], kv_len[3:4],
                          scale=r ** -0.5, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(o1[0], np.float32),
                               np.asarray(out[3, :, 0], np.float32),
                               atol=tol, rtol=tol)
