"""Low-rank self-speculative decoding (repro.serve.spec).

The acceptance property is exactness: speculation may only change speed,
never tokens. Covers:
  * token parity with plain decode — greedy and seeded top-k / top-p
    streams, across dense/factor caches, kernel/XLA lowering, and
    off/fixed/adaptive rank modes,
  * the sampling PRNG folding on (seed, absolute output position): draw
    streams are bitwise identical with speculation on/off and across
    accept/reject histories,
  * rollback page accounting: no leaked or rewound pages under
    refcounting with live prefix-cache hits (speculative writes never
    touch a shared page),
  * mid-stream cancellation while drafts are in flight stays leak-free,
  * per-request accept-length stats (sum == generated tokens, values in
    [1, draft_k + 1]),
  * snapshot-density throttling (EngineConfig.snapshot_every): sparser
    reuse points, parity preserved via nearest-earlier-snapshot fallback,
  * pure helper units (accept_counts / clamp_to_eos) and EngineConfig
    validation.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import RankConfig
from repro.models.api import get_model
from repro.serve import Request, ServeEngine
from repro.serve import spec as spec_mod
from repro.serve.api import Engine, EngineConfig, SamplingParams


pytestmark = pytest.mark.serve

import jax

RNG = jax.random.PRNGKey(0)


def _cfg(mode="adaptive", **kw):
    cfg = get_config("drrl-paper", reduced=True)
    return cfg.with_(rank=RankConfig(mode=mode, rank_grid=(4, 8, 12, 16),
                                     fixed_rank=16, segment_len=8, **kw))


def _prompts(cfg, sizes=(9, 17, 12), seed=0):
    rnd = np.random.default_rng(seed)
    return [rnd.integers(1, cfg.vocab_size, s).astype(np.int32)
            for s in sizes]


def _run(cfg, params, prompts, *, speculative, max_new=12, reqs=None,
         **ekw):
    eng = ServeEngine(cfg, params, n_slots=4, max_len=64, page_size=8,
                      segment_len=8, max_new_cap=32, prefill_chunk=8,
                      speculative=speculative, draft_k=3,
                      draft_rank_frac=0.5, **ekw)
    for i, p in enumerate(prompts):
        kw = dict(reqs[i]) if reqs else {}
        eng.submit(Request(rid=i, tokens=p, max_new=max_new, **kw))
    outs = eng.run()
    return outs, eng


# ---------------------------------------------------------------------------
# pure helper units
# ---------------------------------------------------------------------------

def test_accept_counts_longest_prefix():
    drafts = jnp.array([[5, 6, 7],      # all match
                        [5, 9, 7],      # mismatch at i=1
                        [9, 6, 7],      # immediate mismatch
                        [5, 6, 9]])     # mismatch at last
    targets = jnp.array([[5, 6, 7, 8],
                         [5, 6, 7, 8],
                         [5, 6, 7, 8],
                         [5, 6, 7, 8]])
    np.testing.assert_array_equal(
        np.asarray(spec_mod.accept_counts(drafts, targets)), [4, 2, 1, 3])


def test_clamp_to_eos():
    a = jnp.array([4, 4, 4, 4], jnp.int32)
    targets = jnp.array([[5, 6, 7, 8],      # no EOS
                         [5, 2, 7, 8],      # EOS at 1 -> emit through it
                         [2, 6, 7, 8],      # EOS first -> a == 1
                         [5, 2, 7, 8]])     # eos_id == -1 -> no clamp
    eos = jnp.array([2, 2, 2, -1], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(spec_mod.clamp_to_eos(a, targets, eos)), [4, 2, 1, 4])


def test_apply_deferred_mass_matches_sequential():
    """Ordered masked application == sequential per-token accumulation,
    bitwise, for every accept count."""
    rnd = np.random.default_rng(0)
    L, ns, C, M, hkv = 2, 3, 4, 16, 2
    pool = jnp.asarray(rnd.random((L, ns, M, hkv), np.float32))
    contrib = jnp.asarray(rnd.random((L, ns, C, M, hkv), np.float32))
    lens = jnp.array([3, 7, 0], jnp.int32)
    n_q = jnp.array([2, 4, 0], jnp.int32)
    got = spec_mod.apply_deferred_mass(pool, contrib, lens, n_q)
    want = np.asarray(pool).copy()
    for r, (l0, nq) in enumerate(zip([3, 7, 0], [2, 4, 0])):
        want[:, r, l0:l0 + nq] = 0.0
        for q in range(nq):
            want[:, r] = want[:, r] + np.asarray(contrib)[:, r, q]
    np.testing.assert_array_equal(np.asarray(got), want)


# ---------------------------------------------------------------------------
# acceptance: token parity with plain decode, all modes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode,factor,kernel", [
    ("adaptive", None, False),          # factor cache, live ranks, XLA
    ("adaptive", None, True),           # factor cache, Pallas kernel
    ("fixed", True, False),             # factor cache, fixed rank
    ("fixed", False, False),            # dense paged read at fixed rank
    ("off", None, False),               # no rank path at all
])
def test_spec_parity_greedy(mode, factor, kernel):
    cfg = _cfg(mode)
    params = get_model(cfg).init(RNG)
    prompts = _prompts(cfg)
    kw = dict(factor_cache=factor, use_kernel=kernel)
    off, _ = _run(cfg, params, prompts, speculative=False, **kw)
    on, eng = _run(cfg, params, prompts, speculative=True, **kw)
    for i in range(len(prompts)):
        np.testing.assert_array_equal(
            on[i], off[i],
            err_msg=f"stream {i}: speculative decode diverged")
    s = eng.stats
    assert s["spec_steps"] > 0
    # every decoding row-step emits its verify bonus token plus accepts;
    # each engine step covers >= 1 decoding row
    assert s["spec_tokens"] - s["spec_accepted"] >= s["spec_steps"]
    # page accounting unchanged by rollback
    assert eng.cache.free_pages == eng.cache.n_pages - 1
    assert (eng.cache.page_table == 0).all()


def test_spec_parity_sampled_streams():
    """Seeded top-k and top-p streams are bitwise identical with
    speculation on/off: targets reuse the same (seed, output position)
    fold plain decode samples with."""
    cfg = _cfg("adaptive")
    params = get_model(cfg).init(RNG)
    prompts = _prompts(cfg, seed=3)
    reqs = [dict(temperature=0.9, top_k=8, seed=11),
            dict(temperature=0.7, top_p=0.85, seed=12),
            dict()]                                    # greedy rides along
    kw = dict(sampling=True, nucleus=True, reqs=reqs)
    off, _ = _run(cfg, params, prompts, speculative=False, **kw)
    on, eng = _run(cfg, params, prompts, speculative=True, **kw)
    for i in range(len(prompts)):
        np.testing.assert_array_equal(
            on[i], off[i], err_msg=f"sampled stream {i} diverged")
    assert eng.stats["spec_accepted"] > 0


def test_spec_parity_with_eos_cutoff():
    """A draft run crossing EOS truncates at it — same stop token, same
    stream length as plain decode."""
    cfg = _cfg("off")
    params = get_model(cfg).init(RNG)
    prompts = _prompts(cfg, sizes=(9, 13), seed=5)
    # pick each stream's own 3rd greedy token as its EOS so the cutoff
    # genuinely lands mid-run
    probe, _ = _run(cfg, params, prompts, speculative=False, max_new=6)
    reqs = [dict(eos_id=int(probe[i][2])) for i in range(len(prompts))]
    off, _ = _run(cfg, params, prompts, speculative=False, reqs=reqs)
    on, eng = _run(cfg, params, prompts, speculative=True, reqs=reqs)
    for i in range(len(prompts)):
        np.testing.assert_array_equal(on[i], off[i])
        assert on[i][-1] == reqs[i]["eos_id"]
    assert eng.cache.free_pages == eng.cache.n_pages - 1


# ---------------------------------------------------------------------------
# rollback + prefix cache: shared pages are never touched, nothing leaks
# ---------------------------------------------------------------------------

def test_spec_rollback_with_live_prefix_hits():
    """Speculative decode over prefix-hit admissions: rejected drafts
    roll back without touching refcounted shared pages, outputs match the
    cold cache-off engine, and the generalized leak invariant holds."""
    cfg = _cfg("adaptive")
    params = get_model(cfg).init(RNG)
    rnd = np.random.default_rng(6)
    shared = rnd.integers(0, cfg.vocab_size, 24).astype(np.int32)
    prompts = [np.concatenate([shared,
                               rnd.integers(0, cfg.vocab_size,
                                            8).astype(np.int32)])
               for _ in range(3)]
    reqs = [dict(arrival=10 * i) for i in range(3)]
    off, _ = _run(cfg, params, prompts, speculative=False, reqs=reqs)
    on, eng = _run(cfg, params, prompts, speculative=True,
                   prefix_cache=True, reqs=reqs)
    for i in range(3):
        np.testing.assert_array_equal(
            on[i], off[i], err_msg=f"prefix-hit stream {i} diverged")
    assert eng.stats["prefix_hits"] == 2
    eng.cache.check_refs(eng.prefix.all_pages())
    tree = len(eng.prefix.all_pages())
    assert eng.cache.free_pages == eng.cache.n_pages - 1 - tree


def test_spec_cancel_mid_stream_leak_free():
    """Cancelling a stream between speculative steps releases its pages
    and stops delivery; the survivors finish with correct tokens."""
    cfg = _cfg("adaptive")
    params = get_model(cfg).init(RNG)
    prompts = _prompts(cfg, sizes=(9, 17), seed=7)
    ref = Engine(cfg, params, config=EngineConfig(
        n_slots=2, max_len=64, page_size=8, segment_len=8,
        prefill_chunk=8, max_new_cap=32))
    hr = [ref.submit(p, SamplingParams(max_new=12)) for p in prompts]
    ref.run()

    eng = Engine(cfg, params, config=EngineConfig(
        n_slots=2, max_len=64, page_size=8, segment_len=8,
        prefill_chunk=8, max_new_cap=32, speculative=True, draft_k=3,
        draft_rank_frac=0.5))
    h = [eng.submit(p, SamplingParams(max_new=12)) for p in prompts]
    for _ in range(4):                     # past prefill, drafts in flight
        eng.step()
    assert h[0].cancel()
    eng.run()
    assert h[0].cancelled and not h[1].cancelled
    np.testing.assert_array_equal(h[1].result(), hr[1].result())
    assert eng.core.cache.free_pages == eng.core.cache.n_pages - 1
    assert (eng.core.cache.page_table == 0).all()
    # the cancelled stream's accept history was still harvested
    assert 0 in eng.accept_lens()


# ---------------------------------------------------------------------------
# accept-length stats
# ---------------------------------------------------------------------------

def test_accept_len_stats_account_for_every_token():
    cfg = _cfg("adaptive")
    params = get_model(cfg).init(RNG)
    prompts = _prompts(cfg, seed=9)
    eng = Engine(cfg, params, config=EngineConfig(
        n_slots=4, max_len=64, page_size=8, segment_len=8,
        prefill_chunk=8, max_new_cap=32, speculative=True, draft_k=3,
        draft_rank_frac=0.5))
    hs = [eng.submit(p, SamplingParams(max_new=12)) for p in prompts]
    eng.run()
    acc = eng.accept_lens()
    assert set(acc) == {h.rid for h in hs}
    for h in hs:
        runs = acc[h.rid]
        assert all(1 <= a <= 4 for a in runs)
        # token 0 comes from prefill; every later token from some run
        assert sum(runs) == len(h.result()) - 1
    s = eng.stats
    assert s["spec_drafted"] >= s["spec_accepted"] >= 0
    assert s["spec_tokens"] == sum(sum(v) for v in acc.values())


# ---------------------------------------------------------------------------
# satellite: sampled streams are accept/reject-history invariant
# ---------------------------------------------------------------------------

def test_prng_stream_invariant_to_draft_k():
    """The fold is (seed, absolute output position): the same request
    draws the same stream under different draft depths (different
    accept/reject histories) and without speculation at all."""
    cfg = _cfg("off")
    params = get_model(cfg).init(RNG)
    prompts = _prompts(cfg, sizes=(9,), seed=10)
    reqs = [dict(temperature=0.8, top_k=16, seed=21)]

    outs = []
    for spec, k in [(False, None), (True, 1), (True, 3), (True, 5)]:
        eng = ServeEngine(cfg, params, n_slots=2, max_len=64, page_size=8,
                          segment_len=8, max_new_cap=32, prefill_chunk=8,
                          speculative=spec, sampling=True,
                          **({"draft_k": k} if k else {}))
        eng.submit(Request(rid=0, tokens=prompts[0], max_new=12, **reqs[0]))
        outs.append(eng.run()[0])
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)


# ---------------------------------------------------------------------------
# satellite: snapshot-density throttling
# ---------------------------------------------------------------------------

def test_snapshot_throttle_sparser_reuse_parity():
    """snapshot_every=2 keeps every other page boundary: a prompt
    diverging between kept snapshots falls back to the nearest earlier
    one (shorter reuse, identical tokens)."""
    cfg = _cfg("adaptive")
    params = get_model(cfg).init(RNG)
    rnd = np.random.default_rng(11)
    shared = rnd.integers(0, cfg.vocab_size, 24).astype(np.int32)
    prompts = [np.concatenate([shared, rnd.integers(
        0, cfg.vocab_size, 8).astype(np.int32)]) for _ in range(2)]
    reqs = [dict(arrival=10 * i) for i in range(2)]
    off, _ = _run(cfg, params, prompts, speculative=False, reqs=reqs)

    dense_eng = ServeEngine(cfg, params, n_slots=4, max_len=64, page_size=8,
                            segment_len=8, max_new_cap=32, prefill_chunk=8,
                            prefix_cache=True)
    sparse_eng = ServeEngine(cfg, params, n_slots=4, max_len=64,
                             page_size=8, segment_len=8, max_new_cap=32,
                             prefill_chunk=8, prefix_cache=True,
                             snapshot_every=2)
    for eng in (dense_eng, sparse_eng):
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, tokens=p, max_new=12, **reqs[i]))
    dense_out = dense_eng.run()
    sparse_out = sparse_eng.run()
    for i in range(2):
        np.testing.assert_array_equal(dense_out[i], off[i])
        np.testing.assert_array_equal(sparse_out[i], off[i])
    # both hit, but the sparse tree only offers every other boundary:
    # the shared 24 = 3 pages reuse snaps 24 -> 16 under snapshot_every=2
    assert dense_eng.stats["prefix_hits"] == 1
    assert sparse_eng.stats["prefix_hits"] == 1
    assert (sparse_eng.stats["prefix_reused_tokens"]
            <= dense_eng.stats["prefix_reused_tokens"])
    sparse_eng.cache.check_refs(sparse_eng.prefix.all_pages())


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------

def test_spec_config_validation():
    cfg = _cfg("off")
    params = get_model(cfg).init(RNG)
    with pytest.raises(ValueError, match="speculative"):
        ServeEngine(cfg, params, prefill_chunk=None, speculative=True)
    with pytest.raises(ValueError, match="speculative"):
        EngineConfig(prefill_chunk=None, speculative=True)
    with pytest.raises(ValueError, match="draft_k"):
        EngineConfig(speculative=True, draft_k=0)
    with pytest.raises(ValueError, match="draft_rank_frac"):
        EngineConfig(draft_rank_frac=0.0)
    with pytest.raises(ValueError, match="snapshot_every"):
        EngineConfig(snapshot_every=0)


# ---------------------------------------------------------------------------
# adaptive draft length (EWMA controller)
# ---------------------------------------------------------------------------

def test_adaptive_draft_parity_and_stats():
    """Whatever the EWMA controller does to the draft window, accepts stay
    exact: adaptive-draft streams are token-identical to plain decode,
    and stats expose the effective draft length."""
    cfg = _cfg("adaptive")
    params = get_model(cfg).init(RNG)
    prompts = _prompts(cfg)
    plain, _ = _run(cfg, params, prompts, speculative=False)
    outs, eng = _run(cfg, params, prompts, speculative=True,
                     adaptive_draft=True)
    for i in range(len(prompts)):
        np.testing.assert_array_equal(outs[i], plain[i])
    assert 0 <= eng.stats["eff_draft_k"] <= eng.draft_k


def test_adaptive_draft_collapse_routes_plain_decode():
    """shrink_below > 1 shrinks on every spec step (the EWMA can never
    clear it): eff_k decays 3 -> 1 -> 0 and decode rides the mixed step
    with only probe spec steps left. Parity stays exact — the collapsed
    path is the plain fused step, not an approximation."""
    cfg = _cfg("adaptive")
    params = get_model(cfg).init(RNG)
    prompts = _prompts(cfg)
    plain, _ = _run(cfg, params, prompts, speculative=False, max_new=24)
    outs, eng = _run(cfg, params, prompts, speculative=True, max_new=24,
                     adaptive_draft=True, draft_shrink_below=1.01)
    for i in range(len(prompts)):
        np.testing.assert_array_equal(outs[i], plain[i])
    assert eng.stats["eff_draft_k"] == 0
    # collapsed decode steps are NOT spec dispatches (probes excepted)
    assert eng.stats["spec_steps"] < eng.stats["steps"]


def test_adaptive_draft_recovers_from_collapse():
    """A collapsed window grows back through probe steps: with the grow
    threshold always met, eff_k climbs 0 -> 2 -> 3 on the probe cadence
    and the stream still matches plain decode exactly."""
    cfg = _cfg("adaptive")
    params = get_model(cfg).init(RNG)
    prompts = _prompts(cfg)
    plain, _ = _run(cfg, params, prompts, speculative=False, max_new=24)
    eng = ServeEngine(cfg, params, n_slots=4, max_len=64, page_size=8,
                      segment_len=8, max_new_cap=32, prefill_chunk=8,
                      speculative=True, draft_k=3, draft_rank_frac=0.5,
                      adaptive_draft=True, draft_shrink_below=-1.0,
                      draft_grow_above=-1.0)
    eng._eff_k = 0                      # start collapsed
    eng.stats["eff_draft_k"] = 0
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, tokens=p, max_new=24))
    outs = eng.run()
    for i in range(len(prompts)):
        np.testing.assert_array_equal(outs[i], plain[i])
    assert eng.stats["eff_draft_k"] == eng.draft_k


def test_adaptive_draft_requires_speculative():
    cfg = _cfg("off")
    params = get_model(cfg).init(RNG)
    with pytest.raises(ValueError, match="adaptive_draft"):
        ServeEngine(cfg, params, adaptive_draft=True)
    with pytest.raises(ValueError, match="adaptive_draft"):
        EngineConfig(adaptive_draft=True)


# ---------------------------------------------------------------------------
# drift-trigger clock under speculation
# ---------------------------------------------------------------------------

def test_drift_check_once_per_accepted_run_post_accept():
    """The drift check fires once per fused step (= once per accepted
    run, NOT once per token) and always against the post-accept
    position: at call time the host lens mirror has already advanced
    past every token the verify pass accepted (the cache holds prompt +
    all emitted tokens but the newest, whose KV lands next dispatch)."""
    cfg = _cfg("adaptive")
    params = get_model(cfg).init(RNG)
    prompts = _prompts(cfg)
    eng = ServeEngine(cfg, params, n_slots=4, max_len=64, page_size=8,
                      segment_len=8, max_new_cap=32, prefill_chunk=8,
                      speculative=True, draft_k=3, draft_rank_frac=0.5,
                      drift_threshold=1e9)
    calls = []
    orig = eng._check_drift

    def spy(live):
        for i in live:
            st = eng.sched.slots[i]
            assert (eng.cache.lens[i]
                    == st.req.tokens.size + st.n_out - 1), \
                f"slot {i}: drift check saw a pre-accept position"
        calls.append(list(live))
        return orig(live)

    eng._check_drift = spy
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, tokens=p, max_new=12))
    eng.run()
    # one check per spec dispatch with decoding rows...
    assert len(calls) == eng.stats["spec_steps"]
    # ...which is strictly coarser than per-token (accepts ran > 1)
    assert eng.stats["tokens_decoded"] > len(calls)


def test_drift_clock_never_firing_is_bitwise_inert():
    """A drift threshold no residual can reach must leave streams
    bitwise identical to running with the trigger off — on the plain
    path and under speculation alike (the check reads, never writes)."""
    cfg = _cfg("adaptive")
    params = get_model(cfg).init(RNG)
    prompts = _prompts(cfg)
    base, _ = _run(cfg, params, prompts, speculative=False)
    for speculative in (False, True):
        outs, eng = _run(cfg, params, prompts, speculative=speculative,
                         drift_threshold=1e9)
        assert not any(eng.force_decide)
        for i in range(len(prompts)):
            np.testing.assert_array_equal(outs[i], base[i])


def test_drift_trigger_under_speculation_forces_redecision():
    """drift_threshold=0 re-decides on every accepted run; the decide
    count must exceed the pure segment schedule's, the re-decision lands
    at the next step (streams may legally diverge from plain decode —
    the paper's adaptation clock just got finer), and streams stay
    valid."""
    cfg = _cfg("adaptive")
    params = get_model(cfg).init(RNG)
    prompts = _prompts(cfg)
    _, eng_base = _run(cfg, params, prompts, speculative=True)
    outs, eng = _run(cfg, params, prompts, speculative=True,
                     drift_threshold=0.0)
    assert eng.stats["decides"] > eng_base.stats["decides"]
    for i in range(len(prompts)):
        assert outs[i].shape == (12,)
