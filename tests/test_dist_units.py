"""Fast single-host unit tests for repro.dist — no subprocesses, no forced
device counts: path_str round-tripping, logits_spec per config, and the
divisibility-dropping rules on hostile (prime) dims and trivial meshes."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.dist import sharding as shd
from repro.dist.ctx import activation_spec, logits_spec


class FakeMesh:
    """Duck-typed mesh: sharding rules only consult .shape / .axis_names,
    so unit tests can exercise big meshes without real devices."""

    def __init__(self, **sizes):
        self._sizes = dict(sizes)

    @property
    def shape(self):
        return dict(self._sizes)

    @property
    def axis_names(self):
        return tuple(self._sizes)


def _cfg(**kw):
    return get_config("qwen2.5-14b", reduced=True).with_(**kw)


# ---------------------------------------------------------------- path_str

def test_path_str_round_trips_dict_trees():
    tree = {"layers": {"attn": {"wq": 1, "wo": 2}, "ln": 3},
            "embed": 4}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        node = tree
        for part in shd.path_str(path).split("/"):
            node = node[part]
        assert node == leaf


def test_path_str_handles_list_indices():
    tree = {"dense_layers": [{"w": 1}, {"w": 2}]}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = [shd.path_str(p) for p, _ in flat]
    assert names == ["dense_layers/0/w", "dense_layers/1/w"]
    for path, leaf in flat:
        node = tree
        for part in shd.path_str(path).split("/"):
            node = node[int(part)] if part.isdigit() else node[part]
        assert node == leaf


def test_path_str_is_unique_per_leaf():
    cfg = _cfg()
    from repro.models.api import get_model
    shapes = jax.eval_shape(get_model(cfg).init, jax.random.PRNGKey(0))
    names = [shd.path_str(p)
             for p, _ in jax.tree_util.tree_flatten_with_path(shapes)[0]]
    assert len(names) == len(set(names))


# ------------------------------------------------------------- logits_spec

def test_logits_spec_none_without_mesh_axes():
    assert logits_spec(_cfg(mesh_axes=())) is None


def test_logits_spec_single_pod():
    spec = logits_spec(_cfg(mesh_axes=("data", "model"), sharding="fsdp_tp"))
    assert spec == P("data", None, "model")


def test_logits_spec_multi_pod_batch_axes():
    spec = logits_spec(
        _cfg(mesh_axes=("pod", "data", "model"), sharding="fsdp_tp"))
    assert spec == P(("pod", "data"), None, "model")


def test_logits_spec_dp_keeps_vocab_replicated():
    spec = logits_spec(_cfg(mesh_axes=("data", "model"), sharding="dp"))
    assert spec == P("data", None, None)


def test_activation_spec():
    assert activation_spec(_cfg(mesh_axes=())) is None
    assert activation_spec(
        _cfg(mesh_axes=("data", "model"))) == P("data", None, None)


# ------------------------------------------- divisibility / rule dropping

def test_prime_dims_drop_all_axes():
    mesh = FakeMesh(data=4, model=2)
    fake = {"layers": {"attn": {"wq": jnp.zeros((7, 13))}}}  # primes
    spec = shd.param_pspecs(fake, _cfg(), mesh)
    assert spec["layers"]["attn"]["wq"] == P(None, None)


def test_partial_drop_keeps_dividing_axis():
    mesh = FakeMesh(data=4, model=2)
    fake = {"layers": {"attn": {"wq": jnp.zeros((7, 64))}}}
    spec = shd.param_pspecs(fake, _cfg(), mesh)
    # input dim 7 can't take 'data'; output dim 64 still takes 'model'
    assert spec["layers"]["attn"]["wq"] == P(None, "model")


def test_mesh_size_one_divides_everything():
    mesh = FakeMesh(data=1, model=1)
    fake = {"layers": {"attn": {"wq": jnp.zeros((7, 13))}}}
    spec = shd.param_pspecs(fake, _cfg(), mesh)
    assert spec["layers"]["attn"]["wq"] == P("data", "model")


def test_col_and_row_parallel_rules():
    mesh = FakeMesh(data=4, model=2)
    fake = {"layers": {"attn": {"wq": jnp.zeros((2, 64, 32)),
                                "wo": jnp.zeros((2, 32, 64))},
                       "ffn": {"w_down": jnp.zeros((2, 32, 64))}}}
    spec = shd.param_pspecs(fake, _cfg(), mesh)
    assert spec["layers"]["attn"]["wq"] == P(None, "data", "model")
    assert spec["layers"]["attn"]["wo"] == P(None, "model", "data")
    assert spec["layers"]["ffn"]["w_down"] == P(None, "model", "data")


def test_expert_stack_shards_expert_dim():
    mesh = FakeMesh(data=4, model=2)
    fake = {"layers": {"moe": {"w_gate": jnp.zeros((2, 4, 64, 32)),
                               "router": jnp.zeros((2, 64, 4)),
                               "shared": {"w_gate": jnp.zeros((2, 64, 32))}}}}
    spec = shd.param_pspecs(fake, _cfg(), mesh)
    assert spec["layers"]["moe"]["w_gate"] == P(None, "model", "data", None)
    assert spec["layers"]["moe"]["router"] == P(None, "data", "model")
    # shared expert is a plain column-parallel ffn, not an expert stack
    assert spec["layers"]["moe"]["shared"]["w_gate"] == P(None, "data", "model")


def test_dp_mode_replicates_everything():
    mesh = FakeMesh(data=4, model=2)
    fake = {"embed": jnp.zeros((64, 64)),
            "layers": {"attn": {"wq": jnp.zeros((64, 64))}}}
    spec = shd.param_pspecs(fake, _cfg(sharding="dp"), mesh)
    for leaf in jax.tree_util.tree_leaves(
            spec, is_leaf=lambda x: isinstance(x, P)):
        assert leaf == P(None, None)


def test_norms_replicate():
    mesh = FakeMesh(data=4, model=2)
    fake = {"layers": {"ln1": jnp.zeros((2, 64))}, "ln_f": jnp.zeros((64,))}
    spec = shd.param_pspecs(fake, _cfg(), mesh)
    assert spec["layers"]["ln1"] == P(None, None)
    assert spec["ln_f"] == P(None)


def test_batch_pspecs_shards_leading_dim():
    mesh = FakeMesh(data=4, model=2)
    batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
             "odd": jnp.zeros((7, 32)),            # prime batch: dropped
             "scalar": jnp.zeros(())}
    spec = shd.batch_pspecs(batch, mesh)
    assert spec["tokens"] == P("data", None)
    assert spec["odd"] == P(None, None)
    assert spec["scalar"] == P()


def test_batch_pspecs_multi_pod():
    mesh = FakeMesh(pod=2, data=4, model=2)
    spec = shd.batch_pspecs({"tokens": jnp.zeros((16, 8), jnp.int32)}, mesh)
    assert spec["tokens"] == P(("pod", "data"), None)


def test_cache_pspecs_batch_and_seq():
    mesh = FakeMesh(data=4, model=2)
    cache = {"k": jnp.zeros((2, 8, 64, 2, 16)),
             "len": jnp.zeros((), jnp.int32)}
    cfg = _cfg()
    spec = shd.cache_pspecs(cache, cfg, mesh)
    assert spec["k"] == P(None, "data", None, None, None)
    assert spec["len"] == P()
    spec = shd.cache_pspecs(cache, cfg.with_(cache_seq_shard=True), mesh)
    assert spec["k"] == P(None, "data", "model", None, None)


def test_param_pspecs_cover_model_leaves_host_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cfg = _cfg()
    from repro.models.api import get_model
    shapes = jax.eval_shape(get_model(cfg).init, jax.random.PRNGKey(0))
    specs = shd.param_pspecs(shapes, cfg, mesh)
    flat_shapes = jax.tree_util.tree_flatten_with_path(shapes)[0]
    flat_specs = {shd.path_str(p): s for p, s in
                  jax.tree_util.tree_flatten_with_path(
                      specs, is_leaf=lambda x: isinstance(x, P))[0]}
    for path, leaf in flat_shapes:
        spec = flat_specs[shd.path_str(path)]
        assert len(spec) == len(leaf.shape), shd.path_str(path)
