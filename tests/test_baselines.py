"""Static low-rank baselines (Performer / Nystromformer) sanity: they must
approximate softmax attention on easy inputs and stay finite everywhere."""
import jax
import numpy as np

from repro.core.baselines import (favor_features, nystrom_attention,
                                  orthogonal_proj, performer_attention)
from repro.models.attention import attend

K0 = jax.random.PRNGKey(0)


def _qkv(b=2, s=48, h=2, d=16, scale=0.3):
    ks = jax.random.split(K0, 3)
    q = jax.random.normal(ks[0], (b, s, h, d)) * scale
    k = jax.random.normal(ks[1], (b, s, h, d)) * scale
    v = jax.random.normal(ks[2], (b, s, h, d))
    return q, k, v


def test_performer_approximates_softmax_noncausal():
    q, k, v = _qkv()
    d = q.shape[-1]
    proj = orthogonal_proj(jax.random.PRNGKey(3), q.shape[2], 256, d)
    out = performer_attention(q, k, v, proj=proj, causal=False)
    # exact softmax attention with the kernel's 1/sqrt(d) scaling
    ref = attend(q, k, v, scale=d ** -0.5, causal=False)
    # random features: expect high correlation, not exactness
    c = np.corrcoef(np.asarray(out).ravel(), np.asarray(ref).ravel())[0, 1]
    assert c > 0.9, c


def test_performer_causal_finite_and_causal():
    q, k, v = _qkv()
    d = q.shape[-1]
    proj = orthogonal_proj(jax.random.PRNGKey(3), q.shape[2], 128, d)
    out = performer_attention(q, k, v, proj=proj, causal=True)
    assert np.isfinite(np.asarray(out)).all()
    # causality: output at t must not depend on future v
    v2 = v.at[:, -1].set(v[:, -1] + 100.0)
    out2 = performer_attention(q, k, v2, proj=proj, causal=True)
    np.testing.assert_allclose(np.asarray(out[:, :-1]),
                               np.asarray(out2[:, :-1]), atol=1e-5)


def test_favor_features_positive():
    q, _, _ = _qkv()
    proj = orthogonal_proj(jax.random.PRNGKey(3), q.shape[2], 64, q.shape[-1])
    phi = favor_features(q, proj)
    assert (np.asarray(phi) >= 0).all()


def test_nystrom_approximates_softmax_noncausal():
    q, k, v = _qkv(s=64)
    d = q.shape[-1]
    out = nystrom_attention(q, k, v, n_landmarks=32, causal=False)
    ref = attend(q, k, v, scale=d ** -0.5, causal=False)
    c = np.corrcoef(np.asarray(out).ravel(), np.asarray(ref).ravel())[0, 1]
    assert c > 0.8, c
    assert np.isfinite(np.asarray(out)).all()


def test_nystrom_causal_finite():
    q, k, v = _qkv(s=64)
    out = nystrom_attention(q, k, v, n_landmarks=16, causal=True)
    assert np.isfinite(np.asarray(out)).all()
