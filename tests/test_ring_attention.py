"""Ring attention == exact attention, on a real 8-device ring (subprocess so
the forced device count doesn't leak)."""
import json
import os
import subprocess
import sys

import pytest


SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax, jax.numpy as jnp
import numpy as np
sys.path.insert(0, "__SRC__")
from repro.dist.ring_attention import make_ring_attention
from repro.kernels.ref import flash_ref

mesh = jax.make_mesh((8,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
b, S, h, d = 2, 64, 2, 16
ks = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(ks[0], (b, S, h, d))
k = jax.random.normal(ks[1], (b, S, h, d))
v = jax.random.normal(ks[2], (b, S, h, d))
outs = {}
for causal in (True, False):
    with mesh:
        fn = make_ring_attention(mesh, scale=d ** -0.5, causal=causal)
        out = jax.jit(fn)(q, k, v)
    ref = flash_ref(jnp.transpose(q, (0, 2, 1, 3)),
                    jnp.transpose(k, (0, 2, 1, 3)),
                    jnp.transpose(v, (0, 2, 1, 3)),
                    scale=d ** -0.5, causal=causal)
    ref = jnp.transpose(ref, (0, 2, 1, 3))
    outs[str(causal)] = float(jnp.max(jnp.abs(out - ref)))
print(json.dumps(outs))
"""


@pytest.mark.dist
@pytest.mark.slow
def test_ring_attention_8dev():
    code = _SUBPROC.replace("__SRC__", os.path.abspath(SRC))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    errs = json.loads(out.stdout.strip().splitlines()[-1])
    assert errs["True"] < 1e-4, errs
    assert errs["False"] < 1e-4, errs
