"""Teacher-forced forward logits must match step-by-step decode for every
family (KV caches, absorbed MLA, hybrid/rwkv states, enc-dec cross cache)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.api import get_model

RNG = jax.random.PRNGKey(0)
ARCHS = ["qwen2.5-14b", "deepseek-v3-671b", "zamba2-7b", "rwkv6-1.6b",
         "seamless-m4t-medium", "granite-moe-3b-a800m"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch, reduced=True)
    fns = get_model(cfg)
    params = fns.init(RNG)
    b, s = 2, 12
    tokens = jax.random.randint(RNG, (b, s), 0, cfg.vocab_size)

    if arch == "seamless-m4t-medium":
        from repro.models import encdec as ed
        frames = jax.random.normal(RNG, (b, cfg.frontend_positions,
                                         cfg.d_model))
        logits_fwd, _ = ed.forward_encdec(cfg, params, frames, tokens)
        memory = ed.encode(cfg, params, frames)
        cache = fns.init_cache(b, 16)
        cache = ed.prefill_cross(cfg, params, memory, cache)
    else:
        if cfg.family == "hybrid":
            from repro.models import zamba2 as zb
            logits_fwd, _ = zb.forward_zamba(cfg, params, tokens)
        elif cfg.family == "rwkv":
            from repro.models import rwkv_lm as rk
            logits_fwd, _ = rk.forward_rwkv(cfg, params, tokens)
        elif cfg.mla is not None:
            from repro.models import deepseek_v3 as ds
            logits_fwd, _ = ds.forward_deepseek(cfg, params, tokens)
        else:
            from repro.models import transformer as tr
            logits_fwd, _ = tr.forward_dense(cfg, params, tokens)
        cache = fns.init_cache(b, 16)

    outs = []
    for t in range(s):
        lg, cache = fns.decode_step(params, cache, tokens[:, t:t + 1])
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_fwd), atol=2e-4, rtol=2e-3)


def test_prefill_then_decode_matches_pure_decode():
    """Multi-token prefill through the decode path == token-by-token."""
    cfg = get_config("qwen2.5-14b", reduced=True)
    fns = get_model(cfg)
    params = fns.init(RNG)
    b, s = 2, 8
    tokens = jax.random.randint(RNG, (b, s), 0, cfg.vocab_size)
    cache1 = fns.init_cache(b, 16)
    lg1, cache1 = fns.decode_step(params, cache1, tokens)       # prefill
    cache2 = fns.init_cache(b, 16)
    for t in range(s):
        lg2, cache2 = fns.decode_step(params, cache2, tokens[:, t:t + 1])
    np.testing.assert_allclose(np.asarray(lg1[:, -1]), np.asarray(lg2[:, 0]),
                               atol=2e-4, rtol=2e-3)
